//! End-to-end chaos: a seeded [`FaultPlan`] injects execution errors,
//! kernel panics, pre-batch latency, shard-worker kills, torn `.pasm`
//! loads, and socket resets into the full serving stack — against
//! **both** front-ends — and the fault-tolerance invariants must hold:
//!
//! * every admitted request reaches a terminal reply (success, typed
//!   error, overload, or deadline miss — never silence);
//! * the server stays up and keeps answering after the storm;
//! * a killed shard worker is respawned and its shard keeps serving;
//! * a thief shard killed mid-steal fails its in-flight batch with a
//!   typed `UNAVAILABLE`, the home queue keeps draining, and the
//!   restart counter moves;
//! * a torn artifact swap keeps the previous version serving;
//! * a plan with zero probabilities injects exactly nothing.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::loadgen::{NetLoadOptions, run_open_loop_net};
use pasm_accel::coordinator::{BatchPolicy, Coordinator, CoordinatorBuilder};
use pasm_accel::faults::{FaultPlan, FaultSite};
use pasm_accel::model_store::{ModelRegistry, save_file};
use pasm_accel::quant::fixed::QFormat;
#[cfg(unix)]
use pasm_accel::serving::{EventedConfig, EventedServer};
use pasm_accel::obs::Stage;
use pasm_accel::serving::{Client, ErrorCode, MetricsFrame, RetryPolicy, Server, ServerConfig};
use pasm_accel::tensor::Tensor;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn encoded(seed: u64, bins: usize) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, bins, QFormat::W32)
}

fn image_pool() -> Vec<Tensor<f32>> {
    let mut rng = Rng::new(9);
    (0..8).map(|i| render_digit(&mut rng, i % 10, 0.05)).collect()
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pasm_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A 2-shard registry coordinator with the given fault plan attached
/// (`build` also wires the plan into the registry's artifact loads).
fn chaos_coordinator(registry: &Arc<ModelRegistry>, plan: FaultPlan) -> Arc<Coordinator> {
    Arc::new(
        CoordinatorBuilder::new()
            .registry(Arc::clone(registry))
            .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
            .shards(2)
            .fault_plan(plan)
            .build()
            .expect("coordinator startup"),
    )
}

/// The front-end kinds available on this platform; every scenario runs
/// against each of them.
fn kinds() -> Vec<&'static str> {
    if cfg!(unix) {
        vec!["threaded", "evented"]
    } else {
        vec!["threaded"]
    }
}

/// One of the two interchangeable serving front-ends under test.
enum TestServer {
    Threaded(Server),
    #[cfg(unix)]
    Evented(EventedServer),
}

impl TestServer {
    fn bind(kind: &str, coord: &Arc<Coordinator>) -> TestServer {
        match kind {
            "threaded" => {
                let config = ServerConfig::default();
                let server =
                    Server::bind("127.0.0.1:0", Arc::clone(coord), config).expect("bind threaded");
                TestServer::Threaded(server)
            }
            #[cfg(unix)]
            "evented" => {
                let config = EventedConfig::default();
                let server = EventedServer::bind("127.0.0.1:0", Arc::clone(coord), config)
                    .expect("bind evented");
                TestServer::Evented(server)
            }
            other => panic!("unknown server kind '{other}'"),
        }
    }

    fn local_addr(&self) -> SocketAddr {
        match self {
            TestServer::Threaded(s) => s.local_addr(),
            #[cfg(unix)]
            TestServer::Evented(s) => s.local_addr(),
        }
    }

    fn shutdown(&mut self) {
        match self {
            TestServer::Threaded(s) => s.shutdown(),
            #[cfg(unix)]
            TestServer::Evented(s) => s.shutdown(),
        }
    }
}

/// Post-storm liveness probe.  The plan may reset this very connection
/// instead of answering, so the probe gets a few fresh connections.
fn probe_metrics(addr: SocketAddr) -> MetricsFrame {
    let mut last = String::from("never connected");
    for _ in 0..10 {
        match Client::connect(addr) {
            Ok(mut c) => match c.metrics() {
                Ok(m) => return m,
                Err(e) => last = e.to_string(),
            },
            Err(e) => last = e.to_string(),
        }
    }
    panic!("server not answering after the storm: {last}");
}

#[test]
fn chaos_storm_every_admitted_request_reaches_a_terminal_reply() {
    for kind in kinds() {
        let registry = Arc::new(ModelRegistry::new());
        registry.insert("alpha", encoded(1, 4));
        registry.insert("beta", encoded(2, 8));
        let plan = FaultPlan::seeded(7)
            .with(FaultSite::ExecError, 0.15)
            .with(FaultSite::BatchPanic, 0.15)
            .with(FaultSite::Latency, 0.2)
            .with(FaultSite::SocketReset, 0.05)
            .with_latency(Duration::from_millis(2));
        let coord = chaos_coordinator(&registry, plan);
        let mut server = TestServer::bind(kind, &coord);
        let addr = server.local_addr();

        let n = 96;
        let models = [Some("alpha".to_string()), Some("beta".to_string())];
        let opts = NetLoadOptions {
            connections: 4,
            retry: RetryPolicy::standard(5, 23),
            ..NetLoadOptions::default()
        };
        let mut rng = Rng::new(5);
        let r =
            run_open_loop_net(&addr.to_string(), &models, &image_pool(), n, 800.0, opts, &mut rng)
                .expect("chaos load run");

        // the core invariant: success, typed failure, overload, or miss
        // — but never an admitted request that simply vanishes
        let answered = r.latencies_us.len() + r.errors + r.overloaded + r.deadline_misses;
        assert_eq!(answered, n, "{kind}: request(s) without a terminal reply: {r:?}");
        assert!(!r.latencies_us.is_empty(), "{kind}: nothing succeeded under the storm: {r:?}");

        let injected = coord.fault_plan().expect("plan attached").counters();
        assert!(injected.total() > 0, "{kind}: the storm injected nothing: {injected:?}");

        // the server must still answer a fresh connection after the storm
        let m = probe_metrics(addr);
        assert!(m.requests >= r.latencies_us.len() as u64, "{kind}: metrics lost requests");
        server.shutdown();
    }
}

#[test]
fn killed_shard_workers_respawn_and_the_shard_keeps_serving() {
    for kind in kinds() {
        let registry = Arc::new(ModelRegistry::new());
        registry.insert("alpha", encoded(1, 4));
        let plan = FaultPlan::seeded(11).with(FaultSite::WorkerKill, 0.4);
        let coord = chaos_coordinator(&registry, plan);
        let mut server = TestServer::bind(kind, &coord);
        let addr = server.local_addr();

        let image = render_digit(&mut Rng::new(3), 4, 0.05);
        let mut client = Client::connect(addr)
            .expect("connect")
            .with_retry(RetryPolicy::standard(8, 31));
        let deadline = Instant::now() + Duration::from_secs(30);
        while coord.shard_restarts() == 0 {
            assert!(Instant::now() < deadline, "{kind}: no respawn observed within 30s");
            if client.infer(Some("alpha"), &image).is_err() {
                let _ = client.reset();
            }
        }

        // the supervisor replaced the dead worker: traffic still flows
        // (each batch still rolls the kill dice, hence the filter)
        let served = (0..20).filter(|_| client.infer(Some("alpha"), &image).is_ok()).count();
        assert!(served > 0, "{kind}: shard never recovered after a worker kill");
        assert!(coord.shard_restarts() > 0, "{kind}: restart counter must move");
        assert!(
            coord.fault_plan().expect("plan attached").counters().worker_kills > 0,
            "{kind}: kill counter must move"
        );
        server.shutdown();
    }
}

#[test]
fn a_thief_killed_mid_steal_fails_typed_and_the_home_keeps_draining() {
    for kind in kinds() {
        // one model at four shards: every request routes to alpha's home,
        // so the other three shards never launch a local batch.  Their
        // only kill site is the stolen-batch pop — a worker-kill fault
        // event on a non-home shard is therefore *proof* of a thief
        // dying mid-steal, not a home death.
        let registry = Arc::new(ModelRegistry::new());
        registry.insert("alpha", encoded(1, 4));
        let plan = FaultPlan::seeded(13).with(FaultSite::WorkerKill, 0.25);
        let coord = Arc::new(
            CoordinatorBuilder::new()
                .registry(Arc::clone(&registry))
                .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
                .shards(4)
                .steal(true)
                .steal_promote_us(0)
                .fault_plan(plan)
                .build()
                .expect("coordinator startup"),
        );
        let mut server = TestServer::bind(kind, &coord);
        let addr = server.local_addr();
        let home = coord.shard_for(Some("alpha"));

        // four concurrent no-retry clients keep the home queue deep
        // enough that formed batches sit on the deck long enough to be
        // stolen; each records whether it saw a typed UNAVAILABLE
        let stop = Arc::new(AtomicBool::new(false));
        let unavailable = Arc::new(AtomicBool::new(false));
        let stormers: Vec<_> = (0..4u64)
            .map(|w| {
                let stop = Arc::clone(&stop);
                let unavailable = Arc::clone(&unavailable);
                std::thread::spawn(move || {
                    let image = render_digit(&mut Rng::new(40 + w), w as usize % 10, 0.05);
                    let Ok(mut client) = Client::connect(addr) else { return };
                    while !stop.load(Ordering::Relaxed) {
                        if let Err(e) = client.infer(Some("alpha"), &image) {
                            if e.server_code() == Some(ErrorCode::Unavailable) {
                                unavailable.store(true, Ordering::Relaxed);
                            }
                            let _ = client.reset();
                        }
                    }
                })
            })
            .collect();

        let tracer = Arc::clone(coord.tracer().expect("tracing is on by default"));
        let deadline = Instant::now() + Duration::from_secs(30);
        let thief_killed = loop {
            let seen = tracer
                .snapshot()
                .iter()
                .any(|e| e.stage == Stage::Fault && e.aux == 1 && e.shard != home);
            if seen {
                break true;
            }
            if Instant::now() >= deadline {
                break false;
            }
            std::thread::sleep(Duration::from_millis(20));
        };
        stop.store(true, Ordering::Relaxed);
        for s in stormers {
            let _ = s.join();
        }
        assert!(thief_killed, "{kind}: no thief died mid-steal within 30s");
        assert!(
            unavailable.load(Ordering::Relaxed),
            "{kind}: in-flight requests on a killed thief must fail typed UNAVAILABLE"
        );
        let m = coord.metrics();
        assert!(m.stolen_batches >= 1, "{kind}: the storm never stole a batch");
        assert!(coord.shard_restarts() >= 1, "{kind}: a killed thief must be respawned");

        // the home queue keeps draining: a retrying client still gets
        // answers through the (still ongoing) kill storm
        let image = render_digit(&mut Rng::new(3), 4, 0.05);
        let mut client =
            Client::connect(addr).expect("connect").with_retry(RetryPolicy::standard(8, 31));
        let served = (0..20).filter(|_| client.infer(Some("alpha"), &image).is_ok()).count();
        assert!(served > 0, "{kind}: the home queue stopped draining after a thief death");
        server.shutdown();
    }
}

#[test]
fn torn_artifact_swap_keeps_the_previous_version_serving() {
    for kind in kinds() {
        let dir = tmpdir(&format!("swap_{kind}"));
        save_file(&dir.join("m.pasm"), &encoded(10, 8)).expect("save artifact");
        let registry = Arc::new(ModelRegistry::new());
        registry.sync_dir(&dir).expect("initial sync");

        let plan = FaultPlan::seeded(5).with(FaultSite::TornLoad, 1.0);
        let coord = chaos_coordinator(&registry, plan);
        let mut server = TestServer::bind(kind, &coord);
        let addr = server.local_addr();

        let image = render_digit(&mut Rng::new(3), 7, 0.05);
        let mut client = Client::connect(addr).expect("connect");
        let before = client.infer(Some("m"), &image).expect("infer before swap");

        // the rewritten artifact is perfectly valid on disk; only the
        // injected tear fails its load — mid-run, with the server up
        save_file(&dir.join("m.pasm"), &encoded(11, 16)).expect("rewrite artifact");
        let report = registry.sync_dir(&dir).expect("resync walks the dir");
        assert_eq!(report.errors.len(), 1, "{kind}: the torn load must surface: {report:?}");
        assert!(report.errors[0].1.contains("injected fault"), "{kind}: {report:?}");

        let after = client.infer(Some("m"), &image).expect("infer after torn swap");
        assert_eq!(before.logits, after.logits, "{kind}: previous version must keep serving");
        assert!(coord.fault_plan().expect("plan attached").counters().torn_loads > 0);
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn disabled_plan_is_inert_and_counts_zero_injected_faults() {
    for kind in kinds() {
        let registry = Arc::new(ModelRegistry::new());
        registry.insert("alpha", encoded(1, 4));
        registry.insert("beta", encoded(2, 8));
        // same seed as the storm, all probabilities left at zero: the
        // exact same code paths must inject nothing at all
        let coord = chaos_coordinator(&registry, FaultPlan::seeded(7));
        let mut server = TestServer::bind(kind, &coord);
        let addr = server.local_addr();

        let n = 48;
        let models = [Some("alpha".to_string()), Some("beta".to_string())];
        let opts = NetLoadOptions { connections: 4, ..NetLoadOptions::default() };
        let mut rng = Rng::new(5);
        let r =
            run_open_loop_net(&addr.to_string(), &models, &image_pool(), n, 800.0, opts, &mut rng)
                .expect("clean load run");

        assert_eq!(r.latencies_us.len(), n, "{kind}: clean run must fully succeed: {r:?}");
        assert_eq!(r.errors + r.overloaded + r.deadline_misses, 0, "{kind}: {r:?}");
        assert_eq!(r.retries, 0, "{kind}: nothing to retry on a clean run");
        let injected = coord.fault_plan().expect("plan attached").counters();
        assert_eq!(injected.total(), 0, "{kind}: inert plan injected faults: {injected:?}");
        assert_eq!(coord.shard_restarts(), 0, "{kind}: no worker may die on a clean run");
        server.shutdown();
    }
}
