//! End-to-end over real sockets: a `serving::net::Server` on an
//! ephemeral port, driven concurrently through `serving::client` —
//! multiple model ids at once, a hot-swap mid-run, a deterministic
//! forced-overload rejection, and a clean shutdown that loses no
//! admitted request.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{BatchPolicy, Coordinator, CoordinatorBuilder, NativeBackend};
use pasm_accel::model_store::ModelRegistry;
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::serving::{Client, ClientError, ErrorCode, Server, ServerConfig};
use pasm_accel::tensor::Tensor;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn encoded(seed: u64, bins: usize) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, bins, QFormat::W32)
}

fn registry_coordinator(registry: &Arc<ModelRegistry>) -> Arc<Coordinator> {
    Arc::new(
        CoordinatorBuilder::new()
            .registry(Arc::clone(registry))
            .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
            .build()
            .expect("coordinator startup"),
    )
}

#[test]
fn serves_two_models_concurrently_with_midrun_hot_swap() {
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("alpha", encoded(1, 4));
    registry.insert("beta", encoded(2, 8));
    let coord = registry_coordinator(&registry);
    let server =
        Server::bind("127.0.0.1:0", Arc::clone(&coord), ServerConfig::default()).expect("bind");
    let addr = server.local_addr();

    // a fixed probe image: its logits must change when alpha is swapped
    let probe = render_digit(&mut Rng::new(77), 3, 0.05);
    let mut probe_client = Client::connect(addr).expect("connect probe");
    let before = probe_client.infer(Some("alpha"), &probe).expect("probe before swap");

    let n_per_model = 40usize;
    let swap_at = 20usize;
    std::thread::scope(|scope| {
        let registry = &registry;
        for (model, seed) in [("alpha", 100u64), ("beta", 200u64)] {
            scope.spawn(move || {
                let mut client = Client::connect(addr).expect("connect worker");
                let mut rng = Rng::new(seed);
                for i in 0..n_per_model {
                    if model == "alpha" && i == swap_at {
                        // hot-swap alpha to a different encoding mid-run;
                        // in-flight requests finish on the old snapshot,
                        // the next batch serves the new one
                        registry.insert("alpha", encoded(9, 16));
                    }
                    let img = render_digit(&mut rng, i % 10, 0.05);
                    let reply = client
                        .infer(Some(model), &img)
                        .unwrap_or_else(|e| panic!("{model} request {i}: {e}"));
                    assert_eq!(reply.model.as_deref(), Some(model), "request {i}");
                    assert_eq!(reply.logits.len(), 10, "request {i}");
                    assert!(reply.hw.cycles > 0, "request {i}");
                }
            });
        }
    });

    let after = probe_client.infer(Some("alpha"), &probe).expect("probe after swap");
    assert_eq!(after.model.as_deref(), Some("alpha"));
    assert_ne!(
        before.logits, after.logits,
        "hot-swapped model must serve different weights for the same image"
    );

    // model listing reflects the registry
    let models = probe_client.list_models().expect("list_models");
    assert_eq!(models.models, vec!["alpha".to_string(), "beta".to_string()]);
    assert_eq!(models.default.as_deref(), Some("alpha"));

    // ping is alive, and metrics account for every request we sent
    probe_client.ping().expect("ping");
    let m = probe_client.metrics().expect("metrics");
    assert_eq!(m.backend, "native");
    let alpha = m.per_model.get("alpha").copied().unwrap_or_default();
    let beta = m.per_model.get("beta").copied().unwrap_or_default();
    assert_eq!(alpha.requests, n_per_model as u64 + 2, "alpha = worker + 2 probes");
    assert_eq!(beta.requests, n_per_model as u64);
    assert_eq!(m.failed_batches, 0);
    assert!(m.net.frames_received >= m.net.frames_sent);
    assert_eq!(m.net.requests_failed, 0);
    assert_eq!(m.net.protocol_errors, 0);

    // unknown model is a typed, routable error — not a hang or a close
    let err = probe_client.infer(Some("nope"), &probe).expect_err("unknown model");
    assert_eq!(err.server_code(), Some(ErrorCode::UnknownModel));
    probe_client.ping().expect("connection survives a typed error");

    drop(server);
    // after shutdown the port no longer answers
    assert!(Client::connect(addr).is_err() || {
        let mut c = Client::connect(addr).unwrap();
        c.ping().is_err()
    });
}

/// Deterministic overload: one in-flight slot, a batch policy that parks
/// the first request (bucket of 4, 400 ms wait budget), so a second
/// request must hit the cap while the first is still admitted.
#[test]
fn overload_is_a_typed_retryable_error_and_no_request_is_lost() {
    let coord = Arc::new(
        CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(3, 8)))
            .batch_policy(BatchPolicy::new(vec![4], Duration::from_millis(400)))
            .build()
            .expect("coordinator startup"),
    );
    let config = ServerConfig { max_inflight: 1, ..ServerConfig::default() };
    let mut server = Server::bind("127.0.0.1:0", Arc::clone(&coord), config).expect("bind");
    let addr = server.local_addr();
    let img = render_digit(&mut Rng::new(5), 4, 0.05);

    // phase 1: occupy the only slot with a parked request, then overload
    let slow = {
        let img = img.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect(addr).expect("connect slow");
            client.infer(None, &img)
        })
    };
    let mut client = Client::connect(addr).expect("connect main");
    // wait (via the metrics frame, which needs no admission slot) until
    // the slow request is admitted — this makes the overload deterministic
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let m = client.metrics().expect("metrics");
        if m.net.inflight == 1 {
            break;
        }
        assert!(Instant::now() < deadline, "slow request never admitted");
        std::thread::sleep(Duration::from_millis(5));
    }
    let err = client.infer(None, &img).expect_err("must be rejected at the cap");
    match &err {
        ClientError::Server(e) => {
            assert_eq!(e.code, ErrorCode::ResourceExhausted);
            assert!(e.code.retryable());
            assert_eq!(e.id, Some(1), "error frame echoes the request id");
        }
        other => panic!("expected a typed server rejection, got {other}"),
    }
    // the parked request completes untouched (wait-budget expiry launches it)
    let slow_reply = slow.join().expect("slow thread").expect("parked request must succeed");
    assert_eq!(slow_reply.logits.len(), 10);

    // the slot is free again: the same connection retries successfully
    let deadline = Instant::now() + Duration::from_secs(10);
    let retried = loop {
        match client.infer(None, &img) {
            Ok(ok) => break ok,
            Err(ClientError::Server(e)) if e.code == ErrorCode::ResourceExhausted => {
                assert!(Instant::now() < deadline, "slot never freed");
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(other) => panic!("retry failed: {other}"),
        }
    };
    assert_eq!(retried.logits, slow_reply.logits, "same image, same model, same logits");
    let m = client.metrics().expect("metrics");
    assert!(m.net.overload_rejections >= 1);

    // phase 2: clean shutdown loses no admitted request — park another
    // request, shut down while it is in flight, and require its response
    let parked = {
        let img = img.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect(addr).expect("connect parked");
            client.infer(None, &img)
        })
    };
    std::thread::sleep(Duration::from_millis(100));
    server.shutdown(); // blocks until every connection thread finished
    let reply = parked.join().expect("parked thread").expect("request lost in shutdown");
    assert_eq!(reply.logits, slow_reply.logits);
}

#[test]
fn connection_cap_rejects_with_a_typed_frame() {
    let coord = Arc::new(
        CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(4, 4)))
            .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
            .build()
            .expect("coordinator startup"),
    );
    let config = ServerConfig { max_connections: 1, ..ServerConfig::default() };
    let server = Server::bind("127.0.0.1:0", Arc::clone(&coord), config).expect("bind");
    let addr = server.local_addr();

    let mut first = Client::connect(addr).expect("connect first");
    first.ping().expect("first connection serves");

    let mut second = Client::connect(addr).expect("tcp connect still succeeds");
    let err = second.ping().expect_err("over-cap connection must be refused");
    match err {
        ClientError::Server(e) => assert_eq!(e.code, ErrorCode::ResourceExhausted),
        // the error frame races the close; a hard close is also acceptable
        ClientError::Io(_) | ClientError::Closed => {}
        other => panic!("unexpected rejection shape: {other}"),
    }

    // the first connection is unaffected
    first.ping().expect("capped server keeps serving admitted connections");

    // once the first connection closes, a new one is admitted
    drop(first);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut c = Client::connect(addr).expect("connect");
        if c.ping().is_ok() {
            break;
        }
        assert!(Instant::now() < deadline, "slot never freed after disconnect");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The sharded pool behind the TCP front-end: the wire protocol is
/// unchanged, but the `metrics` frame reports per-shard counters; two
/// models on distinct shards light up two entries, and a mid-run
/// hot-swap lands on the owning shard only.
#[test]
fn sharded_server_reports_per_shard_metrics_and_hot_swaps() {
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("gamma", encoded(31, 4));
    registry.insert("delta", encoded(32, 8));
    let coord = Arc::new(
        CoordinatorBuilder::new()
            .registry(Arc::clone(&registry))
            .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
            .shards(4)
            .build()
            .expect("coordinator startup"),
    );
    // the stable router puts these two models on different shards
    assert_ne!(coord.shard_for(Some("gamma")), coord.shard_for(Some("delta")));
    let server =
        Server::bind("127.0.0.1:0", Arc::clone(&coord), ServerConfig::default()).expect("bind");
    let addr = server.local_addr();

    // drive both models concurrently over real sockets
    std::thread::scope(|scope| {
        for (model, seed) in [("gamma", 300u64), ("delta", 400u64)] {
            scope.spawn(move || {
                let mut client = Client::connect(addr).expect("connect worker");
                let mut rng = Rng::new(seed);
                for i in 0..24usize {
                    let img = render_digit(&mut rng, i % 10, 0.05);
                    let reply = client
                        .infer(Some(model), &img)
                        .unwrap_or_else(|e| panic!("{model} request {i}: {e}"));
                    assert_eq!(reply.model.as_deref(), Some(model), "request {i}");
                    assert_eq!(reply.logits.len(), 10, "request {i}");
                }
            });
        }
    });

    // the metrics frame reports the pool: four shard entries whose
    // counters sum to the merged totals, with (at least) the two owning
    // shards active
    let mut client = Client::connect(addr).expect("connect");
    let m = client.metrics().expect("metrics");
    assert_eq!(m.shards.len(), 4, "one counters entry per shard");
    assert_eq!(m.requests, 48);
    let sum: u64 = m.shards.iter().map(|s| s.requests).sum();
    assert_eq!(sum, m.requests, "per-shard counters must sum to the merged total");
    let active = m.shards.iter().filter(|s| s.batches > 0).count();
    assert!(active >= 2, "two models on distinct shards must light up two shards");
    assert_eq!(m.failed_batches, 0);

    // wire-level mid-run hot swap: the owning shard serves the new
    // weights on its next batch; the other shard is untouched
    let probe = render_digit(&mut Rng::new(88), 6, 0.05);
    let before_g = client.infer(Some("gamma"), &probe).expect("probe gamma");
    let before_d = client.infer(Some("delta"), &probe).expect("probe delta");
    registry.insert("gamma", encoded(33, 16));
    let after_g = client.infer(Some("gamma"), &probe).expect("probe gamma post-swap");
    let after_d = client.infer(Some("delta"), &probe).expect("probe delta post-swap");
    assert_ne!(
        before_g.logits, after_g.logits,
        "hot-swapped model must serve different weights"
    );
    assert_eq!(
        before_d.logits, after_d.logits,
        "un-swapped model must be unaffected by a swap on another shard"
    );
}

#[test]
fn bad_frames_get_typed_errors_without_dropping_the_connection() {
    let coord = Arc::new(
        CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(6, 4)))
            .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
            .build()
            .expect("coordinator startup"),
    );
    let server =
        Server::bind("127.0.0.1:0", Arc::clone(&coord), ServerConfig::default()).expect("bind");
    let mut client = Client::connect(server.local_addr()).expect("connect");

    // wrong image volume
    let bad = Tensor::<f32>::zeros(&[2, 3, 3]);
    let err = client.infer(None, &bad).expect_err("wrong dims");
    assert_eq!(err.server_code(), Some(ErrorCode::BadImage));

    // non-finite data
    let mut inf = Tensor::<f32>::zeros(&[1, 12, 12]);
    inf.data_mut()[0] = f32::INFINITY;
    let err = client.infer(None, &inf).expect_err("non-finite");
    assert_eq!(err.server_code(), Some(ErrorCode::BadImage));

    // naming a model on a registry-less server
    let good = render_digit(&mut Rng::new(8), 1, 0.05);
    let err = client.infer(Some("ghost"), &good).expect_err("no registry");
    assert_eq!(err.server_code(), Some(ErrorCode::UnknownModel));

    // and the connection still serves real work after all of that
    let ok = client.infer(None, &good).expect("recovery");
    assert_eq!(ok.logits.len(), 10);
    let m = client.metrics().expect("metrics");
    assert_eq!(m.net.requests_ok, 1);
    assert_eq!(m.net.connections_open, 1);
}
