//! End-to-end over real sockets, against **both** serving front-ends:
//! the threaded `serving::net::Server` and the evented
//! `serving::evented::EventedServer` on ephemeral ports, driven
//! concurrently through `serving::client` — multiple model ids at once,
//! a hot-swap mid-run, a deterministic forced-overload rejection, and a
//! clean shutdown that loses no admitted request.  Every shared-protocol
//! scenario runs against each front-end; the evented server additionally
//! gets C100K-shaped coverage (a thousand multiplexed connections,
//! out-of-order pipelined replies, byte-level backpressure, slow-loris
//! and idle reaping).

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{BatchPolicy, Coordinator, CoordinatorBuilder, NativeBackend};
use pasm_accel::model_store::ModelRegistry;
use pasm_accel::quant::fixed::QFormat;
#[cfg(unix)]
use pasm_accel::serving::evented;
#[cfg(unix)]
use pasm_accel::serving::proto::{self, Frame, InferFrame, ReadOutcome};
#[cfg(unix)]
use pasm_accel::serving::{EventedConfig, EventedServer, PipelinedClient};
use pasm_accel::serving::{Client, ClientError, ErrorCode, Server, ServerConfig};
use pasm_accel::tensor::Tensor;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn encoded(seed: u64, bins: usize) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, bins, QFormat::W32)
}

fn registry_coordinator(registry: &Arc<ModelRegistry>) -> Arc<Coordinator> {
    Arc::new(
        CoordinatorBuilder::new()
            .registry(Arc::clone(registry))
            .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
            .build()
            .expect("coordinator startup"),
    )
}

/// Config overrides applied uniformly to whichever front-end a scenario
/// is bound against; `None` keeps that server's default.
#[derive(Clone, Default)]
struct Tune {
    max_connections: Option<usize>,
    max_inflight: Option<usize>,
    idle_timeout: Option<Duration>,
    frame_timeout: Option<Duration>,
}

/// One of the two interchangeable serving front-ends under test.
enum TestServer {
    Threaded(Server),
    #[cfg(unix)]
    Evented(EventedServer),
}

impl TestServer {
    /// The front-end kinds available on this platform.  Every shared
    /// scenario loops over all of them.
    fn kinds() -> Vec<&'static str> {
        if cfg!(unix) {
            vec!["threaded", "evented"]
        } else {
            vec!["threaded"]
        }
    }

    fn bind(kind: &str, coord: &Arc<Coordinator>, tune: &Tune) -> TestServer {
        match kind {
            "threaded" => {
                let mut config = ServerConfig::default();
                if let Some(v) = tune.max_connections {
                    config.max_connections = v;
                }
                if let Some(v) = tune.max_inflight {
                    config.max_inflight = v;
                }
                if let Some(v) = tune.idle_timeout {
                    config.idle_timeout = v;
                }
                if let Some(v) = tune.frame_timeout {
                    config.frame_timeout = v;
                }
                let server =
                    Server::bind("127.0.0.1:0", Arc::clone(coord), config).expect("bind threaded");
                TestServer::Threaded(server)
            }
            #[cfg(unix)]
            "evented" => {
                let mut config = EventedConfig::default();
                if let Some(v) = tune.max_connections {
                    config.max_connections = v;
                }
                if let Some(v) = tune.max_inflight {
                    config.max_inflight = v;
                }
                if let Some(v) = tune.idle_timeout {
                    config.idle_timeout = v;
                }
                if let Some(v) = tune.frame_timeout {
                    config.frame_timeout = v;
                }
                let server = EventedServer::bind("127.0.0.1:0", Arc::clone(coord), config)
                    .expect("bind evented");
                TestServer::Evented(server)
            }
            other => panic!("unknown server kind '{other}'"),
        }
    }

    fn local_addr(&self) -> SocketAddr {
        match self {
            TestServer::Threaded(s) => s.local_addr(),
            #[cfg(unix)]
            TestServer::Evented(s) => s.local_addr(),
        }
    }

    fn shutdown(&mut self) {
        match self {
            TestServer::Threaded(s) => s.shutdown(),
            #[cfg(unix)]
            TestServer::Evented(s) => s.shutdown(),
        }
    }
}

#[test]
fn serves_two_models_concurrently_with_midrun_hot_swap() {
    for kind in TestServer::kinds() {
        let registry = Arc::new(ModelRegistry::new());
        registry.insert("alpha", encoded(1, 4));
        registry.insert("beta", encoded(2, 8));
        let coord = registry_coordinator(&registry);
        let server = TestServer::bind(kind, &coord, &Tune::default());
        let addr = server.local_addr();

        // a fixed probe image: its logits must change when alpha is swapped
        let probe = render_digit(&mut Rng::new(77), 3, 0.05);
        let mut probe_client = Client::connect(addr).expect("connect probe");
        let before = probe_client.infer(Some("alpha"), &probe).expect("probe before swap");

        let n_per_model = 40usize;
        let swap_at = 20usize;
        std::thread::scope(|scope| {
            let registry = &registry;
            for (model, seed) in [("alpha", 100u64), ("beta", 200u64)] {
                scope.spawn(move || {
                    let mut client = Client::connect(addr).expect("connect worker");
                    let mut rng = Rng::new(seed);
                    for i in 0..n_per_model {
                        if model == "alpha" && i == swap_at {
                            // hot-swap alpha to a different encoding mid-run;
                            // in-flight requests finish on the old snapshot,
                            // the next batch serves the new one
                            registry.insert("alpha", encoded(9, 16));
                        }
                        let img = render_digit(&mut rng, i % 10, 0.05);
                        let reply = client
                            .infer(Some(model), &img)
                            .unwrap_or_else(|e| panic!("{kind}: {model} request {i}: {e}"));
                        assert_eq!(reply.model.as_deref(), Some(model), "{kind} request {i}");
                        assert_eq!(reply.logits.len(), 10, "{kind} request {i}");
                        assert!(reply.hw.cycles > 0, "{kind} request {i}");
                    }
                });
            }
        });

        let after = probe_client.infer(Some("alpha"), &probe).expect("probe after swap");
        assert_eq!(after.model.as_deref(), Some("alpha"));
        assert_ne!(
            before.logits, after.logits,
            "{kind}: hot-swapped model must serve different weights for the same image"
        );

        // model listing reflects the registry
        let models = probe_client.list_models().expect("list_models");
        assert_eq!(models.models, vec!["alpha".to_string(), "beta".to_string()]);
        assert_eq!(models.default.as_deref(), Some("alpha"));

        // ping is alive, and metrics account for every request we sent
        probe_client.ping().expect("ping");
        let m = probe_client.metrics().expect("metrics");
        assert_eq!(m.backend, "native");
        let alpha = m.per_model.get("alpha").copied().unwrap_or_default();
        let beta = m.per_model.get("beta").copied().unwrap_or_default();
        assert_eq!(alpha.requests, n_per_model as u64 + 2, "{kind}: alpha = worker + 2 probes");
        assert_eq!(beta.requests, n_per_model as u64, "{kind}");
        assert_eq!(m.failed_batches, 0, "{kind}");
        assert!(m.net.frames_received >= m.net.frames_sent, "{kind}");
        assert_eq!(m.net.requests_failed, 0, "{kind}");
        assert_eq!(m.net.protocol_errors, 0, "{kind}");

        // unknown model is a typed, routable error — not a hang or a close
        let err = probe_client.infer(Some("nope"), &probe).expect_err("unknown model");
        assert_eq!(err.server_code(), Some(ErrorCode::UnknownModel), "{kind}");
        probe_client.ping().expect("connection survives a typed error");

        drop(server);
        // after shutdown the port no longer answers
        assert!(
            Client::connect(addr).is_err() || {
                let mut c = Client::connect(addr).unwrap();
                c.ping().is_err()
            },
            "{kind}: port answered after shutdown"
        );
    }
}

/// Deterministic overload: one in-flight slot, a batch policy that parks
/// the first request (bucket of 4, 400 ms wait budget), so a second
/// request must hit the cap while the first is still admitted.
#[test]
fn overload_is_a_typed_retryable_error_and_no_request_is_lost() {
    for kind in TestServer::kinds() {
        let coord = Arc::new(
            CoordinatorBuilder::new()
                .backend(NativeBackend::new(encoded(3, 8)))
                .batch_policy(BatchPolicy::new(vec![4], Duration::from_millis(400)))
                .build()
                .expect("coordinator startup"),
        );
        let tune = Tune { max_inflight: Some(1), ..Tune::default() };
        let mut server = TestServer::bind(kind, &coord, &tune);
        let addr = server.local_addr();
        let img = render_digit(&mut Rng::new(5), 4, 0.05);

        // phase 1: occupy the only slot with a parked request, then overload
        let slow = {
            let img = img.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("connect slow");
                client.infer(None, &img)
            })
        };
        let mut client = Client::connect(addr).expect("connect main");
        // wait (via the metrics frame, which needs no admission slot) until
        // the slow request is admitted — this makes the overload deterministic
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let m = client.metrics().expect("metrics");
            if m.net.inflight == 1 {
                break;
            }
            assert!(Instant::now() < deadline, "{kind}: slow request never admitted");
            std::thread::sleep(Duration::from_millis(5));
        }
        let err = client.infer(None, &img).expect_err("must be rejected at the cap");
        match &err {
            ClientError::Server(e) => {
                assert_eq!(e.code, ErrorCode::ResourceExhausted, "{kind}");
                assert!(e.code.retryable(), "{kind}");
                assert_eq!(e.id, Some(1), "{kind}: error frame echoes the request id");
            }
            other => panic!("{kind}: expected a typed server rejection, got {other}"),
        }
        // the parked request completes untouched (wait-budget expiry launches it)
        let slow_reply = slow.join().expect("slow thread").expect("parked request must succeed");
        assert_eq!(slow_reply.logits.len(), 10);

        // the slot is free again: the same connection retries successfully
        let deadline = Instant::now() + Duration::from_secs(10);
        let retried = loop {
            match client.infer(None, &img) {
                Ok(ok) => break ok,
                Err(ClientError::Server(e)) if e.code == ErrorCode::ResourceExhausted => {
                    assert!(Instant::now() < deadline, "{kind}: slot never freed");
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(other) => panic!("{kind}: retry failed: {other}"),
            }
        };
        assert_eq!(retried.logits, slow_reply.logits, "same image, same model, same logits");
        let m = client.metrics().expect("metrics");
        assert!(m.net.overload_rejections >= 1, "{kind}");

        // phase 2: clean shutdown loses no admitted request — park another
        // request, shut down while it is in flight, and require its response
        let parked = {
            let img = img.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("connect parked");
                client.infer(None, &img)
            })
        };
        std::thread::sleep(Duration::from_millis(100));
        server.shutdown(); // blocks until the front-end drained
        let reply = parked.join().expect("parked thread").expect("request lost in shutdown");
        assert_eq!(reply.logits, slow_reply.logits, "{kind}");
    }
}

#[test]
fn connection_cap_rejects_with_a_typed_frame() {
    for kind in TestServer::kinds() {
        let coord = Arc::new(
            CoordinatorBuilder::new()
                .backend(NativeBackend::new(encoded(4, 4)))
                .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
                .build()
                .expect("coordinator startup"),
        );
        let tune = Tune { max_connections: Some(1), ..Tune::default() };
        let server = TestServer::bind(kind, &coord, &tune);
        let addr = server.local_addr();

        let mut first = Client::connect(addr).expect("connect first");
        first.ping().expect("first connection serves");

        let mut second = Client::connect(addr).expect("tcp connect still succeeds");
        let err = second.ping().expect_err("over-cap connection must be refused");
        match err {
            ClientError::Server(e) => {
                assert_eq!(e.code, ErrorCode::ResourceExhausted, "{kind}");
            }
            // the error frame races the close; a hard close is also acceptable
            ClientError::Io(_) | ClientError::Closed => {}
            other => panic!("{kind}: unexpected rejection shape: {other}"),
        }

        // the first connection is unaffected
        first.ping().expect("capped server keeps serving admitted connections");

        // once the first connection closes, a new one is admitted
        drop(first);
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let mut c = Client::connect(addr).expect("connect");
            if c.ping().is_ok() {
                break;
            }
            assert!(Instant::now() < deadline, "{kind}: slot never freed after disconnect");
            std::thread::sleep(Duration::from_millis(10));
        }
        drop(server);
    }
}

/// The sharded pool behind the TCP front-end: the wire protocol is
/// unchanged, but the `metrics` frame reports per-shard counters; two
/// models on distinct shards light up two entries, and a mid-run
/// hot-swap lands on the owning shard only.
#[test]
fn sharded_server_reports_per_shard_metrics_and_hot_swaps() {
    for kind in TestServer::kinds() {
        let registry = Arc::new(ModelRegistry::new());
        registry.insert("gamma", encoded(31, 4));
        registry.insert("delta", encoded(32, 8));
        let coord = Arc::new(
            CoordinatorBuilder::new()
                .registry(Arc::clone(&registry))
                .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
                .shards(4)
                .build()
                .expect("coordinator startup"),
        );
        // the stable router puts these two models on different shards
        assert_ne!(coord.shard_for(Some("gamma")), coord.shard_for(Some("delta")));
        let server = TestServer::bind(kind, &coord, &Tune::default());
        let addr = server.local_addr();

        // drive both models concurrently over real sockets
        std::thread::scope(|scope| {
            for (model, seed) in [("gamma", 300u64), ("delta", 400u64)] {
                scope.spawn(move || {
                    let mut client = Client::connect(addr).expect("connect worker");
                    let mut rng = Rng::new(seed);
                    for i in 0..24usize {
                        let img = render_digit(&mut rng, i % 10, 0.05);
                        let reply = client
                            .infer(Some(model), &img)
                            .unwrap_or_else(|e| panic!("{kind}: {model} request {i}: {e}"));
                        assert_eq!(reply.model.as_deref(), Some(model), "{kind} request {i}");
                        assert_eq!(reply.logits.len(), 10, "{kind} request {i}");
                    }
                });
            }
        });

        // the metrics frame reports the pool: four shard entries whose
        // counters sum to the merged totals, with (at least) the two owning
        // shards active
        let mut client = Client::connect(addr).expect("connect");
        let m = client.metrics().expect("metrics");
        assert_eq!(m.shards.len(), 4, "{kind}: one counters entry per shard");
        assert_eq!(m.requests, 48, "{kind}");
        let sum: u64 = m.shards.iter().map(|s| s.requests).sum();
        assert_eq!(sum, m.requests, "{kind}: per-shard counters must sum to the merged total");
        let active = m.shards.iter().filter(|s| s.batches > 0).count();
        assert!(active >= 2, "{kind}: two models on distinct shards must light up two shards");
        assert_eq!(m.failed_batches, 0, "{kind}");

        // wire-level mid-run hot swap: the owning shard serves the new
        // weights on its next batch; the other shard is untouched
        let probe = render_digit(&mut Rng::new(88), 6, 0.05);
        let before_g = client.infer(Some("gamma"), &probe).expect("probe gamma");
        let before_d = client.infer(Some("delta"), &probe).expect("probe delta");
        registry.insert("gamma", encoded(33, 16));
        let after_g = client.infer(Some("gamma"), &probe).expect("probe gamma post-swap");
        let after_d = client.infer(Some("delta"), &probe).expect("probe delta post-swap");
        assert_ne!(
            before_g.logits, after_g.logits,
            "{kind}: hot-swapped model must serve different weights"
        );
        assert_eq!(
            before_d.logits, after_d.logits,
            "{kind}: un-swapped model must be unaffected by a swap on another shard"
        );
        drop(server);
    }
}

#[test]
fn bad_frames_get_typed_errors_without_dropping_the_connection() {
    for kind in TestServer::kinds() {
        let coord = Arc::new(
            CoordinatorBuilder::new()
                .backend(NativeBackend::new(encoded(6, 4)))
                .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
                .build()
                .expect("coordinator startup"),
        );
        let server = TestServer::bind(kind, &coord, &Tune::default());
        let mut client = Client::connect(server.local_addr()).expect("connect");

        // wrong image volume
        let bad = Tensor::<f32>::zeros(&[2, 3, 3]);
        let err = client.infer(None, &bad).expect_err("wrong dims");
        assert_eq!(err.server_code(), Some(ErrorCode::BadImage), "{kind}");

        // non-finite data
        let mut inf = Tensor::<f32>::zeros(&[1, 12, 12]);
        inf.data_mut()[0] = f32::INFINITY;
        let err = client.infer(None, &inf).expect_err("non-finite");
        assert_eq!(err.server_code(), Some(ErrorCode::BadImage), "{kind}");

        // naming a model on a registry-less server
        let good = render_digit(&mut Rng::new(8), 1, 0.05);
        let err = client.infer(Some("ghost"), &good).expect_err("no registry");
        assert_eq!(err.server_code(), Some(ErrorCode::UnknownModel), "{kind}");

        // and the connection still serves real work after all of that
        let ok = client.infer(None, &good).expect("recovery");
        assert_eq!(ok.logits.len(), 10, "{kind}");
        let m = client.metrics().expect("metrics");
        assert_eq!(m.net.requests_ok, 1, "{kind}");
        assert_eq!(m.net.connections_open, 1, "{kind}");
        drop(server);
    }
}

/// Both front-ends reap connections that go quiet: an idle socket that
/// never sends a frame, and a slow-loris peer that dribbles a partial
/// header then stalls, are both closed by deadline — while a healthy
/// connection pinging through the same window stays up.
#[test]
fn idle_and_slow_loris_connections_are_reaped_while_healthy_ones_survive() {
    use std::io::{Read, Write};
    for kind in TestServer::kinds() {
        let coord = Arc::new(
            CoordinatorBuilder::new()
                .backend(NativeBackend::new(encoded(11, 4)))
                .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
                .build()
                .expect("coordinator startup"),
        );
        let tune = Tune {
            idle_timeout: Some(Duration::from_millis(300)),
            frame_timeout: Some(Duration::from_millis(200)),
            ..Tune::default()
        };
        let server = TestServer::bind(kind, &coord, &tune);
        let addr = server.local_addr();

        // an idle connection (no bytes at all) and a slow-loris one (two
        // bytes of a four-byte header, then silence)
        let idle = std::net::TcpStream::connect(addr).expect("connect idle");
        let mut loris = std::net::TcpStream::connect(addr).expect("connect loris");
        loris.write_all(&[0, 0]).expect("partial header");

        // a healthy client keeps pinging through the reap window
        let mut healthy = Client::connect(addr).expect("connect healthy");
        for _ in 0..16 {
            healthy.ping().unwrap_or_else(|e| panic!("{kind}: healthy ping failed: {e}"));
            std::thread::sleep(Duration::from_millis(50));
        }

        // both quiet connections must observe EOF (or a reset) by now
        for (name, mut stream) in [("idle", idle), ("slow-loris", loris)] {
            stream.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
            let mut buf = [0u8; 16];
            match stream.read(&mut buf) {
                Ok(0) | Err(_) => {}
                Ok(n) => panic!("{kind}: {name} connection got {n} bytes instead of a close"),
            }
        }

        // the healthy connection is still alive after the reaping
        healthy.ping().unwrap_or_else(|e| panic!("{kind}: survivor ping failed: {e}"));
        drop(server);
    }
}

/// A pipelined client against the threaded front-end degrades cleanly:
/// the `hello` negotiation grants a serial window of one and requests
/// still round-trip.
#[cfg(unix)]
#[test]
fn pipelined_client_degrades_to_serial_against_the_threaded_server() {
    let coord = Arc::new(
        CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(12, 4)))
            .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
            .build()
            .expect("coordinator startup"),
    );
    let server =
        Server::bind("127.0.0.1:0", Arc::clone(&coord), ServerConfig::default()).expect("bind");
    let mut client = PipelinedClient::connect(server.local_addr()).expect("negotiate");
    assert_eq!(client.depth(), 1, "threaded server grants a serial window");

    let img = render_digit(&mut Rng::new(13), 7, 0.05);
    for _ in 0..4 {
        let id = client.submit(None, &img).expect("submit");
        let reply = client.recv().expect("recv");
        assert_eq!(reply.id, id);
        let ok = reply.result.expect("infer ok");
        assert_eq!(ok.logits.len(), 10);
    }
    // the window really is one: a second submit without a recv is refused
    let _ = client.submit(None, &img).expect("submit");
    assert!(client.submit(None, &img).is_err(), "window of one must refuse a second in-flight");
}

/// The headline pipelining behavior: one connection, several requests in
/// flight, responses returning **out of order** and matched by id.  A
/// single-bucket batch policy makes the reordering deterministic — the
/// first-submitted request (model `a`, alone in its bucket) parks on the
/// wait budget while four model-`b` requests fill an exact bucket and
/// launch immediately, so `a`'s reply arrives last.
#[cfg(unix)]
#[test]
fn pipelined_responses_come_back_out_of_order_matched_by_id() {
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("a", encoded(21, 4));
    registry.insert("b", encoded(22, 4));
    let coord = Arc::new(
        CoordinatorBuilder::new()
            .registry(Arc::clone(&registry))
            .batch_policy(BatchPolicy::new(vec![4], Duration::from_millis(300)))
            .build()
            .expect("coordinator startup"),
    );
    let server = EventedServer::bind("127.0.0.1:0", Arc::clone(&coord), EventedConfig::default())
        .expect("bind");

    let mut client = PipelinedClient::connect(server.local_addr()).expect("negotiate");
    assert!(client.depth() >= 16, "granted depth {} is below the pipelining bar", client.depth());

    let img = render_digit(&mut Rng::new(23), 5, 0.05);
    let a_id = client.submit(Some("a"), &img).expect("submit a");
    let b_ids: Vec<u64> = (0..4)
        .map(|i| client.submit(Some("b"), &img).unwrap_or_else(|e| panic!("b {i}: {e}")))
        .collect();
    assert_eq!(client.in_flight(), 5);

    let mut order = Vec::new();
    for i in 0..5 {
        let reply = client.recv().unwrap_or_else(|e| panic!("recv {i}: {e}"));
        let ok = reply.result.unwrap_or_else(|e| panic!("request {} failed: {e}", reply.id));
        assert_eq!(ok.id, reply.id);
        assert_eq!(ok.logits.len(), 10);
        order.push(reply.id);
    }
    assert_eq!(client.in_flight(), 0);

    // submission order was [a, b, b, b, b]; arrival order must not be —
    // the batched b's overtake the parked a, which lands last
    assert_eq!(order.last(), Some(&a_id), "the parked request must arrive last");
    assert_ne!(order.first(), Some(&a_id));
    let mut overtakers: Vec<u64> = order[..4].to_vec();
    overtakers.sort_unstable();
    let mut expected = b_ids.clone();
    expected.sort_unstable();
    assert_eq!(overtakers, expected, "every b reply arrives before the parked a reply");
}

/// C100K shape: a thousand idle connections held open on one evented
/// server (two workers, a handful of threads total) while real inference
/// traffic flows beside them, and sampled idle sockets still answer
/// pings — every connection stays multiplexed, none is starved.
#[cfg(unix)]
#[test]
fn evented_server_multiplexes_a_thousand_connections() {
    let soft = evented::raise_fd_limit(4096).expect("raise fd limit");
    assert!(soft >= 1200, "soft fd limit {soft} too low even after raising");

    let coord = Arc::new(
        CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(41, 4)))
            .batch_policy(BatchPolicy::new(vec![1, 8], Duration::from_millis(1)))
            .build()
            .expect("coordinator startup"),
    );
    let config = EventedConfig { max_connections: 2048, ..EventedConfig::default() };
    let server = EventedServer::bind("127.0.0.1:0", Arc::clone(&coord), config).expect("bind");
    let addr = server.local_addr();

    let held: Vec<std::net::TcpStream> = (0..1000)
        .map(|i| std::net::TcpStream::connect(addr).unwrap_or_else(|e| panic!("connect {i}: {e}")))
        .collect();

    // the server registers all of them (plus our metrics connection)
    let mut metrics_client = Client::connect(addr).expect("connect metrics");
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let m = metrics_client.metrics().expect("metrics");
        if m.net.connections_open >= 1001 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "only {} of 1001 connections registered",
            m.net.connections_open
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // real work flows beside the idle mass
    std::thread::scope(|scope| {
        for seed in 0..8u64 {
            scope.spawn(move || {
                let mut client = Client::connect(addr).expect("connect worker");
                let mut rng = Rng::new(500 + seed);
                for i in 0..25usize {
                    let img = render_digit(&mut rng, i % 10, 0.05);
                    let reply = client
                        .infer(None, &img)
                        .unwrap_or_else(|e| panic!("worker {seed} request {i}: {e}"));
                    assert_eq!(reply.logits.len(), 10);
                }
            });
        }
    });

    // sampled held connections are live, not just accepted: each answers
    // a ping frame in place
    for (i, stream) in held.iter().enumerate().step_by(100) {
        let mut stream = stream;
        stream.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
        let nonce = 9000 + i as u64;
        proto::write_frame(&mut stream, &Frame::Ping { nonce })
            .unwrap_or_else(|e| panic!("ping {i}: {e}"));
        match proto::read_frame(&mut stream, proto::DEFAULT_MAX_FRAME_BYTES) {
            Ok(ReadOutcome::Frame(Frame::Pong { nonce: got })) => assert_eq!(got, nonce),
            other => panic!("held connection {i}: expected pong, got {other:?}"),
        }
    }

    let m = metrics_client.metrics().expect("metrics");
    assert!(m.net.requests_ok >= 200, "all 200 concurrent requests served");
    assert_eq!(m.net.requests_failed, 0);
    assert_eq!(m.net.protocol_errors, 0);
    drop(held);
    drop(server);
}

/// Byte-level backpressure: a client that fires hundreds of requests but
/// never reads its replies.  With a tiny server write buffer and socket
/// buffers, the server must *stop reading* from that connection once its
/// write buffer crosses the high watermark — `frames_received` plateaus
/// far below the request count instead of ballooning server memory —
/// and admission slots for the unflushed replies stay held.  When the
/// client finally drains, every reply arrives, in order, matched by id.
#[cfg(target_os = "linux")]
#[test]
fn backpressure_pauses_reads_on_a_non_draining_connection() {
    use std::io::Write;

    const N: u64 = 600;
    let coord = Arc::new(
        CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(51, 4)))
            .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
            .build()
            .expect("coordinator startup"),
    );
    let config = EventedConfig {
        max_write_buffer: 4096,
        sock_sndbuf: Some(4096),
        idle_timeout: Duration::from_secs(120),
        frame_timeout: Duration::from_secs(120),
        ..EventedConfig::default()
    };
    let server = EventedServer::bind("127.0.0.1:0", Arc::clone(&coord), config).expect("bind");
    let addr = server.local_addr();

    let stream = std::net::TcpStream::connect(addr).expect("connect");
    // shrink our receive window so the kernel cannot absorb the replies
    evented::set_recv_buffer(&stream, 4096).expect("shrink rcvbuf");
    let mut reader = stream.try_clone().expect("clone stream");
    let img = render_digit(&mut Rng::new(53), 2, 0.05);

    // writer half: fire all N requests without ever reading a reply; the
    // write itself blocks once the server stops reading from us
    let writer = {
        let mut stream = stream;
        let img = img.clone();
        std::thread::spawn(move || {
            for id in 1..=N {
                let frame = Frame::Infer(InferFrame {
                    id,
                    model: None,
                    deadline_ms: None,
                    dims: img.dims().to_vec(),
                    data: img.data().to_vec(),
                });
                proto::write_frame(&mut stream, &frame)
                    .unwrap_or_else(|e| panic!("write {id}: {e}"));
            }
            let _ = stream.flush();
        })
    };

    // watch from a second connection: frames_received must plateau well
    // below N while the reply bytes sit unflushed in the write buffer.
    // Each metrics poll is itself one received frame (counted before its
    // own reply snapshot), so subtract our polls to isolate the infers.
    let mut metrics_client = Client::connect(addr).expect("connect metrics");
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut polls = 0u64;
    let mut last = 0u64;
    let mut stable = 0u32;
    let plateau = loop {
        polls += 1;
        let m = metrics_client.metrics().expect("metrics");
        let received = m.net.frames_received - polls;
        if received == last && last > 0 {
            stable += 1;
            if stable >= 20 {
                assert!(m.net.inflight >= 1, "unflushed replies must hold admission slots");
                break received;
            }
        } else {
            stable = 0;
            last = received;
        }
        assert!(Instant::now() < deadline, "reads never plateaued (received {last})");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(
        plateau < N,
        "server read all {N} requests while the peer drained nothing — no backpressure"
    );

    // now drain: every reply arrives, serial order, matched by id
    reader.set_read_timeout(Some(Duration::from_secs(60))).expect("read timeout");
    for expect in 1..=N {
        match proto::read_frame(&mut reader, proto::DEFAULT_MAX_FRAME_BYTES) {
            Ok(ReadOutcome::Frame(Frame::InferOk(ok))) => {
                assert_eq!(ok.id, expect, "serial replies must stay in request order");
                assert_eq!(ok.logits.len(), 10);
            }
            other => panic!("reply {expect}: expected infer_ok, got {other:?}"),
        }
    }
    writer.join().expect("writer thread");
    let m = metrics_client.metrics().expect("metrics");
    assert_eq!(m.net.overload_rejections, 0, "backpressure must pause, not reject");
    drop(server);
}
