//! Property-style tests for [`BatchPolicy::decide`] — pure decision logic,
//! no backend needed.  Randomized bucket configurations come from the
//! crate's deterministic [`Rng`] (proptest is unavailable in the offline
//! build; seeds reproduce failures exactly).

use pasm_accel::cnn::data::Rng;
use pasm_accel::coordinator::BatchPolicy;
use std::time::Duration;

/// A random sorted/deduped bucket set with 1..=5 entries in 1..=64.
fn random_policy(rng: &mut Rng) -> BatchPolicy {
    let n = 1 + rng.below(5);
    let buckets: Vec<usize> = (0..n).map(|_| 1 + rng.below(64)).collect();
    BatchPolicy::new(buckets, Duration::from_millis(2))
}

#[test]
fn decision_is_always_a_configured_bucket() {
    let mut rng = Rng::new(1);
    for _ in 0..200 {
        let p = random_policy(&mut rng);
        for queued in 0..=(p.max_bucket() + 8) {
            for expired in [false, true] {
                if let Some(b) = p.decide(queued, expired) {
                    assert!(p.buckets.contains(&b), "{b} not in {:?}", p.buckets);
                }
            }
        }
    }
}

#[test]
fn exact_fill_launches_immediately() {
    // a queue that exactly fills some bucket never waits
    let mut rng = Rng::new(2);
    for _ in 0..200 {
        let p = random_policy(&mut rng);
        for &b in &p.buckets {
            assert_eq!(p.decide(b, false), Some(b), "buckets {:?}", p.buckets);
        }
    }
}

#[test]
fn underfull_after_deadline_pads_to_smallest_fitting_bucket() {
    let mut rng = Rng::new(3);
    for _ in 0..200 {
        let p = random_policy(&mut rng);
        for queued in 1..=p.max_bucket() {
            let b = p
                .decide(queued, true)
                .expect("expired non-empty queue must launch");
            // smallest configured bucket that fits everything queued
            let want = p.buckets.iter().copied().find(|&x| x >= queued).unwrap();
            assert_eq!(b, want, "queued {queued}, buckets {:?}", p.buckets);
            assert!(b >= queued, "padding, never splitting, below max bucket");
        }
    }
}

#[test]
fn queue_beyond_max_bucket_launches_max() {
    // with more work than the largest bucket, launch the largest bucket at
    // once — expired or not
    let mut rng = Rng::new(4);
    for _ in 0..200 {
        let p = random_policy(&mut rng);
        for extra in [0usize, 1, 7, 100] {
            let queued = p.max_bucket() + extra;
            for expired in [false, true] {
                assert_eq!(p.decide(queued, expired), Some(p.max_bucket()));
            }
        }
    }
}

#[test]
fn never_launches_empty_and_never_drops_expired_work() {
    let mut rng = Rng::new(5);
    for _ in 0..200 {
        let p = random_policy(&mut rng);
        assert_eq!(p.decide(0, false), None);
        assert_eq!(p.decide(0, true), None);
        for queued in 1..=(p.max_bucket() + 3) {
            assert!(
                p.decide(queued, true).is_some(),
                "expired queue of {queued} must launch (buckets {:?})",
                p.buckets
            );
        }
    }
}

#[test]
fn not_expired_waits_unless_exact_or_full() {
    let mut rng = Rng::new(6);
    for _ in 0..200 {
        let p = random_policy(&mut rng);
        for queued in 1..p.max_bucket() {
            let d = p.decide(queued, false);
            if p.buckets.contains(&queued) {
                assert_eq!(d, Some(queued));
            } else {
                assert_eq!(d, None, "queued {queued}, buckets {:?}", p.buckets);
            }
        }
    }
}

#[test]
fn single_bucket_configs() {
    let mut rng = Rng::new(7);
    for _ in 0..100 {
        let b = 1 + rng.below(64);
        let p = BatchPolicy::new(vec![b], Duration::ZERO);
        assert_eq!(p.max_bucket(), b);
        // below the bucket: wait until the deadline, then pad
        for queued in 1..b {
            assert_eq!(p.decide(queued, false), None);
            assert_eq!(p.decide(queued, true), Some(b));
        }
        // at or beyond: launch immediately
        assert_eq!(p.decide(b, false), Some(b));
        assert_eq!(p.decide(b + 1 + rng.below(32), false), Some(b));
    }
}
