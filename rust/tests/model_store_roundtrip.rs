//! Property suite for the `.pasm` model artifact store: pack → load must
//! be **bit-exact** (both the f32 and fixed-point forwards agree to the
//! bit with the source model) across random architectures, bin counts and
//! fixed-point formats — and corrupted or truncated artifacts must load
//! as errors, never panics.  Seeds route through [`common::rng::TestRng`]
//! so any failure prints the seed that reproduces it.

mod common;

use common::rng::{bits, TestRng};
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::CoordinatorBuilder;
use pasm_accel::model_store::{self, ModelRegistry};
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::tensor::Tensor;
use std::path::PathBuf;
use std::sync::Arc;

/// A random but valid digits-style architecture: even input side so the
/// 2x2 pool divides evenly, kernel 3, and a pooled side that still fits
/// the second convolution.
fn random_arch(rng: &mut TestRng) -> DigitsCnn {
    DigitsCnn {
        in_side: [8, 10, 12, 14][rng.below(4)],
        conv1_m: 2 + rng.below(6),
        conv2_m: 2 + rng.below(10),
        kernel: 3,
        classes: 2 + rng.below(9),
    }
}

fn random_model(rng: &mut TestRng) -> EncodedCnn {
    let arch = random_arch(rng);
    let bins = [2usize, 3, 4, 8, 16, 33][rng.below(6)];
    let wq = [QFormat::W8, QFormat::W16, QFormat::W32, QFormat::new(12, 6)][rng.below(4)];
    let params = arch.init(rng.raw());
    EncodedCnn::encode(arch, &params, bins, wq)
}

#[test]
fn pack_load_forward_bitexact_over_random_models() {
    let mut rng = TestRng::new(0xC0FFEE);
    for trial in 0..12u32 {
        let enc = random_model(&mut rng);
        let bytes = model_store::pack(&enc).expect("pack");
        let back = model_store::load(&bytes).expect("load");
        let side = enc.arch.in_side;
        for img_i in 0..3u32 {
            let img = Tensor::from_fn(&[1, side, side], |_| rng.signed());
            for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
                let tag = format!("trial {trial} img {img_i} {variant:?}");
                assert_eq!(
                    bits(&enc.forward(&img, variant)),
                    bits(&back.forward(&img, variant)),
                    "f32 forward diverged ({tag})"
                );
                assert_eq!(
                    bits(&enc.forward_fx(&img, variant, QFormat::IMAGE32)),
                    bits(&back.forward_fx(&img, variant, QFormat::IMAGE32)),
                    "fixed-point forward diverged ({tag})"
                );
            }
        }
    }
}

#[test]
fn pack_is_deterministic() {
    let mut rng = TestRng::new(99);
    let enc = random_model(&mut rng);
    let a = model_store::pack(&enc).unwrap();
    let b = model_store::pack(&enc).unwrap();
    assert_eq!(a, b, "same model must pack to identical bytes");
}

#[test]
fn corrupted_bytes_error_never_panic() {
    let mut rng = TestRng::new(7);
    let enc = random_model(&mut rng);
    let bytes = model_store::pack(&enc).unwrap();
    // dense sweep over the header + start of payload, sparse over the rest
    for pos in (0..bytes.len().min(64)).chain((64..bytes.len()).step_by(7)) {
        for flip in [0x01u8, 0x80] {
            let mut bad = bytes.clone();
            bad[pos] ^= flip;
            assert!(
                model_store::load(&bad).is_err(),
                "flipped bit {flip:#x} at byte {pos} was not detected"
            );
        }
    }
}

#[test]
fn truncated_files_error_never_panic() {
    let mut rng = TestRng::new(8);
    let enc = random_model(&mut rng);
    let bytes = model_store::pack(&enc).unwrap();
    for keep in (0..bytes.len()).step_by(11).chain([bytes.len() - 1]) {
        assert!(
            model_store::load(&bytes[..keep]).is_err(),
            "truncation to {keep}/{} bytes was accepted",
            bytes.len()
        );
    }
    // and garbage appended past the declared length is rejected too
    let mut extended = bytes.clone();
    extended.extend_from_slice(&[0u8; 9]);
    assert!(model_store::load(&extended).is_err());
}

#[test]
fn artifact_compresses_conv_weights() {
    // the §2.1 story: a packed artifact is smaller than the raw f32
    // parameters it encodes, at every swept bin count
    let arch = DigitsCnn::default();
    let mut rng = TestRng::new(21);
    let params = arch.init(rng.raw());
    for bins in [4usize, 16, 64] {
        let enc = EncodedCnn::encode(arch, &params, bins, QFormat::W32);
        let bytes = model_store::pack(&enc).unwrap();
        let raw = model_store::raw_dense_bytes(&enc);
        assert!(
            (bytes.len() as u64) < raw,
            "bins={bins}: artifact {} bytes vs raw {raw}",
            bytes.len()
        );
    }
}

#[test]
fn packed_artifact_serves_bitexact_through_registry_coordinator() {
    // disk -> registry -> coordinator -> logits must equal the in-memory
    // model's reference forward bit for bit
    let dir = tmpdir("serve");
    let mut rng = TestRng::new(31);
    let arch = DigitsCnn::default();
    let params = arch.init(rng.raw());
    let enc = EncodedCnn::encode(arch, &params, 8, QFormat::W16);
    model_store::save_file(&dir.join("digits.pasm"), &enc).unwrap();

    let registry = Arc::new(ModelRegistry::load_dir(&dir).unwrap());
    let entry = registry.get("digits").expect("artifact loaded");
    let on_disk = std::fs::metadata(dir.join("digits.pasm")).unwrap().len();
    assert_eq!(entry.artifact_bytes(), Some(on_disk));

    let coord = CoordinatorBuilder::new().registry(Arc::clone(&registry)).build().unwrap();
    assert_eq!(coord.default_model(), Some("digits"));
    for d in 0..4usize {
        let img = pasm_accel::cnn::data::render_digit(rng.raw(), d, 0.05);
        let resp = coord.infer(img.clone()).unwrap();
        assert_eq!(resp.model.as_deref(), Some("digits"));
        let want = enc.forward(&img, ConvVariant::Pasm);
        assert_eq!(bits(&resp.logits), bits(&want), "digit {d}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pasm_store_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}
