//! Loop-shape test for the histogram PAS inner loop: disassembles this
//! very test binary and asserts the accumulate-tile probes compiled to
//! **packed vector adds**, pinning the "SIMD-friendly layout
//! autovectorizes" claim to emitted machine code rather than to hope.
//!
//! Only meaningful in release builds on x86_64 (debug builds do not
//! vectorize, other ISAs spell their vectors differently), so the whole
//! suite is compiled away elsewhere; CI runs it explicitly via
//! `cargo test --release --test kernel_vectorization`.
#![cfg(all(target_arch = "x86_64", not(debug_assertions)))]

use pasm_accel::cnn::plan::{pasm_hist_acc_tile_f32_probe, pasm_hist_acc_tile_fx_probe, HIST_TILE};
use std::process::Command;

/// Extract the disassembly block of `symbol` from `objdump -d` output:
/// everything between the `<symbol>:` header and the next symbol header.
fn symbol_block(disasm: &str, symbol: &str) -> String {
    let header = format!("<{symbol}>:");
    let start = disasm
        .lines()
        .position(|l| l.ends_with(&header))
        .unwrap_or_else(|| panic!("symbol {symbol} not found in disassembly"));
    disasm
        .lines()
        .skip(start + 1)
        .take_while(|l| !l.contains(">:"))
        .collect::<Vec<_>>()
        .join("\n")
}

/// True if the block contains a packed add of the given family — SSE2
/// baseline (`addps`/`paddq`) or its AVX spelling (`vaddps`/`vpaddq`);
/// scalar forms (`addss`, `add rax, ...`) do not count.
fn has_packed_add(block: &str, mnemonics: &[&str]) -> bool {
    block.lines().any(|l| mnemonics.iter().any(|m| l.split_whitespace().any(|tok| tok == *m)))
}

#[test]
fn histogram_accumulate_tiles_emit_packed_vector_adds() {
    // Call the probes first: a correct result is the cheap sanity check,
    // and the calls guarantee the linker kept the symbols in this binary.
    let mut acc_f = vec![1.0f32; HIST_TILE];
    let src_f = vec![2.0f32; HIST_TILE];
    let mut acc_i = vec![3i64; HIST_TILE];
    let src_i = vec![4i64; HIST_TILE];
    unsafe {
        pasm_hist_acc_tile_f32_probe(acc_f.as_mut_ptr(), src_f.as_ptr(), HIST_TILE);
        pasm_hist_acc_tile_fx_probe(acc_i.as_mut_ptr(), src_i.as_ptr(), HIST_TILE);
    }
    assert!(acc_f.iter().all(|&v| v == 3.0));
    assert!(acc_i.iter().all(|&v| v == 7));

    let exe = std::env::current_exe().expect("own path");
    let out = match Command::new("objdump").arg("-d").arg(&exe).output() {
        Ok(out) if out.status.success() => out,
        // no disassembler on this machine: nothing to measure against —
        // skip loudly rather than fail a test about *available* tooling
        _ => {
            eprintln!("skipping: objdump unavailable or failed; loop shape not checked");
            return;
        }
    };
    let disasm = String::from_utf8_lossy(&out.stdout).into_owned();

    let f32_block = symbol_block(&disasm, "pasm_hist_acc_tile_f32_probe");
    assert!(
        has_packed_add(&f32_block, &["addps", "vaddps"]),
        "f32 accumulate tile did not vectorize (no addps/vaddps):\n{f32_block}"
    );

    let fx_block = symbol_block(&disasm, "pasm_hist_acc_tile_fx_probe");
    assert!(
        has_packed_add(&fx_block, &["paddq", "vpaddq"]),
        "fx accumulate tile did not vectorize (no paddq/vpaddq):\n{fx_block}"
    );
}
