//! Retry and backoff behavior of the serving client, pinned for
//! determinism: the jittered exponential backoff schedule is a pure
//! function of the policy seed, and a full network load run against a
//! scripted flaky server reports identical retry accounting on every
//! same-seed run.
//!
//! The flaky server here is scripted, not chaos-injected: it answers
//! each `infer` by a fixed per-connection pattern (alternate
//! fail/succeed, always-fail retryable, always-fail non-retryable),
//! which makes *exact* retry counts assertable — a real server with an
//! attached fault plan can only promise the aggregate distribution, not
//! which request observes a fault.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::coordinator::loadgen::{LoadResult, NetLoadOptions, run_open_loop_net};
use pasm_accel::coordinator::HwCost;
use pasm_accel::serving::proto::{self, ErrorCode, ErrorFrame, Frame, InferOkFrame, ReadOutcome};
use pasm_accel::serving::RetryPolicy;
use pasm_accel::tensor::Tensor;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

#[test]
fn backoff_schedule_is_deterministic_capped_and_jittered() {
    let policy = RetryPolicy {
        max_attempts: 8,
        base: Duration::from_millis(10),
        cap: Duration::from_millis(500),
        seed: 42,
    };
    let schedule = |seed: u64| -> Vec<Duration> {
        let mut rng = Rng::new(seed);
        (0..12).map(|attempt| policy.backoff(attempt, &mut rng)).collect()
    };
    let a = schedule(42);
    assert_eq!(a, schedule(42), "same jitter seed must produce the same schedule");
    assert_ne!(a, schedule(43), "different jitter seeds must diverge");

    for (i, &delay) in a.iter().enumerate() {
        let attempt = u32::try_from(i).unwrap();
        let full = policy.base.saturating_mul(1u32 << attempt.min(16)).min(policy.cap);
        assert!(delay <= full, "attempt {attempt}: {delay:?} above un-jittered {full:?}");
        assert!(delay >= full.mul_f64(0.5), "attempt {attempt}: {delay:?} under half of {full:?}");
        assert!(delay <= policy.cap, "attempt {attempt}: {delay:?} exceeds the cap");
    }
    // the exponential actually grows before the cap bites: attempt 2's
    // jitter floor (20ms) clears attempt 0's jitter ceiling (10ms)
    assert!(a[2] > a[0], "backoff must grow: attempt 0 {:?}, attempt 2 {:?}", a[0], a[2]);
}

/// How the scripted server answers each `infer` frame.
#[derive(Clone, Copy)]
enum Script {
    /// Per connection, alternate `RESOURCE_EXHAUSTED` / success starting
    /// with the failure.  A retrying client resends on the same
    /// connection, so every request costs exactly one retry — however
    /// the load generator spreads requests over connections.
    AlternateExhausted,
    /// Every infer gets `RESOURCE_EXHAUSTED`: retries must exhaust.
    AlwaysExhausted,
    /// Every infer gets `INTERNAL`: not retryable, must fail at once.
    AlwaysInternal,
}

/// A minimal protocol-speaking TCP server with scripted replies.  The
/// accept thread outlives the test harmlessly; handlers exit on EOF.
fn scripted_server(script: Script) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind scripted server");
    let addr = listener.local_addr().expect("scripted server addr");
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(stream) = conn else { return };
            std::thread::spawn(move || serve_conn(stream, script));
        }
    });
    addr
}

fn serve_conn(mut stream: TcpStream, script: Script) {
    let mut fail_next = true;
    loop {
        let frame = match proto::read_frame(&mut stream, proto::DEFAULT_MAX_FRAME_BYTES) {
            Ok(ReadOutcome::Frame(f)) => f,
            Ok(_) | Err(_) => return,
        };
        let reply = match frame {
            Frame::Infer(req) => {
                let fail_code = match script {
                    Script::AlternateExhausted => {
                        let fail = fail_next;
                        fail_next = !fail_next;
                        fail.then_some(ErrorCode::ResourceExhausted)
                    }
                    Script::AlwaysExhausted => Some(ErrorCode::ResourceExhausted),
                    Script::AlwaysInternal => Some(ErrorCode::Internal),
                };
                match fail_code {
                    Some(code) => {
                        Frame::Error(ErrorFrame::new(Some(req.id), code, "scripted failure"))
                    }
                    None => Frame::InferOk(InferOkFrame {
                        id: req.id,
                        model: req.model.clone(),
                        logits: vec![0.0; 10],
                        predicted: 0,
                        queue_us: 50,
                        compute_us: 50,
                        batch_size: 1,
                        batch_occupancy: 1,
                        hw: HwCost::default(),
                    }),
                }
            }
            Frame::Ping { nonce } => Frame::Pong { nonce },
            _ => return,
        };
        if proto::write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

fn image_pool() -> Vec<Tensor<f32>> {
    let mut rng = Rng::new(9);
    (0..8).map(|i| render_digit(&mut rng, i % 10, 0.05)).collect()
}

fn drive(addr: SocketAddr, n: usize, retry: RetryPolicy) -> LoadResult {
    let opts = NetLoadOptions { connections: 2, retry, ..NetLoadOptions::default() };
    let mut rng = Rng::new(17);
    run_open_loop_net(&addr.to_string(), &[], &image_pool(), n, 2000.0, opts, &mut rng)
        .expect("load run against scripted server")
}

#[test]
fn retried_failures_cost_exactly_one_retry_each_and_replay_identically() {
    let addr = scripted_server(Script::AlternateExhausted);
    let n = 24;
    let a = drive(addr, n, RetryPolicy::standard(3, 7));
    let b = drive(addr, n, RetryPolicy::standard(3, 7));
    for r in [&a, &b] {
        assert_eq!(r.latencies_us.len(), n, "every request must succeed on its retry");
        assert_eq!(r.errors, 0);
        assert_eq!(r.overloaded, 0);
        assert_eq!(r.deadline_misses, 0);
        assert_eq!(r.retries, n as u64, "one failed first attempt per request");
    }
    assert_eq!(a.retries, b.retries, "same seeds must reproduce the same retry count");
}

#[test]
fn retries_are_bounded_by_max_attempts() {
    let addr = scripted_server(Script::AlwaysExhausted);
    let n = 8;
    let r = drive(addr, n, RetryPolicy::standard(3, 7));
    assert!(r.latencies_us.is_empty(), "an always-failing server cannot complete a request");
    // terminal classification: exhausted retries on RESOURCE_EXHAUSTED
    // land in `overloaded`, not `errors`
    assert_eq!(r.overloaded, n);
    assert_eq!(r.errors, 0);
    assert_eq!(r.retries, 2 * n as u64, "3 attempts = 2 retries per request, then give up");
}

#[test]
fn non_retryable_errors_are_never_retried() {
    let addr = scripted_server(Script::AlwaysInternal);
    let n = 8;
    let r = drive(addr, n, RetryPolicy::standard(4, 7));
    assert!(r.latencies_us.is_empty());
    assert_eq!(r.errors, n, "INTERNAL is terminal");
    assert_eq!(r.retries, 0, "execution errors must not be resubmitted");
}
