//! Integration: the full coordinator path — submit concurrent requests,
//! verify batching, numerics (vs the rust reference forward), metrics, and
//! clean shutdown.  Runs on the [`NativeBackend`] by default (no artifacts
//! or external runtime needed); a PJRT variant is kept `#[ignore]`d behind
//! the `pjrt` feature.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{
    BatchPolicy, Coordinator, CoordinatorBuilder, CostModel, NativeBackend, NativePrecision,
};
use pasm_accel::quant::fixed::QFormat;
use std::time::Duration;

fn encoded_net(seed: u64) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, 16, QFormat::W32)
}

fn native_coordinator(enc: EncodedCnn, policy: BatchPolicy) -> Coordinator {
    CoordinatorBuilder::new()
        .backend(NativeBackend::new(enc))
        .batch_policy(policy)
        .build()
        .expect("native coordinator startup")
}

#[test]
fn serves_concurrent_requests_correctly() {
    let enc = encoded_net(1);
    let reference = enc.clone();
    let coord = native_coordinator(
        enc,
        BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(5)),
    );

    // fire 30 requests and hold the receivers
    let mut rng = Rng::new(42);
    let mut cases = Vec::new();
    for i in 0..30usize {
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit(img.clone()).unwrap();
        cases.push((img, rx));
    }

    for (i, (img, rx)) in cases.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("no response")
            .expect("inference failed");
        // NativeBackend runs the reference forward itself: bit-equal logits
        let want = reference.forward(&img, ConvVariant::Pasm);
        for (j, (&got, &w)) in resp.logits.iter().zip(want.iter()).enumerate() {
            assert_eq!(got.to_bits(), w.to_bits(), "request {i} logit {j}: {got} vs {w}");
        }
        assert!(resp.batch_size >= resp.batch_occupancy);
        assert!(resp.hw.cycles > 0);
        assert!(resp.hw.energy_j > 0.0);
    }

    let m = coord.metrics();
    assert_eq!(m.backend, "native");
    assert_eq!(m.requests, 30);
    assert!(m.batches >= 2, "expected batching, got {} batches", m.batches);
    assert!(m.mean_occupancy() > 1.0);
    assert!(m.percentile_us(50.0).is_some());
}

#[test]
fn single_blocking_infer() {
    let enc = encoded_net(2);
    let reference = enc.clone();
    let coord = native_coordinator(enc, BatchPolicy::default());
    let mut rng = Rng::new(7);
    let img = render_digit(&mut rng, 3, 0.05);
    let resp = coord.infer(img.clone()).unwrap();
    let want = reference.forward(&img, ConvVariant::Pasm);
    let want_pred = pasm_accel::cnn::layer::argmax(&want);
    assert_eq!(resp.predicted, want_pred);
}

#[test]
fn shutdown_flushes_pending() {
    let enc = encoded_net(3);
    let coord = native_coordinator(
        enc,
        BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(50)),
    );
    let mut rng = Rng::new(9);
    let mut rxs = Vec::new();
    for i in 0..5usize {
        let img = render_digit(&mut rng, i, 0.05);
        rxs.push(coord.submit(img).unwrap());
    }
    drop(coord); // shutdown must flush, not drop, the 5 pending requests
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(30));
        assert!(resp.is_ok(), "request {i} was dropped at shutdown");
        assert!(resp.unwrap().is_ok());
    }
}

#[test]
fn mixed_digit_accuracy_via_coordinator() {
    // random-init net won't classify well, but the coordinator's output
    // must equal the reference forward's argmax for every image
    let enc = encoded_net(4);
    let reference = enc.clone();
    let coord = native_coordinator(enc, BatchPolicy::default());
    let mut rng = Rng::new(5);
    for d in 0..10usize {
        let img = render_digit(&mut rng, d, 0.1);
        let resp = coord.infer(img.clone()).unwrap();
        let want = reference.forward(&img, ConvVariant::Pasm);
        assert_eq!(resp.predicted, pasm_accel::cnn::layer::argmax(&want), "digit {d}");
    }
}

#[test]
fn fixed_point_backend_bitexact_vs_reference() {
    // the acceptance bar: NativeBackend in fixed-point mode must reproduce
    // the EncodedCnn fixed-point reference forward bit for bit, through the
    // whole batching/padding path
    let enc = encoded_net(6);
    let reference = enc.clone();
    let coord = CoordinatorBuilder::new()
        .backend(
            NativeBackend::new(enc).with_precision(NativePrecision::Fixed(QFormat::IMAGE32)),
        )
        .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(2)))
        .build()
        .unwrap();
    let mut rng = Rng::new(31);
    for d in 0..8usize {
        let img = render_digit(&mut rng, d, 0.05);
        let resp = coord.infer(img.clone()).unwrap();
        let want = reference.forward_fx(&img, ConvVariant::Pasm, QFormat::IMAGE32);
        let got: Vec<u32> = resp.logits.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, wb, "digit {d}");
        // §5.3: the WS fixed-point forward is the same function
        let ws = reference.forward_fx(&img, ConvVariant::WeightShared, QFormat::IMAGE32);
        let wsb: Vec<u32> = ws.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, wsb, "digit {d} (ws)");
    }
}

#[test]
fn cost_model_decoupled_from_backend() {
    // same backend + requests, different silicon pricing: the PASM model
    // must report more cycles than the WS-MAC model (Fig 14's latency
    // overhead) on identical numerics
    let run = |cost: CostModel| -> u64 {
        let coord = CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded_net(8)))
            .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
            .cost_model(cost)
            .build()
            .unwrap();
        let mut rng = Rng::new(3);
        let resp = coord.infer(render_digit(&mut rng, 2, 0.05)).unwrap();
        resp.hw.cycles
    };
    let pasm = run(CostModel::pasm_asic());
    let ws = run(CostModel::weight_shared_asic());
    assert!(pasm > ws, "pasm {pasm} cycles vs ws {ws}");
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn variant_net(seed: u64, bins: usize) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, bins, QFormat::W32)
}

#[test]
fn registry_coordinator_routes_two_models_concurrently() {
    use pasm_accel::model_store::ModelRegistry;
    use std::sync::Arc;

    let a = variant_net(11, 4);
    let b = variant_net(12, 16);
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("a", a.clone());
    registry.insert("b", b.clone());
    let coord = CoordinatorBuilder::new()
        .registry(Arc::clone(&registry))
        .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(2)))
        .build()
        .unwrap();

    // interleave submissions to both models, holding every receiver so
    // batches for the two models overlap in the queue
    let mut rng = Rng::new(5);
    let mut cases = Vec::new();
    for i in 0..20usize {
        let name = if i % 2 == 0 { "a" } else { "b" };
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit_to(name, img.clone()).unwrap();
        cases.push((name, img, rx));
    }
    for (i, (name, img, rx)) in cases.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("no response")
            .expect("inference failed");
        assert_eq!(resp.model.as_deref(), Some(name), "request {i}");
        let reference = if name == "a" { &a } else { &b };
        let want = reference.forward(&img, ConvVariant::Pasm);
        assert_eq!(bits(&resp.logits), bits(&want), "request {i} on '{name}'");
    }
    let m = coord.metrics();
    assert_eq!(m.model("a").requests, 10);
    assert_eq!(m.model("b").requests, 10);
    assert_eq!(m.requests, 20);
}

#[test]
fn hot_swap_takes_effect_without_restart() {
    use pasm_accel::model_store::ModelRegistry;
    use std::sync::Arc;

    let v1 = variant_net(13, 4);
    let v2 = variant_net(14, 16);
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("m", v1.clone());
    let coord = CoordinatorBuilder::new().registry(Arc::clone(&registry)).build().unwrap();

    let mut rng = Rng::new(6);
    let img = render_digit(&mut rng, 3, 0.05);
    let before = coord.infer_model("m", img.clone()).unwrap();
    assert_eq!(bits(&before.logits), bits(&v1.forward(&img, ConvVariant::Pasm)));

    // swap in the new variant — no rebuild, no restart
    registry.insert("m", v2.clone());
    let after = coord.infer_model("m", img.clone()).unwrap();
    assert_eq!(bits(&after.logits), bits(&v2.forward(&img, ConvVariant::Pasm)));
}

#[test]
fn hot_swap_under_load_drops_and_misroutes_nothing() {
    use pasm_accel::model_store::ModelRegistry;
    use std::sync::Arc;

    let a = variant_net(15, 4);
    let b = variant_net(16, 8);
    let b2 = variant_net(17, 33);
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("a", a.clone());
    registry.insert("b", b.clone());
    let coord = CoordinatorBuilder::new()
        .registry(Arc::clone(&registry))
        .batch_policy(BatchPolicy::new(vec![1, 8], Duration::from_millis(1)))
        .build()
        .unwrap();

    let mut rng = Rng::new(7);
    let mut cases = Vec::new();
    for i in 0..16usize {
        let name = if i % 2 == 0 { "a" } else { "b" };
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit_to(name, img.clone()).unwrap();
        cases.push((name, img, rx));
    }
    // swap 'b' while those requests are in flight, then keep submitting
    registry.insert("b", b2.clone());
    for i in 16..32usize {
        let name = if i % 2 == 0 { "a" } else { "b" };
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit_to(name, img.clone()).unwrap();
        cases.push((name, img, rx));
    }

    for (i, (name, img, rx)) in cases.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("request dropped across the hot swap")
            .expect("inference failed across the hot swap");
        assert_eq!(resp.model.as_deref(), Some(name), "request {i}");
        match name {
            // 'a' was never swapped: always bit-exact to its weights
            "a" => {
                let want = a.forward(&img, ConvVariant::Pasm);
                assert_eq!(bits(&resp.logits), bits(&want), "request {i} on 'a'");
            }
            // 'b' answers with whichever version its batch ran on —
            // never with 'a', and post-swap submissions get the new one
            _ => {
                let old = bits(&b.forward(&img, ConvVariant::Pasm));
                let new = bits(&b2.forward(&img, ConvVariant::Pasm));
                let got = bits(&resp.logits);
                assert!(
                    got == old || got == new,
                    "request {i} on 'b' matches neither version"
                );
                if i >= 16 {
                    assert_eq!(got, new, "post-swap request {i} served stale weights");
                }
            }
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
#[ignore = "requires `make artifacts` and the pjrt feature"]
fn serves_concurrent_requests_via_pjrt() {
    use pasm_accel::coordinator::PjrtBackend;
    let enc = encoded_net(1);
    let reference = enc.clone();
    let coord = CoordinatorBuilder::new()
        .backend(PjrtBackend::new("artifacts", enc))
        .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(5)))
        .build()
        .expect("run `make artifacts` first");

    let mut rng = Rng::new(42);
    let mut cases = Vec::new();
    for i in 0..30usize {
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit(img.clone()).unwrap();
        cases.push((img, rx));
    }
    for (i, (img, rx)) in cases.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("no response")
            .expect("inference failed");
        let want = reference.forward(&img, ConvVariant::Pasm);
        for (j, (&got, &w)) in resp.logits.iter().zip(want.iter()).enumerate() {
            assert!((got - w).abs() < 1e-2, "request {i} logit {j}: {got} vs {w}");
        }
    }
    assert_eq!(coord.metrics().backend, "pjrt");
}
