//! Integration: the full coordinator path — submit concurrent requests,
//! verify batching, numerics (vs the rust reference forward), metrics, and
//! clean shutdown.  Runs on the [`NativeBackend`] by default (no artifacts
//! or external runtime needed); a PJRT variant is kept `#[ignore]`d behind
//! the `pjrt` feature.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{
    BatchPolicy, Coordinator, CoordinatorBuilder, CostModel, NativeBackend, NativePrecision,
};
use pasm_accel::quant::fixed::QFormat;
use std::time::Duration;

fn encoded_net(seed: u64) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, 16, QFormat::W32)
}

fn native_coordinator(enc: EncodedCnn, policy: BatchPolicy) -> Coordinator {
    CoordinatorBuilder::new()
        .backend(NativeBackend::new(enc))
        .batch_policy(policy)
        .build()
        .expect("native coordinator startup")
}

#[test]
fn serves_concurrent_requests_correctly() {
    let enc = encoded_net(1);
    let reference = enc.clone();
    let coord = native_coordinator(
        enc,
        BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(5)),
    );

    // fire 30 requests and hold the receivers
    let mut rng = Rng::new(42);
    let mut cases = Vec::new();
    for i in 0..30usize {
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit(img.clone()).unwrap();
        cases.push((img, rx));
    }

    for (i, (img, rx)) in cases.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("no response")
            .expect("inference failed");
        // NativeBackend runs the reference forward itself: bit-equal logits
        let want = reference.forward(&img, ConvVariant::Pasm);
        for (j, (&got, &w)) in resp.logits.iter().zip(want.iter()).enumerate() {
            assert_eq!(got.to_bits(), w.to_bits(), "request {i} logit {j}: {got} vs {w}");
        }
        assert!(resp.batch_size >= resp.batch_occupancy);
        assert!(resp.hw.cycles > 0);
        assert!(resp.hw.energy_j > 0.0);
    }

    let m = coord.metrics();
    assert_eq!(m.backend, "native");
    assert_eq!(m.requests, 30);
    assert!(m.batches >= 2, "expected batching, got {} batches", m.batches);
    assert!(m.mean_occupancy() > 1.0);
    assert!(m.percentile_us(50.0).is_some());
}

#[test]
fn single_blocking_infer() {
    let enc = encoded_net(2);
    let reference = enc.clone();
    let coord = native_coordinator(enc, BatchPolicy::default());
    let mut rng = Rng::new(7);
    let img = render_digit(&mut rng, 3, 0.05);
    let resp = coord.infer(img.clone()).unwrap();
    let want = reference.forward(&img, ConvVariant::Pasm);
    let want_pred = pasm_accel::cnn::layer::argmax(&want);
    assert_eq!(resp.predicted, want_pred);
}

#[test]
fn shutdown_flushes_pending() {
    let enc = encoded_net(3);
    let coord = native_coordinator(
        enc,
        BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(50)),
    );
    let mut rng = Rng::new(9);
    let mut rxs = Vec::new();
    for i in 0..5usize {
        let img = render_digit(&mut rng, i, 0.05);
        rxs.push(coord.submit(img).unwrap());
    }
    drop(coord); // shutdown must flush, not drop, the 5 pending requests
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(30));
        assert!(resp.is_ok(), "request {i} was dropped at shutdown");
        assert!(resp.unwrap().is_ok());
    }
}

#[test]
fn mixed_digit_accuracy_via_coordinator() {
    // random-init net won't classify well, but the coordinator's output
    // must equal the reference forward's argmax for every image
    let enc = encoded_net(4);
    let reference = enc.clone();
    let coord = native_coordinator(enc, BatchPolicy::default());
    let mut rng = Rng::new(5);
    for d in 0..10usize {
        let img = render_digit(&mut rng, d, 0.1);
        let resp = coord.infer(img.clone()).unwrap();
        let want = reference.forward(&img, ConvVariant::Pasm);
        assert_eq!(resp.predicted, pasm_accel::cnn::layer::argmax(&want), "digit {d}");
    }
}

#[test]
fn fixed_point_backend_bitexact_vs_reference() {
    // the acceptance bar: NativeBackend in fixed-point mode must reproduce
    // the EncodedCnn fixed-point reference forward bit for bit, through the
    // whole batching/padding path
    let enc = encoded_net(6);
    let reference = enc.clone();
    let coord = CoordinatorBuilder::new()
        .backend(
            NativeBackend::new(enc).with_precision(NativePrecision::Fixed(QFormat::IMAGE32)),
        )
        .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(2)))
        .build()
        .unwrap();
    let mut rng = Rng::new(31);
    for d in 0..8usize {
        let img = render_digit(&mut rng, d, 0.05);
        let resp = coord.infer(img.clone()).unwrap();
        let want = reference.forward_fx(&img, ConvVariant::Pasm, QFormat::IMAGE32);
        let got: Vec<u32> = resp.logits.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, wb, "digit {d}");
        // §5.3: the WS fixed-point forward is the same function
        let ws = reference.forward_fx(&img, ConvVariant::WeightShared, QFormat::IMAGE32);
        let wsb: Vec<u32> = ws.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, wsb, "digit {d} (ws)");
    }
}

#[test]
fn cost_model_decoupled_from_backend() {
    // same backend + requests, different silicon pricing: the PASM model
    // must report more cycles than the WS-MAC model (Fig 14's latency
    // overhead) on identical numerics
    let run = |cost: CostModel| -> u64 {
        let coord = CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded_net(8)))
            .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
            .cost_model(cost)
            .build()
            .unwrap();
        let mut rng = Rng::new(3);
        let resp = coord.infer(render_digit(&mut rng, 2, 0.05)).unwrap();
        resp.hw.cycles
    };
    let pasm = run(CostModel::pasm_asic());
    let ws = run(CostModel::weight_shared_asic());
    assert!(pasm > ws, "pasm {pasm} cycles vs ws {ws}");
}

#[test]
#[allow(deprecated)]
fn deprecated_start_shim_still_serves() {
    // the old free-argument constructor must keep compiling and serving
    // (natively when the pjrt feature is off)
    let enc = encoded_net(9);
    let reference = enc.clone();
    let coord = Coordinator::start("artifacts", enc, BatchPolicy::default());
    #[cfg(feature = "pjrt")]
    let coord = match coord {
        Ok(c) => c,
        Err(_) => return, // pjrt build without `make artifacts`: startup error is correct
    };
    #[cfg(not(feature = "pjrt"))]
    let coord = coord.expect("shim must serve natively without artifacts");
    let mut rng = Rng::new(10);
    let img = render_digit(&mut rng, 1, 0.05);
    let resp = coord.infer(img.clone()).unwrap();
    let want = reference.forward(&img, ConvVariant::Pasm);
    assert_eq!(resp.predicted, pasm_accel::cnn::layer::argmax(&want));
}

#[cfg(feature = "pjrt")]
#[test]
#[ignore = "requires `make artifacts` and the pjrt feature"]
fn serves_concurrent_requests_via_pjrt() {
    use pasm_accel::coordinator::PjrtBackend;
    let enc = encoded_net(1);
    let reference = enc.clone();
    let coord = CoordinatorBuilder::new()
        .backend(PjrtBackend::new("artifacts", enc))
        .batch_policy(BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(5)))
        .build()
        .expect("run `make artifacts` first");

    let mut rng = Rng::new(42);
    let mut cases = Vec::new();
    for i in 0..30usize {
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit(img.clone()).unwrap();
        cases.push((img, rx));
    }
    for (i, (img, rx)) in cases.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("no response")
            .expect("inference failed");
        let want = reference.forward(&img, ConvVariant::Pasm);
        for (j, (&got, &w)) in resp.logits.iter().zip(want.iter()).enumerate() {
            assert!((got - w).abs() < 1e-2, "request {i} logit {j}: {got} vs {w}");
        }
    }
    assert_eq!(coord.metrics().backend, "pjrt");
}
