//! Integration: the full coordinator path — submit concurrent requests,
//! verify batching, numerics (vs the rust reference forward), metrics, and
//! clean shutdown.  Requires `make artifacts`.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{BatchPolicy, Coordinator};
use pasm_accel::quant::fixed::QFormat;
use std::time::Duration;

fn encoded_net(seed: u64) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, 16, QFormat::W32)
}

#[test]
fn serves_concurrent_requests_correctly() {
    let enc = encoded_net(1);
    let reference = enc.clone();
    let coord = Coordinator::start(
        "artifacts",
        enc,
        BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(5)),
    )
    .expect("run `make artifacts` first");

    // fire 30 requests and hold the receivers
    let mut rng = Rng::new(42);
    let mut cases = Vec::new();
    for i in 0..30usize {
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit(img.clone()).unwrap();
        cases.push((img, rx));
    }

    for (i, (img, rx)) in cases.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("no response")
            .expect("inference failed");
        let want = reference.forward(&img, ConvVariant::Pasm);
        for (j, (&got, &w)) in resp.logits.iter().zip(want.iter()).enumerate() {
            assert!(
                (got - w).abs() < 1e-2,
                "request {i} logit {j}: {got} vs {w}"
            );
        }
        assert!(resp.batch_size >= resp.batch_occupancy);
        assert!(resp.hw.cycles > 0);
        assert!(resp.hw.energy_j > 0.0);
    }

    let m = coord.metrics();
    assert_eq!(m.requests, 30);
    assert!(m.batches >= 2, "expected batching, got {} batches", m.batches);
    assert!(m.mean_occupancy() > 1.0);
    assert!(m.percentile_us(50.0).is_some());
}

#[test]
fn single_blocking_infer() {
    let enc = encoded_net(2);
    let reference = enc.clone();
    let coord = Coordinator::start("artifacts", enc, BatchPolicy::default())
        .expect("run `make artifacts` first");
    let mut rng = Rng::new(7);
    let img = render_digit(&mut rng, 3, 0.05);
    let resp = coord.infer(img.clone()).unwrap();
    let want = reference.forward(&img, ConvVariant::Pasm);
    let want_pred = pasm_accel::cnn::layer::argmax(&want);
    assert_eq!(resp.predicted, want_pred);
}

#[test]
fn shutdown_flushes_pending() {
    let enc = encoded_net(3);
    let coord = Coordinator::start(
        "artifacts",
        enc,
        BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(50)),
    )
    .expect("run `make artifacts` first");
    let mut rng = Rng::new(9);
    let mut rxs = Vec::new();
    for i in 0..5usize {
        let img = render_digit(&mut rng, i, 0.05);
        rxs.push(coord.submit(img).unwrap());
    }
    drop(coord); // shutdown must flush, not drop, the 5 pending requests
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(30));
        assert!(resp.is_ok(), "request {i} was dropped at shutdown");
        assert!(resp.unwrap().is_ok());
    }
}

#[test]
fn mixed_digit_accuracy_via_coordinator() {
    // random-init net won't classify well, but the coordinator's output
    // must equal the reference forward's argmax for every image
    let enc = encoded_net(4);
    let reference = enc.clone();
    let coord = Coordinator::start("artifacts", enc, BatchPolicy::default())
        .expect("run `make artifacts` first");
    let mut rng = Rng::new(5);
    for d in 0..10usize {
        let img = render_digit(&mut rng, d, 0.1);
        let resp = coord.infer(img.clone()).unwrap();
        let want = reference.forward(&img, ConvVariant::Pasm);
        assert_eq!(resp.predicted, pasm_accel::cnn::layer::argmax(&want), "digit {d}");
    }
}
