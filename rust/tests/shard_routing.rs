//! Routing invariants of the sharded coordinator pool: one model, one
//! shard (stable across requests and builds); per-model FIFO witnessed
//! through batch sequence numbers; no batch mixes models at any shard
//! count; a mid-run hot-swap goes live on the owning shard's next batch
//! without touching the others; shutdown drains every shard.
//!
//! With cross-shard batch stealing enabled the same witnesses must keep
//! holding: `batch_seq` stays monotone per model (the home shard is the
//! only batch former, so stamping happens before handoff), responses
//! attribute `shard` to the home and `executed_by` to whichever shard
//! ran the batch, no thief-executed batch mixes models, stealing off is
//! bit-for-bit the legacy single-owner routing, and per-shard counters
//! sum exactly to the merged snapshot even when a batch is formed on
//! one shard and executed on another.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{
    BatchPolicy, Coordinator, CoordinatorBuilder, Executable, ExecutionBackend, NativeBackend,
};
use pasm_accel::model_store::ModelRegistry;
use pasm_accel::quant::fixed::QFormat;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// FNV-1a at 4 shards: alpha -> 3, beta -> 3 (a deliberate collision),
/// gamma -> 2, delta -> 1 — three distinct shards busy, one pair sharing.
const MODELS: [&str; 4] = ["alpha", "beta", "gamma", "delta"];

fn encoded(seed: u64, bins: usize) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, bins, QFormat::W32)
}

fn four_model_registry() -> Arc<ModelRegistry> {
    let registry = Arc::new(ModelRegistry::new());
    for (i, name) in MODELS.iter().enumerate() {
        registry.insert(*name, encoded(i as u64 + 1, 4 * (i + 1)));
    }
    registry
}

fn pool(registry: &Arc<ModelRegistry>, shards: usize) -> Coordinator {
    CoordinatorBuilder::new()
        .registry(Arc::clone(registry))
        .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
        .shards(shards)
        .build()
        .expect("coordinator startup")
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// A pool with cross-shard batch stealing on and the promotion
/// threshold at zero: every formed batch with a costed EWMA is donated
/// to the deck, so idle shards steal eagerly and deterministically.
fn steal_pool(registry: &Arc<ModelRegistry>, shards: usize) -> Coordinator {
    CoordinatorBuilder::new()
        .registry(Arc::clone(registry))
        .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
        .shards(shards)
        .steal(true)
        .steal_promote_us(0)
        .build()
        .expect("coordinator startup")
}

/// Hot-skewed assignment: 3/4 of traffic to "alpha", the rest
/// round-robined over the remaining models — enough home-side backlog
/// to donate, enough idle capacity elsewhere to steal.
fn hot_skewed(i: usize) -> &'static str {
    if i % 4 == 0 {
        MODELS[1 + (i / 4) % 3]
    } else {
        "alpha"
    }
}

#[test]
fn one_model_lands_on_one_shard_only() {
    let registry = four_model_registry();
    let coord = pool(&registry, 4);
    assert_eq!(coord.shards(), 4);

    let mut rng = Rng::new(9);
    let mut rxs = Vec::new();
    for i in 0..40usize {
        let name = MODELS[i % MODELS.len()];
        let rx = coord.submit_to(name, render_digit(&mut rng, i % 10, 0.05)).unwrap();
        rxs.push((name, rx));
    }
    let mut seen: BTreeMap<&str, usize> = BTreeMap::new();
    for (name, rx) in rxs {
        let resp = rx.recv().unwrap().expect("inference failed");
        assert_eq!(
            resp.shard,
            coord.shard_for(Some(name)),
            "'{name}' served off its routed shard"
        );
        if let Some(&shard) = seen.get(name) {
            assert_eq!(shard, resp.shard, "'{name}' moved between shards");
        }
        seen.insert(name, resp.shard);
    }

    // the per-shard metrics agree: each model's counters live on exactly
    // the shard the router names, and nowhere else
    let per_shard = coord.shard_metrics();
    for name in MODELS {
        let with_counts: Vec<usize> = per_shard
            .iter()
            .enumerate()
            .filter(|(_, m)| m.model(name).requests > 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(with_counts, vec![coord.shard_for(Some(name))], "model '{name}'");
    }
    // and the merged snapshot aggregates everything
    let merged = coord.metrics();
    assert_eq!(merged.requests, 40);
    assert_eq!(merged.failed_batches, 0);
    let summed: u64 = coord.shard_counters().iter().map(|s| s.requests).sum();
    assert_eq!(summed, 40);
}

#[test]
fn per_model_fifo_is_preserved_at_every_shard_count() {
    for shards in [1usize, 2, 4, 5] {
        let registry = four_model_registry();
        let coord = pool(&registry, shards);
        let mut rng = Rng::new(13);
        let mut rxs = Vec::new();
        for i in 0..60usize {
            let name = MODELS[i % MODELS.len()];
            let rx = coord.submit_to(name, render_digit(&mut rng, i % 10, 0.05)).unwrap();
            rxs.push((name, i, rx));
        }
        // receive in submission order: within one model, the serving
        // batch sequence must never go backwards — a later request in an
        // earlier batch would be a FIFO violation
        let mut last: BTreeMap<&str, (usize, u64)> = BTreeMap::new();
        for (name, i, rx) in rxs {
            let resp = rx.recv().unwrap().expect("inference failed");
            if let Some(&(shard, seq)) = last.get(name) {
                assert_eq!(resp.shard, shard, "'{name}' moved shards ({shards} shards)");
                assert!(
                    resp.batch_seq >= seq,
                    "model '{name}' request {i}: batch_seq {} after {} \
                     ({shards} shards) — FIFO violated",
                    resp.batch_seq,
                    seq
                );
            }
            last.insert(name, (resp.shard, resp.batch_seq));
        }
    }
}

#[test]
fn no_batch_mixes_models_at_any_shard_count() {
    for shards in [1usize, 2, 4] {
        let registry = four_model_registry();
        let coord = pool(&registry, shards);
        let mut rng = Rng::new(17);
        // hold every receiver while submitting so queues for different
        // models overlap inside each shard
        let mut rxs = Vec::new();
        for i in 0..80usize {
            let name = MODELS[i % MODELS.len()];
            let rx = coord.submit_to(name, render_digit(&mut rng, i % 10, 0.05)).unwrap();
            rxs.push((name, rx));
        }
        // a batch is identified by (shard, batch_seq); every response in
        // it must name the same model
        let mut batch_model: BTreeMap<(usize, u64), &str> = BTreeMap::new();
        for (name, rx) in rxs {
            let resp = rx.recv().unwrap().expect("inference failed");
            assert_eq!(resp.model.as_deref(), Some(name));
            match batch_model.get(&(resp.shard, resp.batch_seq)) {
                Some(&m) => assert_eq!(
                    m, name,
                    "batch (shard {}, seq {}) mixed '{m}' and '{name}' ({shards} shards)",
                    resp.shard, resp.batch_seq
                ),
                None => {
                    batch_model.insert((resp.shard, resp.batch_seq), name);
                }
            }
        }
        // the engine hard-errors mixed batches; none may have fired
        assert_eq!(coord.metrics().failed_batches, 0, "{shards} shards");
    }
}

#[test]
fn hot_swap_becomes_visible_on_the_owning_shard() {
    let registry = four_model_registry();
    let coord = pool(&registry, 4);
    // gamma and delta live on different shards (FNV-1a: 2 vs 1)
    assert_ne!(coord.shard_for(Some("gamma")), coord.shard_for(Some("delta")));

    let img = render_digit(&mut Rng::new(3), 3, 0.05);
    let before_g = coord.infer_model("gamma", img.clone()).unwrap();
    let before_d = coord.infer_model("delta", img.clone()).unwrap();

    let v2 = encoded(99, 16);
    registry.insert("gamma", v2.clone());

    // the owning shard serves the new weights on its next batch...
    let after_g = coord.infer_model("gamma", img.clone()).unwrap();
    assert_ne!(
        bits(&before_g.logits),
        bits(&after_g.logits),
        "hot-swapped model must serve different weights"
    );
    assert_eq!(
        bits(&after_g.logits),
        bits(&v2.forward(&img, ConvVariant::Pasm)),
        "post-swap logits must be bit-exact to the new model"
    );
    // ...and the other shards are untouched
    let after_d = coord.infer_model("delta", img.clone()).unwrap();
    assert_eq!(bits(&before_d.logits), bits(&after_d.logits));
}

#[test]
fn unnamed_traffic_follows_the_default_model() {
    let registry = four_model_registry();
    let coord = CoordinatorBuilder::new()
        .registry(Arc::clone(&registry))
        .default_model("delta")
        .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
        .shards(4)
        .build()
        .unwrap();
    assert_eq!(coord.shard_for(None), coord.shard_for(Some("delta")));

    let resp = coord.infer(render_digit(&mut Rng::new(5), 2, 0.05)).unwrap();
    assert_eq!(resp.model.as_deref(), Some("delta"));
    assert_eq!(resp.shard, coord.shard_for(Some("delta")));
}

#[test]
fn shutdown_drains_every_shard() {
    let registry = four_model_registry();
    // a bucket that cannot fill and a long wait budget: every request
    // parks in its shard's queue until shutdown forces the flush
    let coord = CoordinatorBuilder::new()
        .registry(Arc::clone(&registry))
        .batch_policy(BatchPolicy::new(vec![8], Duration::from_secs(5)))
        .shards(4)
        .build()
        .unwrap();
    let mut rng = Rng::new(23);
    let mut rxs = Vec::new();
    for i in 0..12usize {
        let name = MODELS[i % MODELS.len()];
        let rx = coord.submit_to(name, render_digit(&mut rng, i % 10, 0.05)).unwrap();
        rxs.push((name, rx));
    }
    drop(coord); // shutdown must flush all four shards, losing nothing
    for (i, (name, rx)) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|_| panic!("request {i} to '{name}' was dropped at shutdown"));
        let resp = resp.unwrap_or_else(|e| panic!("request {i} to '{name}' failed: {e}"));
        assert_eq!(resp.model.as_deref(), Some(name));
    }
}

#[test]
fn zero_shards_is_a_startup_error() {
    let err = CoordinatorBuilder::new()
        .backend(NativeBackend::new(encoded(1, 4)))
        .shards(0)
        .build()
        .unwrap_err();
    assert!(format!("{err:#}").contains("shard"), "error should name the problem: {err:#}");
}

/// A backend that works but cannot be replicated (the default
/// `ExecutionBackend::replicate` returns `None`), standing in for
/// single-instance resources like an AOT runtime handle.
struct SingleInstance(NativeBackend);

impl ExecutionBackend for SingleInstance {
    fn name(&self) -> &'static str {
        "single-instance"
    }
    fn encoded(&self) -> &EncodedCnn {
        self.0.encoded()
    }
    fn compile(&self, batch: usize) -> anyhow::Result<Box<dyn Executable>> {
        self.0.compile(batch)
    }
}

#[test]
fn non_replicable_backend_explicit_shards_errors_default_degrades() {
    // explicitly asking for a pool the backend cannot populate fails loudly
    let err = CoordinatorBuilder::new()
        .backend(SingleInstance(NativeBackend::new(encoded(2, 4))))
        .shards(2)
        .build()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("replicated"), "unhelpful error: {msg}");

    // under the default shard count (multi-shard once a registry is
    // attached) the pool degrades to one shard and serves
    let registry = four_model_registry();
    let coord = CoordinatorBuilder::new()
        .backend(SingleInstance(NativeBackend::new(encoded(2, 4))))
        .registry(Arc::clone(&registry))
        .build()
        .unwrap();
    assert_eq!(coord.shards(), 1);
    let resp = coord.infer(render_digit(&mut Rng::new(7), 4, 0.05)).unwrap();
    assert_eq!(resp.logits.len(), 10);
    assert_eq!(resp.shard, 0);
}

#[test]
fn per_model_fifo_is_preserved_under_active_stealing() {
    // with eager donation idle shards steal the hot model's formed
    // batches; the FIFO witness must survive the handoff because the
    // home shard is the only batch former and stamps batch_seq before
    // the batch ever reaches the deck. Whether a particular batch gets
    // stolen is a race, so retry fresh pools until at least one was.
    for shards in [2usize, 4, 5] {
        let mut stole = 0u64;
        for _attempt in 0..5 {
            let registry = four_model_registry();
            let coord = steal_pool(&registry, shards);
            let mut rng = Rng::new(29);
            let mut rxs = Vec::new();
            for i in 0..96usize {
                let name = hot_skewed(i);
                let rx = coord.submit_to(name, render_digit(&mut rng, i % 10, 0.05)).unwrap();
                rxs.push((name, i, rx));
            }
            let mut last: BTreeMap<&str, u64> = BTreeMap::new();
            for (name, i, rx) in rxs {
                let resp = rx.recv().unwrap().expect("inference failed");
                // `shard` names the home even when a thief executed
                assert_eq!(
                    resp.shard,
                    coord.shard_for(Some(name)),
                    "'{name}' reported off its home shard ({shards} shards)"
                );
                if resp.executed_by != resp.shard {
                    stole += 1;
                }
                if let Some(&seq) = last.get(name) {
                    assert!(
                        resp.batch_seq >= seq,
                        "model '{name}' request {i}: batch_seq {} after {} \
                         ({shards} shards) — FIFO violated under stealing",
                        resp.batch_seq,
                        seq
                    );
                }
                last.insert(name, resp.batch_seq);
            }
            assert_eq!(coord.metrics().failed_batches, 0, "{shards} shards");
            if stole >= 1 {
                break;
            }
        }
        assert!(stole >= 1, "no steal observed in 5 attempts at {shards} shards");
    }
}

#[test]
fn stolen_batches_never_mix_models_and_have_one_executor() {
    let registry = four_model_registry();
    let coord = steal_pool(&registry, 4);
    let mut rng = Rng::new(31);
    // hold every receiver while submitting so queues overlap and the
    // deck sees real contention between home pops and thief pops
    let mut rxs = Vec::new();
    for i in 0..80usize {
        let name = MODELS[i % MODELS.len()];
        let rx = coord.submit_to(name, render_digit(&mut rng, i % 10, 0.05)).unwrap();
        rxs.push((name, rx));
    }
    // a batch is identified by (home shard, batch_seq) no matter who
    // executes it; every response in it must agree on both the model
    // and the executing shard
    let mut batch_ident: BTreeMap<(usize, u64), (&str, usize)> = BTreeMap::new();
    for (name, rx) in rxs {
        let resp = rx.recv().unwrap().expect("inference failed");
        assert_eq!(resp.model.as_deref(), Some(name));
        match batch_ident.get(&(resp.shard, resp.batch_seq)) {
            Some(&(m, ex)) => {
                assert_eq!(
                    m, name,
                    "batch (shard {}, seq {}) mixed '{m}' and '{name}' under stealing",
                    resp.shard, resp.batch_seq
                );
                assert_eq!(
                    ex, resp.executed_by,
                    "batch (shard {}, seq {}) split across executors {ex} and {}",
                    resp.shard, resp.batch_seq, resp.executed_by
                );
            }
            None => {
                batch_ident.insert((resp.shard, resp.batch_seq), (name, resp.executed_by));
            }
        }
    }
    assert_eq!(coord.metrics().failed_batches, 0);
}

#[test]
fn steal_off_is_bit_for_bit_the_legacy_routing() {
    // sequential single-model traffic is fully deterministic: one batch
    // per request, formed and executed at home, batch_seq counting up
    // from 0. A pool with stealing explicitly off must reproduce the
    // default pool exactly — same attribution, same sequence, same bits.
    let registry = four_model_registry();
    let legacy = pool(&registry, 4);
    let explicit = CoordinatorBuilder::new()
        .registry(Arc::clone(&registry))
        .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
        .shards(4)
        .steal(false)
        .steal_promote_us(0)
        .build()
        .unwrap();
    // gamma is alone on its shard at 4 shards, so its batch sequence is
    // not interleaved with any other model's
    let home = legacy.shard_for(Some("gamma"));
    assert_eq!(explicit.shard_for(Some("gamma")), home);

    let mut rng = Rng::new(37);
    for i in 0..6u64 {
        let img = render_digit(&mut rng, (i as usize) % 10, 0.05);
        let a = legacy.infer_model("gamma", img.clone()).unwrap();
        let b = explicit.infer_model("gamma", img).unwrap();
        for r in [&a, &b] {
            assert_eq!(r.shard, home);
            assert_eq!(r.executed_by, home, "steal-off must never execute off-home");
            assert_eq!(r.batch_seq, i);
        }
        assert_eq!(bits(&a.logits), bits(&b.logits));
    }
    for c in [&legacy, &explicit] {
        let m = c.metrics();
        assert_eq!(m.stolen_batches, 0);
        assert_eq!(m.donated_batches, 0);
        assert_eq!(m.replicas_installed, 0);
        assert_eq!(m.replicas_evicted, 0);
    }
}

#[test]
fn per_shard_counters_sum_exactly_to_merged_totals_under_stealing() {
    // execute-stage counts land on the executing shard and queue-side
    // counts on the home shard; each event is attributed exactly once,
    // so per-shard counters must sum to the merged snapshot even while
    // batches migrate between shards mid-flight
    let mut stole = 0u64;
    for _attempt in 0..5 {
        let registry = four_model_registry();
        let coord = steal_pool(&registry, 4);
        let mut rng = Rng::new(41);
        let mut rxs = Vec::new();
        for i in 0..96usize {
            let name = hot_skewed(i);
            rxs.push(coord.submit_to(name, render_digit(&mut rng, i % 10, 0.05)).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap().expect("inference failed");
        }
        let merged = coord.metrics();
        let shards = coord.shard_counters();
        assert_eq!(shards.iter().map(|s| s.requests).sum::<u64>(), merged.requests);
        assert_eq!(merged.requests, 96);
        assert_eq!(shards.iter().map(|s| s.batches).sum::<u64>(), merged.batches);
        assert_eq!(shards.iter().map(|s| s.failed_batches).sum::<u64>(), merged.failed_batches);
        assert_eq!(shards.iter().map(|s| s.stolen_batches).sum::<u64>(), merged.stolen_batches);
        assert_eq!(
            shards.iter().map(|s| s.donated_batches).sum::<u64>(),
            merged.donated_batches
        );
        // every stolen batch was donated by exactly one home shard
        assert_eq!(merged.stolen_batches, merged.donated_batches);
        assert_eq!(merged.failed_batches, 0);
        stole = merged.stolen_batches;
        if stole >= 1 {
            break;
        }
    }
    assert!(stole >= 1, "no steal observed in 5 attempts — counters unexercised");
}

#[test]
fn plain_backend_defaults_to_one_shard() {
    // without a registry there is exactly one routable model: the
    // default pool must not spawn workers that can never receive traffic
    let coord = CoordinatorBuilder::new()
        .backend(NativeBackend::new(encoded(1, 4)))
        .build()
        .unwrap();
    assert_eq!(coord.shards(), 1);
}
