//! Differential suite pinning every compiled kernel to the golden
//! reference (hand-rolled generator loops over [`common::rng::TestRng`],
//! which prints its seed so any failure reproduces in isolation —
//! proptest is not available in the offline build).
//!
//! Invariants:
//! * `CompiledCnn` fixed-point forward is **bit-identical** to
//!   `EncodedCnn::forward_fx` for random architectures, bin counts, weight
//!   formats and images, for both `ConvVariant`s, across variants (paper
//!   §5.3 lifted through the plan), and for **every `KernelChoice`** —
//!   per-tap and histogram (count-then-multiply) fx kernels agree with
//!   the reference and with each other bit for bit.
//! * `CompiledCnn` f32 forward is bit-identical to `EncodedCnn::forward`
//!   under every kernel choice (the histogram f32 kernel replays the
//!   per-bin IEEE addition sequence exactly; see `cnn::plan` docs).
//! * The bit-equalities survive adversarial inputs — denormals,
//!   max-magnitude activations (saturating `QFormat::encode` keeps the
//!   overflow proof's `max_raw` assumption honest), all-zero images —
//!   and degenerate codebooks (single-bin, max-B) and odd `QFormat`s.
//! * A plan whose accumulator bound fails compiles onto the checked-add
//!   fallback and still matches the reference at full-network scale, for
//!   both fx kernel families.
//! * The multi-threaded `NativeBackend` batch path is bit-identical to the
//!   single-threaded one at every thread count and occupancy, under every
//!   kernel choice.

mod common;

use common::rng::{bits, encode_arch, random_encoded, random_image, TestRng};
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::cnn::plan::{CompiledCnn, KernelChoice};
use pasm_accel::coordinator::{ExecutionBackend, NativeBackend, NativePrecision};
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::tensor::Tensor;

const ALL_CHOICES: [KernelChoice; 3] =
    [KernelChoice::PerTap, KernelChoice::Histogram, KernelChoice::Auto];

/// Compile `enc` once per kernel choice, paired with its label for
/// assertion messages.
fn plans_for(enc: &EncodedCnn, iq: QFormat) -> Vec<(KernelChoice, CompiledCnn)> {
    ALL_CHOICES
        .iter()
        .map(|&choice| {
            let plan = CompiledCnn::compile_with(enc, iq, choice)
                .unwrap_or_else(|e| panic!("{choice:?} plan compiles: {e}"));
            (choice, plan)
        })
        .collect()
}

/// Assert every kernel choice reproduces the reference logits bit for bit
/// on `img`, for both variants and both numeric modes, at `iq`.
fn assert_all_kernels_match_reference(
    enc: &EncodedCnn,
    plans: &[(KernelChoice, CompiledCnn)],
    img: &Tensor<f32>,
    iq: QFormat,
    ctx: &str,
) {
    for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
        let want_fx = bits(&enc.forward_fx(img, variant, iq));
        let want_f32 = bits(&enc.forward(img, variant));
        for (choice, plan) in plans {
            assert_eq!(
                bits(&plan.forward_fx(img, variant)),
                want_fx,
                "{ctx} {variant:?} {choice:?} fx"
            );
            assert_eq!(
                bits(&plan.forward_f32(img, variant)),
                want_f32,
                "{ctx} {variant:?} {choice:?} f32"
            );
        }
    }
}

#[test]
fn prop_plan_fx_bitexact_reference_all_kernels() {
    for case_i in 0..15 {
        let mut rng = TestRng::case(9001, case_i);
        let enc = random_encoded(&mut rng);
        let plans = plans_for(&enc, QFormat::IMAGE32);
        for img_i in 0..3 {
            let img = random_image(&mut rng, &enc.arch);
            for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
                let want = bits(&enc.forward_fx(&img, variant, QFormat::IMAGE32));
                for (choice, plan) in &plans {
                    assert_eq!(
                        bits(&plan.forward_fx(&img, variant)),
                        want,
                        "case {case_i} img {img_i} {variant:?} {choice:?}"
                    );
                }
            }
            // §5.3 through the plan: PASM ≡ WS bit for bit (every kernel
            // already matched the reference above, so one cross-variant
            // check on the reference itself closes the loop)
            assert_eq!(
                bits(&enc.forward_fx(&img, ConvVariant::Pasm, QFormat::IMAGE32)),
                bits(&enc.forward_fx(&img, ConvVariant::WeightShared, QFormat::IMAGE32)),
                "case {case_i} img {img_i} cross-variant"
            );
        }
    }
}

#[test]
fn prop_plan_f32_bitexact_reference_all_kernels() {
    for case_i in 0..15 {
        let mut rng = TestRng::case(9002, case_i);
        let enc = random_encoded(&mut rng);
        let plans = plans_for(&enc, QFormat::IMAGE32);
        for img_i in 0..3 {
            let img = random_image(&mut rng, &enc.arch);
            for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
                let want = bits(&enc.forward(&img, variant));
                for (choice, plan) in &plans {
                    assert_eq!(
                        bits(&plan.forward_f32(&img, variant)),
                        want,
                        "case {case_i} img {img_i} {variant:?} {choice:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_plan_adversarial_inputs_and_codebooks_bitexact() {
    // degenerate codebooks × odd formats × hostile images, every kernel:
    // single-bin (B=1) collapses the histogram to one partial sum, B=64 is
    // the sweep maximum, and the image sets probe IEEE denormals,
    // saturation (max-magnitude activations rely on `QFormat::encode`
    // clamping to `max_raw`, which is what the overflow proof assumed),
    // and the all-zero fast-path.
    let arch = DigitsCnn { in_side: 11, conv1_m: 2, conv2_m: 3, kernel: 3, classes: 4 };
    let side = arch.in_side;
    let images: Vec<(&str, Tensor<f32>)> = vec![
        ("zeros", Tensor::from_fn(&[1, side, side], |_| 0.0)),
        (
            "denormals",
            Tensor::from_fn(&[1, side, side], |i| {
                let tiny = f32::from_bits((i as u32 % 7) + 1); // subnormal
                if i % 2 == 0 {
                    tiny
                } else {
                    -tiny
                }
            }),
        ),
        (
            "max-magnitude",
            Tensor::from_fn(
                &[1, side, side],
                |i| if i % 2 == 0 { f32::MAX } else { f32::MIN },
            ),
        ),
    ];
    let mut case_i = 0;
    for bins in [1usize, 64] {
        for wq in [QFormat::W8, QFormat::new(12, 6), QFormat::W32] {
            for iq in [QFormat::IMAGE32, QFormat::new(16, 8)] {
                let mut rng = TestRng::case(9005, case_i);
                case_i += 1;
                let enc = encode_arch(&mut rng, arch, bins, wq);
                let plans = plans_for(&enc, iq);
                let ctx_base = format!("bins {bins} wq {wq:?} iq {iq:?}");
                for (name, img) in &images {
                    let ctx = format!("{ctx_base} {name}");
                    assert_all_kernels_match_reference(&enc, &plans, img, iq, &ctx);
                }
                // and one random image per config, for contrast
                let img = random_image(&mut rng, &arch);
                assert_all_kernels_match_reference(&enc, &plans, &img, iq, &ctx_base);
            }
        }
    }
}

#[test]
fn prop_unprovable_plan_checked_fallback_bitexact_full_net() {
    // Defeat the conv1 accumulator bound at network scale: conv1 weights
    // scaled so the W32 codebook saturates near max_raw, making the
    // plan-time worst case (taps × max_img × max_cb) exceed i64 — the
    // checked-add instantiations of *both* fx kernel families must
    // execute and still match the reference bit for bit.  Inputs stay
    // small (|x| <= 0.5) so the *actual* sums never overflow; conv2 keeps
    // ordinary weights and stays proven.
    for case_i in 0..4 {
        let mut rng = TestRng::case(9006, case_i);
        // kernel pinned to 3: at 9 taps the saturated codebook pushes the
        // worst case past i64 (a 1×1 kernel's single tap would still prove)
        let arch = DigitsCnn {
            in_side: 11 + rng.below(4),
            conv1_m: 1 + rng.below(4),
            conv2_m: 1 + rng.below(4),
            kernel: 3,
            classes: 2 + rng.below(5),
        };
        let mut prng = rng.child();
        let mut params = arch.init(&mut prng);
        for w in params.conv1_w.data_mut() {
            *w *= 1.0e6; // saturates to ±32768 under W32 encode
        }
        let enc = EncodedCnn::encode(arch, &params, 4, QFormat::W32);
        let plans = plans_for(&enc, QFormat::IMAGE32);
        for (choice, plan) in &plans {
            let (conv1, conv2) = plan.layers();
            assert!(!conv1.proved_no_overflow(), "{choice:?} conv1 bound must fail");
            assert!(conv2.proved_no_overflow(), "{choice:?} conv2 bound must hold");
        }
        let img = Tensor::from_fn(&[1, arch.in_side, arch.in_side], |_| rng.signed() * 0.5);
        assert_all_kernels_match_reference(
            &enc,
            &plans,
            &img,
            QFormat::IMAGE32,
            &format!("case {case_i}"),
        );
    }
}

#[test]
fn prop_parallel_batch_bitexact_single_threaded_all_kernels() {
    for case_i in 0..8 {
        let mut rng = TestRng::case(9003, case_i);
        let enc = random_encoded(&mut rng);
        let arch = enc.arch;
        let batch = 1 + rng.below(16);
        let live = 1 + rng.below(batch);
        let img_len = arch.in_side * arch.in_side;
        let mut data = vec![0f32; batch * img_len];
        for i in 0..live {
            let img = random_image(&mut rng, &arch);
            data[i * img_len..(i + 1) * img_len].copy_from_slice(img.data());
        }
        let padded = Tensor::from_vec(&[batch, 1, arch.in_side, arch.in_side], data);
        for precision in [NativePrecision::F32, NativePrecision::Fixed(QFormat::IMAGE32)] {
            let run = |choice: KernelChoice, threads: usize| -> Vec<u32> {
                let exe = NativeBackend::new(enc.clone())
                    .with_precision(precision)
                    .with_kernel(choice)
                    .with_threads(threads)
                    .compile(batch)
                    .unwrap();
                bits(exe.execute(&padded, live).unwrap().data())
            };
            // one serial baseline; every kernel choice at every thread
            // count must reproduce it exactly (per-tap vs histogram
            // equality is part of the assertion, not just thread counts)
            let serial = run(KernelChoice::PerTap, 1);
            for choice in ALL_CHOICES {
                for threads in [1usize, 2, 3, 5, 16] {
                    assert_eq!(
                        run(choice, threads),
                        serial,
                        "case {case_i} {precision:?} batch {batch} live {live} \
                         {choice:?} threads {threads}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_plan_survives_scratch_reuse_across_mixed_kernels_and_variants() {
    // interleaving kernels, variants and numeric modes over one scratch
    // arena must not leak state between forwards: the histogram plan's
    // arena (the larger `scratch_len`) serves the per-tap plan too, so a
    // shared worker arena is exercised exactly as `NativeBackend` would
    // after a kernel-choice reconfiguration
    let mut rng = TestRng::new(9004);
    let enc = random_encoded(&mut rng);
    let per_tap = CompiledCnn::compile_with(&enc, QFormat::IMAGE32, KernelChoice::PerTap).unwrap();
    let hist = CompiledCnn::compile_with(&enc, QFormat::IMAGE32, KernelChoice::Histogram).unwrap();
    let mut scratch = hist.scratch();
    let mut logits = vec![0f32; hist.classes()];
    for i in 0..12 {
        let img = random_image(&mut rng, &enc.arch);
        let plan = if i % 4 < 2 { &hist } else { &per_tap };
        let variant = if i % 2 == 0 {
            ConvVariant::Pasm
        } else {
            ConvVariant::WeightShared
        };
        plan.forward_fx_into(img.data(), variant, &mut scratch, &mut logits);
        let want = enc.forward_fx(&img, variant, QFormat::IMAGE32);
        assert_eq!(bits(&logits), bits(&want), "fx iteration {i}");
        plan.forward_f32_into(img.data(), variant, &mut scratch, &mut logits);
        let want = enc.forward(&img, variant);
        assert_eq!(bits(&logits), bits(&want), "f32 iteration {i}");
    }
}
