//! Property tests pinning the compiled plan to the golden reference
//! (hand-rolled generator loop, deterministic seeds — proptest is not
//! available in the offline build).
//!
//! Invariants:
//! * `CompiledCnn` fixed-point forward is **bit-identical** to
//!   `EncodedCnn::forward_fx` for random architectures, bin counts, weight
//!   formats and images, for both `ConvVariant`s (and across variants —
//!   paper §5.3 lifted through the plan).
//! * `CompiledCnn` f32 forward is bit-identical to `EncodedCnn::forward`.
//! * The multi-threaded `NativeBackend` batch path is bit-identical to the
//!   single-threaded one at every thread count and occupancy.

use pasm_accel::cnn::data::Rng;
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::cnn::plan::CompiledCnn;
use pasm_accel::coordinator::{ExecutionBackend, NativeBackend, NativePrecision};
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::tensor::Tensor;

/// Random digits-CNN architecture.  Constraint: the pooled conv1 output
/// must still fit the conv2 kernel, i.e. `(in_side - kernel + 1) / 2 >=
/// kernel`.
fn random_arch(rng: &mut Rng) -> DigitsCnn {
    let kernel = 1 + 2 * rng.below(2); // 1 or 3
    let in_side = kernel * 2 + 5 + rng.below(6);
    DigitsCnn {
        in_side,
        conv1_m: 1 + rng.below(6),
        conv2_m: 1 + rng.below(8),
        kernel,
        classes: 2 + rng.below(9),
    }
}

fn random_encoded(rng: &mut Rng) -> EncodedCnn {
    let arch = random_arch(rng);
    let mut prng = Rng::new(rng.next_u64());
    let params = arch.init(&mut prng);
    let bins = 1usize << (1 + rng.below(6));
    let wq = [QFormat::W8, QFormat::W16, QFormat::W32][rng.below(3)];
    EncodedCnn::encode(arch, &params, bins, wq)
}

fn random_image(rng: &mut Rng, arch: &DigitsCnn) -> Tensor<f32> {
    Tensor::from_fn(&[1, arch.in_side, arch.in_side], |_| rng.signed() * 2.0)
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prop_plan_fx_bitexact_reference() {
    let mut rng = Rng::new(9001);
    for case_i in 0..15 {
        let enc = random_encoded(&mut rng);
        let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).expect("plan compiles");
        for img_i in 0..3 {
            let img = random_image(&mut rng, &enc.arch);
            let mut per_variant = Vec::new();
            for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
                let got = plan.forward_fx(&img, variant);
                let want = enc.forward_fx(&img, variant, QFormat::IMAGE32);
                assert_eq!(
                    bits(&got),
                    bits(&want),
                    "case {case_i} img {img_i} {variant:?}"
                );
                per_variant.push(bits(&got));
            }
            // §5.3 through the plan: PASM ≡ WS bit for bit
            assert_eq!(per_variant[0], per_variant[1], "case {case_i} img {img_i}");
        }
    }
}

#[test]
fn prop_plan_f32_bitexact_reference() {
    let mut rng = Rng::new(9002);
    for case_i in 0..15 {
        let enc = random_encoded(&mut rng);
        let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).expect("plan compiles");
        for img_i in 0..3 {
            let img = random_image(&mut rng, &enc.arch);
            for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
                let got = plan.forward_f32(&img, variant);
                let want = enc.forward(&img, variant);
                assert_eq!(
                    bits(&got),
                    bits(&want),
                    "case {case_i} img {img_i} {variant:?}"
                );
            }
        }
    }
}

#[test]
fn prop_parallel_batch_bitexact_single_threaded() {
    let mut rng = Rng::new(9003);
    for case_i in 0..10 {
        let enc = random_encoded(&mut rng);
        let arch = enc.arch;
        let batch = 1 + rng.below(16);
        let live = 1 + rng.below(batch);
        let img_len = arch.in_side * arch.in_side;
        let mut data = vec![0f32; batch * img_len];
        for i in 0..live {
            let img = random_image(&mut rng, &arch);
            data[i * img_len..(i + 1) * img_len].copy_from_slice(img.data());
        }
        let padded = Tensor::from_vec(&[batch, 1, arch.in_side, arch.in_side], data);
        for precision in [NativePrecision::F32, NativePrecision::Fixed(QFormat::IMAGE32)] {
            let run = |threads: usize| -> Vec<u32> {
                let exe = NativeBackend::new(enc.clone())
                    .with_precision(precision)
                    .with_threads(threads)
                    .compile(batch)
                    .unwrap();
                bits(exe.execute(&padded, live).unwrap().data())
            };
            let serial = run(1);
            for threads in [2usize, 3, 5, 16] {
                assert_eq!(
                    run(threads),
                    serial,
                    "case {case_i} {precision:?} batch {batch} live {live} threads {threads}"
                );
            }
        }
    }
}

#[test]
fn prop_plan_survives_scratch_reuse_across_mixed_variants() {
    // interleaving variants and numeric modes over one scratch arena must
    // not leak state between forwards
    let mut rng = Rng::new(9004);
    let enc = random_encoded(&mut rng);
    let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
    let mut scratch = plan.scratch();
    let mut logits = vec![0f32; plan.classes()];
    for i in 0..12 {
        let img = random_image(&mut rng, &enc.arch);
        let variant = if i % 2 == 0 {
            ConvVariant::Pasm
        } else {
            ConvVariant::WeightShared
        };
        plan.forward_fx_into(img.data(), variant, &mut scratch, &mut logits);
        let want = enc.forward_fx(&img, variant, QFormat::IMAGE32);
        assert_eq!(bits(&logits), bits(&want), "fx iteration {i}");
        plan.forward_f32_into(img.data(), variant, &mut scratch, &mut logits);
        let want = enc.forward(&img, variant);
        assert_eq!(bits(&logits), bits(&want), "f32 iteration {i}");
    }
}
