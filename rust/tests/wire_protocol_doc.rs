//! Keeps `docs/WIRE_PROTOCOL.md` normative: every ```json example frame
//! in the spec must decode to a valid frame and re-encode **byte for
//! byte** — so a drifted field name, a non-canonical key order, or a
//! float that doesn't round-trip fails the build, not a reader.

use pasm_accel::serving::proto;
use std::collections::BTreeSet;

const SPEC: &str = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/WIRE_PROTOCOL.md"));

/// Every ```json fenced block in the spec, one example frame per block.
fn example_frames() -> Vec<String> {
    let mut frames = Vec::new();
    let mut in_block = false;
    let mut current = String::new();
    for line in SPEC.lines() {
        let trimmed = line.trim();
        if in_block {
            if trimmed == "```" {
                in_block = false;
                frames.push(std::mem::take(&mut current));
            } else {
                if !current.is_empty() {
                    current.push('\n');
                }
                current.push_str(trimmed);
            }
        } else if trimmed == "```json" {
            in_block = true;
        }
    }
    assert!(!in_block, "unterminated ```json block in WIRE_PROTOCOL.md");
    frames
}

#[test]
fn every_documented_example_round_trips_byte_for_byte() {
    let frames = example_frames();
    assert!(
        frames.len() >= 10,
        "expected at least one example per frame type, found {}",
        frames.len()
    );
    let mut seen_types = BTreeSet::new();
    for (i, example) in frames.iter().enumerate() {
        assert!(
            !example.contains('\n'),
            "example {i} spans multiple lines; canonical frames are one line:\n{example}"
        );
        let frame = proto::decode(example.as_bytes())
            .unwrap_or_else(|e| panic!("example {i} does not decode ({e}):\n{example}"));
        let encoded = String::from_utf8(proto::encode(&frame)).unwrap();
        assert_eq!(
            encoded, *example,
            "example {i} ({}) is not in canonical encoding",
            frame.type_str()
        );
        seen_types.insert(frame.type_str());
    }
    for required in [
        "infer",
        "infer_ok",
        "error",
        "list_models",
        "models",
        "get_metrics",
        "metrics",
        "ping",
        "pong",
        "hello",
        "hello_ok",
        "get_trace",
        "trace",
    ] {
        assert!(
            seen_types.contains(required),
            "WIRE_PROTOCOL.md documents no '{required}' example"
        );
    }
}

#[test]
fn spec_documents_every_error_code() {
    use proto::ErrorCode::*;
    for code in [
        InvalidFrame,
        UnsupportedVersion,
        UnknownType,
        BadImage,
        UnknownModel,
        ResourceExhausted,
        DeadlineExceeded,
        Unavailable,
        ShuttingDown,
        Internal,
    ] {
        assert!(
            SPEC.contains(code.as_str()),
            "WIRE_PROTOCOL.md does not mention error code {}",
            code.as_str()
        );
    }
}

#[test]
fn spec_documents_every_trace_stage() {
    use pasm_accel::obs::Stage;
    for stage in [
        Stage::Accepted,
        Stage::Decoded,
        Stage::Enqueued,
        Stage::BatchFormed,
        Stage::Launched,
        Stage::Executed,
        Stage::ReplyWritten,
        Stage::DeadlineDrop,
        Stage::Fault,
        Stage::Retried,
        Stage::Stolen,
    ] {
        assert!(
            SPEC.contains(&format!("`{}`", stage.as_str())),
            "WIRE_PROTOCOL.md does not document trace stage {}",
            stage.as_str()
        );
    }
}

#[test]
fn spec_states_the_current_protocol_version() {
    assert!(
        SPEC.contains(&format!("`\"v\": {}`", proto::PROTOCOL_VERSION)),
        "WIRE_PROTOCOL.md must state the current protocol version"
    );
}
