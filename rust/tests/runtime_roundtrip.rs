//! Integration: AOT artifacts (JAX/Pallas -> HLO text) executed via PJRT
//! must match the rust functional dataflows — the cross-layer correctness
//! proof that L1/L2 and L3 compute the same convolution.
//!
//! Requires `make artifacts` and the `pjrt` cargo feature (part of the
//! prescribed `make test` flow; compiled out of the default build).
#![cfg(feature = "pjrt")]

use pasm_accel::cnn::conv::{pasm_conv_f32, ws_conv_f32};
use pasm_accel::cnn::data::Rng;
use pasm_accel::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::runtime::client::ModelParams;
use pasm_accel::runtime::Runtime;
use pasm_accel::tensor::Tensor;

fn tile_case(seed: u64, bins: usize) -> (Tensor<f32>, Tensor<u16>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let image = Tensor::from_fn(&[15, 5, 5], |_| rng.signed() * 2.0);
    let bin_idx = Tensor::from_fn(&[2, 15, 3, 3], |_| rng.below(bins) as u16);
    let codebook: Vec<f32> = (0..bins).map(|_| rng.signed()).collect();
    (image, bin_idx, codebook)
}

fn max_abs_diff(a: &Tensor<f32>, b: &Tensor<f32>) -> f32 {
    a.max_abs_diff(b)
}

#[test]
fn pasm_tile_matches_rust_reference() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts` first");
    let tile = rt.load_tile("pasm_tile").unwrap();
    for seed in [1u64, 2, 3] {
        let (image, bin_idx, cb) = tile_case(seed, tile.bins);
        let got = tile.run(&image, &bin_idx, &cb).unwrap();
        let want = pasm_conv_f32(&image, &bin_idx, &cb, 1);
        assert!(
            max_abs_diff(&got, &want) < 1e-3,
            "seed {seed}: diff {}",
            max_abs_diff(&got, &want)
        );
    }
}

#[test]
fn ws_tile_matches_rust_reference_and_pasm_tile() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts` first");
    let ws = rt.load_tile("ws_tile").unwrap();
    let pasm = rt.load_tile("pasm_tile").unwrap();
    let (image, bin_idx, cb) = tile_case(7, ws.bins);
    let got_ws = ws.run(&image, &bin_idx, &cb).unwrap();
    let got_pasm = pasm.run(&image, &bin_idx, &cb).unwrap();
    let want = ws_conv_f32(&image, &bin_idx, &cb, 1);
    assert!(max_abs_diff(&got_ws, &want) < 1e-3);
    // paper §5.3: identical results between the two accelerators
    assert!(max_abs_diff(&got_ws, &got_pasm) < 1e-3);
}

#[test]
fn model_artifact_matches_rust_forward() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts` first");
    let exe = rt.load_model(1).unwrap();

    // random encoded network + one digit image
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(11);
    let params = arch.init(&mut rng);
    let enc = EncodedCnn::encode(arch, &params, 16, QFormat::W32);
    let img = pasm_accel::cnn::data::render_digit(&mut rng, 4, 0.05);

    let batch = Tensor::from_vec(
        &[1, 1, 12, 12],
        img.data().to_vec(),
    );
    let logits = exe.run(&batch, &ModelParams::from_encoded(&enc)).unwrap();
    let want = enc.forward(&img, ConvVariant::Pasm);

    for (i, (&got, &w)) in logits.data().iter().zip(want.iter()).enumerate() {
        assert!(
            (got - w).abs() < 1e-2,
            "logit {i}: pjrt {got} vs rust {w}"
        );
    }
}

#[test]
fn model_batch8_rows_independent() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts` first");
    let exe = rt.load_model(8).unwrap();

    let arch = DigitsCnn::default();
    let mut rng = Rng::new(13);
    let params = arch.init(&mut rng);
    let enc = EncodedCnn::encode(arch, &params, 16, QFormat::W32);
    let mp = ModelParams::from_encoded(&enc);

    let mut data = Vec::new();
    let mut imgs = Vec::new();
    for d in 0..8usize {
        let img = pasm_accel::cnn::data::render_digit(&mut rng, d % 10, 0.05);
        data.extend_from_slice(img.data());
        imgs.push(img);
    }
    let batch = Tensor::from_vec(&[8, 1, 12, 12], data);
    let logits = exe.run(&batch, &mp).unwrap();

    for (i, img) in imgs.iter().enumerate() {
        let want = enc.forward(img, ConvVariant::Pasm);
        for (j, &w) in want.iter().enumerate() {
            let got = logits.data()[i * 10 + j];
            assert!((got - w).abs() < 1e-2, "row {i} logit {j}: {got} vs {w}");
        }
    }
}
