//! Property-based tests (hand-rolled generator loop over
//! [`common::rng::TestRng`], which announces its seed so failures
//! reproduce from the captured output — proptest is not available in the
//! offline build).
//!
//! The central invariant is the paper's §5.3 claim: over the integers,
//! PASM, the weight-shared MAC and the decoded direct convolution are the
//! *same function*.  Plus: simulator ≡ functional dataflow, latency
//! formulas, model monotonicity, quantizer and batcher invariants, and
//! fuzzing the JSON parser.

mod common;

use common::rng::TestRng;
use pasm_accel::accel::conv::{ConvAccel, ConvVariantKind};
use pasm_accel::accel::standalone::StandaloneUnit;
use pasm_accel::cnn::conv::{
    direct_conv_f32, pasm_conv_f32, pasm_conv_fx, ws_conv_f32, ws_conv_fx, FxConvInputs,
};
use pasm_accel::coordinator::BatchPolicy;
use pasm_accel::hw::Tech;
use pasm_accel::quant::codebook::encode_weights;
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::quant::kmeans::kmeans_1d;
use pasm_accel::sim::conv::simulate_conv;
use pasm_accel::sim::standalone::{random_streams, simulate_standalone};
use pasm_accel::tensor::{ConvShape, Tensor};
use std::time::Duration;

/// Random conv case: shapes small enough for exhaustive loops but covering
/// stride, 1x1 kernels, many channels, bin counts 2..64.
struct Case {
    image: Tensor<f32>,
    weights: Tensor<f32>,
    bins: usize,
    stride: usize,
    shape: ConvShape,
}

fn random_case(rng: &mut TestRng) -> Case {
    let c = 1 + rng.below(6);
    let k = 1 + rng.below(3);
    let extra = rng.below(5);
    let stride = 1 + rng.below(2);
    let side = k + extra + 1;
    let m = 1 + rng.below(4);
    let bins = 1usize << (1 + rng.below(6));
    let image = Tensor::from_fn(&[c, side, side], |_| rng.signed() * 4.0);
    let weights = Tensor::from_fn(&[m, c, k, k], |_| rng.signed());
    let shape = ConvShape::new(c, side, side, k, k, m, stride);
    Case { image, weights, bins, stride, shape }
}

#[test]
fn prop_pasm_ws_direct_equivalent_f32() {
    let mut rng = TestRng::new(1001);
    for case_i in 0..60 {
        let case = random_case(&mut rng);
        let enc = encode_weights(&case.weights, case.bins, QFormat::W32);
        let cb = &enc.codebook.values;
        let pasm = pasm_conv_f32(&case.image, &enc.bin_idx, cb, case.stride);
        let ws = ws_conv_f32(&case.image, &enc.bin_idx, cb, case.stride);
        let direct = direct_conv_f32(&case.image, &enc.decode(), case.stride);
        assert!(pasm.max_abs_diff(&ws) < 1e-3, "case {case_i}: pasm vs ws");
        assert!(ws.max_abs_diff(&direct) < 1e-3, "case {case_i}: ws vs direct");
        assert_eq!(pasm.dims(), case.shape.out_shape().dims());
    }
}

#[test]
fn prop_pasm_ws_bitexact_fixed_point() {
    // §5.3 exactness, in integers, across the whole shape space
    let mut rng = TestRng::new(2002);
    for case_i in 0..60 {
        let case = random_case(&mut rng);
        let enc = encode_weights(&case.weights, case.bins, QFormat::W16);
        let inp = FxConvInputs::encode(&case.image, &enc, QFormat::IMAGE32, case.stride);
        assert_eq!(
            ws_conv_fx(&inp).data(),
            pasm_conv_fx(&inp).data(),
            "case {case_i}"
        );
    }
}

#[test]
fn prop_simulator_matches_functional() {
    let mut rng = TestRng::new(3003);
    for case_i in 0..25 {
        let case = random_case(&mut rng);
        let enc = encode_weights(&case.weights, case.bins, QFormat::W16);
        let inp = FxConvInputs::encode(&case.image, &enc, QFormat::IMAGE32, case.stride);
        for variant in [ConvVariantKind::WeightShared, ConvVariantKind::Pasm] {
            let accel = ConvAccel::new(variant, case.shape.clone(), case.bins, 16);
            let sim = simulate_conv(&accel, &inp);
            let want = match variant {
                ConvVariantKind::Pasm => pasm_conv_fx(&inp),
                _ => ws_conv_fx(&inp),
            };
            assert_eq!(sim.out.data(), want.data(), "case {case_i} {variant:?}");
            assert!(sim.cycles > 0);
        }
    }
}

#[test]
fn prop_standalone_sim_invariants() {
    let mut rng = TestRng::new(4004);
    for case_i in 0..20 {
        let bins = 1usize << (1 + rng.below(6));
        let n = 16 + rng.below(200);
        let streams = random_streams(rng.raw(), 16, n, bins, 1 << 16);
        let cb: Vec<i64> = (0..bins).map(|_| (rng.signed() * 1e4) as i64).collect();
        let mac = StandaloneUnit::mac16(32, bins);
        let pasm = StandaloneUnit::pas16mac4(32, bins);
        let rm = simulate_standalone(&mac, &streams, &cb);
        let rp = simulate_standalone(&pasm, &streams, &cb);
        // identical results (§5.3), exact cycle formulas (§2.2)
        assert_eq!(rm.results, rp.results, "case {case_i}");
        assert_eq!(rm.cycles, mac.stream_cycles(n as u64));
        assert_eq!(rp.cycles, pasm.stream_cycles(n as u64));
        // activities are probabilities
        assert!(rp.activity.mean() >= 0.0 && rp.activity.mean() <= 1.0);
    }
}

#[test]
fn prop_latency_model_invariants() {
    for bins in [2usize, 4, 8, 16, 32, 64] {
        let ws = ConvAccel::paper(ConvVariantKind::WeightShared, bins, 32);
        let pasm = ConvAccel::paper(ConvVariantKind::Pasm, bins, 32);
        // PASM always costs extra cycles, and the overhead grows with B
        assert!(pasm.latency_cycles_exact() > ws.latency_cycles_exact());
        let mut more_muls = pasm.clone();
        more_muls.hls = more_muls.hls.with_postpass_muls(4);
        assert!(more_muls.latency_cycles_exact() <= pasm.latency_cycles_exact());
    }
    let overhead = |b: usize| {
        let ws = ConvAccel::paper(ConvVariantKind::WeightShared, b, 32);
        let pasm = ConvAccel::paper(ConvVariantKind::Pasm, b, 32);
        pasm.latency_cycles_exact() / ws.latency_cycles_exact()
    };
    assert!(overhead(4) < overhead(8) && overhead(8) < overhead(16));
}

#[test]
fn prop_gate_model_monotonicity() {
    let t = Tech::asic_100mhz();
    // standalone units grow with W and with B
    let mut prev = 0.0;
    for w in [4u32, 8, 16, 32] {
        let g = StandaloneUnit::mac16(w, 16).gates(&t).total();
        assert!(g > prev, "W={w}");
        prev = g;
    }
    let mut prev = 0.0;
    for b in [4usize, 16, 64, 256] {
        let g = StandaloneUnit::pas16mac4(32, b).gates(&t).total();
        assert!(g > prev, "B={b}");
        prev = g;
    }
    // power is positive and leakage scales with gates
    for b in [4usize, 64] {
        let u = StandaloneUnit::pas16mac4(32, b);
        let p = u.power(&t);
        assert!(p.leakage_w > 0.0 && p.dynamic_w > 0.0);
    }
}

#[test]
fn prop_quantizer_invariants() {
    let mut rng = TestRng::new(5005);
    for case_i in 0..40 {
        let n = 4 + rng.below(400);
        let bins = 1 + rng.below(32);
        let data: Vec<f32> = (0..n).map(|_| rng.signed() * 3.0).collect();
        let r = kmeans_1d(&data, bins, 40);
        assert_eq!(r.codebook.len(), bins, "case {case_i}");
        assert!(r.codebook.iter().all(|c| c.is_finite()));
        assert!(r.assignments.iter().all(|&a| (a as usize) < bins));
        // nearest-centroid property
        for (&x, &a) in data.iter().zip(&r.assignments) {
            let d = (x - r.codebook[a as usize]).abs();
            for &c in &r.codebook {
                assert!(d <= (x - c).abs() + 1e-5, "case {case_i}");
            }
        }
        // reconstruction error bounded by data span
        let span = data.iter().cloned().fold(f32::MIN, f32::max)
            - data.iter().cloned().fold(f32::MAX, f32::min);
        assert!(r.mse.sqrt() <= span as f64 + 1e-6);
    }
}

#[test]
fn prop_batch_policy_invariants() {
    let mut rng = TestRng::new(6006);
    for _ in 0..200 {
        let mut buckets: Vec<usize> = (0..1 + rng.below(4))
            .map(|_| 1 + rng.below(32))
            .collect();
        buckets.push(1 + rng.below(32));
        let policy = BatchPolicy::new(buckets.clone(), Duration::from_millis(1));
        let queued = rng.below(64);
        let expired = rng.below(2) == 0;
        match policy.decide(queued, expired) {
            Some(bucket) => {
                assert!(policy.buckets.contains(&bucket), "bucket must be exported");
                assert!(queued > 0);
                // never launch a padded batch unless forced
                if !expired && queued < policy.max_bucket() {
                    assert_eq!(bucket, queued, "non-expired partial launch must fill exactly");
                }
            }
            None => {
                // waiting is only allowed if nothing launchable
                assert!(
                    queued == 0 || (!expired && queued < policy.max_bucket()),
                    "queued={queued} expired={expired} buckets={:?}",
                    policy.buckets
                );
            }
        }
    }
}

#[test]
fn prop_json_parser_never_panics() {
    use pasm_accel::runtime::json::parse;
    let mut rng = TestRng::new(7007);
    let alphabet: Vec<char> = r#"{}[]",:0123456789.eE+-truefalsn \u"#.chars().collect();
    for _ in 0..500 {
        let len = rng.below(64);
        let doc: String = (0..len).map(|_| alphabet[rng.below(alphabet.len())]).collect();
        let _ = parse(&doc); // must not panic, Ok or Err both fine
    }
    // and valid docs parse
    assert!(parse(r#"{"a":[1,2,3],"b":{"c":null}}"#).is_ok());
}

#[test]
fn prop_fx_encode_bounded_error() {
    // fixed-point conv vs f32 conv over the fx-rounded codebook: error
    // bounded by image quantization ulp x taps x max|w|
    let mut rng = TestRng::new(8008);
    for case_i in 0..20 {
        let case = random_case(&mut rng);
        let enc = encode_weights(&case.weights, case.bins, QFormat::W16);
        let inp = FxConvInputs::encode(&case.image, &enc, QFormat::IMAGE32, case.stride);
        let fx = ws_conv_fx(&inp);
        let scale = (1u64 << inp.out_frac()) as f32;
        let fxf = fx.map(|r| r as f32 / scale);
        let cb_fx: Vec<f32> = enc
            .codebook
            .raw()
            .iter()
            .map(|&r| enc.codebook.wq.decode(r) as f32)
            .collect();
        let f = ws_conv_f32(&case.image, &enc.bin_idx, &cb_fx, case.stride);
        let taps = case.shape.taps() as f32;
        let tol = QFormat::IMAGE32.ulp() as f32 * taps * 1.5 + 1e-3;
        assert!(fxf.max_abs_diff(&f) < tol, "case {case_i}: {}", fxf.max_abs_diff(&f));
    }
}
