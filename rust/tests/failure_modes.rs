//! Failure injection: the runtime and coordinator must fail loudly and
//! precisely on bad inputs — and keep serving after a rejected request.
//! Manifest and coordinator tests are artifact-free; the PJRT-client cases
//! need `make artifacts` and the `pjrt` feature.

use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::coordinator::{CoordinatorBuilder, NativeBackend, NativePrecision};
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::runtime::ArtifactManifest;
use pasm_accel::tensor::Tensor;
use std::path::PathBuf;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pasm_fail_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn encoded_net(seed: u64) -> EncodedCnn {
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    EncodedCnn::encode(arch, &params, 16, QFormat::W32)
}

#[test]
fn missing_manifest_mentions_make_artifacts() {
    let dir = tmpdir("missing");
    let err = ArtifactManifest::load(&dir).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_manifest_rejected() {
    let dir = tmpdir("corrupt");
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(ArtifactManifest::load(&dir).is_err());
}

#[test]
fn manifest_missing_fields_rejected() {
    let dir = tmpdir("fields");
    std::fs::write(dir.join("manifest.json"), r#"{"format": "hlo-text"}"#).unwrap();
    let err = ArtifactManifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("tile"));
}

#[test]
fn builder_requires_backend() {
    let err = CoordinatorBuilder::new().build();
    assert!(err.is_err());
    assert!(
        format!("{:#}", err.unwrap_err()).contains("backend"),
        "error should name the missing piece"
    );
}

#[test]
fn coordinator_survives_bad_request() {
    let coord = CoordinatorBuilder::new()
        .backend(NativeBackend::new(encoded_net(21)))
        .build()
        .unwrap();
    let mut rng = Rng::new(21);

    // wrong-shaped image: the whole batch it rides in fails, but the
    // coordinator must answer (with an error) and keep serving
    let bad = Tensor::<f32>::zeros(&[3, 3, 3]);
    let rx = coord.submit(bad).unwrap();
    let resp = rx.recv().expect("coordinator dropped the bad request");
    assert!(resp.is_err(), "bad shape must be rejected");

    // and a good request afterwards still works
    let good = render_digit(&mut rng, 4, 0.05);
    let resp = coord.infer(good).expect("coordinator died after bad request");
    assert_eq!(resp.logits.len(), 10);
}

#[test]
fn unknown_model_request_fails_cleanly_and_serving_continues() {
    use pasm_accel::model_store::ModelRegistry;
    use std::sync::Arc;

    let registry = Arc::new(ModelRegistry::new());
    registry.insert("real", encoded_net(24));
    let coord = CoordinatorBuilder::new().registry(Arc::clone(&registry)).build().unwrap();
    let mut rng = Rng::new(24);

    // a request naming a model that does not exist must error, not hang
    // or kill the worker
    let img = render_digit(&mut rng, 2, 0.05);
    let rx = coord.submit_to("ghost", img).unwrap();
    let resp = rx.recv().expect("coordinator dropped the unknown-model request");
    let err = resp.expect_err("unknown model must be an error");
    assert!(err.contains("ghost"), "error should name the model: {err}");

    // and the real model still serves afterwards
    let ok = coord.infer_model("real", render_digit(&mut rng, 5, 0.05));
    assert!(ok.is_ok(), "coordinator died after an unknown-model request");

    // a removed model stops serving with a clean error too
    assert!(registry.remove("real"));
    let gone = coord.infer_model("real", render_digit(&mut rng, 6, 0.05));
    assert!(gone.is_err(), "removed model kept serving");
}

#[test]
fn registry_builder_requires_nonempty_registry() {
    use pasm_accel::model_store::ModelRegistry;
    use std::sync::Arc;

    let err = CoordinatorBuilder::new()
        .registry(Arc::new(ModelRegistry::new()))
        .build()
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("empty"),
        "error should say the registry is empty: {err:#}"
    );
}

#[test]
fn corrupt_artifact_file_is_a_load_error() {
    let dir = tmpdir("badpasm");
    let path = dir.join("broken.pasm");
    std::fs::write(&path, b"PASM but not really").unwrap();
    let err = pasm_accel::model_store::load_file(&path).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("broken.pasm"), "error should name the file: {msg}");
}

#[test]
fn coordinator_survives_kernel_panic() {
    // extreme weights x extreme image overflow the fixed-point kernels'
    // accumulator guards (a panic, by design); the batch must fail with an
    // error response and the coordinator must keep serving
    let arch = DigitsCnn::default();
    let mut rng = Rng::new(33);
    let mut params = arch.init(&mut rng);
    for w in params.conv1_w.data_mut() {
        *w = 30000.0;
    }
    let enc = EncodedCnn::encode(arch, &params, 4, QFormat::W32);
    let coord = CoordinatorBuilder::new()
        .backend(
            NativeBackend::new(enc).with_precision(NativePrecision::Fixed(QFormat::IMAGE32)),
        )
        .build()
        .unwrap();

    let huge = Tensor::from_fn(&[1, 12, 12], |_| 32000.0f32);
    let rx = coord.submit(huge).unwrap();
    let resp = rx.recv().expect("coordinator dropped the overflowing request");
    assert!(resp.is_err(), "overflowing batch must fail, not succeed");

    let ok = coord.infer(render_digit(&mut rng, 1, 0.05));
    assert!(ok.is_ok(), "coordinator must survive a kernel panic");
}

// -- PJRT-client failure cases (need artifacts + the pjrt feature) ----------

#[cfg(feature = "pjrt")]
mod pjrt_failures {
    use super::*;
    use pasm_accel::coordinator::PjrtBackend;
    use pasm_accel::runtime::Runtime;

    #[test]
    #[ignore = "requires `make artifacts`"]
    fn dangling_artifact_path_fails_at_load() {
        // valid manifest structure, but the HLO file it names does not exist
        let dir = tmpdir("dangling");
        let manifest_text = std::fs::read_to_string("artifacts/manifest.json").unwrap();
        std::fs::write(dir.join("manifest.json"), manifest_text).unwrap();
        // no hlo files copied
        let rt = Runtime::new(&dir).expect("manifest parse should succeed");
        let err = match rt.load_tile("pasm_tile") {
            Ok(_) => panic!("load of dangling artifact should fail"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("pasm_tile") || msg.contains("hlo"),
            "error should name the artifact: {msg}"
        );
    }

    #[test]
    #[ignore = "requires `make artifacts`"]
    fn corrupt_hlo_text_fails_at_compile() {
        let dir = tmpdir("badhlo");
        let manifest_text = std::fs::read_to_string("artifacts/manifest.json").unwrap();
        std::fs::write(dir.join("manifest.json"), manifest_text).unwrap();
        std::fs::write(dir.join("pasm_tile.hlo.txt"), "HloModule garbage\nnot hlo").unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert!(rt.load_tile("pasm_tile").is_err());
    }

    #[test]
    #[ignore = "requires `make artifacts`"]
    fn tile_run_validates_shapes() {
        let rt = Runtime::new("artifacts").expect("run `make artifacts` first");
        let tile = rt.load_tile("pasm_tile").unwrap();
        let good_image = Tensor::<f32>::zeros(&[15, 5, 5]);
        let good_idx = Tensor::<u16>::zeros(&[2, 15, 3, 3]);
        let good_cb = vec![0f32; tile.bins];
        // wrong image shape
        assert!(tile
            .run(&Tensor::<f32>::zeros(&[3, 5, 5]), &good_idx, &good_cb)
            .is_err());
        // wrong codebook length
        assert!(tile.run(&good_image, &good_idx, &vec![0f32; 3]).is_err());
        // good shapes pass
        assert!(tile.run(&good_image, &good_idx, &good_cb).is_ok());
    }

    #[test]
    #[ignore = "requires `make artifacts`"]
    fn model_rejects_unexported_batch() {
        let rt = Runtime::new("artifacts").expect("run `make artifacts` first");
        let err = match rt.load_model(7) {
            Ok(_) => panic!("unexported batch size should fail"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("7"));
    }

    #[test]
    fn coordinator_bad_artifacts_dir_fails_at_startup() {
        let enc = encoded_net(22);
        let err = CoordinatorBuilder::new()
            .backend(PjrtBackend::new("/nonexistent_dir", enc))
            .build();
        assert!(err.is_err());
    }
}
