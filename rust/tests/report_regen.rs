//! Integration: the report generator reproduces every qualitative claim of
//! the paper's evaluation (the quantitative residuals live in
//! EXPERIMENTS.md).  This is the regression net for the calibrated models:
//! if someone retunes a constant and flips a conclusion, these fail.

use pasm_accel::report::{all_report_ids, run_report};

fn note(id: &str) -> String {
    run_report(id).unwrap().notes.join(" ")
}

fn pct_in_note(id: &str) -> f64 {
    // first signed percentage in the notes, as a fraction
    let n = note(id);
    let idx = n.find(['+', '-']).unwrap_or_else(|| panic!("{id}: no pct in '{n}'"));
    let tail = &n[idx..];
    let end = tail.find('%').unwrap();
    tail[..end].parse::<f64>().unwrap() / 100.0
}

#[test]
fn all_fifteen_exhibits_regenerate() {
    let ids = all_report_ids();
    assert_eq!(ids.len(), 15, "2 tables + 13 figures");
    for id in ids {
        let r = run_report(id).unwrap();
        assert!(!r.rows.is_empty());
        assert!(!r.render().is_empty());
    }
}

#[test]
fn fig7_pasm_large_gate_saving_at_w32() {
    // paper: -66%; model should be a large negative saving
    let v = pct_in_note("fig7");
    assert!(v < -0.40, "fig7 W=32 saving {v}");
}

#[test]
fn fig8_pasm_large_power_saving_at_w32() {
    // paper: -70%
    let v = pct_in_note("fig8");
    assert!(v < -0.50, "fig8 W=32 power saving {v}");
}

#[test]
fn fig15_pasm_wins_4bin() {
    // paper: -47.8% gates, -53.2% power
    let v = pct_in_note("fig15");
    assert!(v < -0.35, "fig15 saving {v}");
}

#[test]
fn fig16_pasm_wins_8bin_smaller() {
    // paper: -8.1% gates
    let v15 = pct_in_note("fig15");
    let v16 = pct_in_note("fig16");
    assert!(v16 < 0.0, "fig16 should still save: {v16}");
    assert!(v16 > v15, "8-bin saving must be smaller than 4-bin");
}

#[test]
fn fig17_pasm_loses_16bin() {
    // paper: PASM worse at 16-bin/32-bit, 1 GHz
    let v = pct_in_note("fig17");
    assert!(v > 0.0, "fig17 should show PASM worse: {v}");
}

#[test]
fn fig18_8bit_kernels_still_win() {
    // paper: -19.8% gates, -31.3% power at 8-bit/4-bin
    let v = pct_in_note("fig18");
    assert!(v < 0.0, "fig18 saving {v}");
}

#[test]
fn fpga_figs_dsp_and_power() {
    // paper: 99% fewer DSPs in every FPGA config; power saving shrinks
    // with bins but never flips at 200 MHz
    for id in ["fig19", "fig20", "fig21", "fig22"] {
        let n = note(id);
        assert!(n.contains("-99"), "{id}: DSP saving missing in '{n}'");
    }
    // last percentage in the note is the power saving
    let power_pct = |id: &str| {
        let n = note(id);
        let parts: Vec<f64> = n
            .split('%')
            .filter_map(|chunk| {
                let idx = chunk.rfind(['+', '-'])?;
                chunk[idx..].parse::<f64>().ok()
            })
            .collect();
        *parts.last().unwrap()
    };
    let p19 = power_pct("fig19");
    let p20 = power_pct("fig20");
    let p21 = power_pct("fig21");
    assert!(p19 < p20 && p20 < p21, "power savings shrink: {p19} {p20} {p21}");
    assert!(p21 < 0.0, "16-bin FPGA power saving must stay positive: {p21}");
}

#[test]
fn fig14_latency_band() {
    // paper: +8.5% (4-bin) .. +12.75% (16-bin)
    let r = run_report("fig14").unwrap();
    // column 3 is the overhead
    let overhead: Vec<f64> = r
        .rows
        .iter()
        .map(|row| row[3].trim_end_matches('%').parse::<f64>().unwrap() / 100.0)
        .collect();
    assert!(overhead[0] > 0.05 && overhead[0] < 0.12, "4-bin {}", overhead[0]);
    assert!(overhead[2] > 0.10 && overhead[2] < 0.16, "16-bin {}", overhead[2]);
    assert!(overhead.windows(2).all(|w| w[0] <= w[1]), "monotone in B");
}

#[test]
fn table2_exact() {
    let r = run_report("table2").unwrap();
    // row "5x5", column C=32 -> 800
    let row = r.rows.iter().find(|row| row[0] == "5x5").unwrap();
    assert_eq!(row[1], "800");
    let row7 = r.rows.iter().find(|row| row[0] == "7x7").unwrap();
    assert_eq!(row7[3], "25088");
}
