//! Seeded test RNG and shared generators for the property suites.
//!
//! proptest is unavailable in the offline build, so the suites hand-roll
//! their generator loops.  Before this module each test file carried its
//! own ad-hoc seeding; now every suite draws from one [`TestRng`] that
//! **prints its seed on construction** — cargo shows captured stdout for
//! failing tests only, so any property failure arrives with the exact
//! line needed to replay it:
//!
//! ```text
//! [test-rng] case 7: seed 0x9f34... (reproduce: TestRng::new(0x9f34...))
//! ```
//!
//! The generator itself delegates to the crate's xorshift*
//! [`Rng`](pasm_accel::cnn::data::Rng), so test streams stay identical to
//! what the crate's own seeded paths produce.

use pasm_accel::cnn::data::Rng;
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::tensor::Tensor;

/// Seeded RNG for property tests: announces its seed so failures
/// reproduce from the captured test output.
pub struct TestRng {
    inner: Rng,
    seed: u64,
}

impl TestRng {
    /// Generator with an explicit seed (announced on stdout).
    pub fn new(seed: u64) -> TestRng {
        println!("[test-rng] seed {seed:#018x} (reproduce: TestRng::new({seed:#x}))");
        TestRng { inner: Rng::new(seed), seed }
    }

    /// Per-case generator derived from a suite root seed and case index
    /// (splitmix64 mix), so each case of a generator loop reproduces in
    /// isolation from its printed seed — no need to replay earlier cases.
    pub fn case(root: u64, index: usize) -> TestRng {
        let mut z = root ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        let seed = z ^ (z >> 31);
        println!("[test-rng] case {index}: seed {seed:#018x} (reproduce: TestRng::new({seed:#x}))");
        TestRng { inner: Rng::new(seed), seed }
    }

    /// The announced seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Borrow the underlying crate RNG (for APIs taking `&mut Rng`).
    pub fn raw(&mut self) -> &mut Rng {
        &mut self.inner
    }

    /// An independent child stream (for param init etc.), seeded from
    /// this stream so it is reproducible but structurally decoupled.
    pub fn child(&mut self) -> Rng {
        Rng::new(self.inner.next_u64())
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.inner.next_u64()
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f32 {
        self.inner.uniform()
    }

    /// Uniform in `[-1, 1)`.
    pub fn signed(&mut self) -> f32 {
        self.inner.signed()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        self.inner.below(n)
    }

    /// Uniform pick from a slice.
    pub fn pick<T: Copy>(&mut self, options: &[T]) -> T {
        options[self.inner.below(options.len())]
    }
}

/// f32 slice as IEEE bit patterns — the comparison currency of the
/// bit-exactness suites (`==` on f32 would accept `-0.0 == 0.0` and
/// reject NaN ≡ NaN; bits do neither).
pub fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Random digits-CNN architecture.  Constraint: the pooled conv1 output
/// must still fit the conv2 kernel, i.e. `(in_side - kernel + 1) / 2 >=
/// kernel`.
pub fn random_arch(rng: &mut TestRng) -> DigitsCnn {
    let kernel = 1 + 2 * rng.below(2); // 1 or 3
    let in_side = kernel * 2 + 5 + rng.below(6);
    DigitsCnn {
        in_side,
        conv1_m: 1 + rng.below(6),
        conv2_m: 1 + rng.below(8),
        kernel,
        classes: 2 + rng.below(9),
    }
}

/// Randomly architected, randomly parameterized, dictionary-encoded net:
/// bin counts sweep 2..=64 (powers of two) and the weight format sweeps
/// the paper's W8/W16/W32.
pub fn random_encoded(rng: &mut TestRng) -> EncodedCnn {
    let arch = random_arch(rng);
    let bins = 1usize << (1 + rng.below(6));
    let wq = rng.pick(&[QFormat::W8, QFormat::W16, QFormat::W32]);
    encode_arch(rng, arch, bins, wq)
}

/// Encode `arch` with fresh random parameters at an explicit bin count
/// and weight format (the knobs the adversarial sweeps pin: single-bin,
/// max-B, odd widths).
pub fn encode_arch(rng: &mut TestRng, arch: DigitsCnn, bins: usize, wq: QFormat) -> EncodedCnn {
    let mut prng = rng.child();
    let params = arch.init(&mut prng);
    EncodedCnn::encode(arch, &params, bins, wq)
}

/// Random input image in `[-2, 2)` — wider than the renderer's `[0, 1]`
/// so negative activations and the fixed-point sign path are exercised.
pub fn random_image(rng: &mut TestRng, arch: &DigitsCnn) -> Tensor<f32> {
    Tensor::from_fn(&[1, arch.in_side, arch.in_side], |_| rng.signed() * 2.0)
}
