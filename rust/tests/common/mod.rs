//! Shared helpers for the integration-test crates.
//!
//! Each file under `tests/` is its own crate, so cargo compiles this
//! module once per suite — not every suite uses every helper, hence the
//! file-wide `dead_code` allowance.
#![allow(dead_code)]

pub mod rng;
