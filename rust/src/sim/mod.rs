//! Cycle-accurate simulation of the paper's units and accelerators.
//!
//! The simulator serves three purposes:
//!
//! 1. **Functional truth** — its fixed-point outputs must be bit-exact
//!    against the functional dataflows in [`crate::cnn::conv`] (and hence
//!    against the PJRT-executed Pallas kernels up to float rounding).
//! 2. **Latency truth** — cycle counts validate the analytical latency
//!    formulas (`stream_cycles`, `latency_cycles`), including the paper's
//!    §2.2 worked example (1024 vs 1088 cycles).
//! 3. **Activity truth** — Hamming-distance toggle counters on the
//!    architectural registers produce measured switching activities that
//!    feed the power model (replacing the component-library defaults).
//!
//! Modules: [`activity`] (toggle probes), [`units`] (clocked MAC / PAS /
//! post-pass units), [`standalone`] (the §2.4 16-MAC vs 16-PAS-4-MAC
//! streaming experiment), [`conv`] (the §3-4 conv-layer accelerator).

pub mod activity;
pub mod conv;
pub mod standalone;
pub mod units;

pub use activity::{ActivityReport, ToggleProbe};
pub use conv::{simulate_conv, ConvSimResult};
pub use standalone::{simulate_standalone, StandaloneSimResult};
