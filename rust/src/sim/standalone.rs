//! Cycle simulation of the §2.4 standalone experiment.
//!
//! Streams `n_pairs` (image, bin-index) pairs through each of the 16 lanes
//! of a 16-MAC or a 16-PAS-4-MAC and counts exact cycles:
//!
//! * 16-MAC: one pair per lane per cycle -> `n_pairs` cycles, results in
//!   the lane accumulators.
//! * 16-PAS-4-MAC: `n_pairs` accumulate cycles, then each shared MAC
//!   drains its `lanes/postpass` PAS units sequentially, `B` bins each ->
//!   `n_pairs + (lanes/postpass) * B` cycles (§2.2: 1024 + 4*16 = 1088).
//!
//! Results are checked bit-exact between the two (paper §5.3) and the
//! toggle probes provide measured activities for Figs 8/10.

use crate::accel::standalone::{StandaloneUnit, UnitKind};
use crate::sim::activity::ActivityReport;
use crate::sim::units::{PasUnit, PostPassMac, WsMacUnit};

/// One lane's input stream.
#[derive(Clone, Debug)]
pub struct LaneStream {
    /// Raw fixed-point image values, one per cycle.
    pub images: Vec<i64>,
    /// Dictionary bin index paired with each image value.
    pub bin_idx: Vec<u16>,
}

impl LaneStream {
    /// Number of (image, bin-index) pairs in the stream.
    pub fn len(&self) -> usize {
        debug_assert_eq!(self.images.len(), self.bin_idx.len());
        self.images.len()
    }

    /// Whether the stream holds no pairs.
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct StandaloneSimResult {
    /// Final accumulator per lane (raw fixed point).
    pub results: Vec<i64>,
    /// Exact simulated cycles.
    pub cycles: u64,
    /// Measured register activities.
    pub activity: ActivityReport,
}

/// Simulate a standalone unit over per-lane streams with a shared codebook.
///
/// `codebook` holds the raw dictionary weights (length >= B); every lane
/// uses the same dictionary, as in the paper's shared-weight design.
pub fn simulate_standalone(
    unit: &StandaloneUnit,
    streams: &[LaneStream],
    codebook: &[i64],
) -> StandaloneSimResult {
    assert_eq!(streams.len(), unit.lanes, "one stream per lane");
    assert!(codebook.len() >= unit.bins, "codebook smaller than bins");
    let n_pairs = streams[0].len();
    assert!(
        streams.iter().all(|s| s.len() == n_pairs),
        "lanes must stream equal lengths"
    );
    for s in streams {
        assert!(
            s.bin_idx.iter().all(|&b| (b as usize) < unit.bins),
            "bin index out of range"
        );
    }

    match unit.kind {
        UnitKind::Mac16 => {
            let mut lanes: Vec<WsMacUnit> = (0..unit.lanes)
                .map(|_| WsMacUnit::new(codebook[..unit.bins].to_vec(), 64))
                .collect();
            // lane-major: each lane streams its pairs contiguously (the
            // hardware is parallel; simulated cycle count is unaffected,
            // and the unit state stays register-resident — §Perf)
            for (lane, s) in lanes.iter_mut().zip(streams) {
                for (&im, &ix) in s.images.iter().zip(&s.bin_idx) {
                    lane.step(im, ix);
                }
            }
            let cycles = n_pairs as u64;
            let probes: Vec<_> = lanes.iter().map(|l| &l.acc_probe).collect();
            StandaloneSimResult {
                results: lanes.iter().map(|l| l.acc).collect(),
                cycles,
                activity: ActivityReport::from_probes(probes),
            }
        }
        UnitKind::Pas16Mac4 => {
            let mut pas: Vec<PasUnit> =
                (0..unit.lanes).map(|_| PasUnit::new(unit.bins, 64)).collect();
            // phase 1: parallel accumulate (lane-major, see Mac16 note)
            for (p, s) in pas.iter_mut().zip(streams) {
                for (&im, &ix) in s.images.iter().zip(&s.bin_idx) {
                    p.step(im, ix);
                }
            }
            let mut cycles = n_pairs as u64;
            // phase 2: each shared MAC drains its group sequentially
            let groups = unit.lanes / unit.postpass.max(1);
            let mut macs: Vec<PostPassMac> = (0..unit.postpass)
                .map(|_| PostPassMac::new(codebook[..unit.bins].to_vec(), 64))
                .collect();
            let mut results = vec![0i64; unit.lanes];
            for g in 0..groups {
                for b in 0..unit.bins {
                    for (mi, mac) in macs.iter_mut().enumerate() {
                        let lane = mi * groups + g;
                        mac.step(pas[lane].bins[b], b);
                    }
                    cycles += 1;
                }
                for (mi, mac) in macs.iter_mut().enumerate() {
                    let lane = mi * groups + g;
                    results[lane] = mac.acc;
                    mac.reset();
                }
            }
            let probes: Vec<_> = pas
                .iter()
                .map(|p| &p.bin_probe)
                .chain(macs.iter().map(|m| &m.acc_probe))
                .collect();
            StandaloneSimResult {
                results,
                cycles,
                activity: ActivityReport::from_probes(probes),
            }
        }
    }
}

/// Generate deterministic random streams (test/bench workload).
pub fn random_streams(
    rng: &mut crate::cnn::data::Rng,
    lanes: usize,
    n_pairs: usize,
    bins: usize,
    magnitude: i64,
) -> Vec<LaneStream> {
    (0..lanes)
        .map(|_| LaneStream {
            images: (0..n_pairs)
                .map(|_| (rng.signed() * magnitude as f32) as i64)
                .collect(),
            bin_idx: (0..n_pairs).map(|_| rng.below(bins) as u16).collect(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::Rng;

    fn setup(n_pairs: usize, bins: usize) -> (Vec<LaneStream>, Vec<i64>) {
        let mut rng = Rng::new(42);
        let streams = random_streams(&mut rng, 16, n_pairs, bins, 1000);
        let codebook: Vec<i64> = (0..bins).map(|_| (rng.signed() * 500.0) as i64).collect();
        (streams, codebook)
    }

    #[test]
    fn paper_1024_1088_cycles() {
        let (streams, cb) = setup(1024, 16);
        let mac = simulate_standalone(&StandaloneUnit::mac16(32, 16), &streams, &cb);
        let pasm = simulate_standalone(&StandaloneUnit::pas16mac4(32, 16), &streams, &cb);
        assert_eq!(mac.cycles, 1024);
        assert_eq!(pasm.cycles, 1088); // 1024 + 4 * 16
    }

    #[test]
    fn results_bitexact_between_designs() {
        for bins in [4usize, 16, 64] {
            let (streams, cb) = setup(257, bins);
            let mac = simulate_standalone(&StandaloneUnit::mac16(32, bins), &streams, &cb);
            let pasm =
                simulate_standalone(&StandaloneUnit::pas16mac4(32, bins), &streams, &cb);
            assert_eq!(mac.results, pasm.results, "bins {bins}");
        }
    }

    #[test]
    fn cycles_match_analytical_model() {
        for (n, bins) in [(100usize, 4usize), (1000, 16), (333, 64)] {
            let (streams, cb) = setup(n, bins);
            let unit = StandaloneUnit::pas16mac4(32, bins);
            let sim = simulate_standalone(&unit, &streams, &cb);
            assert_eq!(sim.cycles, unit.stream_cycles(n as u64), "n={n} bins={bins}");
        }
    }

    #[test]
    fn results_match_direct_computation() {
        let (streams, cb) = setup(50, 8);
        let mac = simulate_standalone(&StandaloneUnit::mac16(32, 8), &streams, &cb);
        for (lane, s) in streams.iter().enumerate() {
            let want: i64 = s
                .images
                .iter()
                .zip(&s.bin_idx)
                .map(|(&im, &b)| im * cb[b as usize])
                .sum();
            assert_eq!(mac.results[lane], want, "lane {lane}");
        }
    }

    #[test]
    fn activity_measured_nonzero() {
        let (streams, cb) = setup(64, 16);
        let sim = simulate_standalone(&StandaloneUnit::pas16mac4(32, 16), &streams, &cb);
        let mean = sim.activity.mean();
        assert!(mean > 0.0 && mean < 1.0, "activity {mean}");
    }

    #[test]
    fn zero_stream_zero_activity() {
        let streams: Vec<LaneStream> = (0..16)
            .map(|_| LaneStream { images: vec![0; 32], bin_idx: vec![0; 32] })
            .collect();
        let cb = vec![0i64; 16];
        let sim = simulate_standalone(&StandaloneUnit::mac16(32, 16), &streams, &cb);
        assert_eq!(sim.activity.mean(), 0.0);
        assert!(sim.results.iter().all(|&r| r == 0));
    }
}
