//! Clocked datapath units: weight-shared MAC, PAS, post-pass MAC.
//!
//! Each unit exposes a `step(...)` that models one clock edge: consume at
//! most one input, update architectural state, clock the toggle probes.
//! The paper's Figures 2-6 describe exactly these three state machines.

use crate::sim::activity::ToggleProbe;

/// Weight-shared MAC (Fig 3/4): `acc += image * weights[bin_idx]`.
#[derive(Clone, Debug)]
pub struct WsMacUnit {
    /// Dictionary register file (B entries, raw fixed-point).
    pub weights: Vec<i64>,
    /// Accumulator register.
    pub acc: i64,
    /// Toggle probe on the accumulator register.
    pub acc_probe: ToggleProbe,
    /// Toggle probe on the multiplier output bus.
    pub mul_probe: ToggleProbe,
}

impl WsMacUnit {
    /// A zeroed unit over a raw fixed-point dictionary (non-empty) with
    /// `acc_width`-bit probes.
    pub fn new(weights: Vec<i64>, acc_width: u32) -> Self {
        assert!(!weights.is_empty());
        WsMacUnit {
            weights,
            acc: 0,
            acc_probe: ToggleProbe::new("ws_acc", acc_width.min(64)),
            mul_probe: ToggleProbe::new("ws_mul_out", acc_width.min(64)),
        }
    }

    /// One clock: multiply-accumulate one (image, bin index) pair.
    #[inline]
    pub fn step(&mut self, image: i64, bin_idx: u16) {
        let w = self.weights[bin_idx as usize];
        let product = image.checked_mul(w).expect("WS-MAC product overflow");
        self.acc = self.acc.checked_add(product).expect("WS-MAC acc overflow");
        self.mul_probe.clock(product);
        self.acc_probe.clock(self.acc);
    }

    /// Idle clock (no input this cycle).
    #[inline]
    pub fn step_idle(&mut self) {
        self.mul_probe.idle();
        self.acc_probe.idle();
    }

    /// Clear the accumulator (probes keep counting).
    pub fn reset(&mut self) {
        self.acc = 0;
    }
}

/// PAS unit (Fig 5/6a): `bins[bin_idx] += image` — the weighted histogram.
#[derive(Clone, Debug)]
pub struct PasUnit {
    /// Accumulation bins, one per dictionary entry.
    pub bins: Vec<i64>,
    /// Toggle probe on the bin write port.
    pub bin_probe: ToggleProbe,
}

impl PasUnit {
    /// A zeroed unit with `n_bins` bins and an `acc_width`-bit probe.
    pub fn new(n_bins: usize, acc_width: u32) -> Self {
        assert!(n_bins >= 1);
        PasUnit {
            bins: vec![0; n_bins],
            bin_probe: ToggleProbe::new("pas_bin", acc_width.min(64)),
        }
    }

    /// One clock: accumulate one (image, bin index) pair.
    #[inline]
    pub fn step(&mut self, image: i64, bin_idx: u16) {
        let b = bin_idx as usize;
        self.bins[b] = self.bins[b].checked_add(image).expect("PAS bin overflow");
        self.bin_probe.clock(self.bins[b]);
    }

    /// Idle clock (no input this cycle).
    #[inline]
    pub fn step_idle(&mut self) {
        self.bin_probe.idle();
    }

    /// Clear every bin (probes keep counting).
    pub fn reset(&mut self) {
        self.bins.iter_mut().for_each(|b| *b = 0);
    }
}

/// Post-pass MAC (Fig 5/6b): drains PAS bins against the codebook, one bin
/// per cycle.
#[derive(Clone, Debug)]
pub struct PostPassMac {
    /// Raw fixed-point dictionary the bins contract against.
    pub codebook: Vec<i64>,
    /// Accumulator register.
    pub acc: i64,
    /// Toggle probe on the accumulator register.
    pub acc_probe: ToggleProbe,
}

impl PostPassMac {
    /// A zeroed unit over a raw fixed-point codebook with an
    /// `acc_width`-bit probe.
    pub fn new(codebook: Vec<i64>, acc_width: u32) -> Self {
        PostPassMac {
            codebook,
            acc: 0,
            acc_probe: ToggleProbe::new("postpass_acc", acc_width.min(64)),
        }
    }

    /// One clock: multiply-accumulate one drained bin.
    #[inline]
    pub fn step(&mut self, bin_value: i64, bin_idx: usize) {
        let product = bin_value
            .checked_mul(self.codebook[bin_idx])
            .expect("post-pass product overflow");
        self.acc = self.acc.checked_add(product).expect("post-pass acc overflow");
        self.acc_probe.clock(self.acc);
    }

    /// Idle clock (no input this cycle).
    #[inline]
    pub fn step_idle(&mut self) {
        self.acc_probe.idle();
    }

    /// Clear the accumulator (probes keep counting).
    pub fn reset(&mut self) {
        self.acc = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Fig 4 / Fig 6 worked example in fixed point (scale 10 to make
    /// the decimal values exact integers).
    #[test]
    fn fig4_fig6_worked_example() {
        // values x10: image [267, 34, 48, 177, 61], cb x10: [17, 4, 13, 20]
        let images = [267i64, 34, 48, 177, 61];
        let idxs = [0u16, 1, 2, 3, 0];
        let cb = vec![17i64, 4, 13, 20];

        // WS-MAC path
        let mut mac = WsMacUnit::new(cb.clone(), 64);
        for (&im, &ix) in images.iter().zip(&idxs) {
            mac.step(im, ix);
        }
        assert_eq!(mac.acc, 9876); // 98.76 * 100

        // PASM path: PAS then post-pass
        let mut pas = PasUnit::new(4, 64);
        for (&im, &ix) in images.iter().zip(&idxs) {
            pas.step(im, ix);
        }
        assert_eq!(pas.bins, vec![328, 34, 48, 177]); // bin0 = 26.7+6.1
        let mut pp = PostPassMac::new(cb, 64);
        for (b, &v) in pas.bins.clone().iter().enumerate() {
            pp.step(v, b);
        }
        assert_eq!(pp.acc, 9876); // identical result (paper §5.3)
    }

    #[test]
    fn toggle_probes_accumulate() {
        let mut pas = PasUnit::new(4, 32);
        pas.step(0xFF, 0);
        assert!(pas.bin_probe.toggles() >= 8);
        pas.step_idle();
        assert_eq!(pas.bin_probe.cycles(), 2);
    }

    #[test]
    fn reset_clears_state_not_probes() {
        let mut mac = WsMacUnit::new(vec![2, 3], 32);
        mac.step(5, 1);
        assert_eq!(mac.acc, 15);
        let toggles = mac.acc_probe.toggles();
        mac.reset();
        assert_eq!(mac.acc, 0);
        assert_eq!(mac.acc_probe.toggles(), toggles);
    }

    #[test]
    #[should_panic]
    fn pas_overflow_guard() {
        let mut pas = PasUnit::new(1, 64);
        pas.step(i64::MAX, 0);
        pas.step(1, 0);
    }
}
