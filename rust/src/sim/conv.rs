//! Cycle simulation of the §3-4 convolution-layer accelerator.
//!
//! Simulates the II=1 pipelined schedule of Fig 13: one `(pixel, m)` output
//! slot per cycle through the unrolled tap datapath, with PASM paying the
//! post-pass drain.  Outputs are bit-exact against the functional
//! fixed-point dataflows ([`crate::cnn::conv`]), and the cycle count is
//! validated against the analytical [`ConvAccel::latency_cycles`] model.

use crate::accel::conv::{ConvAccel, ConvVariantKind};
use crate::cnn::conv::FxConvInputs;
use crate::sim::activity::{ActivityReport, ToggleProbe};
use crate::tensor::Tensor;

/// Simulation output for one conv tile.
#[derive(Clone, Debug)]
pub struct ConvSimResult {
    /// Raw fixed-point output feature map `[M, OH, OW]`.
    pub out: Tensor<i64>,
    /// Exact simulated cycles.
    pub cycles: u64,
    /// Measured activities (output register, bin registers, tree output).
    pub activity: ActivityReport,
}

/// Pipeline fill depth used by both the simulator and the analytical model.
const PIPE_DEPTH: u64 = 10;

/// Simulate the accelerator over one tile of inputs.
///
/// `accel.variant` selects the dataflow; `inputs` carries the fixed-point
/// image/bin-index/codebook exactly as the hardware registers hold them.
pub fn simulate_conv(accel: &ConvAccel, inputs: &FxConvInputs) -> ConvSimResult {
    let shape = inputs.shape();
    assert_eq!(shape.taps(), accel.shape.taps(), "accel/input shape mismatch");
    let bins = inputs.codebook_raw.len();

    let mut out = Tensor::zeros(shape.out_shape().dims());
    let mut out_probe = ToggleProbe::new("outfeat", 64);
    let mut bin_probe = ToggleProbe::new("image_bin", 64);
    let mut tree_probe = ToggleProbe::new("sum_tree", 64);

    let mut cycles: u64 = PIPE_DEPTH; // pipeline fill
    let mut image_bin = vec![0i64; bins];

    // flattened hot-loop bookkeeping (§Perf: Tensor::at costs three
    // multiplies per tap; the simulator must stream)
    let (ih_w, k_w) = (shape.in_w, shape.kernel_w);
    let plane = shape.in_h * ih_w;
    let taps = shape.taps();
    let img = inputs.image_raw.data();
    let bi = inputs.bin_idx.data();
    let cb = &inputs.codebook_raw;
    let (oh, ow) = (shape.out_h(), shape.out_w());
    let out_data = out.data_mut();

    for m in 0..shape.kernels {
        let bi_m = &bi[m * taps..(m + 1) * taps];
        for oy in 0..oh {
            for ox in 0..ow {
                let base = oy * shape.stride * ih_w + ox * shape.stride;
                match accel.variant {
                    ConvVariantKind::Direct | ConvVariantKind::WeightShared => {
                        // one output slot per cycle: all taps in parallel
                        let mut acc = 0i64;
                        let mut t = 0usize;
                        for c in 0..shape.channels {
                            let cplane = &img[c * plane..(c + 1) * plane];
                            for ky in 0..shape.kernel_h {
                                let row =
                                    &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                                for &iv in row {
                                    acc += iv * cb[bi_m[t] as usize];
                                    t += 1;
                                }
                            }
                        }
                        tree_probe.clock(acc);
                        out_probe.clock(acc);
                        out_data[m * oh * ow + oy * ow + ox] = acc;
                        cycles += 1;
                    }
                    ConvVariantKind::Pasm => {
                        // PAS slot: all B gather trees fire in parallel
                        image_bin.iter_mut().for_each(|b| *b = 0);
                        let mut t = 0usize;
                        for c in 0..shape.channels {
                            let cplane = &img[c * plane..(c + 1) * plane];
                            for ky in 0..shape.kernel_h {
                                let row =
                                    &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                                for &iv in row {
                                    image_bin[bi_m[t] as usize] += iv;
                                    t += 1;
                                }
                            }
                        }
                        for &v in &image_bin {
                            bin_probe.clock(v);
                        }
                        cycles += 1;
                        // post-pass: bins drain through the shared
                        // multiplier(s); overlapped with the next slot's PAS
                        // phase, so only the non-overlapped fraction stalls
                        // the pipeline (the analytical model's B/K term).
                        let mut acc = 0i64;
                        for (b, &v) in image_bin.iter().enumerate() {
                            acc += v * cb[b];
                        }
                        tree_probe.clock(acc);
                        out_probe.clock(acc);
                        out_data[m * oh * ow + oy * ow + ox] = acc;
                    }
                }
            }
        }
    }

    if accel.variant == ConvVariantKind::Pasm {
        // non-overlapped post-pass stall cycles (matches the analytical
        // latency model; the simulator accounts them in one lump at drain)
        let extra = accel.latency_cycles_exact()
            - (shape.kernels * shape.out_pixels()) as f64
            - PIPE_DEPTH as f64;
        cycles += extra.round().max(0.0) as u64;
    }

    ConvSimResult {
        out,
        cycles,
        activity: ActivityReport::from_probes([&out_probe, &bin_probe, &tree_probe]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::conv::{pasm_conv_fx, ws_conv_fx};
    use crate::cnn::data::Rng;
    use crate::quant::codebook::encode_weights;
    use crate::quant::fixed::QFormat;
    use crate::tensor::ConvShape;

    fn paper_inputs(seed: u64, bins: usize) -> FxConvInputs {
        let mut rng = Rng::new(seed);
        let image = Tensor::from_fn(&[15, 5, 5], |_| rng.signed() * 4.0);
        let w = Tensor::from_fn(&[2, 15, 3, 3], |_| rng.signed());
        let enc = encode_weights(&w, bins, QFormat::W16);
        FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 1)
    }

    #[test]
    fn ws_sim_bitexact_vs_functional() {
        let inp = paper_inputs(1, 16);
        let accel = ConvAccel::paper(ConvVariantKind::WeightShared, 16, 32);
        let sim = simulate_conv(&accel, &inp);
        assert_eq!(sim.out.data(), ws_conv_fx(&inp).data());
    }

    #[test]
    fn pasm_sim_bitexact_vs_functional_and_ws() {
        for bins in [4usize, 8, 16] {
            let inp = paper_inputs(bins as u64, bins);
            let accel = ConvAccel::paper(ConvVariantKind::Pasm, bins, 32);
            let sim = simulate_conv(&accel, &inp);
            assert_eq!(sim.out.data(), pasm_conv_fx(&inp).data(), "bins {bins}");
            // §5.3: PASM results identical to the weight-shared accelerator
            assert_eq!(sim.out.data(), ws_conv_fx(&inp).data(), "bins {bins}");
        }
    }

    #[test]
    fn cycles_match_analytical_latency() {
        for (variant, bins) in [
            (ConvVariantKind::WeightShared, 16),
            (ConvVariantKind::Pasm, 4),
            (ConvVariantKind::Pasm, 16),
        ] {
            let inp = paper_inputs(7, bins);
            let accel = ConvAccel::paper(variant, bins, 32);
            let sim = simulate_conv(&accel, &inp);
            let model = accel.latency_cycles();
            let diff = sim.cycles.abs_diff(model);
            assert!(diff <= 1, "{variant:?}/{bins}: sim {} vs model {}", sim.cycles, model);
        }
    }

    #[test]
    fn pasm_latency_overhead_positive() {
        let inp = paper_inputs(3, 8);
        let ws = simulate_conv(&ConvAccel::paper(ConvVariantKind::WeightShared, 8, 32), &inp);
        let pasm = simulate_conv(&ConvAccel::paper(ConvVariantKind::Pasm, 8, 32), &inp);
        assert!(pasm.cycles > ws.cycles);
        // and well under 20% (Fig 14 band is 8.5-12.75%)
        assert!((pasm.cycles as f64) < ws.cycles as f64 * 1.2);
    }

    #[test]
    fn nontrivial_activity_measured() {
        let inp = paper_inputs(9, 16);
        let sim = simulate_conv(&ConvAccel::paper(ConvVariantKind::Pasm, 16, 32), &inp);
        assert!(sim.activity.get("image_bin").unwrap() > 0.0);
        assert!(sim.activity.get("outfeat").unwrap() > 0.0);
    }

    #[test]
    fn stride_and_other_shapes() {
        let mut rng = Rng::new(5);
        let image = Tensor::from_fn(&[4, 9, 9], |_| rng.signed() * 2.0);
        let w = Tensor::from_fn(&[3, 4, 3, 3], |_| rng.signed());
        let enc = encode_weights(&w, 8, QFormat::W16);
        let inp = FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 2);
        let shape = ConvShape::new(4, 9, 9, 3, 3, 3, 2);
        let accel = ConvAccel::new(ConvVariantKind::Pasm, shape, 8, 16);
        let sim = simulate_conv(&accel, &inp);
        assert_eq!(sim.out.data(), pasm_conv_fx(&inp).data());
    }
}
