//! Toggle probes: measured switching activity for the power model.
//!
//! A [`ToggleProbe`] watches one architectural register (or bus): each
//! clocked update XORs the previous value with the new one and accumulates
//! the Hamming weight.  `activity()` is then toggles / (cycles x width) —
//! the per-bit switching probability the dynamic power model multiplies by
//! `E_toggle * f`.

/// Toggle counter for one register/bus of `width` bits.
#[derive(Clone, Debug)]
pub struct ToggleProbe {
    /// Probe label, used in activity reports.
    pub name: String,
    width: u32,
    last: i64,
    toggles: u64,
    cycles: u64,
}

impl ToggleProbe {
    /// A zeroed probe over a `width`-bit register (1..=64).
    pub fn new(name: impl Into<String>, width: u32) -> Self {
        assert!(width >= 1 && width <= 64);
        ToggleProbe { name: name.into(), width, last: 0, toggles: 0, cycles: 0 }
    }

    /// Clock the probe with the register's new value (masked to `width`).
    #[inline]
    pub fn clock(&mut self, value: i64) {
        let mask: u64 = if self.width == 64 { !0 } else { (1u64 << self.width) - 1 };
        let diff = ((self.last as u64) ^ (value as u64)) & mask;
        self.toggles += diff.count_ones() as u64;
        self.last = value;
        self.cycles += 1;
    }

    /// Clock with no change (idle cycle — still counts the denominator).
    #[inline]
    pub fn idle(&mut self) {
        self.cycles += 1;
    }

    /// Total bit toggles observed.
    pub fn toggles(&self) -> u64 {
        self.toggles
    }

    /// Total cycles observed (clocked + idle).
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Mean per-bit switching probability in [0, 1].
    pub fn activity(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.toggles as f64 / (self.cycles as f64 * self.width as f64)
        }
    }
}

/// Aggregated activity over a set of probes (gate-count-weighted mean is
/// the caller's job; this is the plain per-bit mean).
#[derive(Clone, Debug, Default)]
pub struct ActivityReport {
    /// `(probe name, per-bit activity)` pairs, in probe order.
    pub probes: Vec<(String, f64)>,
}

impl ActivityReport {
    /// Snapshot the activity of each probe.
    pub fn from_probes<'a>(probes: impl IntoIterator<Item = &'a ToggleProbe>) -> Self {
        ActivityReport {
            probes: probes
                .into_iter()
                .map(|p| (p.name.clone(), p.activity()))
                .collect(),
        }
    }

    /// Mean activity across probes (uniform weights).
    pub fn mean(&self) -> f64 {
        if self.probes.is_empty() {
            return 0.0;
        }
        self.probes.iter().map(|(_, a)| a).sum::<f64>() / self.probes.len() as f64
    }

    /// Activity of a named probe.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.probes.iter().find(|(n, _)| n == name).map(|(_, a)| *a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_hamming_distance() {
        let mut p = ToggleProbe::new("acc", 8);
        p.clock(0b0000_1111); // 4 toggles from 0
        p.clock(0b0000_0000); // 4 back
        assert_eq!(p.toggles(), 8);
        assert_eq!(p.cycles(), 2);
        assert!((p.activity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn masking_to_width() {
        let mut p = ToggleProbe::new("narrow", 4);
        p.clock(-1); // all ones, but only 4 bits counted
        assert_eq!(p.toggles(), 4);
    }

    #[test]
    fn constant_value_no_toggles() {
        let mut p = ToggleProbe::new("const", 16);
        p.clock(1234);
        let t0 = p.toggles();
        for _ in 0..10 {
            p.clock(1234);
        }
        assert_eq!(p.toggles(), t0);
        assert!(p.activity() < 0.1);
    }

    #[test]
    fn idle_dilutes_activity() {
        let mut p = ToggleProbe::new("x", 8);
        p.clock(0xFF);
        for _ in 0..7 {
            p.idle();
        }
        assert!((p.activity() - 8.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn report_lookup() {
        let mut a = ToggleProbe::new("a", 8);
        a.clock(0x0F);
        let b = ToggleProbe::new("b", 8);
        let r = ActivityReport::from_probes([&a, &b]);
        assert!(r.get("a").unwrap() > 0.0);
        assert_eq!(r.get("b").unwrap(), 0.0);
        assert!(r.get("missing").is_none());
        assert!(r.mean() > 0.0);
    }
}
