//! Technology constants: FreePDK45-class 45 nm standard cells.
//!
//! Values are representative of published FreePDK45 characterizations
//! (NAND2X1 at VDD = 1.1 V, typical corner).  The paper's claims are all
//! *relative* (PASM vs MAC ratios), which a consistent constant set
//! preserves; absolute magnitudes land in the right order (mW at 100 MHz-
//! 1 GHz for 10^4-10^6 gate designs).

/// A synthesis target: process constants + clock.
#[derive(Clone, Copy, Debug)]
pub struct Tech {
    /// Clock frequency in Hz.
    pub clock_hz: f64,
    /// Energy per NAND2-equivalent gate output toggle (J).
    pub toggle_energy_j: f64,
    /// Clock-tree + internal clocking energy per sequential bit per cycle (J).
    pub clock_energy_per_bit_j: f64,
    /// Leakage power per NAND2-equivalent gate (W).
    pub leakage_per_gate_w: f64,
    /// Propagation delay of one NAND2X1 (s) under typical load.
    pub gate_delay_s: f64,
    /// Flip-flop clk->Q plus setup overhead (s).
    pub ff_overhead_s: f64,
    /// Extra wire/fanout delay per driven sink on a high-fanout net (s).
    pub fanout_delay_per_sink_s: f64,
}

impl Tech {
    /// The paper's standalone-unit experiments: 45 nm ASIC at 100 MHz (§2.4).
    pub fn asic_100mhz() -> Tech {
        Tech { clock_hz: 100e6, ..Tech::base45() }
    }

    /// The paper's CNN-accelerator experiments: 45 nm ASIC at 1 GHz (§4).
    pub fn asic_1ghz() -> Tech {
        Tech { clock_hz: 1e9, ..Tech::base45() }
    }

    /// A relaxed target the paper suggests for 16-bin PASM ("it might be
    /// better to target a lower clock frequency, for example 800MHz").
    pub fn asic_800mhz() -> Tech {
        Tech { clock_hz: 800e6, ..Tech::base45() }
    }

    /// The paper's FPGA clock (§5.2: Zynq at 200 MHz).  Only the clock
    /// matters on this path — the FPGA resource/power model has its own
    /// per-resource constants (`crate::fpga`); the 45 nm delay constants
    /// are used solely for pipeline-stage decisions, which are relaxed at
    /// 5 ns anyway.
    pub fn fpga_200mhz() -> Tech {
        Tech { clock_hz: 200e6, ..Tech::base45() }
    }

    fn base45() -> Tech {
        Tech {
            clock_hz: 1e9,
            toggle_energy_j: 1.2e-15,          // ~1.2 fJ per gate toggle
            clock_energy_per_bit_j: 2.0e-15,   // clock tree + FF internal
            leakage_per_gate_w: 2.5e-8,        // ~25 nW per NAND2-eq
            gate_delay_s: 2.2e-11,             // ~22 ps NAND2X1
            ff_overhead_s: 1.5e-10,            // ~150 ps clk->Q + setup
            fanout_delay_per_sink_s: 6.0e-12,  // ~6 ps per extra sink
        }
    }

    /// Clock period in seconds.
    pub fn period_s(&self) -> f64 {
        1.0 / self.clock_hz
    }

    /// Clock period in nanoseconds.
    pub fn period_ns(&self) -> f64 {
        self.period_s() * 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periods() {
        assert!((Tech::asic_1ghz().period_ns() - 1.0).abs() < 1e-12);
        assert!((Tech::asic_100mhz().period_ns() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn constants_ordered_sanely() {
        let t = Tech::asic_1ghz();
        // a 32-gate chain of NAND2 should not fit in a 1 GHz cycle together
        // with FF overhead + margin (forces CLA adders at 1 GHz)
        assert!(32.0 * t.gate_delay_s + t.ff_overhead_s > 0.8 * t.period_s());
        // but easily fits at 100 MHz
        assert!(32.0 * t.gate_delay_s + t.ff_overhead_s < 0.2 * Tech::asic_100mhz().period_s());
    }
}
