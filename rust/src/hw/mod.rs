//! ASIC hardware cost models: gates, area, timing, power.
//!
//! The paper evaluates synthesized netlists (Cadence Genus, OSU FreePDK45)
//! and reports NAND2-normalized gate counts split into *sequential /
//! inverter / buffer / logic* categories, plus leakage/dynamic power.  We
//! reproduce those reports with a **structural model** (DESIGN.md §1):
//!
//! * [`gates`] — a component library (adders, array multipliers, registers,
//!   register files, muxes, comparators, adder trees) in NAND2X1
//!   equivalents with the same category breakdown the paper plots.
//! * [`tech`] — FreePDK45-class constants: gate energy, leakage, delays.
//! * [`timing`] — critical-path estimates and the *timing-pressure area
//!   elasticity* that models synthesis upsizing logic to meet an aggressive
//!   clock (the mechanism behind the paper's Fig 17: at 1 GHz / 16 bins the
//!   PAS read-modify-write recurrence no longer fits the period cheaply).
//! * [`power`] — leakage + activity-based dynamic power; activity factors
//!   come from the cycle-accurate simulator's toggle counters when
//!   available, falling back to per-component defaults.

pub mod gates;
pub mod memenergy;
pub mod power;
pub mod sram;
pub mod tech;
pub mod timing;

pub use gates::{Component, GateBreakdown};
pub use power::{PowerBreakdown, PowerModel};
pub use tech::Tech;
pub use timing::{timing_area_factor, PathDelay};
