//! NAND2-normalized gate model of the datapath component library.
//!
//! Counts are split into the same four categories the paper's Genus
//! "report gates" figures plot: **sequential** (flip-flops), **inverter**,
//! **buffer**, and **logic** (everything combinational that is not an
//! inverter/buffer).  Each component also carries a default switching
//! activity (fraction of its gates that toggle in an active cycle) used by
//! the power model when no simulator-measured activity is available, and a
//! combinational depth estimate consumed by the timing model.

use std::ops::{Add, AddAssign, Mul};

/// NAND2-equivalent gate counts by category (fractional counts are fine —
/// they model average cell sizes).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GateBreakdown {
    /// Flip-flop (storage) gates.
    pub sequential: f64,
    /// Inverter gates.
    pub inverter: f64,
    /// Buffer gates.
    pub buffer: f64,
    /// Remaining combinational gates.
    pub logic: f64,
}

impl GateBreakdown {
    /// Total NAND2-equivalent gate count across all four categories.
    pub fn total(&self) -> f64 {
        self.sequential + self.inverter + self.buffer + self.logic
    }

    /// Scale only the combinational part (timing-pressure upsizing leaves
    /// the FF count unchanged but upsizes/buffers the logic cones).
    pub fn scale_combinational(&self, k: f64) -> GateBreakdown {
        GateBreakdown {
            sequential: self.sequential,
            inverter: self.inverter * k,
            buffer: self.buffer * k,
            logic: self.logic * k,
        }
    }
}

impl Add for GateBreakdown {
    type Output = GateBreakdown;
    fn add(self, o: GateBreakdown) -> GateBreakdown {
        GateBreakdown {
            sequential: self.sequential + o.sequential,
            inverter: self.inverter + o.inverter,
            buffer: self.buffer + o.buffer,
            logic: self.logic + o.logic,
        }
    }
}

impl AddAssign for GateBreakdown {
    fn add_assign(&mut self, o: GateBreakdown) {
        *self = *self + o;
    }
}

impl Mul<f64> for GateBreakdown {
    type Output = GateBreakdown;
    fn mul(self, k: f64) -> GateBreakdown {
        GateBreakdown {
            sequential: self.sequential * k,
            inverter: self.inverter * k,
            buffer: self.buffer * k,
            logic: self.logic * k,
        }
    }
}

/// A sized instance of a library component.
#[derive(Clone, Debug)]
pub struct Component {
    /// Component label (for reports).
    pub name: String,
    /// NAND2-normalized gate cost.
    pub gates: GateBreakdown,
    /// Default fraction of gates toggling in an active cycle.
    pub activity: f64,
    /// Combinational depth in NAND2 levels (0 for pure storage).
    pub depth_levels: f64,
    /// Fanout sinks on the widest internal net (drives wire-delay estimates).
    pub max_fanout: f64,
}

impl Component {
    fn new(name: impl Into<String>, gates: GateBreakdown, activity: f64, depth: f64, fanout: f64) -> Self {
        Component { name: name.into(), gates, activity, depth_levels: depth, max_fanout: fanout }
    }
}

// Per-bit cost constants (NAND2 equivalents), representative of standard
// cell mappings:  DFF ≈ 6 gates; full adder ≈ 5 gates; 2:1 mux ≈ 3 gates;
// AND2 ≈ 1.5 gates; XOR2 ≈ 3 gates.
const DFF: f64 = 6.0;
const FA: f64 = 5.0;
const MUX2: f64 = 3.0;
const AND2: f64 = 1.5;

/// Fraction of combinational logic that synthesis maps to inverters/buffers
/// (drive shaping).  Multiplier cones are buffer-heavier than small adders.
const INV_FRAC: f64 = 0.14;
const BUF_FRAC: f64 = 0.10;

fn comb(name: &str, logic: f64, activity: f64, depth: f64, fanout: f64) -> Component {
    Component::new(
        name,
        GateBreakdown {
            sequential: 0.0,
            inverter: logic * INV_FRAC,
            buffer: logic * BUF_FRAC,
            logic,
        },
        activity,
        depth,
        fanout,
    )
}

/// A `width`-bit D flip-flop register (with clock buffering).
pub fn register(width: u32) -> Component {
    let w = width as f64;
    Component::new(
        format!("reg{width}"),
        GateBreakdown {
            sequential: DFF * w,
            inverter: 0.4 * w, // local clock inverters
            buffer: 0.25 * w,  // clock buffers
            logic: 0.0,
        },
        0.15, // data toggle default; clock power handled separately
        0.0,
        2.0,
    )
}

/// A register with a write-enable gate per bit.
pub fn register_en(width: u32) -> Component {
    let mut c = register(width);
    c.name = format!("reg_en{width}");
    c.gates.logic += MUX2 * width as f64; // enable recirculation mux
    c
}

/// Ripple-carry adder (area-efficient; used at relaxed clocks).
pub fn adder_rca(width: u32) -> Component {
    let w = width as f64;
    comb(&format!("rca{width}"), FA * w, 0.20, 2.0 * w, 3.0)
}

/// Carry-lookahead/parallel-prefix adder (speed; ~40% more area, log depth).
pub fn adder_cla(width: u32) -> Component {
    let w = width as f64;
    comb(
        &format!("cla{width}"),
        FA * w * 1.4,
        0.22,
        4.0 + 2.0 * (w.max(2.0)).log2(),
        4.0,
    )
}

/// Pick the adder style that meets `levels_budget` NAND2 levels.
pub fn adder_for_budget(width: u32, levels_budget: f64) -> Component {
    let rca = adder_rca(width);
    if rca.depth_levels <= levels_budget {
        rca
    } else {
        adder_cla(width)
    }
}

/// Array multiplier `a x b` bits: a*b partial-product AND gates plus (a-1)
/// b-bit carry-save rows and a final CLA — the O(W^2) structure of the
/// paper's Table 1.
pub fn multiplier(a: u32, b: u32) -> Component {
    let (af, bf) = (a as f64, b as f64);
    let partial = AND2 * af * bf;
    let rows = FA * bf * (af - 1.0).max(0.0);
    let final_add = FA * (af + bf) * 1.4;
    let logic = partial + rows + final_add;
    // multiplier cones are deep and buffer-heavy
    let mut c = comb(
        &format!("mul{a}x{b}"),
        logic,
        0.28,
        2.0 * bf + 4.0 + 2.0 * (af + bf).log2(),
        6.0,
    );
    c.gates.buffer = logic * (BUF_FRAC + 0.06);
    c.gates.inverter = logic * (INV_FRAC + 0.04);
    c
}

/// `n`:1 mux, `width` bits wide (tree of 2:1 muxes).
pub fn mux(n: usize, width: u32) -> Component {
    assert!(n >= 1);
    let w = width as f64;
    let two_to_one = (n.saturating_sub(1)) as f64;
    comb(
        &format!("mux{n}x{width}"),
        MUX2 * w * two_to_one,
        0.15,
        2.0 * (n.max(2) as f64).log2(),
        2.0,
    )
}

/// Binary decoder `bits -> 2^bits` one-hot lines.
pub fn decoder(bits: u32) -> Component {
    let outputs = (1usize << bits) as f64;
    comb(
        &format!("dec{bits}"),
        outputs * 1.2 + bits as f64,
        0.10,
        2.0 + bits as f64 * 0.5,
        outputs,
    )
}

/// Equality comparator over `bits` (tap index == bin index).
pub fn comparator(bits: u32) -> Component {
    let b = bits as f64;
    comb(&format!("cmp{bits}"), 3.0 * b + 2.0, 0.18, 3.0 + (b.max(2.0)).log2(), 2.0)
}

/// AND-mask of a `width`-bit value by one select line.
pub fn and_mask(width: u32) -> Component {
    comb(&format!("mask{width}"), AND2 * width as f64, 0.18, 1.0, 2.0)
}

/// Balanced adder tree over `n` inputs of `width` bits (carry-save style:
/// n-1 adders, widths growing toward the root — approximated at the mean
/// width `width + log2(n)/2`).
pub fn adder_tree(n: usize, width: u32) -> Component {
    if n <= 1 {
        return comb(&format!("addtree{n}x{width}"), 0.0, 0.0, 0.0, 1.0);
    }
    let mean_w = width as f64 + (n as f64).log2() / 2.0;
    let logic = FA * mean_w * (n as f64 - 1.0) * 1.15; // 1.15: CSA wiring overhead
    comb(
        &format!("addtree{n}x{width}"),
        logic,
        0.20,
        (2.0 * (n as f64).log2()) + 4.0 + 2.0 * mean_w.log2(),
        3.0,
    )
}

/// Register file: `entries x width` bits with `read_ports` and
/// `write_ports`.  Port costs are O(W·B), matching the paper's Table 1
/// "File Port" row.
pub fn regfile(entries: usize, width: u32, read_ports: usize, write_ports: usize) -> Component {
    let storage = register(width).gates * entries as f64;
    let mut total = storage;
    for _ in 0..read_ports {
        total += mux(entries, width).gates;
    }
    let wbits = crate::quant::fixed::ceil_log2(entries.max(2));
    for _ in 0..write_ports {
        total += decoder(wbits).gates;
        total += and_mask(width).gates * entries as f64 * 0.5; // per-entry en
    }
    Component::new(
        format!("rf{entries}x{width}r{read_ports}w{write_ports}"),
        total,
        0.12,
        2.0 * (entries.max(2) as f64).log2() + 2.0,
        entries as f64,
    )
}

/// Small control FSM (gray-encoded, as in the paper §4).
pub fn fsm(states: usize) -> Component {
    let bits = crate::quant::fixed::ceil_log2(states.max(2)) as f64;
    let mut c = comb("fsm", 12.0 * bits, 0.20, 6.0, 3.0);
    c.gates.sequential = DFF * bits;
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiplier_is_quadratic() {
        // Table 1: multiplier O(W^2) — quadrupling W should ~16x the gates
        let m8 = multiplier(8, 8).gates.total();
        let m32 = multiplier(32, 32).gates.total();
        let ratio = m32 / m8;
        assert!(ratio > 10.0 && ratio < 22.0, "ratio {ratio}");
    }

    #[test]
    fn adder_is_linear() {
        let a8 = adder_rca(8).gates.total();
        let a32 = adder_rca(32).gates.total();
        let ratio = a32 / a8;
        assert!(ratio > 3.5 && ratio < 4.5, "ratio {ratio}");
    }

    #[test]
    fn multiplier_dominates_adder() {
        // the premise of the whole paper
        for w in [8u32, 16, 32] {
            assert!(multiplier(w, w).gates.total() > 5.0 * adder_rca(w).gates.total());
        }
    }

    #[test]
    fn regfile_port_cost_scales_with_entries_and_width() {
        // Table 1: file port O(W·B)
        let base = regfile(4, 8, 1, 1).gates.total();
        let more_entries = regfile(16, 8, 1, 1).gates.total();
        let wider = regfile(4, 32, 1, 1).gates.total();
        assert!(more_entries > 2.0 * base);
        assert!(wider > 2.0 * base);
    }

    #[test]
    fn cla_faster_but_bigger() {
        let rca = adder_rca(32);
        let cla = adder_cla(32);
        assert!(cla.depth_levels < rca.depth_levels / 3.0);
        assert!(cla.gates.total() > rca.gates.total());
    }

    #[test]
    fn adder_for_budget_picks_style() {
        // tight budget -> CLA, loose -> RCA
        assert!(adder_for_budget(32, 20.0).name.starts_with("cla"));
        assert!(adder_for_budget(32, 100.0).name.starts_with("rca"));
    }

    #[test]
    fn breakdown_total_sums() {
        let c = multiplier(16, 16);
        let g = c.gates;
        assert!((g.total() - (g.sequential + g.inverter + g.buffer + g.logic)).abs() < 1e-9);
        assert_eq!(g.sequential, 0.0);
    }

    #[test]
    fn scale_combinational_keeps_ffs() {
        let c = register_en(8);
        let scaled = c.gates.scale_combinational(2.0);
        assert_eq!(scaled.sequential, c.gates.sequential);
        assert!(scaled.logic > c.gates.logic);
    }

    #[test]
    fn adder_tree_linear_in_inputs() {
        let t16 = adder_tree(16, 32).gates.total();
        let t64 = adder_tree(64, 32).gates.total();
        assert!(t64 / t16 > 3.5 && t64 / t16 < 4.6);
    }

    #[test]
    fn mux_grows_with_inputs() {
        assert!(mux(16, 32).gates.total() > 3.0 * mux(4, 32).gates.total());
    }
}
