//! Critical-path delay estimation and timing-pressure area elasticity.
//!
//! Synthesis at an aggressive clock (the paper's 1 GHz) upsizes cells,
//! inserts buffers and duplicates logic to close timing — area and power
//! grow super-linearly as the natural path delay approaches the period.
//! This is the mechanism behind the paper's Fig 17 result (16-bin, 32-bit
//! PASM loses at 1 GHz): the PAS read-modify-write recurrence
//! (bin-select mux → accumulator add → write-back across B sinks) is a
//! loop-carried dependency that cannot be pipelined, so its delay must fit
//! one period, and its fanout grows with B.
//!
//! The elasticity curve is calibrated once against the paper's conv-accel
//! series (4/8/16-bin, §5.1) and then reused unchanged for every other
//! experiment.

use crate::hw::gates::Component;
use crate::hw::tech::Tech;

/// A combinational path: accumulated levels + fanout sinks + FF endpoints.
#[derive(Clone, Copy, Debug, Default)]
pub struct PathDelay {
    /// Accumulated combinational depth in NAND2 levels.
    pub levels: f64,
    /// Accumulated fanout sinks on the widest net.
    pub fanout_sinks: f64,
    /// Number of register boundaries crossed (usually 1: reg -> logic -> reg).
    pub ff_stages: f64,
}

impl PathDelay {
    /// An empty single-stage path.
    pub fn new() -> Self {
        PathDelay { levels: 0.0, fanout_sinks: 0.0, ff_stages: 1.0 }
    }

    /// Chain a component onto the path.
    pub fn through(mut self, c: &Component) -> Self {
        self.levels += c.depth_levels;
        self.fanout_sinks += c.max_fanout;
        self
    }

    /// Add raw levels (wire stubs, control gating).
    pub fn plus_levels(mut self, levels: f64) -> Self {
        self.levels += levels;
        self
    }

    /// Add a high-fanout broadcast to `sinks` loads.
    pub fn broadcast(mut self, sinks: f64) -> Self {
        self.fanout_sinks += sinks;
        self
    }

    /// Path delay in seconds under `tech`.
    pub fn delay_s(&self, tech: &Tech) -> f64 {
        self.levels * tech.gate_delay_s
            + self.fanout_sinks * tech.fanout_delay_per_sink_s
            + self.ff_stages * tech.ff_overhead_s
    }

    /// Delay as a fraction of the clock period (>1 = timing violation
    /// before upsizing).
    pub fn utilization(&self, tech: &Tech) -> f64 {
        self.delay_s(tech) / tech.period_s()
    }
}

/// Area multiplier applied to the combinational gates on a path to model
/// synthesis closing timing.
///
/// * `u <= 0.6` — relaxed: tools *downsize* slightly (min-area recovery);
///   we keep the factor at 1.0 to stay conservative.
/// * `0.6 < u <= 1.0` — quadratic upsizing as slack evaporates.
/// * `u > 1.0` — the natural netlist violates timing; logic duplication,
///   speculative/carry-select structures and buffer trees grow area
///   steeply (and the tool may still fail — we model the cost, as Genus
///   does when it "increases the area ... to meet timing", §5.1).
pub fn timing_area_factor(utilization: f64) -> f64 {
    const KNEE: f64 = 0.6;
    const QUAD: f64 = 1.8; // growth inside the period
    const OVER: f64 = 3.5; // growth past the period
    if utilization <= KNEE {
        1.0
    } else if utilization <= 1.0 {
        let x = (utilization - KNEE) / (1.0 - KNEE);
        1.0 + QUAD * x * x
    } else {
        1.0 + QUAD + OVER * (utilization - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gates::{adder_cla, adder_rca, multiplier, mux};

    #[test]
    fn factor_monotone_and_continuous() {
        let mut prev = 0.0;
        for i in 0..200 {
            let u = i as f64 * 0.01;
            let f = timing_area_factor(u);
            assert!(f >= prev, "not monotone at u={u}");
            prev = f;
        }
        // continuity at the knees
        assert!((timing_area_factor(0.6) - 1.0).abs() < 1e-9);
        let below = timing_area_factor(0.9999);
        let above = timing_area_factor(1.0001);
        assert!((above - below).abs() < 0.01);
    }

    #[test]
    fn relaxed_clock_no_penalty() {
        let t = Tech::asic_100mhz();
        // a full 32x32 multiply path fits easily in 10 ns
        let p = PathDelay::new().through(&multiplier(32, 32));
        assert!(p.utilization(&t) < 0.6, "u = {}", p.utilization(&t));
        assert_eq!(timing_area_factor(p.utilization(&t)), 1.0);
    }

    #[test]
    fn rca32_violates_1ghz() {
        let t = Tech::asic_1ghz();
        let p = PathDelay::new().through(&adder_rca(32));
        assert!(p.utilization(&t) > 1.0, "u = {}", p.utilization(&t));
        // ...but a CLA fits
        let p2 = PathDelay::new().through(&adder_cla(32));
        assert!(p2.utilization(&t) < 1.0, "u = {}", p2.utilization(&t));
    }

    #[test]
    fn fanout_pressure_grows_with_bins() {
        let t = Tech::asic_1ghz();
        let path_b = |bins: usize| {
            PathDelay::new()
                .through(&mux(bins, 42))
                .through(&adder_cla(42))
                .broadcast(bins as f64 * 42.0 * 0.25)
        };
        let u4 = path_b(4).utilization(&t);
        let u16 = path_b(16).utilization(&t);
        let u64 = path_b(64).utilization(&t);
        assert!(u4 < u16 && u16 < u64);
        assert!(timing_area_factor(u64) > timing_area_factor(u4));
    }
}
