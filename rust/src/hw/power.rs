//! Power model: leakage + activity-weighted dynamic power.
//!
//! Mirrors the quantities the paper reports from Genus "report power":
//! **leakage**, **dynamic** and **total**, per design.  Dynamic power is
//! `Σ_component gates · α · E_toggle · f` plus the clock tree
//! (`sequential_bits · E_clk · f`); activity factors `α` default to the
//! component library's estimates and can be overridden with measured toggle
//! rates from the cycle-accurate simulator (`sim::activity`).

use crate::hw::gates::{Component, GateBreakdown};
use crate::hw::tech::Tech;

/// Power report for one design (Watts).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PowerBreakdown {
    /// Static (leakage) power.
    pub leakage_w: f64,
    /// Activity-weighted switching power (incl. clock tree).
    pub dynamic_w: f64,
}

impl PowerBreakdown {
    /// Leakage + dynamic power (W).
    pub fn total_w(&self) -> f64 {
        self.leakage_w + self.dynamic_w
    }
}

/// A design is a bag of components, each possibly carrying a measured
/// activity override and a timing-derived area factor.
#[derive(Clone, Debug, Default)]
pub struct PowerModel {
    entries: Vec<Entry>,
}

#[derive(Clone, Debug)]
struct Entry {
    gates: GateBreakdown,
    activity: f64,
    /// Duty cycle: fraction of cycles this component is active at all.
    duty: f64,
}

impl PowerModel {
    /// An empty model (add components, then evaluate).
    pub fn new() -> Self {
        PowerModel { entries: Vec::new() }
    }

    /// Add a component with its default activity, full duty.
    pub fn add(&mut self, c: &Component) -> &mut Self {
        self.add_scaled(c, c.activity, 1.0, 1.0)
    }

    /// Add a component with overrides: measured `activity`, `duty` cycle
    /// fraction, and timing `area_factor` on its combinational gates.
    pub fn add_scaled(
        &mut self,
        c: &Component,
        activity: f64,
        duty: f64,
        area_factor: f64,
    ) -> &mut Self {
        assert!((0.0..=1.0).contains(&activity), "activity out of range");
        assert!((0.0..=1.0).contains(&duty), "duty out of range");
        assert!(area_factor >= 1.0);
        self.entries.push(Entry {
            gates: c.gates.scale_combinational(area_factor),
            activity,
            duty,
        });
        self
    }

    /// Total gate breakdown of the design.
    pub fn gates(&self) -> GateBreakdown {
        self.entries
            .iter()
            .fold(GateBreakdown::default(), |acc, e| acc + e.gates)
    }

    /// Evaluate power under a technology target.
    pub fn power(&self, tech: &Tech) -> PowerBreakdown {
        let mut leakage = 0.0;
        let mut dynamic = 0.0;
        for e in &self.entries {
            let total_gates = e.gates.total();
            leakage += total_gates * tech.leakage_per_gate_w;
            // combinational + data toggling
            dynamic +=
                total_gates * e.activity * e.duty * tech.toggle_energy_j * tech.clock_hz;
            // clock tree burns every cycle regardless of data activity
            let ff_bits = e.gates.sequential / 6.0; // DFF ≈ 6 NAND2-eq
            dynamic += ff_bits * tech.clock_energy_per_bit_j * tech.clock_hz;
        }
        PowerBreakdown { leakage_w: leakage, dynamic_w: dynamic }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gates::{multiplier, register};

    #[test]
    fn leakage_scales_with_gates() {
        let t = Tech::asic_100mhz();
        let mut small = PowerModel::new();
        small.add(&multiplier(8, 8));
        let mut big = PowerModel::new();
        big.add(&multiplier(32, 32));
        let ratio = big.power(&t).leakage_w / small.power(&t).leakage_w;
        assert!(ratio > 10.0, "ratio {ratio}");
    }

    #[test]
    fn dynamic_scales_with_frequency() {
        let mut m = PowerModel::new();
        m.add(&multiplier(16, 16));
        let p100 = m.power(&Tech::asic_100mhz());
        let p1g = m.power(&Tech::asic_1ghz());
        assert!((p1g.dynamic_w / p100.dynamic_w - 10.0).abs() < 1e-6);
        assert!((p1g.leakage_w - p100.leakage_w).abs() < 1e-12);
    }

    #[test]
    fn idle_duty_cuts_dynamic_not_leakage() {
        let c = multiplier(16, 16);
        let mut busy = PowerModel::new();
        busy.add_scaled(&c, c.activity, 1.0, 1.0);
        let mut idle = PowerModel::new();
        idle.add_scaled(&c, c.activity, 0.1, 1.0);
        let t = Tech::asic_1ghz();
        assert!(idle.power(&t).dynamic_w < 0.2 * busy.power(&t).dynamic_w);
        assert_eq!(idle.power(&t).leakage_w, busy.power(&t).leakage_w);
    }

    #[test]
    fn clock_tree_burns_on_registers() {
        let mut m = PowerModel::new();
        // zero data activity: only the clock tree should show up
        m.add_scaled(&register(64), 0.0, 1.0, 1.0);
        let p = m.power(&Tech::asic_1ghz());
        assert!(p.dynamic_w > 0.0);
    }

    #[test]
    fn area_factor_raises_both() {
        let c = multiplier(16, 16);
        let mut plain = PowerModel::new();
        plain.add(&c);
        let mut pressured = PowerModel::new();
        pressured.add_scaled(&c, c.activity, 1.0, 2.0);
        let t = Tech::asic_1ghz();
        assert!(pressured.power(&t).leakage_w > 1.8 * plain.power(&t).leakage_w);
        assert!(pressured.power(&t).dynamic_w > 1.8 * plain.power(&t).dynamic_w);
        assert!(pressured.gates().total() > 1.8 * plain.gates().total());
    }

    #[test]
    fn magnitudes_sane() {
        // 16 parallel 32-bit MACs at 100 MHz should land in the mW range
        let mut m = PowerModel::new();
        for _ in 0..16 {
            m.add(&multiplier(32, 32));
            m.add(&register(74));
        }
        let p = m.power(&Tech::asic_100mhz());
        assert!(p.total_w() > 1e-4 && p.total_w() < 1.0, "total {}", p.total_w());
    }
}
