//! Memory-access energy model — the paper's §1 motivation quantified.
//!
//! Han et al. (cited by the paper): a 32-bit off-chip DRAM access costs
//! **640 pJ** while an on-chip SRAM access costs **5 pJ**; weight sharing
//! exists to shrink weight traffic until it fits on-chip.  This module
//! prices the weight traffic of a conv layer under the compression chain
//! (dense → weight-shared indices → +Huffman) and the storage footprint
//! that decides on-chip vs off-chip residence.

use crate::tensor::ConvShape;

/// Energy per 32-bit off-chip DRAM access (J) — Han et al. 2016's
/// number, as quoted in the paper's introduction.
pub const DRAM_ACCESS_32B_J: f64 = 640e-12;
/// Energy per 32-bit on-chip SRAM access (J) — same source.
pub const SRAM_ACCESS_32B_J: f64 = 5e-12;
/// Register-file access (the shared-weight dictionary itself).
pub const REGFILE_ACCESS_32B_J: f64 = 1e-12;

/// Where the weight data lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residence {
    /// Weights stream from off-chip DRAM.
    OffChipDram,
    /// Weights fit in on-chip SRAM.
    OnChipSram,
}

/// Weight-storage format of a conv layer.
#[derive(Clone, Copy, Debug)]
pub enum WeightFormat {
    /// Dense W-bit weights.
    Dense { width_bits: u32 },
    /// Weight-shared: WCI-bit indices + a B-entry codebook.
    Indexed { index_bits: u32, bins: usize, width_bits: u32 },
    /// Weight-shared + Huffman: mean index length from the bin histogram.
    HuffmanIndexed { mean_bits: f64, bins: usize, width_bits: u32 },
}

impl WeightFormat {
    /// Total storage for one layer's weights (bits).
    pub fn storage_bits(&self, shape: &ConvShape) -> f64 {
        let n = (shape.kernels * shape.taps()) as f64;
        match *self {
            WeightFormat::Dense { width_bits } => n * width_bits as f64,
            WeightFormat::Indexed { index_bits, bins, width_bits } => {
                n * index_bits as f64 + (bins as f64) * width_bits as f64
            }
            WeightFormat::HuffmanIndexed { mean_bits, bins, width_bits } => {
                // indices + codebook + the B-entry code-length table
                n * mean_bits + (bins as f64) * (width_bits as f64 + 8.0)
            }
        }
    }

    /// Compression factor vs dense at the same weight width.
    pub fn compression_vs_dense(&self, shape: &ConvShape) -> f64 {
        let dense = match *self {
            WeightFormat::Dense { width_bits }
            | WeightFormat::Indexed { width_bits, .. }
            | WeightFormat::HuffmanIndexed { width_bits, .. } => {
                WeightFormat::Dense { width_bits }.storage_bits(shape)
            }
        };
        dense / self.storage_bits(shape)
    }
}

/// Energy to stream one layer's weight data once (J): storage bits at the
/// residence's per-32-bit access cost.  Per-tap dictionary reads are NOT
/// charged here — the B-entry register file's read energy is part of the
/// datapath power model (`hw::power`), identically for the WS and PASM
/// designs; this function prices only the *memory traffic* the compression
/// chain shrinks.
pub fn weight_stream_energy(shape: &ConvShape, fmt: &WeightFormat, residence: Residence) -> f64 {
    let per32 = match residence {
        Residence::OffChipDram => DRAM_ACCESS_32B_J,
        Residence::OnChipSram => SRAM_ACCESS_32B_J,
    };
    fmt.storage_bits(shape) / 32.0 * per32
}

/// Does the weight data fit an on-chip budget?
pub fn fits_on_chip(shape: &ConvShape, fmt: &WeightFormat, budget_bits: f64) -> bool {
    fmt.storage_bits(shape) <= budget_bits
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> ConvShape {
        // AlexNet-conv2-like: 96ch, 5x5, 256 kernels
        ConvShape::new(96, 15, 15, 5, 5, 256, 1)
    }

    #[test]
    fn index_compression_is_w_over_wci() {
        let shape = layer();
        let dense = WeightFormat::Dense { width_bits: 32 };
        let idx = WeightFormat::Indexed { index_bits: 4, bins: 16, width_bits: 32 };
        let ratio = idx.compression_vs_dense(&shape);
        // codebook overhead is negligible at this size: ratio ≈ 8
        assert!(ratio > 7.9 && ratio <= 8.0, "{ratio}");
        assert!(dense.compression_vs_dense(&shape) == 1.0);
    }

    #[test]
    fn huffman_beats_fixed_indices_on_skew() {
        let shape = layer();
        let idx = WeightFormat::Indexed { index_bits: 4, bins: 16, width_bits: 32 };
        let huff = WeightFormat::HuffmanIndexed { mean_bits: 2.3, bins: 16, width_bits: 32 };
        assert!(huff.storage_bits(&shape) < idx.storage_bits(&shape));
        assert!(huff.compression_vs_dense(&shape) > 13.0);
    }

    #[test]
    fn dram_vs_sram_is_128x() {
        assert!((DRAM_ACCESS_32B_J / SRAM_ACCESS_32B_J - 128.0).abs() < 1e-9);
    }

    #[test]
    fn compression_moves_weights_on_chip() {
        let shape = layer();
        let budget = 4e6; // 4 Mbit on-chip weight buffer (dense needs ~20 Mbit)
        let dense = WeightFormat::Dense { width_bits: 32 };
        let idx = WeightFormat::Indexed { index_bits: 4, bins: 16, width_bits: 32 };
        assert!(!fits_on_chip(&shape, &dense, budget));
        assert!(fits_on_chip(&shape, &idx, budget));
        // and the energy gap: dense-from-DRAM vs indexed-from-SRAM
        let e_dense = weight_stream_energy(&shape, &dense, Residence::OffChipDram);
        let e_idx = weight_stream_energy(&shape, &idx, Residence::OnChipSram);
        assert!(
            e_dense / e_idx > 100.0,
            "expected >100x energy gap, got {}",
            e_dense / e_idx
        );
    }

    #[test]
    fn stream_energy_is_linear_in_bits() {
        let shape = ConvShape::new(2, 5, 5, 3, 3, 2, 1);
        let idx = WeightFormat::Indexed { index_bits: 4, bins: 16, width_bits: 32 };
        let on = weight_stream_energy(&shape, &idx, Residence::OnChipSram);
        let expected = idx.storage_bits(&shape) / 32.0 * SRAM_ACCESS_32B_J;
        assert!((on - expected).abs() < 1e-18);
        // DRAM residence costs 128x more for the same format
        let off = weight_stream_energy(&shape, &idx, Residence::OffChipDram);
        assert!((off / on - 128.0).abs() < 1e-9);
    }
}
