//! On-chip SRAM macro cost model (the paper's footnote 1 what-if).
//!
//! The paper's OSU FreePDK45 flow could not synthesize SRAM, forcing the
//! image cache into registers and capping the tile at C=15.  "The
//! weight-shared-with-PASM is likely to be even more effective with larger
//! input blocks (particularly a large value of C), because the cost of the
//! post-pass multiplication can be amortized over more inputs."  This
//! module prices SRAM macros (CACTI-like scaling: 6T cell area + periphery
//! that grows with the square root of capacity) so the large-C study
//! (`examples/large_c_study.rs`) can explore exactly that claim.

use crate::hw::gates::{Component, GateBreakdown};

/// 6T SRAM cell area relative to a NAND2X1 (a NAND2 is 4T plus routing;
/// a 6T bitcell is ~0.3x the NAND2 footprint in a commodity 45 nm macro).
const CELL_NAND2_EQUIV: f64 = 0.3;

/// Periphery (decoders, sense amps, drivers) as NAND2-equivalents:
/// `PERIPH_K * sqrt(bits) * ports`.
const PERIPH_K: f64 = 18.0;

/// Read/write energy per access: `E0 + E1 * sqrt(bits)` (bitline/wordline
/// length grows with the array edge).
const ACCESS_E0_J: f64 = 0.4e-12;
const ACCESS_E1_J: f64 = 0.9e-15;

/// Leakage per bit (W) — 6T cells leak far less than DFFs.
const LEAK_PER_BIT_W: f64 = 1.2e-10;

/// A single-bank SRAM macro.
#[derive(Clone, Copy, Debug)]
pub struct SramMacro {
    /// Capacity in bits.
    pub bits: u64,
    /// Read/write port count.
    pub ports: u32,
}

impl SramMacro {
    /// A macro of `bits` capacity with `ports` ports (both >= 1).
    pub fn new(bits: u64, ports: u32) -> Self {
        assert!(bits > 0 && ports >= 1);
        SramMacro { bits, ports }
    }

    /// Area in NAND2 equivalents (cells + periphery).
    pub fn area_nand2(&self) -> f64 {
        self.bits as f64 * CELL_NAND2_EQUIV
            + PERIPH_K * (self.bits as f64).sqrt() * self.ports as f64
    }

    /// Energy of one access (J).
    pub fn access_energy_j(&self) -> f64 {
        ACCESS_E0_J + ACCESS_E1_J * (self.bits as f64).sqrt()
    }

    /// Leakage power (W).
    pub fn leakage_w(&self) -> f64 {
        self.bits as f64 * LEAK_PER_BIT_W
    }

    /// As a [`Component`] for the aggregate models: the area goes into the
    /// `logic` bucket (macros are reported as block area, not cells), with
    /// an activity that reflects `accesses_per_cycle` amortized over the
    /// array (only the accessed row toggles).
    pub fn component(&self, name: &str, accesses_per_cycle: f64) -> Component {
        let area = self.area_nand2();
        // effective toggling fraction: row energy expressed as if
        // `activity` of the block's gates toggled at 1.2 fJ each
        let eq_toggles = self.access_energy_j() / 1.2e-15;
        let activity = (accesses_per_cycle * eq_toggles / area).min(1.0);
        Component {
            name: name.into(),
            gates: GateBreakdown { sequential: 0.0, inverter: 0.0, buffer: 0.0, logic: area },
            activity,
            depth_levels: 8.0 + (self.bits as f64).log2() * 0.5, // decode + array
            max_fanout: 4.0,
        }
    }
}

/// Register-file cost of the same capacity (what the paper was forced to
/// use) — for the crossover comparison.
pub fn register_cost_nand2(bits: u64) -> f64 {
    crate::hw::gates::register(1).gates.total() * bits as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_beats_registers_at_scale() {
        // periphery dominates tiny macros (bad per-bit cost); large macros
        // amortize it and beat registers by >10x
        let small = SramMacro::new(64, 1);
        let big = SramMacro::new(64 * 1024, 1);
        let per_bit_small = small.area_nand2() / 64.0;
        let per_bit_big = big.area_nand2() / (64.0 * 1024.0);
        assert!(per_bit_small > 4.0 * per_bit_big);
        assert!(big.area_nand2() < register_cost_nand2(64 * 1024) / 10.0);
    }

    #[test]
    fn access_energy_grows_sublinearly() {
        let e1 = SramMacro::new(1 << 10, 1).access_energy_j();
        let e2 = SramMacro::new(1 << 20, 1).access_energy_j();
        assert!(e2 > e1);
        assert!(e2 < e1 * 64.0); // sqrt scaling: 32x edge for 1024x bits
    }

    #[test]
    fn ports_cost_periphery() {
        let p1 = SramMacro::new(4096, 1).area_nand2();
        let p2 = SramMacro::new(4096, 2).area_nand2();
        assert!(p2 > p1);
        assert!(p2 < p1 * 2.0); // cells are shared
    }

    #[test]
    fn component_activity_bounded() {
        let m = SramMacro::new(1 << 16, 1);
        let c = m.component("image_sram", 1.0);
        assert!(c.activity > 0.0 && c.activity <= 1.0);
        assert!(c.gates.total() > 0.0);
    }

    #[test]
    fn leakage_much_lower_than_dff() {
        // per bit: DFF leaks ~6 gates x 25 nW; SRAM ~0.12 nW
        let dff_per_bit = 6.0 * 2.5e-8;
        assert!(LEAK_PER_BIT_W < dff_per_bit / 100.0);
    }
}
