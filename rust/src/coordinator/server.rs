//! The coordinator server: builder, supervised shard pool, submission
//! handle.
//!
//! [`CoordinatorBuilder`] assembles a backend (and/or a
//! [`ModelRegistry`]), a batch policy, and a cost model into a running
//! [`Coordinator`] — a **pool of N independent shard workers**
//! ([`CoordinatorBuilder::shards`]; default `available_parallelism`,
//! capped at [`DEFAULT_MAX_SHARDS`]).  Each shard owns its own
//! [`Engine`] (backend executables need not be `Sync`; compilation
//! happens on the shard's thread), its own per-model queues, and its own
//! shard-local [`Metrics`], so batching and dispatch scale past one core
//! with **zero cross-shard coordination**.
//!
//! Requests route to shards by a stable FNV-1a hash of the model id
//! ([`Coordinator::shard_for`]): all traffic for one model lands on one
//! shard, so the single-worker invariants — a launched batch never mixes
//! models, per-model FIFO order, hot-swap without dropping in-flight
//! requests — hold per shard by construction, which is to say globally.
//! Unnamed requests route by the default model's name (or a fixed key
//! when no registry is attached), so they share a shard with the named
//! traffic of the same model.
//!
//! Within a shard the worker drains its request channel into per-model
//! queues, purges requests whose [`InferenceRequest::deadline`] already
//! expired (each purge is answered with a typed `deadline exceeded`
//! error and counted in [`Metrics::record_deadline_miss`]), then applies
//! the [`BatchPolicy`] to each queue: wait for a fillable bucket or the
//! oldest request's wait budget, then launch the queue whose front
//! request has waited longest.  Clients get a per-request response
//! channel.  Drop the [`Coordinator`] to shut down cleanly: every shard
//! flushes its pending requests before its worker exits — the pool
//! drains losing nothing, exactly like the old single worker.
//!
//! # Elasticity (cross-shard batch stealing)
//!
//! Hash routing pins each model to one shard, so a Zipf-skewed workload
//! saturates one engine while the rest of the pool idles.  With
//! [`CoordinatorBuilder::steal`] enabled, a shard whose per-model load
//! signal (queue depth × EWMA batch cost) crosses the promotion
//! threshold ([`CoordinatorBuilder::steal_promote_us`]) stops executing
//! that model's batches inline: it *forms* them as usual — stamping
//! each with its `batch_seq` — and pushes the formed batches onto a
//! pool-shared deck, where any idle shard (or the home shard itself,
//! which polls the deck first) pops and executes them.  Because the
//! home shard remains the only batch former and sequence numbers are
//! stamped at formation, the FIFO witness (`(shard, batch_seq)`
//! non-decreasing per model in submission order) is preserved by
//! construction; responses carry the home shard in
//! [`InferenceResponse::shard`] and the executor in
//! [`InferenceResponse::executed_by`].  A thief lazily compiles a
//! read-only replica of the model's executable on first use (the
//! [`Engine`]'s replica slots) and the periodic sweep evicts it once
//! the model cools, so cold models never bloat every shard's cache.
//! Steal mode off (the default) is bit-for-bit the legacy single-owner
//! behavior.  See `docs/ARCHITECTURE.md` ("Elasticity").
//!
//! # Supervision
//!
//! `catch_unwind` contains a kernel panic per batch, but nothing used to
//! catch a worker *thread* dying outright — that stranded its queues
//! forever.  The pool now runs a **supervisor thread** that sweeps the
//! shards every few tens of milliseconds: a dead worker is joined, its
//! stranded requests are answered with a typed `shard worker died` error
//! (every [`Completion`] is a drop-guard, so a request dropped anywhere
//! on the way down still gets a terminal reply), and a fresh worker is
//! respawned from the registry snapshot (or a
//! [`ExecutionBackend::replicate`] template).  Restarts are counted in
//! [`Coordinator::shard_restarts`] and reported in the `metrics` wire
//! frame.  Submissions that race a dead shard fail with a retryable
//! `unavailable` error rather than hanging.  Backends that cannot
//! replicate (and have no registry to rebuild from) are served without
//! respawn — the drop-guards still answer every stranded request.
//!
//! # Fault injection
//!
//! [`CoordinatorBuilder::fault_plan`] attaches a deterministic
//! [`FaultPlan`] (see [`crate::faults`]): the worker loop consults it
//! before each launch for injected latency, execution errors, kernel
//! panics, and worker kills, and an attached registry inherits the plan
//! for torn artifact loads.  Without a plan every hook is inert.
//!
//! # Observability
//!
//! Unless disabled with [`CoordinatorBuilder::trace_capacity`]`(0)`, the
//! pool allocates one lock-free [`TraceBuf`] ring per shard and records
//! every request's lifecycle into it: `enqueued` → `batch_formed` →
//! `launched` → `executed` on the worker (plus `accepted`/`decoded`
//! ingress timestamps carried in on the request, and
//! `deadline_drop`/`fault` annotations), with the serving front-ends
//! appending `reply_written`/`retried` through
//! [`Coordinator::record_reply_written`] /
//! [`Coordinator::record_retry_advised`].  The same stage boundaries
//! feed the per-stage latency histograms in each shard's [`Metrics`]
//! (queue-wait, batch-form, execute, write-back).  See
//! `docs/ARCHITECTURE.md` ("Observability") for the stage diagram and
//! overhead budget.

use crate::coordinator::backend::{ExecutionBackend, NativeBackend};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::cost::CostModel;
use crate::coordinator::engine::{BatchOrigin, Engine};
use crate::coordinator::metrics::{DEFAULT_MODEL_LABEL, Metrics, ShardCounters};
use crate::coordinator::request::{InferenceRequest, InferenceResponse, Ingress};
use crate::faults::{FaultPlan, FaultSite};
use crate::model_store::ModelRegistry;
use crate::obs::{DEFAULT_TRACE_CAPACITY, Stage, TraceBuf};
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Cap on the *default* shard count (an explicit
/// [`CoordinatorBuilder::shards`] may exceed it).  Each shard is a full
/// engine with compiled executables; past a handful of shards the
/// batcher stops being the bottleneck and extra shards only fragment
/// batches.
pub const DEFAULT_MAX_SHARDS: usize = 8;

/// How often the supervisor sweeps the pool for dead shard workers.
const SUPERVISOR_SWEEP: Duration = Duration::from_millis(20);

/// Error text a request stranded by a dead worker is answered with (the
/// serving layer maps it to a retryable `UNAVAILABLE` wire error).
const WORKER_DIED: &str = "shard worker died before the request was served";

/// Default promotion threshold (µs) for batch donation: a model whose
/// `queue depth × EWMA batch cost` clears this has more backlog than the
/// home shard can drain timely, so formed batches go to the deck.
const DEFAULT_STEAL_PROMOTE_US: u64 = 2_000;

/// How long an idle shard waits on its request channel between deck
/// polls when steal mode is on (steal off blocks indefinitely — the
/// legacy behavior).
const STEAL_POLL: Duration = Duration::from_micros(500);

/// How long a replica executable may sit unused on a thief shard before
/// the periodic sweep evicts it (the demotion half of the promote /
/// demote policy).
const REPLICA_IDLE: Duration = Duration::from_secs(2);

// Poison-tolerant lock helpers: a panicking holder must not cascade into
// every later lock site (the data is counters and channel handles — the
// protected state stays coherent because writers never panic mid-update).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}
fn rlock<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}
fn wlock<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

enum Msg {
    Request(InferenceRequest, Completion),
    Shutdown,
}

enum CompletionKind {
    /// Send down a per-request response channel (receiver may be gone).
    Channel(mpsc::Sender<Result<InferenceResponse, String>>),
    /// Invoke a closure on the shard worker's thread.  Must be cheap and
    /// must not block: it runs inside the batching loop.
    Callback(Box<dyn FnOnce(Result<InferenceResponse, String>) + Send>),
}

/// How a finished request is delivered back to its submitter.
///
/// The channel form backs the blocking [`Coordinator::submit`] family;
/// the callback form backs [`Coordinator::submit_with`], which the
/// evented serving front-end uses so a completion costs a queue push and
/// a wake instead of a parked thread per in-flight request.
///
/// A `Completion` is a **drop-guard**: if it is destroyed without
/// [`Completion::deliver`] being called — a worker thread died with the
/// request still queued, a channel buffer was torn down — it delivers a
/// typed [`WORKER_DIED`] error on the way out.  That is the mechanism
/// behind the pool's "every admitted request gets a terminal reply"
/// guarantee; no code path needs to remember to fail requests by hand.
struct Completion(Option<CompletionKind>);

impl Completion {
    fn channel(tx: mpsc::Sender<Result<InferenceResponse, String>>) -> Self {
        Completion(Some(CompletionKind::Channel(tx)))
    }

    fn callback(f: Box<dyn FnOnce(Result<InferenceResponse, String>) + Send>) -> Self {
        Completion(Some(CompletionKind::Callback(f)))
    }

    fn deliver(mut self, result: Result<InferenceResponse, String>) {
        if let Some(kind) = self.0.take() {
            match kind {
                CompletionKind::Channel(tx) => {
                    let _ = tx.send(result);
                }
                CompletionKind::Callback(f) => f(result),
            }
        }
    }

    /// Defuse the drop-guard: the completion is being handed back to a
    /// caller who will report the failure itself (a failed submit must
    /// not *also* fire the callback).
    fn disarm(mut self) {
        self.0 = None;
    }
}

impl Drop for Completion {
    fn drop(&mut self) {
        if let Some(kind) = self.0.take() {
            match kind {
                CompletionKind::Channel(tx) => {
                    let _ = tx.send(Err(WORKER_DIED.to_string()));
                }
                CompletionKind::Callback(f) => f(Err(WORKER_DIED.to_string())),
            }
        }
    }
}

/// Stable routing hash (FNV-1a, 64-bit): deterministic across runs,
/// processes, and platforms, so a model's shard assignment is a fixed
/// function of its name and the shard count.
fn route_hash(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Builds a [`Coordinator`] from a backend and/or model registry, a batch
/// policy, a cost model, and a shard count.
///
/// The batch policy defaults to the backend's preferred buckets (e.g. the
/// sizes an AOT flow exported) or [`BatchPolicy::default`]; the cost model
/// defaults to PASM silicon at 45 nm / 1 GHz ([`CostModel::pasm_asic`]);
/// the shard count defaults to `available_parallelism` capped at
/// [`DEFAULT_MAX_SHARDS`] when a registry is attached, else 1 (backends
/// that cannot [`ExecutionBackend::replicate`] also serve from one
/// shard).
///
/// ```
/// use pasm_accel::cnn::data::{render_digit, Rng};
/// use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
/// use pasm_accel::coordinator::{BatchPolicy, CoordinatorBuilder, NativeBackend};
/// use pasm_accel::quant::fixed::QFormat;
/// use std::time::Duration;
///
/// let arch = DigitsCnn::default();
/// let mut rng = Rng::new(1);
/// let params = arch.init(&mut rng);
/// let enc = EncodedCnn::encode(arch, &params, 4, QFormat::W16);
///
/// let coord = CoordinatorBuilder::new()
///     .backend(NativeBackend::new(enc))
///     .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
///     .shards(2)
///     .build()?;
/// let resp = coord.infer(render_digit(&mut rng, 3, 0.05))?;
/// assert_eq!(resp.logits.len(), 10);
/// assert!(resp.hw.cycles > 0);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Default)]
pub struct CoordinatorBuilder {
    backend: Option<Box<dyn ExecutionBackend>>,
    policy: Option<BatchPolicy>,
    cost: Option<CostModel>,
    registry: Option<Arc<ModelRegistry>>,
    default_model: Option<String>,
    shards: Option<usize>,
    faults: Option<Arc<FaultPlan>>,
    trace_capacity: Option<usize>,
    steal: bool,
    steal_promote_us: Option<u64>,
}

impl CoordinatorBuilder {
    /// An empty builder (equivalent to `CoordinatorBuilder::default()`).
    pub fn new() -> Self {
        CoordinatorBuilder::default()
    }

    /// The execution backend to serve from (required unless a
    /// [`CoordinatorBuilder::registry`] provides the models).
    pub fn backend(mut self, backend: impl ExecutionBackend + 'static) -> Self {
        self.backend = Some(Box::new(backend));
        self
    }

    /// Same as [`CoordinatorBuilder::backend`] for an already-boxed backend.
    pub fn boxed_backend(mut self, backend: Box<dyn ExecutionBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Serve named models from this registry ([`Coordinator::submit_to`] /
    /// [`Coordinator::infer_model`]).  Without an explicit
    /// [`CoordinatorBuilder::backend`], a [`NativeBackend`] is built
    /// around the registry's default model, and *unnamed* requests route
    /// to that model **by name** — so hot-swapping its artifact takes
    /// effect without a restart.
    ///
    /// ```
    /// use pasm_accel::cnn::data::{render_digit, Rng};
    /// use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
    /// use pasm_accel::coordinator::CoordinatorBuilder;
    /// use pasm_accel::model_store::ModelRegistry;
    /// use pasm_accel::quant::fixed::QFormat;
    /// use std::sync::Arc;
    ///
    /// let arch = DigitsCnn::default();
    /// let mut rng = Rng::new(1);
    /// let registry = Arc::new(ModelRegistry::new());
    /// registry.insert("b4", EncodedCnn::encode(arch, &arch.init(&mut rng), 4, QFormat::W16));
    /// registry.insert("b8", EncodedCnn::encode(arch, &arch.init(&mut rng), 8, QFormat::W16));
    ///
    /// let coord = CoordinatorBuilder::new().registry(Arc::clone(&registry)).build()?;
    /// let resp = coord.infer_model("b8", render_digit(&mut rng, 3, 0.05))?;
    /// assert_eq!(resp.model.as_deref(), Some("b8"));
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn registry(mut self, registry: Arc<ModelRegistry>) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Which registry model unnamed requests route to (default: the
    /// registry's alphabetically first model).  Requires a registry.
    pub fn default_model(mut self, name: impl Into<String>) -> Self {
        self.default_model = Some(name.into());
        self
    }

    /// Bucketed dynamic-batching policy (default: the backend's preferred
    /// buckets with a 2 ms wait budget, else [`BatchPolicy::default`]).
    /// Every shard applies the same policy to its own queues.
    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Hardware cost model batches are priced with (default:
    /// [`CostModel::pasm_asic`]).
    pub fn cost_model(mut self, cost: CostModel) -> Self {
        self.cost = Some(cost);
        self
    }

    /// Attach a deterministic fault-injection plan (see
    /// [`crate::faults`]).  The shard workers consult it before every
    /// batch launch, and an attached [`CoordinatorBuilder::registry`]
    /// inherits it for torn-artifact-load injection.  Without this call
    /// no fault is ever injected.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(Arc::new(plan));
        self
    }

    /// Size of the shard pool: `n` independent workers, each owning its
    /// own engine, queues, and metrics; requests route by stable hash of
    /// the model id ([`Coordinator::shard_for`]).
    ///
    /// Default: `available_parallelism` capped at [`DEFAULT_MAX_SHARDS`]
    /// when a registry is attached, else **1** (without a registry there
    /// is exactly one routable model, so extra shards could never
    /// receive traffic).  A backend whose
    /// [`ExecutionBackend::replicate`] returns `None` falls back to one
    /// shard under the default, but explicitly requesting `n > 1` shards
    /// with such a backend is a startup error.
    ///
    /// Shard workers multiply with any per-batch parallelism inside the
    /// backend: N shards each running a [`NativeBackend`] row pool of M
    /// threads can occupy N×M cores at peak.  The registry-default
    /// backend divides its row pool by the shard count automatically;
    /// when supplying your own backend to a multi-shard pool, size
    /// [`NativeBackend::with_threads`] accordingly.
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = Some(n);
        self
    }

    /// Enable cross-shard batch stealing (default **off**; off is
    /// bit-for-bit the legacy hash-routed behavior).  With stealing on,
    /// a shard whose per-model load signal clears
    /// [`CoordinatorBuilder::steal_promote_us`] donates its formed —
    /// and `batch_seq`-stamped — batches to a pool-shared deck, and any
    /// idle shard executes them on the home shard's behalf.  See the
    /// module docs ("Elasticity") for the protocol and the FIFO
    /// argument.
    pub fn steal(mut self, on: bool) -> Self {
        self.steal = on;
        self
    }

    /// Promotion threshold (µs) of the per-model load signal
    /// `queue depth × EWMA batch cost` above which the home shard
    /// donates formed batches to the deck instead of executing them
    /// inline (default 2000 µs; `0` donates eagerly, which the steal
    /// tests use to force the protocol).  Only meaningful with
    /// [`CoordinatorBuilder::steal`]`(true)`.
    pub fn steal_promote_us(mut self, us: u64) -> Self {
        self.steal_promote_us = Some(us);
        self
    }

    /// Per-shard capacity (events) of the request-lifecycle trace ring
    /// (default [`DEFAULT_TRACE_CAPACITY`]).  `0` disables tracing
    /// entirely — no ring is allocated and no event is ever recorded —
    /// which is the configuration the coordinator bench's overhead
    /// phase compares against.  The ring overwrites oldest-first, so
    /// the capacity bounds memory, not history.
    pub fn trace_capacity(mut self, events_per_shard: usize) -> Self {
        self.trace_capacity = Some(events_per_shard);
        self
    }

    /// Spawn the shard workers, compile every default-model bucket on
    /// each, and start serving.  Returns once every shard compiled
    /// successfully (startup errors surface here, not on first request);
    /// registry models compile lazily on first use so a hot-dropped
    /// artifact needs no restart.
    pub fn build(self) -> Result<Coordinator> {
        anyhow::ensure!(
            self.shards != Some(0),
            "CoordinatorBuilder: .shards(0) — the pool needs at least one shard"
        );
        let registry = self.registry;
        let faults = self.faults;
        if let (Some(reg), Some(plan)) = (&registry, &faults) {
            // the registry participates in the same seeded plan: torn
            // artifact loads come from the TornLoad stream
            reg.set_fault_plan(Arc::clone(plan));
        }
        // Resolve the pool size first (backend construction below can
        // depend on it).  Without a registry there is exactly one
        // routable key — the default model — so extra shards could never
        // receive traffic and the default is a single shard; with a
        // registry the default scales with the machine.
        let requested = self.shards;
        let want = match requested {
            Some(n) => n,
            None if registry.is_some() => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(DEFAULT_MAX_SHARDS),
            None => 1,
        };
        let mut default_model: Option<Arc<str>> = None;
        // how the supervisor rebuilds a dead shard's backend; None = no
        // respawn possible (single-instance backend without a registry)
        let mut factory: Option<BackendFactory> = None;
        let backend: Box<dyn ExecutionBackend> = match (self.backend, &registry) {
            (Some(b), _) => {
                if let Some(name) = &self.default_model {
                    let reg = registry
                        .as_ref()
                        .context("CoordinatorBuilder: default_model requires .registry(...)")?;
                    anyhow::ensure!(
                        reg.get(name).is_some(),
                        "default model '{name}' is not in the registry"
                    );
                    default_model = Some(Arc::from(name.as_str()));
                }
                // respawn template: one extra replica kept aside (shares
                // the model Arc / plan cache, so the cost is a handle)
                if let Some(template) = b.replicate() {
                    factory = Some(Box::new(move || {
                        template.replicate().context("backend template lost replicability")
                    }));
                }
                b
            }
            (None, Some(reg)) => {
                let name = match self.default_model {
                    Some(n) => n,
                    None => reg.default_name().context(
                        "CoordinatorBuilder: the registry is empty — pack at least one \
                         model or set .backend(...)",
                    )?,
                };
                let entry = reg
                    .get(&name)
                    .with_context(|| format!("default model '{name}' is not in the registry"))?;
                default_model = Some(Arc::from(name.as_str()));
                // divide the per-batch row pool across the shards so the
                // default configuration cannot oversubscribe the machine
                // (N shards x N row workers)
                let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
                let per_shard = (cores / want).max(1);
                // respawn path: rebuild from the *current* registry
                // snapshot, so a worker that died across a hot-swap comes
                // back serving the new artifact
                let reg_factory = Arc::clone(reg);
                let name_factory = name.clone();
                factory = Some(Box::new(move || {
                    let entry = reg_factory.get(&name_factory).with_context(|| {
                        format!("default model '{name_factory}' is no longer in the registry")
                    })?;
                    Ok(Box::new(NativeBackend::new((*entry.enc).clone()).with_threads(per_shard)))
                }));
                Box::new(NativeBackend::new((*entry.enc).clone()).with_threads(per_shard))
            }
            (None, None) => anyhow::bail!(
                "CoordinatorBuilder: a backend or a model registry is required \
                 (use .backend(...) or .registry(...))"
            ),
        };
        let policy = self.policy.unwrap_or_else(|| match backend.preferred_buckets() {
            Some(buckets) if !buckets.is_empty() => {
                BatchPolicy::new(buckets, BatchPolicy::default().max_wait)
            }
            _ => BatchPolicy::default(),
        });
        let cost = self.cost.unwrap_or_default();

        // Populate the pool: the primary backend serves shard 0, replicas
        // serve the rest.  An explicitly requested size must be honored
        // exactly or fail loudly; the default degrades to one shard for
        // single-instance backends.
        let mut backends: Vec<Box<dyn ExecutionBackend>> = Vec::with_capacity(want);
        for _ in 1..want {
            match backend.replicate() {
                Some(b) => backends.push(b),
                None => {
                    anyhow::ensure!(
                        requested.is_none(),
                        "CoordinatorBuilder: backend '{}' cannot be replicated across \
                         {want} shards (single-instance resource) — use .shards(1)",
                        backend.name()
                    );
                    backends.clear();
                    break;
                }
            }
        }
        backends.insert(0, backend);

        // One lock-free trace ring per shard, allocated up front (0 =
        // tracing off; the recording code never runs).
        let tracer = match self.trace_capacity.unwrap_or(DEFAULT_TRACE_CAPACITY) {
            0 => None,
            cap => Some(Arc::new(TraceBuf::new(backends.len(), cap))),
        };
        // Metrics slots exist before the shard config because the steal
        // deck carries a handle to every shard's metrics: a thief must
        // be able to credit the *home* shard's queue-side counters.
        let shard_metrics: Vec<Arc<Mutex<Metrics>>> =
            (0..backends.len()).map(|_| Arc::new(Mutex::new(Metrics::new()))).collect();
        let steal = self.steal.then(|| {
            Arc::new(StealState {
                deck: Mutex::new(VecDeque::new()),
                cap: backends.len() * 2,
                promote_us: self.steal_promote_us.unwrap_or(DEFAULT_STEAL_PROMOTE_US),
                metrics: shard_metrics.clone(),
            })
        });
        let config = ShardConfig {
            policy,
            cost,
            registry: registry.clone(),
            faults: faults.clone(),
            tracer: tracer.clone(),
            steal,
        };

        // Spawn every shard worker; each compiles on its own thread
        // (backend executables may not be Send) and reports startup
        // through a ready channel.  All shards must come up before
        // build() returns.
        let mut shards = Vec::with_capacity(shard_metrics.len());
        let mut readies = Vec::with_capacity(shard_metrics.len());
        for (shard_id, backend) in backends.into_iter().enumerate() {
            let metrics = Arc::clone(&shard_metrics[shard_id]);
            let (tx, worker, ready_rx) =
                spawn_shard(shard_id, backend, &config, Arc::clone(&metrics))?;
            shards.push(ShardState {
                tx: RwLock::new(tx),
                worker: Mutex::new(Some(worker)),
                metrics,
            });
            readies.push(ready_rx);
        }
        for (shard_id, ready_rx) in readies.iter().enumerate() {
            let started = ready_rx
                .recv()
                .with_context(|| format!("coordinator shard {shard_id} died during startup"))
                .and_then(|r| r.map_err(|e| anyhow::anyhow!(e)));
            if let Err(e) = started {
                // tear the partial pool down: wake every healthy worker
                // and join it before reporting the startup failure
                for shard in &shards {
                    let _ = rlock(&shard.tx).send(Msg::Shutdown);
                }
                for shard in &shards {
                    if let Some(h) = lock(&shard.worker).take() {
                        let _ = h.join();
                    }
                }
                return Err(e);
            }
        }

        let pool = Arc::new(Pool {
            shards,
            restarts: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        });
        let respawner = Respawner { factory, config };
        let supervisor_pool = Arc::clone(&pool);
        let supervisor = std::thread::Builder::new()
            .name("pasm-coord-supervisor".to_string())
            .spawn(move || supervise(supervisor_pool, respawner))
            .context("spawn coordinator supervisor")?;

        Ok(Coordinator {
            pool,
            supervisor: Some(supervisor),
            next_id: AtomicU64::new(1),
            registry,
            default_model,
            faults,
            tracer,
        })
    }
}

/// Rebuilds a shard's execution backend for a respawn.
type BackendFactory = Box<dyn Fn() -> Result<Box<dyn ExecutionBackend>> + Send>;

/// One shard of the pool: its request channel (swapped on respawn, hence
/// the `RwLock` — submissions take the read side), worker thread, and
/// shard-local metrics.  The metrics `Arc` survives respawns, so a
/// restarted shard keeps its counters.
struct ShardState {
    tx: RwLock<mpsc::Sender<Msg>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    metrics: Arc<Mutex<Metrics>>,
}

/// The shard pool shared between the [`Coordinator`] handle and the
/// supervisor thread.
struct Pool {
    shards: Vec<ShardState>,
    restarts: AtomicU64,
    shutdown: AtomicBool,
}

/// Everything the supervisor needs to rebuild a dead shard.
struct Respawner {
    factory: Option<BackendFactory>,
    config: ShardConfig,
}

/// Everything a shard worker needs besides its backend and its metrics
/// slot — shared verbatim between the initial spawns and supervisor
/// respawns, so a restarted shard runs the same policy, fault plan, and
/// trace ring as the one it replaces.
struct ShardConfig {
    policy: BatchPolicy,
    cost: CostModel,
    registry: Option<Arc<ModelRegistry>>,
    faults: Option<Arc<FaultPlan>>,
    tracer: Option<Arc<TraceBuf>>,
    steal: Option<Arc<StealState>>,
}

/// A batch the home shard formed and donated to the pool: everything an
/// executor needs to run it and answer its requests.  The home stamped
/// `seq` at formation, so execution order cannot perturb the per-model
/// FIFO witness.
struct FormedBatch {
    /// Shard that owns the model's queue and formed this batch.
    home: usize,
    /// The home shard's `batch_seq` at formation.
    seq: u64,
    /// Bucket (padded batch size) the policy chose.
    bucket: usize,
    model: Option<Arc<str>>,
    batch: Vec<Pending>,
    /// Per-request queue wait, measured by the home at formation (queue
    /// wait ends at formation, whichever shard executes).
    queue_waits: Vec<Duration>,
    /// Formation instant: batch-form overhead (and, for donated
    /// batches, deck dwell) is measured from here.
    formed_at: Instant,
}

/// Pool-shared steal state: the deck of donated batches plus a handle
/// to every shard's metrics (a thief credits the *home* shard's
/// donation counter and queue-wait histogram).  Lives in
/// [`ShardConfig`], so supervisor-respawned workers reattach to the
/// same deck.
struct StealState {
    deck: Mutex<VecDeque<FormedBatch>>,
    /// Max donated batches outstanding; past this the home executes
    /// inline (backpressure so the deck cannot buffer unboundedly).
    cap: usize,
    /// Promotion threshold (µs) of `queue depth × EWMA batch cost`.
    promote_us: u64,
    /// Every shard's metrics, indexed by shard id.
    metrics: Vec<Arc<Mutex<Metrics>>>,
}

impl StealState {
    fn pop(&self) -> Option<FormedBatch> {
        lock(&self.deck).pop_front()
    }
}

/// Spawn one shard worker; the returned ready channel reports whether its
/// engine compiled (build() waits on all shards in parallel, the
/// supervisor on one).
fn spawn_shard(
    shard_id: usize,
    backend: Box<dyn ExecutionBackend>,
    config: &ShardConfig,
    metrics: Arc<Mutex<Metrics>>,
) -> Result<(mpsc::Sender<Msg>, JoinHandle<()>, mpsc::Receiver<Result<(), String>>)> {
    let (tx, rx) = mpsc::channel::<Msg>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
    let buckets = config.policy.buckets.clone();
    let policy = config.policy.clone();
    let cost = config.cost;
    let registry = config.registry.clone();
    let faults = config.faults.clone();
    let tracer = config.tracer.clone();
    let steal = config.steal.clone();
    let worker = std::thread::Builder::new()
        .name(format!("pasm-coord-{shard_id}"))
        .spawn(move || {
            let mut engine = match Engine::new(backend, &buckets, &cost, registry) {
                Ok(e) => {
                    // label the metrics before signalling ready so
                    // build() never returns with an empty backend name
                    lock(&metrics).record_backend(e.backend_name());
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            if let Some(t) = &tracer {
                // the engine stamps `launched`/`executed` itself, right
                // around the kernel call
                engine.set_tracer(Arc::clone(t), shard_id);
            }
            worker_loop(engine, WorkerCtx { policy, rx, metrics, shard_id, faults, tracer, steal });
        })
        .with_context(|| format!("spawn coordinator shard {shard_id}"))?;
    Ok((tx, worker, ready_rx))
}

/// The supervisor loop: sweep for dead shard workers and respawn them.
///
/// A shard whose respawn fails (factory error, engine compile error) is
/// left dead and retried on the next sweep — a transiently torn default
/// artifact heals once the registry recovers.
fn supervise(pool: Arc<Pool>, respawner: Respawner) {
    while !pool.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(SUPERVISOR_SWEEP);
        for (shard_id, shard) in pool.shards.iter().enumerate() {
            if pool.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let dead = lock(&shard.worker).as_ref().is_none_or(JoinHandle::is_finished);
            if !dead {
                continue;
            }
            // join the corpse; its queues and channel already dropped,
            // so every stranded Completion has delivered WORKER_DIED
            if let Some(h) = lock(&shard.worker).take() {
                let _ = h.join();
            }
            let Some(factory) = &respawner.factory else {
                continue;
            };
            let respawned = factory().and_then(|backend| {
                spawn_shard(shard_id, backend, &respawner.config, Arc::clone(&shard.metrics))
            });
            let Ok((tx, worker, ready_rx)) = respawned else {
                continue;
            };
            match ready_rx.recv() {
                Ok(Ok(())) => {
                    *wlock(&shard.tx) = tx;
                    *lock(&shard.worker) = Some(worker);
                    pool.restarts.fetch_add(1, Ordering::Relaxed);
                }
                // compile failed: reap the stillborn worker, retry later
                _ => {
                    let _ = worker.join();
                }
            }
        }
    }
}

/// Handle to a running coordinator pool.
pub struct Coordinator {
    pool: Arc<Pool>,
    supervisor: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    registry: Option<Arc<ModelRegistry>>,
    default_model: Option<Arc<str>>,
    faults: Option<Arc<FaultPlan>>,
    tracer: Option<Arc<TraceBuf>>,
}

impl Coordinator {
    /// Submit one image to the default model; returns a receiver for the
    /// response.
    pub fn submit(
        &self,
        image: Tensor<f32>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        Ok(self.submit_routed(image, self.default_model.clone(), None, None)?.1)
    }

    /// Submit one image to a named registry model.
    pub fn submit_to(
        &self,
        model: &str,
        image: Tensor<f32>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        Ok(self.submit_routed(image, Some(Arc::from(model)), None, None)?.1)
    }

    /// Submit with an optional model *and* an optional absolute deadline;
    /// returns a receiver for the response.  A request whose deadline
    /// expires before its batch launches is answered with a typed
    /// `deadline exceeded` error and counted as a deadline miss.
    pub fn submit_deadline(
        &self,
        model: Option<&str>,
        image: Tensor<f32>,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        let model = match model {
            Some(m) => Some(Arc::from(m)),
            None => self.default_model.clone(),
        };
        Ok(self.submit_routed(image, model, deadline, None)?.1)
    }

    /// [`Coordinator::submit_deadline`] plus front-end [`Ingress`]
    /// timestamps, returning the coordinator-assigned request id next to
    /// the response receiver.  The id is what later lifecycle events
    /// ([`Coordinator::record_reply_written`],
    /// [`Coordinator::record_retry_advised`]) key on, and what the
    /// `get_trace` wire frame filters by.
    pub fn submit_traced(
        &self,
        model: Option<&str>,
        image: Tensor<f32>,
        deadline: Option<Instant>,
        ingress: Option<Ingress>,
    ) -> Result<(u64, mpsc::Receiver<Result<InferenceResponse, String>>)> {
        let model = match model {
            Some(m) => Some(Arc::from(m)),
            None => self.default_model.clone(),
        };
        self.submit_routed(image, model, deadline, ingress)
    }

    fn submit_routed(
        &self,
        image: Tensor<f32>,
        model: Option<Arc<str>>,
        deadline: Option<Instant>,
        ingress: Option<Ingress>,
    ) -> Result<(u64, mpsc::Receiver<Result<InferenceResponse, String>>)> {
        let (rtx, rrx) = mpsc::channel();
        let id = self.submit_completion(image, model, deadline, ingress, Completion::channel(rtx))?;
        Ok((id, rrx))
    }

    /// Submit one image and deliver the result through `on_done` instead
    /// of a channel (`model` = `None` routes to the default model).
    ///
    /// The callback runs on the shard worker's thread right after the
    /// batch completes (or fails), so it must be cheap and non-blocking —
    /// push to a queue and wake a poller, don't do work.  This is the
    /// submission path of the evented serving front-end, where no thread
    /// exists to park on a response channel.
    pub fn submit_with<F>(&self, model: Option<&str>, image: Tensor<f32>, on_done: F) -> Result<()>
    where
        F: FnOnce(Result<InferenceResponse, String>) + Send + 'static,
    {
        self.submit_with_deadline(model, image, None, on_done)
    }

    /// [`Coordinator::submit_with`] plus an optional absolute deadline.
    pub fn submit_with_deadline<F>(
        &self,
        model: Option<&str>,
        image: Tensor<f32>,
        deadline: Option<Instant>,
        on_done: F,
    ) -> Result<()>
    where
        F: FnOnce(Result<InferenceResponse, String>) + Send + 'static,
    {
        self.submit_with_traced(model, image, deadline, None, move |_, r| on_done(r)).map(|_| ())
    }

    /// [`Coordinator::submit_with_deadline`] plus front-end [`Ingress`]
    /// timestamps, returning the assigned request id (see
    /// [`Coordinator::submit_traced`]).  The callback also receives that
    /// id as its first argument — it is allocated *before* the request
    /// enters a shard queue, so even a completion that fires before this
    /// method returns can key its trace events correctly.
    pub fn submit_with_traced<F>(
        &self,
        model: Option<&str>,
        image: Tensor<f32>,
        deadline: Option<Instant>,
        ingress: Option<Ingress>,
        on_done: F,
    ) -> Result<u64>
    where
        F: FnOnce(u64, Result<InferenceResponse, String>) + Send + 'static,
    {
        let model = match model {
            Some(m) => Some(Arc::from(m)),
            None => self.default_model.clone(),
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let done = Completion::callback(Box::new(move |r| on_done(id, r)));
        self.submit_prepared(id, image, model, deadline, ingress, done)?;
        Ok(id)
    }

    fn submit_completion(
        &self,
        image: Tensor<f32>,
        model: Option<Arc<str>>,
        deadline: Option<Instant>,
        ingress: Option<Ingress>,
        completion: Completion,
    ) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_prepared(id, image, model, deadline, ingress, completion)?;
        Ok(id)
    }

    fn submit_prepared(
        &self,
        id: u64,
        image: Tensor<f32>,
        model: Option<Arc<str>>,
        deadline: Option<Instant>,
        ingress: Option<Ingress>,
        completion: Completion,
    ) -> Result<()> {
        let shard = self.shard_for(model.as_deref());
        let mut req = InferenceRequest::new(id, image);
        req.model = model;
        req.deadline = deadline;
        req.ingress = ingress;
        // clone the sender out of the read lock so a respawn (write
        // lock) never waits on a blocking channel send
        let tx = rlock(&self.pool.shards[shard].tx).clone();
        tx.send(Msg::Request(req, completion)).map_err(|e| {
            // hand the completion back undelivered: the submitter gets
            // the error through this Result, not through the callback too
            if let Msg::Request(_, c) = e.0 {
                c.disarm();
            }
            if self.pool.shutdown.load(Ordering::SeqCst) {
                anyhow::anyhow!("coordinator is shut down")
            } else {
                anyhow::anyhow!("shard {shard} unavailable (worker died; respawn pending)")
            }
        })?;
        Ok(())
    }

    /// Submit to the default model and block for the answer (convenience).
    pub fn infer(&self, image: Tensor<f32>) -> Result<InferenceResponse> {
        let rx = self.submit(image)?;
        rx.recv()
            .context("coordinator dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit to a named registry model and block for the answer.
    pub fn infer_model(&self, model: &str, image: Tensor<f32>) -> Result<InferenceResponse> {
        let rx = self.submit_to(model, image)?;
        rx.recv()
            .context("coordinator dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// The registry this coordinator serves named models from, if any.
    pub fn registry(&self) -> Option<&Arc<ModelRegistry>> {
        self.registry.as_ref()
    }

    /// The fault-injection plan attached at build time, if any (the
    /// serving front-ends consult it for socket resets).
    pub fn fault_plan(&self) -> Option<&Arc<FaultPlan>> {
        self.faults.as_ref()
    }

    /// The request-lifecycle trace rings, if tracing is enabled (see
    /// [`CoordinatorBuilder::trace_capacity`]).  Front-ends record
    /// their own events through this handle; the `get_trace` wire frame
    /// snapshots it.
    pub fn tracer(&self) -> Option<&Arc<TraceBuf>> {
        self.tracer.as_ref()
    }

    /// Record that the serving front-end wrote (or queued) the reply for
    /// request `id`: a `reply_written` trace event (`aux` = encoded
    /// reply bytes) plus a write-back sample in the owning shard's
    /// per-stage histograms.  `model` labels the per-model histogram;
    /// unnamed traffic follows the default model, mirroring request
    /// routing.
    pub fn record_reply_written(
        &self,
        shard: usize,
        id: u64,
        model: Option<&str>,
        took: Duration,
        bytes: usize,
    ) {
        if let Some(t) = &self.tracer {
            t.record(shard, id, Stage::ReplyWritten, bytes as u64);
        }
        if let Some(s) = self.pool.shards.get(shard) {
            let label = model.or(self.default_model.as_deref()).unwrap_or(DEFAULT_MODEL_LABEL);
            lock(&s.metrics).record_write_back(label, took);
        }
    }

    /// Record a `retried` trace event: request `id` was answered with
    /// the retryable error code `code` (as `aux`).  The client's retry
    /// arrives as a fresh request id — a new span — so this event is
    /// what links the two when reading a trace.
    pub fn record_retry_advised(&self, shard: usize, id: u64, code: u64) {
        if let Some(t) = &self.tracer {
            t.record(shard, id, Stage::Retried, code);
        }
    }

    /// The model unnamed requests route to (`None` = the backend's
    /// built-in model).
    pub fn default_model(&self) -> Option<&str> {
        self.default_model.as_deref()
    }

    /// Number of shards in the pool.
    pub fn shards(&self) -> usize {
        self.pool.shards.len()
    }

    /// How many dead shard workers the supervisor has respawned.
    pub fn shard_restarts(&self) -> u64 {
        self.pool.restarts.load(Ordering::Relaxed)
    }

    /// Which shard requests for `model` route to (`None` = unnamed
    /// traffic, which follows the default model).  Deterministic: a
    /// stable FNV-1a hash of the model name modulo the shard count, so
    /// the answer never changes for the lifetime of the pool.
    pub fn shard_for(&self, model: Option<&str>) -> usize {
        let key = model.or(self.default_model.as_deref()).unwrap_or("");
        (route_hash(key) % self.pool.shards.len() as u64) as usize
    }

    /// Merged snapshot of the serving metrics across all shards.
    pub fn metrics(&self) -> Metrics {
        self.metrics_with_shards().0
    }

    /// One *consistent* snapshot: every shard's metrics are read once,
    /// and both the merged aggregate and the per-shard counters derive
    /// from those same values — so the counters always sum to the merged
    /// totals, the invariant the `metrics` wire frame documents.
    /// (Reading [`Coordinator::metrics`] and
    /// [`Coordinator::shard_counters`] separately under live traffic
    /// could disagree by whatever completed in between.)
    pub fn metrics_with_shards(&self) -> (Metrics, Vec<ShardCounters>) {
        let per_shard = self.shard_metrics();
        let mut merged = Metrics::new();
        for m in &per_shard {
            merged.merge(m);
        }
        let counters = per_shard.iter().map(Metrics::counters).collect();
        (merged, counters)
    }

    /// Per-shard metrics snapshots, indexed by shard id.
    pub fn shard_metrics(&self) -> Vec<Metrics> {
        self.pool.shards.iter().map(|s| lock(&s.metrics).clone()).collect()
    }

    /// Compact per-shard counters, indexed by shard id (what the
    /// `metrics` wire frame reports next to the merged aggregate).
    pub fn shard_counters(&self) -> Vec<ShardCounters> {
        self.pool.shards.iter().map(|s| lock(&s.metrics).counters()).collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Ordering matters: stop the supervisor *first*, so a worker we
        // are about to shut down is not respawned behind our back; only
        // then wake every shard (they drain in parallel) and join them.
        self.pool.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        for shard in &self.pool.shards {
            let _ = rlock(&shard.tx).send(Msg::Shutdown);
        }
        for shard in &self.pool.shards {
            if let Some(h) = lock(&shard.worker).take() {
                let _ = h.join();
            }
        }
    }
}

type Pending = (InferenceRequest, Completion);
type ModelQueues = BTreeMap<Option<Arc<str>>, VecDeque<Pending>>;

/// Enqueue one request, recording its `accepted`/`decoded` ingress
/// timestamps (if a front-end captured them) and the `enqueued` event
/// (`aux` = queue depth after the push) into the shard's trace ring.
fn push(
    queues: &mut ModelQueues,
    r: InferenceRequest,
    done: Completion,
    tracer: Option<&Arc<TraceBuf>>,
    shard_id: usize,
) {
    let q = queues.entry(r.model.clone()).or_default();
    if let Some(t) = tracer {
        if let Some(ing) = r.ingress {
            t.record_at(shard_id, r.id, Stage::Accepted, ing.accepted, 0);
            t.record_at(shard_id, r.id, Stage::Decoded, ing.decoded, 0);
        }
        t.record(shard_id, r.id, Stage::Enqueued, (q.len() + 1) as u64);
    }
    q.push_back((r, done));
}

/// Drop every queued request whose deadline has passed, answering each
/// with a typed error and counting it as a deadline miss.  Runs on every
/// worker iteration, *before* the launch decision — an expired request
/// never costs a batch slot.
fn purge_expired(
    queues: &mut ModelQueues,
    metrics: &Mutex<Metrics>,
    now: Instant,
    tracer: Option<&Arc<TraceBuf>>,
    shard_id: usize,
) {
    for (model, q) in queues.iter_mut() {
        if !q.iter().any(|(r, _)| r.expired_at(now)) {
            continue;
        }
        let label: &str = model.as_deref().unwrap_or(DEFAULT_MODEL_LABEL);
        let mut kept = VecDeque::with_capacity(q.len());
        for (r, done) in q.drain(..) {
            if r.expired_at(now) {
                lock(metrics).record_deadline_miss(label);
                let queued = now.duration_since(r.enqueued_at);
                if let Some(t) = tracer {
                    t.record(shard_id, r.id, Stage::DeadlineDrop, queued.as_micros() as u64);
                }
                let msg = format!("deadline exceeded before batch launch (queued {queued:?})");
                done.deliver(Err(msg));
            } else {
                kept.push_back((r, done));
            }
        }
        *q = kept;
    }
}

/// Everything one shard worker holds besides its engine: channel,
/// config handles, and the shared steal state.  Grouped so the loop and
/// its helpers pass one context instead of eight arguments.
struct WorkerCtx {
    policy: BatchPolicy,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
    shard_id: usize,
    faults: Option<Arc<FaultPlan>>,
    tracer: Option<Arc<TraceBuf>>,
    steal: Option<Arc<StealState>>,
}

/// Drain up to `bucket` requests from `queue` into a [`FormedBatch`],
/// stamping the home shard's `batch_seq` and recording each request's
/// `batch_formed` trace event.  Queue wait ends here for every drained
/// request, whichever shard ends up executing the batch.
fn form_batch(
    queue: &mut VecDeque<Pending>,
    model: &Option<Arc<str>>,
    bucket: usize,
    batch_seq: &mut u64,
    ctx: &WorkerCtx,
) -> FormedBatch {
    let take = bucket.min(queue.len());
    let batch: Vec<Pending> = queue.drain(..take).collect();
    let formed_at = Instant::now();
    let seq = *batch_seq;
    *batch_seq += 1;
    if let Some(t) = &ctx.tracer {
        for (r, _) in &batch {
            t.record_at(ctx.shard_id, r.id, Stage::BatchFormed, formed_at, bucket as u64);
        }
    }
    let queue_waits =
        batch.iter().map(|(r, _)| formed_at.saturating_duration_since(r.enqueued_at)).collect();
    FormedBatch {
        home: ctx.shard_id,
        seq,
        bucket,
        model: model.clone(),
        batch,
        queue_waits,
        formed_at,
    }
}

impl WorkerCtx {
    /// Execute one formed batch and answer its requests.  The inline
    /// path (`fb.home == self.shard_id`, straight from formation) and
    /// the steal path (a deck pop) share this.  Returns `false` when an
    /// injected worker kill fired on the steal path: the caller must
    /// exit its loop (the dropped batch's completion drop-guards have
    /// already answered every request with [`WORKER_DIED`]).
    fn execute_formed(
        &self,
        engine: &mut Engine,
        fb: FormedBatch,
        ewma_us: &mut BTreeMap<Option<Arc<str>>, f64>,
    ) -> bool {
        let stolen = fb.home != self.shard_id;
        if stolen {
            if let Some(plan) = &self.faults {
                if plan.should(FaultSite::WorkerKill) {
                    // die holding the stolen batch: its drop-guards
                    // answer WORKER_DIED, the home queue is untouched,
                    // and the supervisor respawns this shard
                    if let Some(t) = &self.tracer {
                        t.record(self.shard_id, 0, Stage::Fault, 1);
                    }
                    return false;
                }
            }
            if let Some(t) = &self.tracer {
                for (r, _) in &fb.batch {
                    t.record(self.shard_id, r.id, Stage::Stolen, fb.home as u64);
                }
            }
        }
        let FormedBatch { home, seq, bucket, model, batch, queue_waits, formed_at } = fb;
        let label: &str = model.as_deref().unwrap_or(DEFAULT_MODEL_LABEL);
        let requests: Vec<InferenceRequest> = batch.iter().map(|(r, _)| r.clone()).collect();
        let origin = if stolen { BatchOrigin::Stolen } else { BatchOrigin::Home };
        // Contain kernel panics (e.g. the fixed-point overflow guards on
        // an extreme input): the batch fails, the worker keeps serving.
        // The engine's only cross-batch mutable state is a staging
        // buffer that every batch fully overwrites, so resuming is
        // sound.
        let injected_err = self.faults.as_ref().is_some_and(|p| p.should(FaultSite::ExecError));
        let result = if injected_err {
            Err(anyhow::anyhow!("injected fault: execution error"))
        } else {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if self.faults.as_ref().is_some_and(|p| p.should(FaultSite::BatchPanic)) {
                    panic!("injected fault: kernel panic");
                }
                engine.run_batch_from(&requests, bucket, origin)
            }))
            .unwrap_or_else(|p| {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "kernel panicked".to_string());
                Err(anyhow::anyhow!("execution panicked: {msg}"))
            })
        };
        match result {
            Ok(mut responses) => {
                for resp in &mut responses {
                    resp.shard = home;
                    resp.executed_by = self.shard_id;
                    resp.batch_seq = seq;
                }
                // batch-form overhead = wall time since formation minus
                // the kernel execution the engine measured itself (for
                // stolen batches this includes deck dwell — overhead the
                // steal path really added)
                let compute_us = responses.first().map_or(0, |r| r.compute_us);
                let batch_form =
                    formed_at.elapsed().saturating_sub(Duration::from_micros(compute_us));
                // EWMA of this model's batch cost: the donation signal
                // (only the entries of models homed here ever matter)
                let e = ewma_us.entry(model.clone()).or_insert(compute_us as f64);
                *e = 0.8 * *e + 0.2 * compute_us as f64;
                let installs = engine.take_replica_installs();
                // Execute-side counters land on the executing shard,
                // queue-side counters on the home shard: each event is
                // counted exactly once, so per-shard counters still sum
                // to the merged totals under stealing.
                let mut m = lock(&self.metrics);
                m.record_batch(label, requests.len(), bucket);
                if let Some(first) = responses.first() {
                    m.record_hw(first.hw.cycles, first.hw.energy_j);
                }
                for (req, _) in &batch {
                    m.record_latency(req.enqueued_at.elapsed());
                }
                if stolen {
                    m.record_stolen_batch(label);
                } else {
                    for w in &queue_waits {
                        m.record_queue_wait(label, *w);
                    }
                }
                m.record_batch_stages(label, batch_form, compute_us);
                if installs > 0 {
                    m.record_replicas_installed(installs);
                }
                drop(m);
                if stolen {
                    if let Some(st) = &self.steal {
                        let mut hm = lock(&st.metrics[home]);
                        hm.record_donated_batch();
                        for w in &queue_waits {
                            hm.record_queue_wait(label, *w);
                        }
                    }
                }
                for ((_, done), resp) in batch.into_iter().zip(responses) {
                    done.deliver(Ok(resp));
                }
            }
            Err(e) => {
                let msg = format!("batch failed after {:?}: {e:#}", formed_at.elapsed());
                if let Some(t) = &self.tracer {
                    // fault kinds: 2 = execution error, 3 = kernel panic
                    let kind = if msg.contains("execution panicked") { 3 } else { 2 };
                    for (r, _) in &batch {
                        t.record(self.shard_id, r.id, Stage::Fault, kind);
                    }
                }
                let mut m = lock(&self.metrics);
                m.record_failed_batch(label);
                if stolen {
                    // steal / donated counters measure protocol traffic,
                    // not success, so a failed stolen batch still counts
                    m.record_stolen_batch(label);
                } else {
                    for w in &queue_waits {
                        m.record_queue_wait(label, *w);
                    }
                }
                drop(m);
                if stolen {
                    if let Some(st) = &self.steal {
                        let mut hm = lock(&st.metrics[home]);
                        hm.record_donated_batch();
                        for w in &queue_waits {
                            hm.record_queue_wait(label, *w);
                        }
                    }
                }
                for (_, done) in batch {
                    done.deliver(Err(msg.clone()));
                }
            }
        }
        true
    }
}

fn worker_loop(mut engine: Engine, ctx: WorkerCtx) {
    // one queue per model: a launched batch never mixes models, and the
    // policy's wait budget applies to each model's oldest request
    let mut queues: ModelQueues = BTreeMap::new();
    let mut shutting_down = false;
    // this shard's batch sequence, stamped into every response at
    // *formation*: within one model it is non-decreasing in submission
    // order (FIFO witness) even when the batch executes elsewhere
    let mut batch_seq: u64 = 0;
    // per-model EWMA of batch execute cost (µs), fed by the batches this
    // worker executed: `queue depth × ewma` is the promotion signal
    let mut ewma_us: BTreeMap<Option<Arc<str>>, f64> = BTreeMap::new();
    let mut last_evict = Instant::now();

    loop {
        // 0) steal: drain the donated-batch deck first — ready work
        //    beats forming more, and the home popping its own donation
        //    back is the liveness guarantee when no shard is idle
        if let Some(st) = &ctx.steal {
            while let Some(fb) = st.pop() {
                if !ctx.execute_formed(&mut engine, fb, &mut ewma_us) {
                    return;
                }
            }
            if last_evict.elapsed() >= REPLICA_IDLE {
                let evicted = engine.evict_idle_replicas(REPLICA_IDLE);
                if evicted > 0 {
                    lock(&ctx.metrics).record_replicas_evicted(evicted as u64);
                }
                last_evict = Instant::now();
            }
        }

        // 1) drain the channel (non-blocking if we already hold
        //    requests; blocking otherwise — bounded by the deck poll
        //    interval in steal mode)
        let held: usize = queues.values().map(VecDeque::len).sum();
        if held == 0 && !shutting_down {
            if ctx.steal.is_some() {
                match ctx.rx.recv_timeout(STEAL_POLL) {
                    Ok(Msg::Request(r, done)) => {
                        push(&mut queues, r, done, ctx.tracer.as_ref(), ctx.shard_id)
                    }
                    Ok(Msg::Shutdown) => shutting_down = true,
                    // idle beat: go look at the deck again
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => shutting_down = true,
                }
            } else {
                match ctx.rx.recv() {
                    Ok(Msg::Request(r, done)) => {
                        push(&mut queues, r, done, ctx.tracer.as_ref(), ctx.shard_id)
                    }
                    Ok(Msg::Shutdown) | Err(_) => shutting_down = true,
                }
            }
        }
        loop {
            match ctx.rx.try_recv() {
                Ok(Msg::Request(r, done)) => {
                    push(&mut queues, r, done, ctx.tracer.as_ref(), ctx.shard_id)
                }
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }

        purge_expired(&mut queues, &ctx.metrics, Instant::now(), ctx.tracer.as_ref(), ctx.shard_id);
        queues.retain(|_, q| !q.is_empty());
        if queues.is_empty() {
            if shutting_down {
                // drain the deck before exiting: a clean shutdown loses
                // nothing, including batches donated but never stolen
                if let Some(st) = &ctx.steal {
                    while let Some(fb) = st.pop() {
                        if !ctx.execute_formed(&mut engine, fb, &mut ewma_us) {
                            return;
                        }
                    }
                }
                return;
            }
            continue;
        }

        // 2) batching decision, per model: among the launchable queues,
        //    pick the one whose front request has waited longest
        let mut launch: Option<(Option<Arc<str>>, usize, Instant)> = None;
        for (model, q) in &queues {
            let front = q.front().expect("empty queues were dropped above").0.enqueued_at;
            let expired = shutting_down || front.elapsed() >= ctx.policy.max_wait;
            if let Some(bucket) = ctx.policy.decide(q.len(), expired) {
                let older = match &launch {
                    None => true,
                    Some((_, _, t)) => front < *t,
                };
                if older {
                    launch = Some((model.clone(), bucket, front));
                }
            }
        }
        let Some((model, bucket, _)) = launch else {
            // wait a beat for more requests (bounded by the wait budget,
            // and by the deck poll interval in steal mode)
            let wait = match &ctx.steal {
                Some(_) => ctx.policy.max_wait.min(STEAL_POLL),
                None => ctx.policy.max_wait,
            };
            if let Ok(msg) = ctx.rx.recv_timeout(wait) {
                match msg {
                    Msg::Request(r, done) => {
                        push(&mut queues, r, done, ctx.tracer.as_ref(), ctx.shard_id)
                    }
                    Msg::Shutdown => shutting_down = true,
                }
            }
            continue;
        };

        // injected faults, decided per launched batch so the storm scales
        // with traffic (all inert without a plan)
        if let Some(plan) = &ctx.faults {
            if plan.should(FaultSite::WorkerKill) {
                // die silently with queues still held: the completion
                // drop-guards answer every stranded request with a typed
                // error, and the supervisor respawns this shard
                if let Some(t) = &ctx.tracer {
                    t.record(ctx.shard_id, 0, Stage::Fault, 1);
                }
                return;
            }
            if let Some(extra) = plan.injected_latency() {
                if let Some(t) = &ctx.tracer {
                    t.record(ctx.shard_id, 0, Stage::Fault, 4);
                }
                std::thread::sleep(extra);
            }
        }

        // 3) launch
        let queue = queues.get_mut(&model).expect("launch model has a queue");
        // Steal mode: when the model's load signal clears the promotion
        // threshold, donate formed batches to the deck instead of
        // executing inline.  The home stays the only former — seqs are
        // stamped here, in FIFO order — but the whole pool executes.
        if let Some(st) = &ctx.steal {
            let ewma = ewma_us.get(&model).copied().unwrap_or(0.0);
            let hot = (queue.len() as f64 * ewma) >= st.promote_us as f64;
            if hot && !shutting_down {
                let mut donated = false;
                let mut next_bucket = Some(bucket);
                while let Some(b) = next_bucket {
                    // advisory backpressure: a full deck means the pool
                    // is already saturated with donated work
                    if lock(&st.deck).len() >= st.cap {
                        break;
                    }
                    let fb = form_batch(queue, &model, b, &mut batch_seq, &ctx);
                    lock(&st.deck).push_back(fb);
                    donated = true;
                    next_bucket = match queue.front() {
                        Some((front, _)) => {
                            let expired = front.enqueued_at.elapsed() >= ctx.policy.max_wait;
                            ctx.policy.decide(queue.len(), expired)
                        }
                        None => None,
                    };
                }
                if donated {
                    // step 0 pops the deck — possibly our own batch
                    continue;
                }
            }
        }
        let fb = form_batch(queue, &model, bucket, &mut batch_seq, &ctx);
        if !ctx.execute_formed(&mut engine, fb, &mut ewma_us) {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::{render_digit, Rng};
    use crate::cnn::network::{DigitsCnn, EncodedCnn};
    use crate::quant::fixed::QFormat;

    #[test]
    fn route_hash_is_the_pinned_fnv1a() {
        // the routing hash is part of the coordinator's stable behavior:
        // a model's shard must not move between builds.  Reference
        // values computed from the FNV-1a spec (offset 0xcbf29ce484222325,
        // prime 0x100000001b3).
        assert_eq!(route_hash(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(route_hash("alpha") % 4, 3);
        assert_eq!(route_hash("beta") % 4, 3);
        assert_eq!(route_hash("gamma") % 4, 2);
        assert_eq!(route_hash("delta") % 4, 1);
        assert_eq!(route_hash("digits-v0") % 4, 0);
        assert_eq!(route_hash("digits-v1") % 4, 3);
        assert_eq!(route_hash("digits-v2") % 4, 2);
        assert_eq!(route_hash("digits-v3") % 4, 1);
    }

    fn encoded(seed: u64, bins: usize) -> EncodedCnn {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(seed);
        let params = arch.init(&mut rng);
        EncodedCnn::encode(arch, &params, bins, QFormat::W32)
    }

    #[test]
    fn expired_requests_get_a_typed_error_and_count_as_misses() {
        let coord = CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(1, 4)))
            .batch_policy(BatchPolicy::new(vec![4], Duration::from_millis(200)))
            .build()
            .unwrap();
        let mut rng = Rng::new(2);
        // already expired on arrival: the purge must answer it without
        // ever launching a batch
        let rx = coord
            .submit_deadline(None, render_digit(&mut rng, 3, 0.05), Some(Instant::now()))
            .unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("deadline exceeded"), "got: {err}");
        let m = coord.metrics();
        assert_eq!(m.deadline_misses, 1);
        assert_eq!(m.batches, 0, "an expired request must not cost a batch");
        // a request with headroom still completes normally
        let resp = coord.infer(render_digit(&mut rng, 5, 0.05)).unwrap();
        assert_eq!(resp.logits.len(), 10);
    }

    #[test]
    fn killed_workers_are_respawned_and_stranded_requests_get_typed_errors() {
        let coord = CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(1, 4)))
            .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
            .fault_plan(FaultPlan::seeded(3).with(FaultSite::WorkerKill, 1.0))
            .shards(1)
            .build()
            .unwrap();
        let mut rng = Rng::new(2);
        // every batch launch kills the worker, so the request is answered
        // by the completion drop-guard, not by execution
        let err = coord.infer(render_digit(&mut rng, 3, 0.05)).unwrap_err().to_string();
        assert!(
            err.contains("worker died") || err.contains("unavailable"),
            "expected a typed worker-death error, got: {err}"
        );
        // the supervisor must notice and respawn (restart count moves)
        let deadline = Instant::now() + Duration::from_secs(5);
        while coord.shard_restarts() == 0 {
            assert!(Instant::now() < deadline, "supervisor never respawned the shard");
            std::thread::sleep(Duration::from_millis(10));
        }
        // submissions that race the dead window surface a typed error,
        // never a hang: hammer a few and require terminal outcomes
        for _ in 0..5 {
            let _ = coord.infer(render_digit(&mut rng, 4, 0.05));
        }
    }

    #[test]
    fn inert_fault_plan_changes_nothing() {
        let coord = CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(1, 4)))
            .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
            .fault_plan(FaultPlan::seeded(7))
            .build()
            .unwrap();
        let mut rng = Rng::new(2);
        for digit in 0..5 {
            let resp = coord.infer(render_digit(&mut rng, digit, 0.05)).unwrap();
            assert_eq!(resp.logits.len(), 10);
        }
        assert_eq!(coord.shard_restarts(), 0);
        assert_eq!(coord.metrics().failed_batches, 0);
        let plan = coord.fault_plan().unwrap();
        assert_eq!(plan.counters().total(), 0, "an inert plan must never fire");
    }

    #[test]
    fn lifecycle_events_and_stage_histograms_are_recorded() {
        let coord = CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(1, 4)))
            .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
            .build()
            .unwrap();
        let mut rng = Rng::new(2);
        let resp = coord.infer(render_digit(&mut rng, 3, 0.05)).unwrap();
        let tracer = coord.tracer().expect("tracing is on by default");
        let spans = crate::obs::assemble_spans(&tracer.snapshot());
        let span = spans.iter().find(|s| s.id == resp.id).expect("span for the served request");
        let mut last = 0u64;
        for stage in [Stage::Enqueued, Stage::BatchFormed, Stage::Launched, Stage::Executed] {
            let t = span.stage_time(stage).unwrap_or_else(|| panic!("missing {stage:?}"));
            assert!(t >= last, "{stage:?} ran backwards");
            last = t;
        }
        // in-process submissions have no front-end, so the span is not
        // *complete* (no accepted/decoded/reply_written)
        assert!(!span.is_complete());
        // the front-end helpers append write-back under the same id
        coord.record_reply_written(resp.shard, resp.id, None, Duration::from_micros(5), 64);
        let m = coord.metrics();
        assert!(m.stages.queue.count() > 0, "queue-wait histogram is empty");
        assert!(m.stages.batch_form.count() > 0, "batch-form histogram is empty");
        assert!(m.stages.execute.count() > 0, "execute histogram is empty");
        assert!(m.stages.write_back.count() > 0, "write-back histogram is empty");
    }

    #[test]
    fn trace_capacity_zero_disables_tracing() {
        let coord = CoordinatorBuilder::new()
            .backend(NativeBackend::new(encoded(1, 4)))
            .batch_policy(BatchPolicy::new(vec![1], Duration::from_millis(1)))
            .trace_capacity(0)
            .build()
            .unwrap();
        let mut rng = Rng::new(2);
        let resp = coord.infer(render_digit(&mut rng, 3, 0.05)).unwrap();
        assert!(coord.tracer().is_none());
        // the write-back histogram still records: stage metrics are
        // independent of tracing
        coord.record_reply_written(resp.shard, resp.id, None, Duration::from_micros(5), 64);
        assert!(coord.metrics().stages.write_back.count() > 0);
    }
}
