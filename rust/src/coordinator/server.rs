//! The coordinator server: worker thread + submission handle.
//!
//! One worker thread owns the [`Engine`] (PJRT executables are not Sync)
//! and drains a request channel, applying the [`BatchPolicy`]: wait for a
//! fillable bucket or the oldest request's deadline, then launch.  Clients
//! get a per-request response channel.  Drop the [`Coordinator`] to shut
//! down cleanly (pending requests are flushed first).

use crate::cnn::network::EncodedCnn;
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

enum Msg {
    Request(InferenceRequest, mpsc::Sender<Result<InferenceResponse, String>>),
    Shutdown,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<Metrics>>,
}

impl Coordinator {
    /// Start the worker: compiles all batch buckets, then serves until
    /// dropped.  `artifacts_dir` must contain `manifest.json` (run
    /// `make artifacts`).
    pub fn start(
        artifacts_dir: &str,
        enc: EncodedCnn,
        policy: BatchPolicy,
    ) -> Result<Self> {
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let metrics_worker = Arc::clone(&metrics);
        let (tx, rx) = mpsc::channel::<Msg>();
        let dir = artifacts_dir.to_string();

        // Compile on the worker thread (PJRT handles are not Send-safe to
        // move across after use); report startup errors through a channel.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let worker = std::thread::Builder::new()
            .name("pasm-coordinator".into())
            .spawn(move || {
                let engine = match Runtime::new(&dir)
                    .and_then(|rt| Engine::new(&rt, enc))
                {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                worker_loop(engine, policy, rx, metrics_worker);
            })
            .context("spawn coordinator worker")?;

        ready_rx
            .recv()
            .context("coordinator worker died during startup")?
            .map_err(|e| anyhow::anyhow!(e))?;

        Ok(Coordinator { tx, worker: Some(worker), next_id: AtomicU64::new(1), metrics })
    }

    /// Submit one image; returns a receiver for the response.
    pub fn submit(
        &self,
        image: Tensor<f32>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(InferenceRequest::new(id, image), rtx))
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(rrx)
    }

    /// Submit and block for the answer (convenience).
    pub fn infer(&self, image: Tensor<f32>) -> Result<InferenceResponse> {
        let rx = self.submit(image)?;
        rx.recv()
            .context("coordinator dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Snapshot of the serving metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    engine: Engine,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
) {
    type Pending = (InferenceRequest, mpsc::Sender<Result<InferenceResponse, String>>);
    let mut queue: VecDeque<Pending> = VecDeque::new();
    let mut shutting_down = false;

    loop {
        // 1) drain the channel (non-blocking if we already hold requests,
        //    blocking with deadline otherwise)
        if queue.is_empty() && !shutting_down {
            match rx.recv() {
                Ok(Msg::Request(r, tx)) => queue.push_back((r, tx)),
                Ok(Msg::Shutdown) | Err(_) => shutting_down = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Msg::Request(r, tx)) => queue.push_back((r, tx)),
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }

        if queue.is_empty() {
            if shutting_down {
                return;
            }
            continue;
        }

        // 2) batching decision
        let oldest_expired = shutting_down
            || queue
                .front()
                .map(|(r, _)| r.enqueued_at.elapsed() >= policy.max_wait)
                .unwrap_or(false);
        let Some(bucket) = policy.decide(queue.len(), oldest_expired) else {
            // wait a beat for more requests (bounded by the wait budget)
            if let Ok(msg) = rx.recv_timeout(policy.max_wait) {
                match msg {
                    Msg::Request(r, tx) => queue.push_back((r, tx)),
                    Msg::Shutdown => shutting_down = true,
                }
            }
            continue;
        };

        // 3) launch
        let take = bucket.min(queue.len());
        let batch: Vec<Pending> = queue.drain(..take).collect();
        let requests: Vec<InferenceRequest> = batch.iter().map(|(r, _)| r.clone()).collect();
        let started = Instant::now();
        match engine.run_batch(&requests, bucket) {
            Ok(responses) => {
                // one lock per batch, not per request (§Perf)
                let mut m = metrics.lock().unwrap();
                m.record_batch(requests.len(), bucket);
                if let Some(first) = responses.first() {
                    m.record_hw(first.hw.cycles, first.hw.energy_j);
                }
                for (req, _) in &batch {
                    m.record_latency(req.enqueued_at.elapsed());
                }
                drop(m);
                for ((_, tx), resp) in batch.into_iter().zip(responses) {
                    let _ = tx.send(Ok(resp));
                }
            }
            Err(e) => {
                let msg = format!("batch failed after {:?}: {e:#}", started.elapsed());
                for (_, tx) in batch {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
    }
}
