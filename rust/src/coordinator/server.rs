//! The coordinator server: builder, shard pool, submission handle.
//!
//! [`CoordinatorBuilder`] assembles a backend (and/or a
//! [`ModelRegistry`]), a batch policy, and a cost model into a running
//! [`Coordinator`] — a **pool of N independent shard workers**
//! ([`CoordinatorBuilder::shards`]; default `available_parallelism`,
//! capped at [`DEFAULT_MAX_SHARDS`]).  Each shard owns its own
//! [`Engine`] (backend executables need not be `Sync`; compilation
//! happens on the shard's thread), its own per-model queues, and its own
//! shard-local [`Metrics`], so batching and dispatch scale past one core
//! with **zero cross-shard coordination**.
//!
//! Requests route to shards by a stable FNV-1a hash of the model id
//! ([`Coordinator::shard_for`]): all traffic for one model lands on one
//! shard, so the single-worker invariants — a launched batch never mixes
//! models, per-model FIFO order, hot-swap without dropping in-flight
//! requests — hold per shard by construction, which is to say globally.
//! Unnamed requests route by the default model's name (or a fixed key
//! when no registry is attached), so they share a shard with the named
//! traffic of the same model.
//!
//! Within a shard the worker drains its request channel into per-model
//! queues, applying the [`BatchPolicy`] to each: wait for a fillable
//! bucket or the oldest request's deadline, then launch the queue whose
//! front request has waited longest.  Clients get a per-request response
//! channel.  Drop the [`Coordinator`] to shut down cleanly: every shard
//! flushes its pending requests before its worker exits — the pool
//! drains losing nothing, exactly like the old single worker.

use crate::coordinator::backend::{ExecutionBackend, NativeBackend};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::cost::CostModel;
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::{DEFAULT_MODEL_LABEL, Metrics, ShardCounters};
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use crate::model_store::ModelRegistry;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Cap on the *default* shard count (an explicit
/// [`CoordinatorBuilder::shards`] may exceed it).  Each shard is a full
/// engine with compiled executables; past a handful of shards the
/// batcher stops being the bottleneck and extra shards only fragment
/// batches.
pub const DEFAULT_MAX_SHARDS: usize = 8;

enum Msg {
    Request(InferenceRequest, Completion),
    Shutdown,
}

/// How a finished request is delivered back to its submitter.
///
/// The channel form backs the blocking [`Coordinator::submit`] family;
/// the callback form backs [`Coordinator::submit_with`], which the
/// evented serving front-end uses so a completion costs a queue push and
/// a wake instead of a parked thread per in-flight request.
enum Completion {
    /// Send down a per-request response channel (receiver may be gone).
    Channel(mpsc::Sender<Result<InferenceResponse, String>>),
    /// Invoke a closure on the shard worker's thread.  Must be cheap and
    /// must not block: it runs inside the batching loop.
    Callback(Box<dyn FnOnce(Result<InferenceResponse, String>) + Send>),
}

impl Completion {
    fn deliver(self, result: Result<InferenceResponse, String>) {
        match self {
            Completion::Channel(tx) => {
                let _ = tx.send(result);
            }
            Completion::Callback(f) => f(result),
        }
    }
}

/// Stable routing hash (FNV-1a, 64-bit): deterministic across runs,
/// processes, and platforms, so a model's shard assignment is a fixed
/// function of its name and the shard count.
fn route_hash(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Builds a [`Coordinator`] from a backend and/or model registry, a batch
/// policy, a cost model, and a shard count.
///
/// The batch policy defaults to the backend's preferred buckets (e.g. the
/// sizes an AOT flow exported) or [`BatchPolicy::default`]; the cost model
/// defaults to PASM silicon at 45 nm / 1 GHz ([`CostModel::pasm_asic`]);
/// the shard count defaults to `available_parallelism` capped at
/// [`DEFAULT_MAX_SHARDS`] when a registry is attached, else 1 (backends
/// that cannot [`ExecutionBackend::replicate`] also serve from one
/// shard).
///
/// ```
/// use pasm_accel::cnn::data::{render_digit, Rng};
/// use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
/// use pasm_accel::coordinator::{BatchPolicy, CoordinatorBuilder, NativeBackend};
/// use pasm_accel::quant::fixed::QFormat;
/// use std::time::Duration;
///
/// let arch = DigitsCnn::default();
/// let mut rng = Rng::new(1);
/// let params = arch.init(&mut rng);
/// let enc = EncodedCnn::encode(arch, &params, 4, QFormat::W16);
///
/// let coord = CoordinatorBuilder::new()
///     .backend(NativeBackend::new(enc))
///     .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
///     .shards(2)
///     .build()?;
/// let resp = coord.infer(render_digit(&mut rng, 3, 0.05))?;
/// assert_eq!(resp.logits.len(), 10);
/// assert!(resp.hw.cycles > 0);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Default)]
pub struct CoordinatorBuilder {
    backend: Option<Box<dyn ExecutionBackend>>,
    policy: Option<BatchPolicy>,
    cost: Option<CostModel>,
    registry: Option<Arc<ModelRegistry>>,
    default_model: Option<String>,
    shards: Option<usize>,
}

impl CoordinatorBuilder {
    /// An empty builder (equivalent to `CoordinatorBuilder::default()`).
    pub fn new() -> Self {
        CoordinatorBuilder::default()
    }

    /// The execution backend to serve from (required unless a
    /// [`CoordinatorBuilder::registry`] provides the models).
    pub fn backend(mut self, backend: impl ExecutionBackend + 'static) -> Self {
        self.backend = Some(Box::new(backend));
        self
    }

    /// Same as [`CoordinatorBuilder::backend`] for an already-boxed backend.
    pub fn boxed_backend(mut self, backend: Box<dyn ExecutionBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Serve named models from this registry ([`Coordinator::submit_to`] /
    /// [`Coordinator::infer_model`]).  Without an explicit
    /// [`CoordinatorBuilder::backend`], a [`NativeBackend`] is built
    /// around the registry's default model, and *unnamed* requests route
    /// to that model **by name** — so hot-swapping its artifact takes
    /// effect without a restart.
    ///
    /// ```
    /// use pasm_accel::cnn::data::{render_digit, Rng};
    /// use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
    /// use pasm_accel::coordinator::CoordinatorBuilder;
    /// use pasm_accel::model_store::ModelRegistry;
    /// use pasm_accel::quant::fixed::QFormat;
    /// use std::sync::Arc;
    ///
    /// let arch = DigitsCnn::default();
    /// let mut rng = Rng::new(1);
    /// let registry = Arc::new(ModelRegistry::new());
    /// registry.insert("b4", EncodedCnn::encode(arch, &arch.init(&mut rng), 4, QFormat::W16));
    /// registry.insert("b8", EncodedCnn::encode(arch, &arch.init(&mut rng), 8, QFormat::W16));
    ///
    /// let coord = CoordinatorBuilder::new().registry(Arc::clone(&registry)).build()?;
    /// let resp = coord.infer_model("b8", render_digit(&mut rng, 3, 0.05))?;
    /// assert_eq!(resp.model.as_deref(), Some("b8"));
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn registry(mut self, registry: Arc<ModelRegistry>) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Which registry model unnamed requests route to (default: the
    /// registry's alphabetically first model).  Requires a registry.
    pub fn default_model(mut self, name: impl Into<String>) -> Self {
        self.default_model = Some(name.into());
        self
    }

    /// Bucketed dynamic-batching policy (default: the backend's preferred
    /// buckets with a 2 ms wait budget, else [`BatchPolicy::default`]).
    /// Every shard applies the same policy to its own queues.
    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Hardware cost model batches are priced with (default:
    /// [`CostModel::pasm_asic`]).
    pub fn cost_model(mut self, cost: CostModel) -> Self {
        self.cost = Some(cost);
        self
    }

    /// Size of the shard pool: `n` independent workers, each owning its
    /// own engine, queues, and metrics; requests route by stable hash of
    /// the model id ([`Coordinator::shard_for`]).
    ///
    /// Default: `available_parallelism` capped at [`DEFAULT_MAX_SHARDS`]
    /// when a registry is attached, else **1** (without a registry there
    /// is exactly one routable model, so extra shards could never
    /// receive traffic).  A backend whose
    /// [`ExecutionBackend::replicate`] returns `None` falls back to one
    /// shard under the default, but explicitly requesting `n > 1` shards
    /// with such a backend is a startup error.
    ///
    /// Shard workers multiply with any per-batch parallelism inside the
    /// backend: N shards each running a [`NativeBackend`] row pool of M
    /// threads can occupy N×M cores at peak.  The registry-default
    /// backend divides its row pool by the shard count automatically;
    /// when supplying your own backend to a multi-shard pool, size
    /// [`NativeBackend::with_threads`] accordingly.
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = Some(n);
        self
    }

    /// Spawn the shard workers, compile every default-model bucket on
    /// each, and start serving.  Returns once every shard compiled
    /// successfully (startup errors surface here, not on first request);
    /// registry models compile lazily on first use so a hot-dropped
    /// artifact needs no restart.
    pub fn build(self) -> Result<Coordinator> {
        anyhow::ensure!(
            self.shards != Some(0),
            "CoordinatorBuilder: .shards(0) — the pool needs at least one shard"
        );
        let registry = self.registry;
        // Resolve the pool size first (backend construction below can
        // depend on it).  Without a registry there is exactly one
        // routable key — the default model — so extra shards could never
        // receive traffic and the default is a single shard; with a
        // registry the default scales with the machine.
        let requested = self.shards;
        let want = match requested {
            Some(n) => n,
            None if registry.is_some() => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(DEFAULT_MAX_SHARDS),
            None => 1,
        };
        let mut default_model: Option<Arc<str>> = None;
        let backend: Box<dyn ExecutionBackend> = match (self.backend, &registry) {
            (Some(b), _) => {
                if let Some(name) = &self.default_model {
                    let reg = registry
                        .as_ref()
                        .context("CoordinatorBuilder: default_model requires .registry(...)")?;
                    anyhow::ensure!(
                        reg.get(name).is_some(),
                        "default model '{name}' is not in the registry"
                    );
                    default_model = Some(Arc::from(name.as_str()));
                }
                b
            }
            (None, Some(reg)) => {
                let name = match self.default_model {
                    Some(n) => n,
                    None => reg.default_name().context(
                        "CoordinatorBuilder: the registry is empty — pack at least one \
                         model or set .backend(...)",
                    )?,
                };
                let entry = reg
                    .get(&name)
                    .with_context(|| format!("default model '{name}' is not in the registry"))?;
                default_model = Some(Arc::from(name.as_str()));
                // divide the per-batch row pool across the shards so the
                // default configuration cannot oversubscribe the machine
                // (N shards x N row workers)
                let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
                let per_shard = (cores / want).max(1);
                Box::new(NativeBackend::new((*entry.enc).clone()).with_threads(per_shard))
            }
            (None, None) => anyhow::bail!(
                "CoordinatorBuilder: a backend or a model registry is required \
                 (use .backend(...) or .registry(...))"
            ),
        };
        let policy = self.policy.unwrap_or_else(|| match backend.preferred_buckets() {
            Some(buckets) if !buckets.is_empty() => {
                BatchPolicy::new(buckets, BatchPolicy::default().max_wait)
            }
            _ => BatchPolicy::default(),
        });
        let cost = self.cost.unwrap_or_default();

        // Populate the pool: the primary backend serves shard 0, replicas
        // serve the rest.  An explicitly requested size must be honored
        // exactly or fail loudly; the default degrades to one shard for
        // single-instance backends.
        let mut backends: Vec<Box<dyn ExecutionBackend>> = Vec::with_capacity(want);
        for _ in 1..want {
            match backend.replicate() {
                Some(b) => backends.push(b),
                None => {
                    anyhow::ensure!(
                        requested.is_none(),
                        "CoordinatorBuilder: backend '{}' cannot be replicated across \
                         {want} shards (single-instance resource) — use .shards(1)",
                        backend.name()
                    );
                    backends.clear();
                    break;
                }
            }
        }
        backends.insert(0, backend);

        // Spawn every shard worker; each compiles on its own thread
        // (backend executables may not be Send) and reports startup
        // through a ready channel.  All shards must come up before
        // build() returns.
        let mut shards = Vec::with_capacity(backends.len());
        let mut readies = Vec::with_capacity(backends.len());
        for (shard_id, backend) in backends.into_iter().enumerate() {
            let metrics = Arc::new(Mutex::new(Metrics::new()));
            let metrics_worker = Arc::clone(&metrics);
            let (tx, rx) = mpsc::channel::<Msg>();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
            let buckets = policy.buckets.clone();
            let policy_worker = policy.clone();
            let registry_worker = registry.clone();
            let worker = std::thread::Builder::new()
                .name(format!("pasm-coord-{shard_id}"))
                .spawn(move || {
                    let engine = match Engine::new(backend, &buckets, &cost, registry_worker) {
                        Ok(e) => {
                            // label the metrics before signalling ready so
                            // build() never returns with an empty backend name
                            metrics_worker.lock().unwrap().record_backend(e.backend_name());
                            let _ = ready_tx.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                    worker_loop(engine, policy_worker, rx, metrics_worker, shard_id);
                })
                .with_context(|| format!("spawn coordinator shard {shard_id}"))?;
            shards.push(Shard { tx, worker: Some(worker), metrics });
            readies.push(ready_rx);
        }
        for (shard_id, ready_rx) in readies.into_iter().enumerate() {
            let started = ready_rx
                .recv()
                .with_context(|| format!("coordinator shard {shard_id} died during startup"))
                .and_then(|r| r.map_err(|e| anyhow::anyhow!(e)));
            if let Err(e) = started {
                // tear the partial pool down: dropping the senders ends
                // every healthy worker, and Shard::drop joins them
                drop(shards);
                return Err(e);
            }
        }

        Ok(Coordinator {
            shards,
            next_id: AtomicU64::new(1),
            registry,
            default_model,
        })
    }
}

/// One shard of the pool: its request channel, worker thread, and
/// shard-local metrics.
struct Shard {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
}

impl Drop for Shard {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// Handle to a running coordinator pool.
pub struct Coordinator {
    shards: Vec<Shard>,
    next_id: AtomicU64,
    registry: Option<Arc<ModelRegistry>>,
    default_model: Option<Arc<str>>,
}

impl Coordinator {
    /// Submit one image to the default model; returns a receiver for the
    /// response.
    pub fn submit(
        &self,
        image: Tensor<f32>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        self.submit_routed(image, self.default_model.clone())
    }

    /// Submit one image to a named registry model.
    pub fn submit_to(
        &self,
        model: &str,
        image: Tensor<f32>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        self.submit_routed(image, Some(Arc::from(model)))
    }

    fn submit_routed(
        &self,
        image: Tensor<f32>,
        model: Option<Arc<str>>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        let (rtx, rrx) = mpsc::channel();
        self.submit_completion(image, model, Completion::Channel(rtx))?;
        Ok(rrx)
    }

    /// Submit one image and deliver the result through `on_done` instead
    /// of a channel (`model` = `None` routes to the default model).
    ///
    /// The callback runs on the shard worker's thread right after the
    /// batch completes (or fails), so it must be cheap and non-blocking —
    /// push to a queue and wake a poller, don't do work.  This is the
    /// submission path of the evented serving front-end, where no thread
    /// exists to park on a response channel.
    pub fn submit_with<F>(&self, model: Option<&str>, image: Tensor<f32>, on_done: F) -> Result<()>
    where
        F: FnOnce(Result<InferenceResponse, String>) + Send + 'static,
    {
        let model = match model {
            Some(m) => Some(Arc::from(m)),
            None => self.default_model.clone(),
        };
        self.submit_completion(image, model, Completion::Callback(Box::new(on_done)))
    }

    fn submit_completion(
        &self,
        image: Tensor<f32>,
        model: Option<Arc<str>>,
        completion: Completion,
    ) -> Result<()> {
        let shard = self.shard_for(model.as_deref());
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = InferenceRequest::new(id, image);
        req.model = model;
        self.shards[shard]
            .tx
            .send(Msg::Request(req, completion))
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))
    }

    /// Submit to the default model and block for the answer (convenience).
    pub fn infer(&self, image: Tensor<f32>) -> Result<InferenceResponse> {
        let rx = self.submit(image)?;
        rx.recv()
            .context("coordinator dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit to a named registry model and block for the answer.
    pub fn infer_model(&self, model: &str, image: Tensor<f32>) -> Result<InferenceResponse> {
        let rx = self.submit_to(model, image)?;
        rx.recv()
            .context("coordinator dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// The registry this coordinator serves named models from, if any.
    pub fn registry(&self) -> Option<&Arc<ModelRegistry>> {
        self.registry.as_ref()
    }

    /// The model unnamed requests route to (`None` = the backend's
    /// built-in model).
    pub fn default_model(&self) -> Option<&str> {
        self.default_model.as_deref()
    }

    /// Number of shards in the pool.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard requests for `model` route to (`None` = unnamed
    /// traffic, which follows the default model).  Deterministic: a
    /// stable FNV-1a hash of the model name modulo the shard count, so
    /// the answer never changes for the lifetime of the pool.
    pub fn shard_for(&self, model: Option<&str>) -> usize {
        let key = model.or(self.default_model.as_deref()).unwrap_or("");
        (route_hash(key) % self.shards.len() as u64) as usize
    }

    /// Merged snapshot of the serving metrics across all shards.
    pub fn metrics(&self) -> Metrics {
        self.metrics_with_shards().0
    }

    /// One *consistent* snapshot: every shard's metrics are read once,
    /// and both the merged aggregate and the per-shard counters derive
    /// from those same values — so the counters always sum to the merged
    /// totals, the invariant the `metrics` wire frame documents.
    /// (Reading [`Coordinator::metrics`] and
    /// [`Coordinator::shard_counters`] separately under live traffic
    /// could disagree by whatever completed in between.)
    pub fn metrics_with_shards(&self) -> (Metrics, Vec<ShardCounters>) {
        let per_shard = self.shard_metrics();
        let mut merged = Metrics::new();
        for m in &per_shard {
            merged.merge(m);
        }
        let counters = per_shard.iter().map(Metrics::counters).collect();
        (merged, counters)
    }

    /// Per-shard metrics snapshots, indexed by shard id.
    pub fn shard_metrics(&self) -> Vec<Metrics> {
        self.shards.iter().map(|s| s.metrics.lock().unwrap().clone()).collect()
    }

    /// Compact per-shard counters, indexed by shard id (what the
    /// `metrics` wire frame reports next to the merged aggregate).
    pub fn shard_counters(&self) -> Vec<ShardCounters> {
        self.shards.iter().map(|s| s.metrics.lock().unwrap().counters()).collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // wake every shard first so they drain in parallel; Shard::drop
        // then joins each worker (its Shutdown re-send is a no-op)
        for shard in &self.shards {
            let _ = shard.tx.send(Msg::Shutdown);
        }
    }
}

type Pending = (InferenceRequest, Completion);
type ModelQueues = BTreeMap<Option<Arc<str>>, VecDeque<Pending>>;

fn push(queues: &mut ModelQueues, r: InferenceRequest, done: Completion) {
    queues.entry(r.model.clone()).or_default().push_back((r, done));
}

fn worker_loop(
    mut engine: Engine,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
    shard_id: usize,
) {
    // one queue per model: a launched batch never mixes models, and the
    // policy's wait budget applies to each model's oldest request
    let mut queues: ModelQueues = BTreeMap::new();
    let mut shutting_down = false;
    // this shard's batch sequence, stamped into every response: within
    // one model it is non-decreasing in submission order (FIFO witness)
    let mut batch_seq: u64 = 0;

    loop {
        // 1) drain the channel (non-blocking if we already hold requests,
        //    blocking otherwise)
        let held: usize = queues.values().map(VecDeque::len).sum();
        if held == 0 && !shutting_down {
            match rx.recv() {
                Ok(Msg::Request(r, done)) => push(&mut queues, r, done),
                Ok(Msg::Shutdown) | Err(_) => shutting_down = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Msg::Request(r, done)) => push(&mut queues, r, done),
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }

        queues.retain(|_, q| !q.is_empty());
        if queues.is_empty() {
            if shutting_down {
                return;
            }
            continue;
        }

        // 2) batching decision, per model: among the launchable queues,
        //    pick the one whose front request has waited longest
        let mut launch: Option<(Option<Arc<str>>, usize, Instant)> = None;
        for (model, q) in &queues {
            let front = q.front().expect("empty queues were dropped above").0.enqueued_at;
            let expired = shutting_down || front.elapsed() >= policy.max_wait;
            if let Some(bucket) = policy.decide(q.len(), expired) {
                let older = match &launch {
                    None => true,
                    Some((_, _, t)) => front < *t,
                };
                if older {
                    launch = Some((model.clone(), bucket, front));
                }
            }
        }
        let Some((model, bucket, _)) = launch else {
            // wait a beat for more requests (bounded by the wait budget)
            if let Ok(msg) = rx.recv_timeout(policy.max_wait) {
                match msg {
                    Msg::Request(r, done) => push(&mut queues, r, done),
                    Msg::Shutdown => shutting_down = true,
                }
            }
            continue;
        };

        // 3) launch
        let queue = queues.get_mut(&model).expect("launch model has a queue");
        let take = bucket.min(queue.len());
        let batch: Vec<Pending> = queue.drain(..take).collect();
        let requests: Vec<InferenceRequest> = batch.iter().map(|(r, _)| r.clone()).collect();
        let label: &str = model.as_deref().unwrap_or(DEFAULT_MODEL_LABEL);
        let started = Instant::now();
        let seq = batch_seq;
        batch_seq += 1;
        // Contain kernel panics (e.g. the fixed-point overflow guards on an
        // extreme input): the batch fails, the worker keeps serving.  The
        // engine's only cross-batch mutable state is a staging buffer that
        // every batch fully overwrites, so resuming is sound.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run_batch(&requests, bucket)
        }))
        .unwrap_or_else(|p| {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "kernel panicked".to_string());
            Err(anyhow::anyhow!("execution panicked: {msg}"))
        });
        match result {
            Ok(mut responses) => {
                for resp in &mut responses {
                    resp.shard = shard_id;
                    resp.batch_seq = seq;
                }
                // one uncontended shard-local lock per batch, never a
                // global one: snapshot readers merge across shards
                let mut m = metrics.lock().unwrap();
                m.record_batch(label, requests.len(), bucket);
                if let Some(first) = responses.first() {
                    m.record_hw(first.hw.cycles, first.hw.energy_j);
                }
                for (req, _) in &batch {
                    m.record_latency(req.enqueued_at.elapsed());
                }
                drop(m);
                for ((_, done), resp) in batch.into_iter().zip(responses) {
                    done.deliver(Ok(resp));
                }
            }
            Err(e) => {
                metrics.lock().unwrap().record_failed_batch(label);
                let msg = format!("batch failed after {:?}: {e:#}", started.elapsed());
                for (_, done) in batch {
                    done.deliver(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_hash_is_the_pinned_fnv1a() {
        // the routing hash is part of the coordinator's stable behavior:
        // a model's shard must not move between builds.  Reference
        // values computed from the FNV-1a spec (offset 0xcbf29ce484222325,
        // prime 0x100000001b3).
        assert_eq!(route_hash(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(route_hash("alpha") % 4, 3);
        assert_eq!(route_hash("beta") % 4, 3);
        assert_eq!(route_hash("gamma") % 4, 2);
        assert_eq!(route_hash("delta") % 4, 1);
        assert_eq!(route_hash("digits-v0") % 4, 0);
        assert_eq!(route_hash("digits-v1") % 4, 3);
        assert_eq!(route_hash("digits-v2") % 4, 2);
        assert_eq!(route_hash("digits-v3") % 4, 1);
    }
}
