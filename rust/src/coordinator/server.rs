//! The coordinator server: builder, worker thread, submission handle.
//!
//! [`CoordinatorBuilder`] assembles a backend (and/or a
//! [`ModelRegistry`]), a batch policy, and a cost model into a running
//! [`Coordinator`].  One worker thread owns the [`Engine`] (backend
//! executables need not be `Sync`; compilation happens on the worker) and
//! drains a request channel into **per-model queues**, applying the
//! [`BatchPolicy`] to each: wait for a fillable bucket or the oldest
//! request's deadline, then launch the queue whose front request has
//! waited longest — one launched batch never mixes models.  Clients get a
//! per-request response channel.  Drop the [`Coordinator`] to shut down
//! cleanly (pending requests are flushed first).

use crate::coordinator::backend::{ExecutionBackend, NativeBackend};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::cost::CostModel;
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::{DEFAULT_MODEL_LABEL, Metrics};
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use crate::model_store::ModelRegistry;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

enum Msg {
    Request(InferenceRequest, mpsc::Sender<Result<InferenceResponse, String>>),
    Shutdown,
}

/// Builds a [`Coordinator`] from a backend and/or model registry, a batch
/// policy, and a cost model.
///
/// The batch policy defaults to the backend's preferred buckets (e.g. the
/// sizes an AOT flow exported) or [`BatchPolicy::default`]; the cost model
/// defaults to PASM silicon at 45 nm / 1 GHz ([`CostModel::pasm_asic`]).
///
/// ```
/// use pasm_accel::cnn::data::{render_digit, Rng};
/// use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
/// use pasm_accel::coordinator::{BatchPolicy, CoordinatorBuilder, NativeBackend};
/// use pasm_accel::quant::fixed::QFormat;
/// use std::time::Duration;
///
/// let arch = DigitsCnn::default();
/// let mut rng = Rng::new(1);
/// let params = arch.init(&mut rng);
/// let enc = EncodedCnn::encode(arch, &params, 4, QFormat::W16);
///
/// let coord = CoordinatorBuilder::new()
///     .backend(NativeBackend::new(enc))
///     .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
///     .build()?;
/// let resp = coord.infer(render_digit(&mut rng, 3, 0.05))?;
/// assert_eq!(resp.logits.len(), 10);
/// assert!(resp.hw.cycles > 0);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Default)]
pub struct CoordinatorBuilder {
    backend: Option<Box<dyn ExecutionBackend>>,
    policy: Option<BatchPolicy>,
    cost: Option<CostModel>,
    registry: Option<Arc<ModelRegistry>>,
    default_model: Option<String>,
}

impl CoordinatorBuilder {
    /// An empty builder (equivalent to `CoordinatorBuilder::default()`).
    pub fn new() -> Self {
        CoordinatorBuilder::default()
    }

    /// The execution backend to serve from (required unless a
    /// [`CoordinatorBuilder::registry`] provides the models).
    pub fn backend(mut self, backend: impl ExecutionBackend + 'static) -> Self {
        self.backend = Some(Box::new(backend));
        self
    }

    /// Same as [`CoordinatorBuilder::backend`] for an already-boxed backend.
    pub fn boxed_backend(mut self, backend: Box<dyn ExecutionBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Serve named models from this registry ([`Coordinator::submit_to`] /
    /// [`Coordinator::infer_model`]).  Without an explicit
    /// [`CoordinatorBuilder::backend`], a [`NativeBackend`] is built
    /// around the registry's default model, and *unnamed* requests route
    /// to that model **by name** — so hot-swapping its artifact takes
    /// effect without a restart.
    ///
    /// ```
    /// use pasm_accel::cnn::data::{render_digit, Rng};
    /// use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
    /// use pasm_accel::coordinator::CoordinatorBuilder;
    /// use pasm_accel::model_store::ModelRegistry;
    /// use pasm_accel::quant::fixed::QFormat;
    /// use std::sync::Arc;
    ///
    /// let arch = DigitsCnn::default();
    /// let mut rng = Rng::new(1);
    /// let registry = Arc::new(ModelRegistry::new());
    /// registry.insert("b4", EncodedCnn::encode(arch, &arch.init(&mut rng), 4, QFormat::W16));
    /// registry.insert("b8", EncodedCnn::encode(arch, &arch.init(&mut rng), 8, QFormat::W16));
    ///
    /// let coord = CoordinatorBuilder::new().registry(Arc::clone(&registry)).build()?;
    /// let resp = coord.infer_model("b8", render_digit(&mut rng, 3, 0.05))?;
    /// assert_eq!(resp.model.as_deref(), Some("b8"));
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn registry(mut self, registry: Arc<ModelRegistry>) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Which registry model unnamed requests route to (default: the
    /// registry's alphabetically first model).  Requires a registry.
    pub fn default_model(mut self, name: impl Into<String>) -> Self {
        self.default_model = Some(name.into());
        self
    }

    /// Bucketed dynamic-batching policy (default: the backend's preferred
    /// buckets with a 2 ms wait budget, else [`BatchPolicy::default`]).
    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Hardware cost model batches are priced with (default:
    /// [`CostModel::pasm_asic`]).
    pub fn cost_model(mut self, cost: CostModel) -> Self {
        self.cost = Some(cost);
        self
    }

    /// Spawn the worker, compile every default-model bucket, and start
    /// serving.  Returns once the backend compiled successfully (startup
    /// errors surface here, not on first request); registry models
    /// compile lazily on first use so a hot-dropped artifact needs no
    /// restart.
    pub fn build(self) -> Result<Coordinator> {
        let registry = self.registry;
        let mut default_model: Option<Arc<str>> = None;
        let backend: Box<dyn ExecutionBackend> = match (self.backend, &registry) {
            (Some(b), _) => {
                if let Some(name) = &self.default_model {
                    let reg = registry
                        .as_ref()
                        .context("CoordinatorBuilder: default_model requires .registry(...)")?;
                    anyhow::ensure!(
                        reg.get(name).is_some(),
                        "default model '{name}' is not in the registry"
                    );
                    default_model = Some(Arc::from(name.as_str()));
                }
                b
            }
            (None, Some(reg)) => {
                let name = match self.default_model {
                    Some(n) => n,
                    None => reg.default_name().context(
                        "CoordinatorBuilder: the registry is empty — pack at least one \
                         model or set .backend(...)",
                    )?,
                };
                let entry = reg
                    .get(&name)
                    .with_context(|| format!("default model '{name}' is not in the registry"))?;
                default_model = Some(Arc::from(name.as_str()));
                Box::new(NativeBackend::new((*entry.enc).clone()))
            }
            (None, None) => anyhow::bail!(
                "CoordinatorBuilder: a backend or a model registry is required \
                 (use .backend(...) or .registry(...))"
            ),
        };
        let policy = self.policy.unwrap_or_else(|| match backend.preferred_buckets() {
            Some(buckets) if !buckets.is_empty() => {
                BatchPolicy::new(buckets, BatchPolicy::default().max_wait)
            }
            _ => BatchPolicy::default(),
        });
        let cost = self.cost.unwrap_or_default();

        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let metrics_worker = Arc::clone(&metrics);
        let (tx, rx) = mpsc::channel::<Msg>();

        // Compile on the worker thread (backend executables may not be
        // Send); report startup errors through a channel.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let buckets = policy.buckets.clone();
        let registry_worker = registry.clone();
        let worker = std::thread::Builder::new()
            .name("pasm-coordinator".into())
            .spawn(move || {
                let engine = match Engine::new(backend, &buckets, &cost, registry_worker) {
                    Ok(e) => {
                        // label the metrics before signalling ready so
                        // build() never returns with an empty backend name
                        metrics_worker.lock().unwrap().record_backend(e.backend_name());
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                worker_loop(engine, policy, rx, metrics_worker);
            })
            .context("spawn coordinator worker")?;

        ready_rx
            .recv()
            .context("coordinator worker died during startup")?
            .map_err(|e| anyhow::anyhow!(e))?;

        Ok(Coordinator {
            tx,
            worker: Some(worker),
            next_id: AtomicU64::new(1),
            metrics,
            registry,
            default_model,
        })
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<Metrics>>,
    registry: Option<Arc<ModelRegistry>>,
    default_model: Option<Arc<str>>,
}

impl Coordinator {
    /// Submit one image to the default model; returns a receiver for the
    /// response.
    pub fn submit(
        &self,
        image: Tensor<f32>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        self.submit_routed(image, self.default_model.clone())
    }

    /// Submit one image to a named registry model.
    pub fn submit_to(
        &self,
        model: &str,
        image: Tensor<f32>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        self.submit_routed(image, Some(Arc::from(model)))
    }

    fn submit_routed(
        &self,
        image: Tensor<f32>,
        model: Option<Arc<str>>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        let mut req = InferenceRequest::new(id, image);
        req.model = model;
        self.tx
            .send(Msg::Request(req, rtx))
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(rrx)
    }

    /// Submit to the default model and block for the answer (convenience).
    pub fn infer(&self, image: Tensor<f32>) -> Result<InferenceResponse> {
        let rx = self.submit(image)?;
        rx.recv()
            .context("coordinator dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit to a named registry model and block for the answer.
    pub fn infer_model(&self, model: &str, image: Tensor<f32>) -> Result<InferenceResponse> {
        let rx = self.submit_to(model, image)?;
        rx.recv()
            .context("coordinator dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// The registry this coordinator serves named models from, if any.
    pub fn registry(&self) -> Option<&Arc<ModelRegistry>> {
        self.registry.as_ref()
    }

    /// The model unnamed requests route to (`None` = the backend's
    /// built-in model).
    pub fn default_model(&self) -> Option<&str> {
        self.default_model.as_deref()
    }

    /// Snapshot of the serving metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

type ResponseTx = mpsc::Sender<Result<InferenceResponse, String>>;
type Pending = (InferenceRequest, ResponseTx);
type ModelQueues = BTreeMap<Option<Arc<str>>, VecDeque<Pending>>;

fn push(queues: &mut ModelQueues, r: InferenceRequest, tx: ResponseTx) {
    queues.entry(r.model.clone()).or_default().push_back((r, tx));
}

fn worker_loop(
    mut engine: Engine,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
) {
    // one queue per model: a launched batch never mixes models, and the
    // policy's wait budget applies to each model's oldest request
    let mut queues: ModelQueues = BTreeMap::new();
    let mut shutting_down = false;

    loop {
        // 1) drain the channel (non-blocking if we already hold requests,
        //    blocking otherwise)
        let held: usize = queues.values().map(VecDeque::len).sum();
        if held == 0 && !shutting_down {
            match rx.recv() {
                Ok(Msg::Request(r, tx)) => push(&mut queues, r, tx),
                Ok(Msg::Shutdown) | Err(_) => shutting_down = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Msg::Request(r, tx)) => push(&mut queues, r, tx),
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }

        queues.retain(|_, q| !q.is_empty());
        if queues.is_empty() {
            if shutting_down {
                return;
            }
            continue;
        }

        // 2) batching decision, per model: among the launchable queues,
        //    pick the one whose front request has waited longest
        let mut launch: Option<(Option<Arc<str>>, usize, Instant)> = None;
        for (model, q) in &queues {
            let front = q.front().expect("empty queues were dropped above").0.enqueued_at;
            let expired = shutting_down || front.elapsed() >= policy.max_wait;
            if let Some(bucket) = policy.decide(q.len(), expired) {
                let older = match &launch {
                    None => true,
                    Some((_, _, t)) => front < *t,
                };
                if older {
                    launch = Some((model.clone(), bucket, front));
                }
            }
        }
        let Some((model, bucket, _)) = launch else {
            // wait a beat for more requests (bounded by the wait budget)
            if let Ok(msg) = rx.recv_timeout(policy.max_wait) {
                match msg {
                    Msg::Request(r, tx) => push(&mut queues, r, tx),
                    Msg::Shutdown => shutting_down = true,
                }
            }
            continue;
        };

        // 3) launch
        let queue = queues.get_mut(&model).expect("launch model has a queue");
        let take = bucket.min(queue.len());
        let batch: Vec<Pending> = queue.drain(..take).collect();
        let requests: Vec<InferenceRequest> = batch.iter().map(|(r, _)| r.clone()).collect();
        let label: &str = model.as_deref().unwrap_or(DEFAULT_MODEL_LABEL);
        let started = Instant::now();
        // Contain kernel panics (e.g. the fixed-point overflow guards on an
        // extreme input): the batch fails, the worker keeps serving.  The
        // engine's only cross-batch mutable state is a staging buffer that
        // every batch fully overwrites, so resuming is sound.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run_batch(&requests, bucket)
        }))
        .unwrap_or_else(|p| {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "kernel panicked".to_string());
            Err(anyhow::anyhow!("execution panicked: {msg}"))
        });
        match result {
            Ok(responses) => {
                // one lock per batch, not per request (§Perf)
                let mut m = metrics.lock().unwrap();
                m.record_batch(label, requests.len(), bucket);
                if let Some(first) = responses.first() {
                    m.record_hw(first.hw.cycles, first.hw.energy_j);
                }
                for (req, _) in &batch {
                    m.record_latency(req.enqueued_at.elapsed());
                }
                drop(m);
                for ((_, tx), resp) in batch.into_iter().zip(responses) {
                    let _ = tx.send(Ok(resp));
                }
            }
            Err(e) => {
                metrics.lock().unwrap().record_failed_batch(label);
                let msg = format!("batch failed after {:?}: {e:#}", started.elapsed());
                for (_, tx) in batch {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
    }
}
