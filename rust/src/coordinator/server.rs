//! The coordinator server: builder, worker thread, submission handle.
//!
//! [`CoordinatorBuilder`] assembles a backend, a batch policy, and a cost
//! model into a running [`Coordinator`].  One worker thread owns the
//! [`Engine`] (backend executables need not be `Sync`; compilation happens
//! on the worker) and drains a request channel, applying the
//! [`BatchPolicy`]: wait for a fillable bucket or the oldest request's
//! deadline, then launch.  Clients get a per-request response channel.
//! Drop the [`Coordinator`] to shut down cleanly (pending requests are
//! flushed first).

use crate::cnn::network::EncodedCnn;
use crate::coordinator::backend::{default_backend, ExecutionBackend};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::cost::CostModel;
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

enum Msg {
    Request(InferenceRequest, mpsc::Sender<Result<InferenceResponse, String>>),
    Shutdown,
}

/// Builds a [`Coordinator`] from a backend, batch policy, and cost model.
///
/// The batch policy defaults to the backend's preferred buckets (e.g. the
/// sizes an AOT flow exported) or [`BatchPolicy::default`]; the cost model
/// defaults to PASM silicon at 45 nm / 1 GHz ([`CostModel::pasm_asic`]).
///
/// ```
/// use pasm_accel::cnn::data::{render_digit, Rng};
/// use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
/// use pasm_accel::coordinator::{BatchPolicy, CoordinatorBuilder, NativeBackend};
/// use pasm_accel::quant::fixed::QFormat;
/// use std::time::Duration;
///
/// let arch = DigitsCnn::default();
/// let mut rng = Rng::new(1);
/// let params = arch.init(&mut rng);
/// let enc = EncodedCnn::encode(arch, &params, 4, QFormat::W16);
///
/// let coord = CoordinatorBuilder::new()
///     .backend(NativeBackend::new(enc))
///     .batch_policy(BatchPolicy::new(vec![1, 4], Duration::from_millis(1)))
///     .build()?;
/// let resp = coord.infer(render_digit(&mut rng, 3, 0.05))?;
/// assert_eq!(resp.logits.len(), 10);
/// assert!(resp.hw.cycles > 0);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Default)]
pub struct CoordinatorBuilder {
    backend: Option<Box<dyn ExecutionBackend>>,
    policy: Option<BatchPolicy>,
    cost: Option<CostModel>,
}

impl CoordinatorBuilder {
    pub fn new() -> Self {
        CoordinatorBuilder::default()
    }

    /// The execution backend to serve from (required).
    pub fn backend(mut self, backend: impl ExecutionBackend + 'static) -> Self {
        self.backend = Some(Box::new(backend));
        self
    }

    /// Same as [`CoordinatorBuilder::backend`] for an already-boxed backend.
    pub fn boxed_backend(mut self, backend: Box<dyn ExecutionBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Bucketed dynamic-batching policy (default: the backend's preferred
    /// buckets with a 2 ms wait budget, else [`BatchPolicy::default`]).
    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Hardware cost model batches are priced with (default:
    /// [`CostModel::pasm_asic`]).
    pub fn cost_model(mut self, cost: CostModel) -> Self {
        self.cost = Some(cost);
        self
    }

    /// Spawn the worker, compile every bucket, and start serving.  Returns
    /// once the backend compiled successfully (startup errors surface
    /// here, not on first request).
    pub fn build(self) -> Result<Coordinator> {
        let backend = self
            .backend
            .context("CoordinatorBuilder: a backend is required (use .backend(...))")?;
        let policy = self.policy.unwrap_or_else(|| match backend.preferred_buckets() {
            Some(buckets) if !buckets.is_empty() => {
                BatchPolicy::new(buckets, BatchPolicy::default().max_wait)
            }
            _ => BatchPolicy::default(),
        });
        let cost = self.cost.unwrap_or_default();

        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let metrics_worker = Arc::clone(&metrics);
        let (tx, rx) = mpsc::channel::<Msg>();

        // Compile on the worker thread (backend executables may not be
        // Send); report startup errors through a channel.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let buckets = policy.buckets.clone();
        let worker = std::thread::Builder::new()
            .name("pasm-coordinator".into())
            .spawn(move || {
                let engine = match Engine::new(backend, &buckets, &cost) {
                    Ok(e) => {
                        // label the metrics before signalling ready so
                        // build() never returns with an empty backend name
                        metrics_worker.lock().unwrap().record_backend(e.backend_name());
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                worker_loop(engine, policy, rx, metrics_worker);
            })
            .context("spawn coordinator worker")?;

        ready_rx
            .recv()
            .context("coordinator worker died during startup")?
            .map_err(|e| anyhow::anyhow!(e))?;

        Ok(Coordinator { tx, worker: Some(worker), next_id: AtomicU64::new(1), metrics })
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<Metrics>>,
}

impl Coordinator {
    /// Deprecated constructor kept for source compatibility: serves `enc`
    /// from `artifacts_dir` on the PJRT backend when the `pjrt` feature is
    /// enabled, else falls back to the in-process
    /// [`NativeBackend`](crate::coordinator::backend::NativeBackend)
    /// (ignoring `artifacts_dir`).
    #[deprecated(
        since = "0.2.0",
        note = "use CoordinatorBuilder::new().backend(...).batch_policy(...).build()"
    )]
    pub fn start(
        artifacts_dir: &str,
        enc: EncodedCnn,
        policy: BatchPolicy,
    ) -> Result<Self> {
        CoordinatorBuilder::new()
            .boxed_backend(default_backend(artifacts_dir, enc))
            .batch_policy(policy)
            .build()
    }

    /// Submit one image; returns a receiver for the response.
    pub fn submit(
        &self,
        image: Tensor<f32>,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(InferenceRequest::new(id, image), rtx))
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(rrx)
    }

    /// Submit and block for the answer (convenience).
    pub fn infer(&self, image: Tensor<f32>) -> Result<InferenceResponse> {
        let rx = self.submit(image)?;
        rx.recv()
            .context("coordinator dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Snapshot of the serving metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    mut engine: Engine,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
) {
    type Pending = (InferenceRequest, mpsc::Sender<Result<InferenceResponse, String>>);
    let mut queue: VecDeque<Pending> = VecDeque::new();
    let mut shutting_down = false;

    loop {
        // 1) drain the channel (non-blocking if we already hold requests,
        //    blocking with deadline otherwise)
        if queue.is_empty() && !shutting_down {
            match rx.recv() {
                Ok(Msg::Request(r, tx)) => queue.push_back((r, tx)),
                Ok(Msg::Shutdown) | Err(_) => shutting_down = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Msg::Request(r, tx)) => queue.push_back((r, tx)),
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }

        if queue.is_empty() {
            if shutting_down {
                return;
            }
            continue;
        }

        // 2) batching decision
        let oldest_expired = shutting_down
            || queue
                .front()
                .map(|(r, _)| r.enqueued_at.elapsed() >= policy.max_wait)
                .unwrap_or(false);
        let Some(bucket) = policy.decide(queue.len(), oldest_expired) else {
            // wait a beat for more requests (bounded by the wait budget)
            if let Ok(msg) = rx.recv_timeout(policy.max_wait) {
                match msg {
                    Msg::Request(r, tx) => queue.push_back((r, tx)),
                    Msg::Shutdown => shutting_down = true,
                }
            }
            continue;
        };

        // 3) launch
        let take = bucket.min(queue.len());
        let batch: Vec<Pending> = queue.drain(..take).collect();
        let requests: Vec<InferenceRequest> = batch.iter().map(|(r, _)| r.clone()).collect();
        let started = Instant::now();
        // Contain kernel panics (e.g. the fixed-point overflow guards on an
        // extreme input): the batch fails, the worker keeps serving.  The
        // engine's only cross-batch mutable state is a staging buffer that
        // every batch fully overwrites, so resuming is sound.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run_batch(&requests, bucket)
        }))
        .unwrap_or_else(|p| {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "kernel panicked".to_string());
            Err(anyhow::anyhow!("execution panicked: {msg}"))
        });
        match result {
            Ok(responses) => {
                // one lock per batch, not per request (§Perf)
                let mut m = metrics.lock().unwrap();
                m.record_batch(requests.len(), bucket);
                if let Some(first) = responses.first() {
                    m.record_hw(first.hw.cycles, first.hw.energy_j);
                }
                for (req, _) in &batch {
                    m.record_latency(req.enqueued_at.elapsed());
                }
                drop(m);
                for ((_, tx), resp) in batch.into_iter().zip(responses) {
                    let _ = tx.send(Ok(resp));
                }
            }
            Err(e) => {
                let msg = format!("batch failed after {:?}: {e:#}", started.elapsed());
                for (_, tx) in batch {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
    }
}
