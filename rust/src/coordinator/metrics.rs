//! Serving metrics: per-shard counters merged on snapshot.
//!
//! Every coordinator shard owns one [`Metrics`] value and is its only
//! writer, so recording a completed batch touches an **uncontended**
//! shard-local lock — no global mutex sits on the request path.  Readers
//! ([`crate::coordinator::Coordinator::metrics`], the `metrics` wire
//! frame) clone each shard's value and [`Metrics::merge`] them into one
//! aggregate; [`ShardCounters`] is the compact per-shard summary those
//! snapshots also report, so an operator can see whether traffic actually
//! spreads across the pool.

use std::collections::BTreeMap;
use std::time::Duration;

/// Label used for requests served by the default (unnamed) backend model.
pub const DEFAULT_MODEL_LABEL: &str = "default";

/// Latency samples retained for percentile computation (a sliding window
/// over the most recent requests — the network front-end serves
/// indefinitely, so the history must not grow with total traffic).
pub const LATENCY_WINDOW: usize = 65_536;

/// Per-model serving counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ModelCounters {
    /// Requests served (live batch slots, excl. padding).
    pub requests: u64,
    /// Batches launched for this model.
    pub batches: u64,
    /// Batches that failed (execution error or panic) for this model.
    pub failed_batches: u64,
    /// Requests whose deadline expired before their batch launched.
    pub deadline_misses: u64,
}

/// Compact per-shard counter summary, reported next to the merged
/// aggregate in metrics snapshots and the `metrics` wire frame.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardCounters {
    /// Requests this shard served (live batch slots, excl. padding).
    pub requests: u64,
    /// Batches this shard launched.
    pub batches: u64,
    /// Batches that failed on this shard.
    pub failed_batches: u64,
    /// Requests this shard dropped for an expired deadline.
    pub deadline_misses: u64,
}

/// Rolling metrics for one coordinator shard (or, after
/// [`Metrics::merge`], for the whole pool).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Label of the execution backend serving the requests ("native",
    /// "pjrt", ...); empty until the worker starts.
    pub backend: String,
    /// Requests served across all models.
    pub requests: u64,
    /// Batches launched across all models.
    pub batches: u64,
    /// Batches that failed (execution error, panic, or unresolvable
    /// model), across all models.
    pub failed_batches: u64,
    /// Requests dropped because their deadline expired before launch,
    /// across all models.
    pub deadline_misses: u64,
    /// Executed batch slots that were zero padding.
    pub padded_slots: u64,
    /// Per-model request/batch counters, keyed by model name (the default
    /// backend model records under [`DEFAULT_MODEL_LABEL`]).
    pub per_model: BTreeMap<String, ModelCounters>,
    /// End-to-end latencies (µs): a sliding window over the most recent
    /// [`LATENCY_WINDOW`] completed requests, so a long-running server's
    /// memory and snapshot cost stay bounded.
    latencies_us: Vec<u64>,
    /// Next window slot to overwrite once the window is full.
    latency_cursor: usize,
    /// Total simulated accelerator energy (J).
    pub sim_energy_j: f64,
    /// Total simulated accelerator cycles.
    pub sim_cycles: u64,
}

impl Metrics {
    /// Fresh, all-zero metrics.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record which backend is serving (shown in metrics snapshots).
    pub fn record_backend(&mut self, name: &str) {
        self.backend = name.to_string();
    }

    /// Count one launched batch of `occupancy` live requests in a
    /// `bucket`-slot batch for `model`.
    pub fn record_batch(&mut self, model: &str, occupancy: usize, bucket: usize) {
        self.batches += 1;
        self.requests += occupancy as u64;
        self.padded_slots += (bucket - occupancy) as u64;
        let m = self.per_model.entry(model.to_string()).or_default();
        m.batches += 1;
        m.requests += occupancy as u64;
    }

    /// Count a failed batch.  The global counter always moves; the
    /// per-model counter only moves for models that already have an
    /// entry (i.e. served at least one batch) — a client submitting
    /// made-up model names must not grow the map without bound.
    pub fn record_failed_batch(&mut self, model: &str) {
        self.failed_batches += 1;
        if let Some(m) = self.per_model.get_mut(model) {
            m.failed_batches += 1;
        }
    }

    /// Count a request dropped for an expired deadline.  Same map-growth
    /// guard as [`Metrics::record_failed_batch`]: the per-model counter
    /// only moves for models that already have an entry.
    pub fn record_deadline_miss(&mut self, model: &str) {
        self.deadline_misses += 1;
        if let Some(m) = self.per_model.get_mut(model) {
            m.deadline_misses += 1;
        }
    }

    /// Record one request's end-to-end latency (sliding window: once
    /// [`LATENCY_WINDOW`] samples are held, the oldest is overwritten).
    pub fn record_latency(&mut self, lat: Duration) {
        let us = lat.as_micros() as u64;
        if self.latencies_us.len() < LATENCY_WINDOW {
            self.latencies_us.push(us);
        } else {
            self.latencies_us[self.latency_cursor] = us;
        }
        self.latency_cursor = (self.latency_cursor + 1) % LATENCY_WINDOW;
    }

    /// Accumulate one batch's simulated accelerator cost.
    pub fn record_hw(&mut self, cycles: u64, energy_j: f64) {
        self.sim_cycles += cycles;
        self.sim_energy_j += energy_j;
    }

    /// Counters for one model (by name; [`DEFAULT_MODEL_LABEL`] for the
    /// default backend model).
    pub fn model(&self, name: &str) -> ModelCounters {
        self.per_model.get(name).copied().unwrap_or_default()
    }

    /// This shard's compact counter summary.
    pub fn counters(&self) -> ShardCounters {
        ShardCounters {
            requests: self.requests,
            batches: self.batches,
            failed_batches: self.failed_batches,
            deadline_misses: self.deadline_misses,
        }
    }

    /// Fold another shard's snapshot into this one: counters sum,
    /// per-model maps merge, latency samples concatenate (the merged
    /// value is a *snapshot* for percentile queries — shards keep
    /// recording into their own windows).
    pub fn merge(&mut self, other: &Metrics) {
        if self.backend.is_empty() {
            self.backend = other.backend.clone();
        }
        self.requests += other.requests;
        self.batches += other.batches;
        self.failed_batches += other.failed_batches;
        self.deadline_misses += other.deadline_misses;
        self.padded_slots += other.padded_slots;
        self.sim_cycles += other.sim_cycles;
        self.sim_energy_j += other.sim_energy_j;
        for (name, c) in &other.per_model {
            let m = self.per_model.entry(name.clone()).or_default();
            m.requests += c.requests;
            m.batches += c.batches;
            m.failed_batches += c.failed_batches;
            m.deadline_misses += c.deadline_misses;
        }
        self.latencies_us.extend_from_slice(&other.latencies_us);
    }

    /// Latency percentile (p in [0, 100]); None until data arrives.
    pub fn percentile_us(&self, p: f64) -> Option<u64> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        Some(v[rank.min(v.len() - 1)])
    }

    /// Mean batch occupancy (live requests per launched batch).
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Fraction of executed slots that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let total = self.requests + self.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.padded_slots as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new();
        m.record_batch(DEFAULT_MODEL_LABEL, 5, 8);
        m.record_batch(DEFAULT_MODEL_LABEL, 16, 16);
        assert_eq!(m.requests, 21);
        assert_eq!(m.batches, 2);
        assert_eq!(m.padded_slots, 3);
        assert!((m.mean_occupancy() - 10.5).abs() < 1e-9);
        assert!((m.padding_fraction() - 3.0 / 24.0).abs() < 1e-9);
    }

    #[test]
    fn per_model_accounting() {
        let mut m = Metrics::new();
        m.record_batch("a", 4, 8);
        m.record_batch("b", 8, 8);
        m.record_batch("a", 2, 2);
        m.record_failed_batch("b");
        let a = ModelCounters { requests: 6, batches: 2, failed_batches: 0, deadline_misses: 0 };
        assert_eq!(m.model("a"), a);
        let b = ModelCounters { requests: 8, batches: 1, failed_batches: 1, deadline_misses: 0 };
        assert_eq!(m.model("b"), b);
        assert_eq!(m.model("missing"), ModelCounters::default());
        // globals aggregate across models
        assert_eq!(m.requests, 14);
        assert_eq!(m.batches, 3);
        assert_eq!(m.failed_batches, 1);
    }

    #[test]
    fn unknown_model_failures_do_not_grow_the_map() {
        let mut m = Metrics::new();
        for i in 0..100 {
            m.record_failed_batch(&format!("bogus-{i}"));
        }
        assert_eq!(m.failed_batches, 100);
        assert!(m.per_model.is_empty(), "made-up names must not create entries");
    }

    #[test]
    fn deadline_misses_follow_the_same_map_growth_guard() {
        let mut m = Metrics::new();
        m.record_batch("real", 1, 1);
        m.record_deadline_miss("real");
        m.record_deadline_miss("bogus");
        assert_eq!(m.deadline_misses, 2);
        assert_eq!(m.model("real").deadline_misses, 1);
        assert_eq!(m.per_model.len(), 1, "made-up names must not create entries");
    }

    #[test]
    fn percentiles() {
        let mut m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        assert_eq!(m.percentile_us(0.0), Some(100));
        assert_eq!(m.percentile_us(100.0), Some(1000));
        let p50 = m.percentile_us(50.0).unwrap();
        assert!((500..=600).contains(&p50));
    }

    #[test]
    fn empty_percentile_none() {
        assert_eq!(Metrics::new().percentile_us(50.0), None);
    }

    #[test]
    fn latency_window_is_bounded_and_slides() {
        let mut m = Metrics::new();
        for i in 0..(LATENCY_WINDOW + 10) {
            m.record_latency(Duration::from_micros(i as u64));
        }
        assert_eq!(m.latencies_us.len(), LATENCY_WINDOW, "window must not grow");
        // the oldest 10 samples were overwritten by the newest 10
        assert_eq!(m.percentile_us(0.0), Some(10));
        assert_eq!(m.percentile_us(100.0), Some((LATENCY_WINDOW + 9) as u64));
    }

    #[test]
    fn merge_sums_counters_and_concatenates_latencies() {
        let mut a = Metrics::new();
        a.record_backend("native");
        a.record_batch("x", 4, 8);
        a.record_latency(Duration::from_micros(100));
        a.record_hw(1000, 1e-6);
        let mut b = Metrics::new();
        b.record_backend("native");
        b.record_batch("x", 2, 2);
        b.record_batch("y", 8, 8);
        b.record_failed_batch("y");
        b.record_latency(Duration::from_micros(300));
        b.record_latency(Duration::from_micros(500));
        b.record_hw(500, 5e-7);

        let mut merged = Metrics::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.backend, "native");
        assert_eq!(merged.requests, 14);
        assert_eq!(merged.batches, 3);
        assert_eq!(merged.failed_batches, 1);
        assert_eq!(merged.padded_slots, 4);
        let x = ModelCounters { requests: 6, batches: 2, failed_batches: 0, deadline_misses: 0 };
        assert_eq!(merged.model("x"), x);
        let y = ModelCounters { requests: 8, batches: 1, failed_batches: 1, deadline_misses: 0 };
        assert_eq!(merged.model("y"), y);
        assert_eq!(merged.percentile_us(0.0), Some(100));
        assert_eq!(merged.percentile_us(100.0), Some(500));
        assert_eq!(merged.sim_cycles, 1500);
        assert!((merged.sim_energy_j - 1.5e-6).abs() < 1e-12);
    }

    #[test]
    fn counters_summarize_one_shard() {
        let mut m = Metrics::new();
        m.record_batch("a", 3, 4);
        m.record_batch("a", 4, 4);
        m.record_failed_batch("a");
        m.record_deadline_miss("a");
        assert_eq!(
            m.counters(),
            ShardCounters { requests: 7, batches: 2, failed_batches: 1, deadline_misses: 1 }
        );
    }

    #[test]
    fn hw_totals() {
        let mut m = Metrics::new();
        m.record_hw(1000, 1e-6);
        m.record_hw(500, 5e-7);
        assert_eq!(m.sim_cycles, 1500);
        assert!((m.sim_energy_j - 1.5e-6).abs() < 1e-12);
    }
}
