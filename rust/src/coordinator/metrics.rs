//! Serving metrics: per-shard counters and latency histograms merged on
//! snapshot.
//!
//! Every coordinator shard owns one [`Metrics`] value and is its only
//! writer, so recording a completed batch touches an **uncontended**
//! shard-local lock — no global mutex sits on the request path.  Readers
//! ([`crate::coordinator::Coordinator::metrics`], the `metrics` wire
//! frame) clone each shard's value and [`Metrics::merge`] them into one
//! aggregate; [`ShardCounters`] is the compact per-shard summary those
//! snapshots also report, so an operator can see whether traffic actually
//! spreads across the pool.
//!
//! Latency lives in fixed-size log-bucketed histograms
//! ([`crate::obs::LogHistogram`]): one end-to-end histogram plus a
//! per-stage set ([`crate::obs::StageHistograms`] — queue-wait,
//! batch-form, execute, write-back) kept both shard-wide and per model.
//! Histograms merge by bucket-wise addition, so a merged snapshot is
//! exact, order-independent, and bounded — unlike the sliding-window
//! sample concatenation this replaced, which could exceed the window
//! and over-weight recently-idle shards.

use crate::obs::{LogHistogram, StageHistograms};
use std::collections::BTreeMap;
use std::time::Duration;

/// Label used for requests served by the default (unnamed) backend model.
pub const DEFAULT_MODEL_LABEL: &str = "default";

/// Per-model serving counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ModelCounters {
    /// Requests served (live batch slots, excl. padding).
    pub requests: u64,
    /// Batches launched for this model.
    pub batches: u64,
    /// Batches that failed (execution error or panic) for this model.
    pub failed_batches: u64,
    /// Requests whose deadline expired before their batch launched.
    pub deadline_misses: u64,
    /// Batches of this model executed on a shard other than its home
    /// (counted on the executing shard).
    pub stolen_batches: u64,
}

/// Compact per-shard counter summary, reported next to the merged
/// aggregate in metrics snapshots and the `metrics` wire frame.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardCounters {
    /// Requests this shard served (live batch slots, excl. padding).
    pub requests: u64,
    /// Batches this shard launched.
    pub batches: u64,
    /// Batches that failed on this shard.
    pub failed_batches: u64,
    /// Requests this shard dropped for an expired deadline.
    pub deadline_misses: u64,
    /// Batches this shard executed on behalf of another model's home
    /// shard (it was the thief).
    pub stolen_batches: u64,
    /// Batches this shard formed that another shard executed (it was
    /// the home).
    pub donated_batches: u64,
}

/// Rolling metrics for one coordinator shard (or, after
/// [`Metrics::merge`], for the whole pool).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Label of the execution backend serving the requests ("native",
    /// "pjrt", ...); empty until the worker starts.
    pub backend: String,
    /// Requests served across all models.
    pub requests: u64,
    /// Batches launched across all models.
    pub batches: u64,
    /// Batches that failed (execution error, panic, or unresolvable
    /// model), across all models.
    pub failed_batches: u64,
    /// Requests dropped because their deadline expired before launch,
    /// across all models.
    pub deadline_misses: u64,
    /// Batches this shard executed that were formed on another shard
    /// (this shard was the thief).  Such batches also count in
    /// [`Metrics::batches`] here — execute-stage accounting follows the
    /// executing shard.
    pub stolen_batches: u64,
    /// Batches this shard formed and stamped that another shard
    /// executed (this shard was the home).  Queue-side accounting stays
    /// here; the executed batch itself counts on the thief.
    pub donated_batches: u64,
    /// Read-only hot-model executable replicas this shard materialized
    /// to execute stolen batches.
    pub replicas_installed: u64,
    /// Replicas this shard evicted after the model's traffic cooled.
    pub replicas_evicted: u64,
    /// Executed batch slots that were zero padding.
    pub padded_slots: u64,
    /// Per-model request/batch counters, keyed by model name (the default
    /// backend model records under [`DEFAULT_MODEL_LABEL`]).
    pub per_model: BTreeMap<String, ModelCounters>,
    /// Per-stage latency histograms (queue-wait / batch-form / execute /
    /// write-back) across all models.
    pub stages: StageHistograms,
    /// Per-model per-stage latency histograms.  Kept beside
    /// [`Metrics::per_model`] (instead of inside [`ModelCounters`]) so
    /// the counter summary stays `Copy`; entries appear only for models
    /// that actually served a batch (same map-growth guard as the
    /// counters).
    pub per_model_stages: BTreeMap<String, StageHistograms>,
    /// End-to-end latency histogram (µs, enqueue → delivery): fixed
    /// bucket count, so a long-running server's memory and snapshot
    /// cost stay bounded no matter the traffic volume.
    latency: LogHistogram,
    /// Total simulated accelerator energy (J).
    pub sim_energy_j: f64,
    /// Total simulated accelerator cycles.
    pub sim_cycles: u64,
}

impl Metrics {
    /// Fresh, all-zero metrics.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record which backend is serving (shown in metrics snapshots).
    pub fn record_backend(&mut self, name: &str) {
        self.backend = name.to_string();
    }

    /// Count one launched batch of `occupancy` live requests in a
    /// `bucket`-slot batch for `model`.
    pub fn record_batch(&mut self, model: &str, occupancy: usize, bucket: usize) {
        self.batches += 1;
        self.requests += occupancy as u64;
        self.padded_slots += (bucket - occupancy) as u64;
        let m = self.per_model.entry(model.to_string()).or_default();
        m.batches += 1;
        m.requests += occupancy as u64;
    }

    /// Count a failed batch.  The global counter always moves; the
    /// per-model counter only moves for models that already have an
    /// entry (i.e. served at least one batch) — a client submitting
    /// made-up model names must not grow the map without bound.
    pub fn record_failed_batch(&mut self, model: &str) {
        self.failed_batches += 1;
        if let Some(m) = self.per_model.get_mut(model) {
            m.failed_batches += 1;
        }
    }

    /// Count a request dropped for an expired deadline.  Same map-growth
    /// guard as [`Metrics::record_failed_batch`]: the per-model counter
    /// only moves for models that already have an entry.
    pub fn record_deadline_miss(&mut self, model: &str) {
        self.deadline_misses += 1;
        if let Some(m) = self.per_model.get_mut(model) {
            m.deadline_misses += 1;
        }
    }

    /// Count one stolen batch executed on this shard (the thief side of
    /// a cross-shard handoff).  Call after [`Metrics::record_batch`] —
    /// the per-model counter follows the same map-growth guard as the
    /// failure counters, and the execute just created the entry.
    pub fn record_stolen_batch(&mut self, model: &str) {
        self.stolen_batches += 1;
        if let Some(m) = self.per_model.get_mut(model) {
            m.stolen_batches += 1;
        }
    }

    /// Count one batch this shard formed that a thief executed (the
    /// home side of a cross-shard handoff).
    pub fn record_donated_batch(&mut self) {
        self.donated_batches += 1;
    }

    /// Count hot-model executable replicas installed on this shard.
    pub fn record_replicas_installed(&mut self, n: u64) {
        self.replicas_installed += n;
    }

    /// Count cooled-model executable replicas evicted from this shard.
    pub fn record_replicas_evicted(&mut self, n: u64) {
        self.replicas_evicted += n;
    }

    /// Record one request's end-to-end latency into the bounded
    /// histogram.
    pub fn record_latency(&mut self, lat: Duration) {
        self.latency.record_duration(lat);
    }

    /// Record one request's queue-wait (enqueue → batch formation) for
    /// `model`.  The shard-wide stage histogram always records; the
    /// per-model one follows the same map-growth guard as the counters
    /// (only models with a [`Metrics::per_model`] entry).
    pub fn record_queue_wait(&mut self, model: &str, wait: Duration) {
        self.stages.queue.record_duration(wait);
        if self.per_model.contains_key(model) {
            self.per_model_stages.entry(model.to_string()).or_default().queue.record_duration(wait);
        }
    }

    /// Record one launched batch's formation overhead (drain + padding +
    /// executable resolve, excluding execution) and its kernel execution
    /// time for `model`.
    pub fn record_batch_stages(&mut self, model: &str, batch_form: Duration, execute_us: u64) {
        self.stages.batch_form.record_duration(batch_form);
        self.stages.execute.record(execute_us);
        if self.per_model.contains_key(model) {
            let s = self.per_model_stages.entry(model.to_string()).or_default();
            s.batch_form.record_duration(batch_form);
            s.execute.record(execute_us);
        }
    }

    /// Record one reply's write-back time (encode + socket write on the
    /// front-end) for `model`.
    pub fn record_write_back(&mut self, model: &str, write: Duration) {
        self.stages.write_back.record_duration(write);
        if self.per_model.contains_key(model) {
            self.per_model_stages
                .entry(model.to_string())
                .or_default()
                .write_back
                .record_duration(write);
        }
    }

    /// Accumulate one batch's simulated accelerator cost.
    pub fn record_hw(&mut self, cycles: u64, energy_j: f64) {
        self.sim_cycles += cycles;
        self.sim_energy_j += energy_j;
    }

    /// Counters for one model (by name; [`DEFAULT_MODEL_LABEL`] for the
    /// default backend model).
    pub fn model(&self, name: &str) -> ModelCounters {
        self.per_model.get(name).copied().unwrap_or_default()
    }

    /// Per-stage histograms for one model (empty set when the model has
    /// recorded nothing).
    pub fn model_stages(&self, name: &str) -> StageHistograms {
        self.per_model_stages.get(name).cloned().unwrap_or_default()
    }

    /// The end-to-end latency histogram (for wire export; use
    /// [`Metrics::percentile_us`] for queries).
    pub fn latency_histogram(&self) -> &LogHistogram {
        &self.latency
    }

    /// This shard's compact counter summary.
    pub fn counters(&self) -> ShardCounters {
        ShardCounters {
            requests: self.requests,
            batches: self.batches,
            failed_batches: self.failed_batches,
            deadline_misses: self.deadline_misses,
            stolen_batches: self.stolen_batches,
            donated_batches: self.donated_batches,
        }
    }

    /// Fold another shard's snapshot into this one: counters sum,
    /// per-model maps merge, and every latency histogram merges by
    /// bucket-wise addition — associative, commutative, and bounded, so
    /// the merged value weighs each shard by exactly the samples it
    /// recorded (an idle shard contributes nothing) and never grows
    /// beyond the fixed bucket count.  The merged value is a *snapshot*
    /// for percentile queries — shards keep recording into their own
    /// histograms.
    pub fn merge(&mut self, other: &Metrics) {
        if self.backend.is_empty() {
            self.backend = other.backend.clone();
        }
        self.requests += other.requests;
        self.batches += other.batches;
        self.failed_batches += other.failed_batches;
        self.deadline_misses += other.deadline_misses;
        self.stolen_batches += other.stolen_batches;
        self.donated_batches += other.donated_batches;
        self.replicas_installed += other.replicas_installed;
        self.replicas_evicted += other.replicas_evicted;
        self.padded_slots += other.padded_slots;
        self.sim_cycles += other.sim_cycles;
        self.sim_energy_j += other.sim_energy_j;
        for (name, c) in &other.per_model {
            let m = self.per_model.entry(name.clone()).or_default();
            m.requests += c.requests;
            m.batches += c.batches;
            m.failed_batches += c.failed_batches;
            m.deadline_misses += c.deadline_misses;
            m.stolen_batches += c.stolen_batches;
        }
        for (name, s) in &other.per_model_stages {
            self.per_model_stages.entry(name.clone()).or_default().merge(s);
        }
        self.stages.merge(&other.stages);
        self.latency.merge(&other.latency);
    }

    /// End-to-end latency percentile (p in [0, 100]); `None` until data
    /// arrives.  Exact within one histogram bucket (≤ ~3.1% relative
    /// error, always conservative) and exact at `p = 100`.
    pub fn percentile_us(&self, p: f64) -> Option<u64> {
        self.latency.percentile_us(p)
    }

    /// Mean batch occupancy (live requests per launched batch).
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Fraction of executed slots that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let total = self.requests + self.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.padded_slots as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new();
        m.record_batch(DEFAULT_MODEL_LABEL, 5, 8);
        m.record_batch(DEFAULT_MODEL_LABEL, 16, 16);
        assert_eq!(m.requests, 21);
        assert_eq!(m.batches, 2);
        assert_eq!(m.padded_slots, 3);
        assert!((m.mean_occupancy() - 10.5).abs() < 1e-9);
        assert!((m.padding_fraction() - 3.0 / 24.0).abs() < 1e-9);
    }

    #[test]
    fn per_model_accounting() {
        let mut m = Metrics::new();
        m.record_batch("a", 4, 8);
        m.record_batch("b", 8, 8);
        m.record_batch("a", 2, 2);
        m.record_failed_batch("b");
        let a = ModelCounters { requests: 6, batches: 2, ..ModelCounters::default() };
        assert_eq!(m.model("a"), a);
        let b = ModelCounters { requests: 8, batches: 1, failed_batches: 1, ..a };
        assert_eq!(m.model("b"), b);
        assert_eq!(m.model("missing"), ModelCounters::default());
        // globals aggregate across models
        assert_eq!(m.requests, 14);
        assert_eq!(m.batches, 3);
        assert_eq!(m.failed_batches, 1);
    }

    #[test]
    fn unknown_model_failures_do_not_grow_the_map() {
        let mut m = Metrics::new();
        for i in 0..100 {
            m.record_failed_batch(&format!("bogus-{i}"));
        }
        assert_eq!(m.failed_batches, 100);
        assert!(m.per_model.is_empty(), "made-up names must not create entries");
    }

    #[test]
    fn deadline_misses_follow_the_same_map_growth_guard() {
        let mut m = Metrics::new();
        m.record_batch("real", 1, 1);
        m.record_deadline_miss("real");
        m.record_deadline_miss("bogus");
        assert_eq!(m.deadline_misses, 2);
        assert_eq!(m.model("real").deadline_misses, 1);
        assert_eq!(m.per_model.len(), 1, "made-up names must not create entries");
    }

    #[test]
    fn percentiles_are_exact_within_a_bucket() {
        let mut m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            m.record_latency(Duration::from_micros(us));
        }
        // the histogram reports a bucket upper edge: conservative, and
        // within 1/32 relative error of the exact order statistic
        let p0 = m.percentile_us(0.0).unwrap();
        assert!((100..=104).contains(&p0), "p0 {p0}");
        let p50 = m.percentile_us(50.0).unwrap();
        assert!((500..=620).contains(&p50), "p50 {p50}");
        // p100 is the exact observed maximum
        assert_eq!(m.percentile_us(100.0), Some(1000));
    }

    #[test]
    fn empty_percentile_none() {
        assert_eq!(Metrics::new().percentile_us(50.0), None);
    }

    #[test]
    fn latency_history_is_bounded() {
        let mut m = Metrics::new();
        for i in 0..200_000u64 {
            m.record_latency(Duration::from_micros(i));
        }
        // the histogram's footprint is fixed regardless of volume
        assert!(m.latency_histogram().to_sparse().len() <= crate::obs::BUCKET_COUNT);
        assert_eq!(m.latency_histogram().count(), 200_000);
        // and the exact maximum survives
        assert_eq!(m.percentile_us(100.0), Some(199_999));
    }

    #[test]
    fn merge_sums_counters_and_adds_histogram_buckets() {
        let mut a = Metrics::new();
        a.record_backend("native");
        a.record_batch("x", 4, 8);
        a.record_latency(Duration::from_micros(100));
        a.record_hw(1000, 1e-6);
        let mut b = Metrics::new();
        b.record_backend("native");
        b.record_batch("x", 2, 2);
        b.record_batch("y", 8, 8);
        b.record_failed_batch("y");
        b.record_latency(Duration::from_micros(300));
        b.record_latency(Duration::from_micros(500));
        b.record_hw(500, 5e-7);

        let mut merged = Metrics::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.backend, "native");
        assert_eq!(merged.requests, 14);
        assert_eq!(merged.batches, 3);
        assert_eq!(merged.failed_batches, 1);
        assert_eq!(merged.padded_slots, 4);
        let x = ModelCounters { requests: 6, batches: 2, ..ModelCounters::default() };
        assert_eq!(merged.model("x"), x);
        let y = ModelCounters { requests: 8, batches: 1, failed_batches: 1, ..x };
        assert_eq!(merged.model("y"), y);
        // histograms merged by bucket addition: all three samples
        // present, count exact, max exact
        assert_eq!(merged.latency_histogram().count(), 3);
        let p0 = merged.percentile_us(0.0).unwrap();
        assert!((100..=104).contains(&p0), "p0 {p0}");
        assert_eq!(merged.percentile_us(100.0), Some(500));
        assert_eq!(merged.sim_cycles, 1500);
        assert!((merged.sim_energy_j - 1.5e-6).abs() < 1e-12);
    }

    #[test]
    fn merged_snapshots_stay_bounded_and_weigh_shards_by_samples() {
        // the bug this replaced: concatenating shard windows grew the
        // merged sample set without bound and over-weighted idle shards
        let mut busy = Metrics::new();
        for i in 0..100_000u64 {
            busy.record_latency(Duration::from_micros(i % 1000));
        }
        let mut idle = Metrics::new();
        idle.record_latency(Duration::from_micros(5));

        let mut merged = Metrics::new();
        merged.merge(&busy);
        merged.merge(&idle);
        assert_eq!(merged.latency_histogram().count(), 100_001);
        assert!(merged.latency_histogram().to_sparse().len() <= crate::obs::BUCKET_COUNT);
        // the idle shard's single sample cannot drag the median
        assert!(merged.percentile_us(50.0).unwrap() >= 400);
    }

    #[test]
    fn stage_recording_and_per_model_guard() {
        let mut m = Metrics::new();
        m.record_batch("a", 4, 8);
        m.record_queue_wait("a", Duration::from_micros(50));
        m.record_batch_stages("a", Duration::from_micros(20), 700);
        m.record_write_back("a", Duration::from_micros(9));
        // unknown model: shard-wide stages record, the map does not grow
        m.record_queue_wait("bogus", Duration::from_micros(1));
        m.record_write_back("bogus", Duration::from_micros(1));

        assert_eq!(m.stages.queue.count(), 2);
        assert_eq!(m.stages.batch_form.count(), 1);
        assert_eq!(m.stages.execute.count(), 1);
        assert_eq!(m.stages.write_back.count(), 2);
        assert_eq!(m.stages.execute.percentile_us(100.0), Some(700));

        let a = m.model_stages("a");
        assert_eq!(a.queue.count(), 1);
        assert_eq!(a.write_back.count(), 1);
        assert!(m.model_stages("bogus").is_empty());
        assert_eq!(m.per_model_stages.len(), 1, "made-up names must not create entries");
    }

    #[test]
    fn merge_combines_stage_histograms_per_model() {
        let mut a = Metrics::new();
        a.record_batch("x", 1, 1);
        a.record_queue_wait("x", Duration::from_micros(10));
        let mut b = Metrics::new();
        b.record_batch("x", 1, 1);
        b.record_queue_wait("x", Duration::from_micros(30));
        b.record_batch("y", 1, 1);
        b.record_batch_stages("y", Duration::from_micros(5), 80);

        let mut merged = Metrics::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.stages.queue.count(), 2);
        assert_eq!(merged.model_stages("x").queue.count(), 2);
        assert_eq!(merged.model_stages("y").execute.count(), 1);
        assert_eq!(merged.model_stages("y").execute.max_us(), 80);
    }

    #[test]
    fn counters_summarize_one_shard() {
        let mut m = Metrics::new();
        m.record_batch("a", 3, 4);
        m.record_batch("a", 4, 4);
        m.record_failed_batch("a");
        m.record_deadline_miss("a");
        assert_eq!(
            m.counters(),
            ShardCounters {
                requests: 7,
                batches: 2,
                failed_batches: 1,
                deadline_misses: 1,
                stolen_batches: 0,
                donated_batches: 0,
            }
        );
    }

    #[test]
    fn steal_counters_merge_and_follow_the_map_growth_guard() {
        // the thief executed one batch of "hot" it did not form...
        let mut thief = Metrics::new();
        thief.record_batch("hot", 4, 4);
        thief.record_stolen_batch("hot");
        thief.record_stolen_batch("bogus"); // guard: no entry, no growth
        thief.record_replicas_installed(1);
        // ...and the home shard formed it without executing it
        let mut home = Metrics::new();
        home.record_donated_batch();
        home.record_replicas_evicted(2);

        assert_eq!(thief.model("hot").stolen_batches, 1);
        assert_eq!(thief.per_model.len(), 1, "made-up names must not create entries");
        assert_eq!(thief.counters().stolen_batches, 2);
        assert_eq!(home.counters().donated_batches, 1);

        let mut merged = Metrics::new();
        merged.merge(&thief);
        merged.merge(&home);
        assert_eq!(merged.stolen_batches, 2);
        assert_eq!(merged.donated_batches, 1);
        assert_eq!(merged.replicas_installed, 1);
        assert_eq!(merged.replicas_evicted, 2);
        assert_eq!(merged.model("hot").stolen_batches, 1);
    }

    #[test]
    fn hw_totals() {
        let mut m = Metrics::new();
        m.record_hw(1000, 1e-6);
        m.record_hw(500, 5e-7);
        assert_eq!(m.sim_cycles, 1500);
        assert!((m.sim_energy_j - 1.5e-6).abs() < 1e-12);
    }
}
