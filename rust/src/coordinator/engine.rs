//! Batch execution engine: backend numerics + modeled hardware cost.
//!
//! Owns an [`ExecutionBackend`] and one compiled [`Executable`] per batch
//! bucket for the default model, plus — when a
//! [`ModelRegistry`](crate::model_store::ModelRegistry) is attached — a
//! lazily built slot of per-bucket executables for **every registry model
//! requested**, keyed by the registry generation: the per-batch fast path
//! is one atomic generation load, and only an actual hot-swap forces a
//! re-resolve and recompile, so in-flight batches finish on the model
//! snapshot they started with and the next batch picks up the new one.
//!
//! `run_batch` pads the live requests to the chosen bucket, executes once,
//! splits the logits, and attaches the [`CostModel`]'s price for the batch
//! — the figures a deployment would actually trade off (the paper's
//! thesis: same numerics, less silicon and power, slightly more cycles).
//! Numerics and pricing are independent: a native-served batch can be
//! priced as PASM silicon and vice versa, and every registry model is
//! priced through the same model.
//!
//! Execution *strategy* rides on the backend, not the engine: a
//! `NativeBackend` configured with
//! [`KernelChoice`](crate::cnn::plan::KernelChoice) (the `--kernel`
//! flag) compiles per-tap or histogram (count-then-multiply) plans, and
//! both `compile` and `compile_entry` carry that choice into the plan
//! caches, so served traffic — single-model and registry alike — runs
//! whichever kernel the deployment selected with bit-identical results.

use crate::cnn::network::EncodedCnn;
use crate::coordinator::backend::{Executable, ExecutionBackend};
use crate::coordinator::cost::CostModel;
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use crate::model_store::{ModelEntry, ModelRegistry};
use crate::obs::{Stage, TraceBuf};
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub use crate::coordinator::cost::HwCost;

/// How a batch reached this engine: through the model's own home-shard
/// queue, or stolen off another shard's handoff deck.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchOrigin {
    /// The normal path — this engine's shard is the model's home.
    Home,
    /// A cross-shard steal: the batch was formed (and `batch_seq`-
    /// stamped) by the model's home shard; this engine only executes
    /// it, materializing a read-only replica executable if needed.
    Stolen,
}

/// Per-registry-model compiled state, invalidated by generation.
struct ModelSlot {
    entry: Arc<ModelEntry>,
    /// Registry generation at which `entry` was last confirmed current —
    /// when it still matches [`ModelRegistry::generation`], the slot is
    /// reused without touching the registry lock at all.
    checked_at: u64,
    exes: BTreeMap<usize, Box<dyn Executable>>,
    per_image: HwCost,
    in_dims: [usize; 3],
    classes: usize,
    /// True while this slot exists only to execute *stolen* batches of
    /// a hot model homed on another shard.  Replicas are cheap — the
    /// backend's `replicate`/`compile_entry` path shares the model Arc
    /// and the registry's per-`(iq, kernel)` plan cache — but they are
    /// still evicted once the model's traffic cools
    /// ([`Engine::evict_idle_replicas`]) so cold models don't bloat
    /// every shard's executable cache.  A home-queue batch clears the
    /// flag: the slot is then resident, exactly as before stealing.
    replica: bool,
    /// Last time a batch executed out of this slot (eviction clock).
    last_used: Instant,
}

/// The batch execution engine.
pub struct Engine {
    backend: Box<dyn ExecutionBackend>,
    exes: BTreeMap<usize, Box<dyn Executable>>,
    classes: usize,
    in_dims: [usize; 3],
    /// Per-image accelerator cost of the default model, precomputed from
    /// the cost model at construction.
    per_image: HwCost,
    cost: CostModel,
    /// Multi-model serving state (None = single-model engine).
    registry: Option<Arc<ModelRegistry>>,
    slots: HashMap<String, ModelSlot>,
    /// Reused padded-batch staging buffer: one allocation amortized over
    /// every batch instead of one per `run_batch` call.
    pad_buf: Vec<f32>,
    /// Lifecycle trace ring + owning shard id, when tracing is on: the
    /// engine stamps `launched` (executable resolved, kernel about to
    /// start) and `executed` (kernel finished) around the backend call.
    tracer: Option<(Arc<TraceBuf>, usize)>,
    /// Replica slots materialized since the last
    /// [`Engine::take_replica_installs`] call (the worker loop drains
    /// this into the shard's metrics).
    replica_installs: u64,
}

impl Engine {
    /// Compile every batch bucket of the default model on `backend`, price
    /// its conv layers with `cost`, and (optionally) attach the registry
    /// that named-model requests resolve against.
    pub fn new(
        backend: Box<dyn ExecutionBackend>,
        buckets: &[usize],
        cost: &CostModel,
        registry: Option<Arc<ModelRegistry>>,
    ) -> Result<Self> {
        anyhow::ensure!(!buckets.is_empty(), "no batch buckets configured");
        let mut exes = BTreeMap::new();
        for &b in buckets {
            let exe = backend
                .compile(b)
                .with_context(|| format!("compile batch bucket {b}"))?;
            exes.insert(b, exe);
        }
        let per_image = cost.price_image(backend.encoded());
        Ok(Engine {
            classes: backend.classes(),
            in_dims: backend.in_dims(),
            backend,
            exes,
            per_image,
            cost: *cost,
            registry,
            slots: HashMap::new(),
            pad_buf: Vec::new(),
            tracer: None,
            replica_installs: 0,
        })
    }

    /// Attach a lifecycle trace ring (the coordinator's, shared by every
    /// shard) and the shard id this engine serves: `run_batch` then
    /// records `launched`/`executed` events around every kernel call.
    pub fn set_tracer(&mut self, tracer: Arc<TraceBuf>, shard: usize) {
        self.tracer = Some((tracer, shard));
    }

    /// Compiled bucket sizes of the default model, ascending.
    pub fn buckets(&self) -> Vec<usize> {
        self.exes.keys().copied().collect()
    }

    /// The backend's short label ("native", "pjrt", ...).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The default encoded model this engine serves.
    pub fn encoded(&self) -> &EncodedCnn {
        self.backend.encoded()
    }

    /// Modeled per-image hardware cost of the default model.
    pub fn per_image_cost(&self) -> HwCost {
        self.per_image
    }

    /// Execute up to `bucket` live requests as one padded batch.  All
    /// requests must target the same model (the batcher buckets per
    /// model); named models resolve through the attached registry.
    pub fn run_batch(
        &mut self,
        requests: &[InferenceRequest],
        bucket: usize,
    ) -> Result<Vec<InferenceResponse>> {
        self.run_batch_from(requests, bucket, BatchOrigin::Home)
    }

    /// [`Engine::run_batch`] with the batch's origin spelled out.  A
    /// [`BatchOrigin::Stolen`] batch that resolves a model this engine
    /// has never compiled materializes the slot as a *replica* — same
    /// lazy `compile_entry` path, flagged for later eviction.
    pub fn run_batch_from(
        &mut self,
        requests: &[InferenceRequest],
        bucket: usize,
        origin: BatchOrigin,
    ) -> Result<Vec<InferenceResponse>> {
        let model = requests.first().and_then(|r| r.model.clone());
        anyhow::ensure!(
            requests.iter().all(|r| r.model.as_deref() == model.as_deref()),
            "mixed-model batch (batcher invariant violated)"
        );
        match model {
            None => {
                let exe = self
                    .exes
                    .get(&bucket)
                    .with_context(|| format!("bucket {bucket} not compiled"))?;
                let ctx = BatchCtx {
                    exe: exe.as_ref(),
                    in_dims: self.in_dims,
                    classes: self.classes,
                    per_image: self.per_image,
                    model: None,
                    tracer: self.tracer.as_ref(),
                };
                execute_padded(ctx, requests, bucket, &mut self.pad_buf)
            }
            Some(name) => {
                let fresh = !self.slots.contains_key(name.as_ref());
                self.refresh_slot(&name)?;
                // split borrows: slot (self.slots) + backend + pad_buf are
                // disjoint fields
                let slot = self.slots.get_mut(name.as_ref()).expect("slot just refreshed");
                slot.last_used = Instant::now();
                match origin {
                    // serving from the home queue makes the slot resident
                    BatchOrigin::Home => slot.replica = false,
                    BatchOrigin::Stolen if fresh => {
                        slot.replica = true;
                        self.replica_installs += 1;
                    }
                    BatchOrigin::Stolen => {}
                }
                if !slot.exes.contains_key(&bucket) {
                    let what = format!("compile model '{name}' at batch bucket {bucket}");
                    let exe = self.backend.compile_entry(&slot.entry, bucket).context(what)?;
                    slot.exes.insert(bucket, exe);
                }
                let ctx = BatchCtx {
                    exe: slot.exes.get(&bucket).expect("just inserted").as_ref(),
                    in_dims: slot.in_dims,
                    classes: slot.classes,
                    per_image: slot.per_image,
                    model: Some(&name),
                    tracer: self.tracer.as_ref(),
                };
                execute_padded(ctx, requests, bucket, &mut self.pad_buf)
            }
        }
    }

    /// Ensure the slot for `name` exists and reflects the current registry
    /// generation.  Fast path: one atomic load; the registry lock is only
    /// taken when the generation moved, and executables only recompile
    /// when the entry itself was hot-swapped.
    fn refresh_slot(&mut self, name: &str) -> Result<()> {
        let registry = self.registry.as_ref().context(
            "request names a model but no registry is attached \
             (use CoordinatorBuilder::registry)",
        )?;
        let generation = registry.generation();
        if let Some(slot) = self.slots.get(name) {
            if slot.checked_at == generation {
                return Ok(());
            }
        }
        // slow path: the registry changed since this slot was validated,
        // or the model was never resolved
        let Some(entry) = registry.get(name) else {
            // evict any stale slot so retired model names do not leak
            // compiled executables in a long-running coordinator
            self.slots.remove(name);
            anyhow::bail!("model '{name}' is not in the registry");
        };
        match self.slots.get_mut(name) {
            Some(slot) if slot.entry.generation == entry.generation => {
                // registry changed, but not this model
                slot.checked_at = generation;
            }
            // new model, or hot-swapped: (re)build the slot (insert
            // overwrites, dropping the stale executables)
            _ => self.insert_slot(name, entry, generation),
        }
        Ok(())
    }

    fn insert_slot(&mut self, name: &str, entry: Arc<ModelEntry>, generation: u64) {
        let arch = &entry.enc.arch;
        let slot = ModelSlot {
            per_image: self.cost.price_image(&entry.enc),
            in_dims: [1, arch.in_side, arch.in_side],
            classes: arch.classes,
            exes: BTreeMap::new(),
            checked_at: generation,
            entry,
            // a hot-swap rebuild keeps the slot's replica status; a
            // brand-new slot starts resident and run_batch_from flags it
            replica: self.slots.get(name).is_some_and(|s| s.replica),
            last_used: Instant::now(),
        };
        self.slots.insert(name.to_string(), slot);
    }

    /// Drop every replica slot that has not executed a batch for `idle`
    /// (the demotion half of hot-model elasticity: traffic cooled, the
    /// executables go).  Resident slots — models homed on this shard —
    /// are never touched.  Returns how many replicas were evicted.
    pub fn evict_idle_replicas(&mut self, idle: Duration) -> usize {
        let now = Instant::now();
        let before = self.slots.len();
        self.slots
            .retain(|_, s| !(s.replica && now.saturating_duration_since(s.last_used) >= idle));
        before - self.slots.len()
    }

    /// True while `name` is held as a replica (stolen-batch) slot.
    pub fn is_replica(&self, name: &str) -> bool {
        self.slots.get(name).is_some_and(|s| s.replica)
    }

    /// Drain the count of replica slots materialized since the last
    /// call (the worker loop folds this into the shard's metrics).
    pub fn take_replica_installs(&mut self) -> u64 {
        std::mem::take(&mut self.replica_installs)
    }
}

/// Everything `execute_padded` needs about the resolved model, bundled so
/// the field-disjoint borrows out of [`Engine`] stay obvious.
struct BatchCtx<'a> {
    exe: &'a dyn Executable,
    in_dims: [usize; 3],
    classes: usize,
    per_image: HwCost,
    model: Option<&'a Arc<str>>,
    tracer: Option<&'a (Arc<TraceBuf>, usize)>,
}

/// Pad the live requests to `bucket`, execute once, split the logits.
fn execute_padded(
    ctx: BatchCtx,
    requests: &[InferenceRequest],
    bucket: usize,
    pad_buf: &mut Vec<f32>,
) -> Result<Vec<InferenceResponse>> {
    anyhow::ensure!(
        requests.len() <= bucket,
        "batch of {} exceeds bucket {bucket}",
        requests.len()
    );

    // pad with zeros up to the bucket, staging into the reused buffer
    // (taken out and restored so a failed batch just re-allocates)
    let img_len: usize = ctx.in_dims.iter().product();
    let mut data = std::mem::take(pad_buf);
    data.clear();
    data.resize(bucket * img_len, 0.0);
    for (i, r) in requests.iter().enumerate() {
        anyhow::ensure!(
            r.image.dims() == ctx.in_dims,
            "request {} image dims {:?} != model {:?}",
            r.id,
            r.image.dims(),
            ctx.in_dims
        );
        data[i * img_len..(i + 1) * img_len].copy_from_slice(r.image.data());
    }
    let batch = Tensor::from_vec(&[bucket, ctx.in_dims[0], ctx.in_dims[1], ctx.in_dims[2]], data);

    let t0 = Instant::now();
    if let Some((t, shard)) = ctx.tracer {
        // `launched` is stamped *after* executable resolution and padding:
        // the gap to `batch_formed` is the batch-form overhead
        for r in requests {
            t.record_at(*shard, r.id, Stage::Launched, t0, bucket as u64);
        }
    }
    let result = ctx.exe.execute(&batch, requests.len());
    *pad_buf = batch.into_vec();
    let logits = result?;
    let compute_us = t0.elapsed().as_micros() as u64;
    let done = Instant::now();
    if let Some((t, shard)) = ctx.tracer {
        for r in requests {
            t.record_at(*shard, r.id, Stage::Executed, done, compute_us);
        }
    }

    let hw = ctx.per_image.scale(requests.len());

    Ok(requests
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let row = &logits.data()[i * ctx.classes..(i + 1) * ctx.classes];
            InferenceResponse {
                id: r.id,
                model: ctx.model.cloned(),
                logits: row.to_vec(),
                predicted: crate::cnn::layer::argmax(row),
                queue_us: done
                    .duration_since(r.enqueued_at)
                    .as_micros()
                    .saturating_sub(compute_us as u128) as u64,
                compute_us,
                batch_size: bucket,
                batch_occupancy: requests.len(),
                // the engine is shard-agnostic; the owning shard's worker
                // loop stamps these before the response is sent
                shard: 0,
                executed_by: 0,
                batch_seq: 0,
                hw,
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::{render_digit, Rng};
    use crate::cnn::network::DigitsCnn;
    use crate::coordinator::NativeBackend;
    use crate::quant::fixed::QFormat;

    fn registry_engine() -> (Arc<ModelRegistry>, Engine) {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(5);
        let params = arch.init(&mut rng);
        let enc = EncodedCnn::encode(arch, &params, 8, QFormat::W32);
        let registry = Arc::new(ModelRegistry::new());
        registry.insert("hot", enc.clone());
        let backend = Box::new(NativeBackend::new(enc).with_threads(1));
        let engine =
            Engine::new(backend, &[1, 4], &CostModel::default(), Some(Arc::clone(&registry)))
                .expect("engine startup");
        (registry, engine)
    }

    fn request(id: u64, model: &str) -> InferenceRequest {
        let img = render_digit(&mut Rng::new(id), (id % 10) as usize, 0.05);
        InferenceRequest::new(id, img).with_model(model)
    }

    #[test]
    fn stolen_batches_install_replicas_and_idle_replicas_evict() {
        let (_registry, mut engine) = registry_engine();
        assert_eq!(engine.take_replica_installs(), 0);

        // a stolen batch for a never-seen model materializes a replica
        let reqs = [request(1, "hot")];
        engine.run_batch_from(&reqs, 1, BatchOrigin::Stolen).expect("stolen batch");
        assert!(engine.is_replica("hot"));
        assert_eq!(engine.take_replica_installs(), 1);
        // further stolen batches reuse it: no second install
        engine.run_batch_from(&[request(2, "hot")], 1, BatchOrigin::Stolen).expect("reuse");
        assert_eq!(engine.take_replica_installs(), 0);

        // the replica survives while fresh, and goes once idle
        assert_eq!(engine.evict_idle_replicas(Duration::from_secs(3600)), 0);
        assert!(engine.is_replica("hot"));
        assert_eq!(engine.evict_idle_replicas(Duration::ZERO), 1);
        assert!(!engine.is_replica("hot"));
    }

    #[test]
    fn home_batches_promote_a_replica_to_resident() {
        let (_registry, mut engine) = registry_engine();
        engine.run_batch_from(&[request(1, "hot")], 1, BatchOrigin::Stolen).expect("stolen");
        assert!(engine.is_replica("hot"));
        // a home-queue batch makes the slot resident: eviction spares it
        engine.run_batch(&[request(2, "hot")], 1).expect("home batch");
        assert!(!engine.is_replica("hot"));
        assert_eq!(engine.evict_idle_replicas(Duration::ZERO), 0);
    }

    #[test]
    fn stolen_and_home_logits_are_bit_identical() {
        let (registry, mut engine) = registry_engine();
        let req = request(3, "hot");
        let home = engine.run_batch(std::slice::from_ref(&req), 1).expect("home");
        // a second engine that only ever sees the stolen path
        let (_r2, mut thief) = registry_engine();
        registry.get("hot").expect("entry"); // same weights via clone above
        let stolen = thief.run_batch_from(&[req], 1, BatchOrigin::Stolen).expect("stolen");
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&home[0].logits), bits(&stolen[0].logits));
    }
}
