//! Batch execution engine: backend numerics + modeled hardware cost.
//!
//! Owns an [`ExecutionBackend`] and one compiled [`Executable`] per batch
//! bucket.  `run_batch` pads the live requests to the chosen bucket,
//! executes once, splits the logits, and attaches the [`CostModel`]'s price
//! for the batch — the figures a deployment would actually trade off (the
//! paper's thesis: same numerics, less silicon and power, slightly more
//! cycles).  Numerics and pricing are independent: a native-served batch
//! can be priced as PASM silicon and vice versa.

use crate::cnn::network::EncodedCnn;
use crate::coordinator::backend::{Executable, ExecutionBackend};
use crate::coordinator::cost::CostModel;
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

pub use crate::coordinator::cost::HwCost;

/// The batch execution engine.
pub struct Engine {
    backend: Box<dyn ExecutionBackend>,
    exes: BTreeMap<usize, Box<dyn Executable>>,
    classes: usize,
    in_dims: [usize; 3],
    /// Per-image accelerator cost, precomputed from the cost model at
    /// construction.
    per_image: HwCost,
    /// Reused padded-batch staging buffer: one allocation amortized over
    /// every batch instead of one per `run_batch` call.
    pad_buf: Vec<f32>,
}

impl Engine {
    /// Compile every batch bucket on `backend` and price the encoded
    /// model's conv layers with `cost`.
    pub fn new(
        backend: Box<dyn ExecutionBackend>,
        buckets: &[usize],
        cost: &CostModel,
    ) -> Result<Self> {
        anyhow::ensure!(!buckets.is_empty(), "no batch buckets configured");
        let mut exes = BTreeMap::new();
        for &b in buckets {
            let exe = backend
                .compile(b)
                .with_context(|| format!("compile batch bucket {b}"))?;
            exes.insert(b, exe);
        }
        let per_image = cost.price_image(backend.encoded());
        Ok(Engine {
            classes: backend.classes(),
            in_dims: backend.in_dims(),
            backend,
            exes,
            per_image,
            pad_buf: Vec::new(),
        })
    }

    /// Compiled bucket sizes, ascending.
    pub fn buckets(&self) -> Vec<usize> {
        self.exes.keys().copied().collect()
    }

    /// The backend's short label ("native", "pjrt", ...).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The encoded model this engine serves.
    pub fn encoded(&self) -> &EncodedCnn {
        self.backend.encoded()
    }

    /// Modeled per-image hardware cost.
    pub fn per_image_cost(&self) -> HwCost {
        self.per_image
    }

    /// Execute up to `bucket` live requests as one padded batch.
    pub fn run_batch(
        &mut self,
        requests: &[InferenceRequest],
        bucket: usize,
    ) -> Result<Vec<InferenceResponse>> {
        let exe = self
            .exes
            .get(&bucket)
            .with_context(|| format!("bucket {bucket} not compiled"))?;
        anyhow::ensure!(
            requests.len() <= bucket,
            "batch of {} exceeds bucket {bucket}",
            requests.len()
        );

        // pad with zeros up to the bucket, staging into the reused buffer
        // (taken out and restored so a failed batch just re-allocates)
        let img_len: usize = self.in_dims.iter().product();
        let mut data = std::mem::take(&mut self.pad_buf);
        data.clear();
        data.resize(bucket * img_len, 0.0);
        for (i, r) in requests.iter().enumerate() {
            anyhow::ensure!(
                r.image.dims() == self.in_dims,
                "request {} image dims {:?} != model {:?}",
                r.id,
                r.image.dims(),
                self.in_dims
            );
            data[i * img_len..(i + 1) * img_len].copy_from_slice(r.image.data());
        }
        let batch = Tensor::from_vec(
            &[bucket, self.in_dims[0], self.in_dims[1], self.in_dims[2]],
            data,
        );

        let t0 = Instant::now();
        let result = exe.execute(&batch, requests.len());
        self.pad_buf = batch.into_vec();
        let logits = result?;
        let compute_us = t0.elapsed().as_micros() as u64;
        let done = Instant::now();

        let hw = self.per_image.scale(requests.len());

        Ok(requests
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let row = &logits.data()[i * self.classes..(i + 1) * self.classes];
                InferenceResponse {
                    id: r.id,
                    logits: row.to_vec(),
                    predicted: crate::cnn::layer::argmax(row),
                    queue_us: done
                        .duration_since(r.enqueued_at)
                        .as_micros()
                        .saturating_sub(compute_us as u128) as u64,
                    compute_us,
                    batch_size: bucket,
                    batch_occupancy: requests.len(),
                    hw,
                }
            })
            .collect())
    }
}
