//! Batch execution engine: PJRT numerics + simulated hardware cost.
//!
//! Owns one compiled [`ModelExecutable`] per exported batch bucket and the
//! dictionary-encoded model parameters.  `run_batch` pads the live
//! requests to the chosen bucket, executes once, splits the logits, and
//! prices the batch on the modeled PASM accelerator: cycles from the
//! latency model of each conv layer, energy from the 45 nm power model —
//! the figures a deployment would actually trade off (the paper's thesis:
//! same numerics, less silicon and power, slightly more cycles).

use crate::accel::conv::{ConvAccel, ConvVariantKind};
use crate::cnn::network::EncodedCnn;
use crate::coordinator::request::{InferenceRequest, InferenceResponse};
use crate::hw::Tech;
use crate::runtime::client::{ModelExecutable, ModelParams};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Simulated hardware cost of serving one batch on the PASM accelerator.
#[derive(Clone, Copy, Debug, Default)]
pub struct HwCost {
    /// Accelerator cycles for the batch (both conv layers, all images).
    pub cycles: u64,
    /// Energy at the modeled tech point (J).
    pub energy_j: f64,
    /// Wall time on the modeled accelerator (s).
    pub accel_time_s: f64,
}

/// The batch execution engine.
pub struct Engine {
    exes: BTreeMap<usize, ModelExecutable>,
    params: ModelParams,
    enc: EncodedCnn,
    classes: usize,
    in_dims: [usize; 3],
    /// Per-image accelerator cost (cycles / energy), precomputed from the
    /// hw model at construction.
    per_image_cycles: u64,
    per_image_energy_j: f64,
    tech: Tech,
}

impl Engine {
    /// Compile every exported batch bucket and price the encoded model's
    /// conv layers on the PASM accelerator model.
    pub fn new(runtime: &Runtime, enc: EncodedCnn) -> Result<Self> {
        let m = &runtime.manifest.model;
        let mut exes = BTreeMap::new();
        for &b in &m.batch_sizes {
            exes.insert(b, runtime.load_model(b).context("compile batch bucket")?);
        }
        anyhow::ensure!(!exes.is_empty(), "no batch buckets exported");

        // hardware pricing: both conv layers as PASM accelerators
        let tech = Tech::asic_1ghz();
        let bins = enc.conv1.codebook.bins();
        let ww = enc.conv1.codebook.wq.width;
        let accel1 = ConvAccel::new(ConvVariantKind::Pasm, enc.arch.conv1_shape(), bins, ww);
        let accel2 = ConvAccel::new(ConvVariantKind::Pasm, enc.arch.conv2_shape(), bins, ww);
        let cycles = accel1.latency_cycles() + accel2.latency_cycles();
        let time_s = cycles as f64 * tech.period_s();
        let power_w = accel1.power(&tech).total_w() + accel2.power(&tech).total_w();
        let energy = power_w * time_s;

        Ok(Engine {
            params: ModelParams::from_encoded(&enc),
            enc,
            classes: m.classes,
            in_dims: [m.in_c, m.in_h, m.in_w],
            exes,
            per_image_cycles: cycles,
            per_image_energy_j: energy,
            tech,
        })
    }

    /// Exported bucket sizes, ascending.
    pub fn buckets(&self) -> Vec<usize> {
        self.exes.keys().copied().collect()
    }

    /// The encoded model this engine serves.
    pub fn encoded(&self) -> &EncodedCnn {
        &self.enc
    }

    /// Execute up to `bucket` live requests as one padded batch.
    pub fn run_batch(
        &self,
        requests: &[InferenceRequest],
        bucket: usize,
    ) -> Result<Vec<InferenceResponse>> {
        let exe = self
            .exes
            .get(&bucket)
            .with_context(|| format!("bucket {bucket} not compiled"))?;
        anyhow::ensure!(
            requests.len() <= bucket,
            "batch of {} exceeds bucket {bucket}",
            requests.len()
        );

        // pad with zeros up to the bucket
        let img_len: usize = self.in_dims.iter().product();
        let mut data = vec![0f32; bucket * img_len];
        for (i, r) in requests.iter().enumerate() {
            anyhow::ensure!(
                r.image.dims() == self.in_dims,
                "request {} image dims {:?} != model {:?}",
                r.id,
                r.image.dims(),
                self.in_dims
            );
            data[i * img_len..(i + 1) * img_len].copy_from_slice(r.image.data());
        }
        let batch = Tensor::from_vec(
            &[bucket, self.in_dims[0], self.in_dims[1], self.in_dims[2]],
            data,
        );

        let t0 = Instant::now();
        let logits = exe.run(&batch, &self.params)?;
        let compute_us = t0.elapsed().as_micros() as u64;
        let done = Instant::now();

        let hw = HwCost {
            cycles: self.per_image_cycles * requests.len() as u64,
            energy_j: self.per_image_energy_j * requests.len() as f64,
            accel_time_s: self.per_image_cycles as f64
                * requests.len() as f64
                * self.tech.period_s(),
        };

        Ok(requests
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let row = &logits.data()[i * self.classes..(i + 1) * self.classes];
                InferenceResponse {
                    id: r.id,
                    logits: row.to_vec(),
                    predicted: crate::cnn::layer::argmax(row),
                    queue_us: done
                        .duration_since(r.enqueued_at)
                        .as_micros()
                        .saturating_sub(compute_us as u128) as u64,
                    compute_us,
                    batch_size: bucket,
                    batch_occupancy: requests.len(),
                    hw,
                }
            })
            .collect())
    }
}
