//! Layer-3 inference coordinator.
//!
//! The request-path owner: a worker thread holds the PJRT executables (one
//! per exported batch size) and the dictionary-encoded model; clients
//! submit single-image requests; the [`batcher`] groups them into the
//! largest exported batch bucket that the queue can fill without exceeding
//! the wait budget (vLLM-style bucketed dynamic batching, scaled to this
//! model's sizes); the [`engine`] pads, executes, splits, and attaches the
//! *simulated hardware cost* of serving that batch on the PASM accelerator
//! (cycles from the latency model, energy from the power model) — the
//! paper's metrics, reported per request.
//!
//! No async runtime is available in this offline build; the coordinator
//! uses std threads + channels (one worker, many producers), which for a
//! single-device CPU backend is also the contention-minimal design.

pub mod batcher;
pub mod engine;
pub mod loadgen;
pub mod metrics;
pub mod request;
pub mod server;

pub use batcher::BatchPolicy;
pub use engine::{Engine, HwCost};
pub use metrics::Metrics;
pub use request::{InferenceRequest, InferenceResponse};
pub use server::Coordinator;
