//! Layer-3 inference coordinator: a sharded pool of batching workers.
//!
//! The request-path owner, scaled the way the paper scales silicon — by
//! replication.  PASM makes each compute unit small enough to afford
//! many in parallel; the coordinator applies the same logic to batching:
//! [`server::CoordinatorBuilder::shards`] spawns **N independent shard
//! workers** (default: `available_parallelism`, capped, when a model
//! registry is attached; one shard otherwise), each owning its own
//! [`engine::Engine`] with compiled executables, its own per-model
//! queues, and its own shard-local [`metrics::Metrics`].  A router
//! assigns every request to a shard by a **stable hash of its model id**
//! ([`server::Coordinator::shard_for`]), so all traffic for one model
//! lands on one shard and the core invariants need zero cross-shard
//! coordination:
//!
//! * a launched batch never mixes models (per-model queues, per shard);
//! * per-model FIFO order (one FIFO queue per model, on one shard);
//! * a hot-swapped artifact goes live on the owning shard's next batch
//!   without dropping in-flight requests (each shard's engine observes
//!   the registry generation independently);
//! * shutdown drains every shard before the pool exits — nothing is
//!   lost, exactly as with the old single worker.
//!
//! Within a shard, the [`batcher`] groups queued requests into the
//! largest bucket the queue can fill without exceeding the wait budget
//! (vLLM-style bucketed dynamic batching, scaled to this model's sizes);
//! the [`engine`] pads, executes, splits, and attaches the *simulated
//! hardware cost* of serving that batch on the modeled accelerator
//! (cycles from the latency model, energy from the power model) — the
//! paper's metrics, reported per request.
//!
//! Backends and pricing are independent axes: [`backend::NativeBackend`]
//! serves the crate's own f32/fixed-point reference kernels with no
//! artifacts (and [`backend::ExecutionBackend::replicate`]s across
//! shards); `PjrtBackend` (feature `pjrt`) serves the AOT-compiled
//! PJRT/Pallas path from a single shard; either can be priced as Direct
//! / WS-MAC / PASM silicon via [`cost::CostModel`].  Assemble with
//! [`server::CoordinatorBuilder`].
//!
//! The coordinator is **multi-model**: attach a
//! [`crate::model_store::ModelRegistry`] and requests may name any
//! registered model variant ([`server::Coordinator::submit_to`]).
//! [`metrics::Metrics`] counts per model and per shard; snapshots merge
//! shard-local values on demand, so no global metrics lock sits on the
//! request path.
//!
//! No async runtime is available in this offline build; the coordinator
//! uses std threads + channels (N shard workers, many producers), which
//! for CPU backends is also the contention-minimal design — each shard's
//! channel has a single consumer and the shards share no mutable state.

pub mod backend;
pub mod batcher;
pub mod cost;
pub mod engine;
pub mod loadgen;
pub mod metrics;
pub mod request;
pub mod server;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use backend::{default_backend, Executable, ExecutionBackend, NativeBackend, NativePrecision};
pub use batcher::{BatchPolicy, LaunchReason};
pub use cost::{CostModel, HwCost};
pub use engine::Engine;
pub use metrics::{DEFAULT_MODEL_LABEL, Metrics, ModelCounters, ShardCounters};
pub use request::{InferenceRequest, InferenceResponse, Ingress};
pub use server::{Coordinator, CoordinatorBuilder, DEFAULT_MAX_SHARDS};
