//! Layer-3 inference coordinator.
//!
//! The request-path owner: a worker thread holds an [`backend::ExecutionBackend`]'s
//! compiled executables (one per batch bucket) and the dictionary-encoded
//! model; clients submit single-image requests; the [`batcher`] groups them
//! into the largest bucket that the queue can fill without exceeding the
//! wait budget (vLLM-style bucketed dynamic batching, scaled to this
//! model's sizes); the [`engine`] pads, executes, splits, and attaches the
//! *simulated hardware cost* of serving that batch on the modeled
//! accelerator (cycles from the latency model, energy from the power
//! model) — the paper's metrics, reported per request.
//!
//! Backends and pricing are independent axes: [`backend::NativeBackend`]
//! serves the crate's own f32/fixed-point reference kernels with no
//! artifacts; `PjrtBackend` (feature `pjrt`) serves the AOT-compiled
//! PJRT/Pallas path; either can be priced as Direct / WS-MAC / PASM
//! silicon via [`cost::CostModel`].  Assemble with
//! [`server::CoordinatorBuilder`].
//!
//! The coordinator is **multi-model**: attach a
//! [`crate::model_store::ModelRegistry`] and requests may name any
//! registered model variant ([`server::Coordinator::submit_to`]).  The
//! batcher keeps one queue per model (a launched batch never mixes
//! models), the [`engine`] holds per-model executables keyed by the
//! registry generation, [`metrics::Metrics`] counts per model, and a
//! hot-swapped artifact goes live on the next batch without dropping
//! in-flight requests.
//!
//! No async runtime is available in this offline build; the coordinator
//! uses std threads + channels (one worker, many producers), which for a
//! single-device CPU backend is also the contention-minimal design.

pub mod backend;
pub mod batcher;
pub mod cost;
pub mod engine;
pub mod loadgen;
pub mod metrics;
pub mod request;
pub mod server;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use backend::{default_backend, Executable, ExecutionBackend, NativeBackend, NativePrecision};
pub use batcher::BatchPolicy;
pub use cost::{CostModel, HwCost};
pub use engine::Engine;
pub use metrics::{DEFAULT_MODEL_LABEL, Metrics, ModelCounters};
pub use request::{InferenceRequest, InferenceResponse};
pub use server::{Coordinator, CoordinatorBuilder};
