//! Open-loop Poisson load generation for serving experiments.
//!
//! Closed-loop clients (fire, wait, fire) hide queueing pathologies; the
//! standard serving methodology is an *open-loop* arrival process at a
//! fixed offered rate.  [`poisson_schedule`] draws exponential
//! inter-arrival gaps from the deterministic [`Rng`], and
//! [`run_open_loop`] replays them against a coordinator, returning
//! per-request end-to-end latencies (`examples/latency_under_load.rs`
//! sweeps the offered rate against capacity); [`run_open_loop_models`]
//! cycles the same schedule across several model ids — the load shape
//! that exercises a **sharded** coordinator pool, where each model's
//! traffic lands on its own shard.
//!
//! [`run_open_loop_net`] is the same methodology over **real TCP
//! sockets**: a pool of [`crate::serving::Client`] connections replays
//! the schedule against a running serving front-end, so the measured
//! latency includes framing, the network stack, and the server's
//! admission control (`RESOURCE_EXHAUSTED` rejections are counted
//! separately from hard errors).  [`run_closed_loop_pipelined`] is the
//! single-connection closed-loop complement: it drives **one** socket
//! with a fixed window of pipelined requests, which is how the
//! serial-vs-pipelined comparison in `BENCH_serving.json` isolates the
//! protocol's round-trip amortization from connection-level
//! parallelism.  `cargo bench --bench coordinator` records all of these
//! paths in `BENCH_serving.json`.

use crate::cnn::data::Rng;
use crate::coordinator::server::Coordinator;
use crate::serving::client::{Client, ClientError, PipelinedClient, RetryPolicy};
use crate::serving::proto::ErrorCode;
use crate::tensor::Tensor;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

/// How long [`run_open_loop`] waits on each in-process completion before
/// counting the request as a deadline miss.
pub const DEFAULT_REQUEST_TIMEOUT: Duration = Duration::from_secs(60);

/// Exponential inter-arrival times for `n` requests at `rate_hz`.
pub fn poisson_schedule(rng: &mut Rng, n: usize, rate_hz: f64) -> Vec<Duration> {
    assert!(rate_hz > 0.0);
    (0..n)
        .map(|_| {
            // inverse-CDF sampling; clamp u away from 0 to bound the tail
            let u = rng.uniform().max(1e-7) as f64;
            Duration::from_secs_f64(-u.ln() / rate_hz)
        })
        .collect()
}

/// Result of one open-loop run.
#[derive(Clone, Debug)]
pub struct LoadResult {
    /// The arrival rate the schedule was drawn at (req/s).
    pub offered_hz: f64,
    /// Completed requests divided by the run's wall time (req/s).
    pub achieved_hz: f64,
    /// Per-request end-to-end latencies (µs), submission to response.
    pub latencies_us: Vec<u64>,
    /// Requests that failed outright (transport or execution errors),
    /// after any retries were exhausted.
    pub errors: usize,
    /// Requests the server's admission control rejected with a typed
    /// `RESOURCE_EXHAUSTED` frame (network runs only; always 0 for the
    /// in-process path, which has no admission layer).
    pub overloaded: usize,
    /// Requests that missed their deadline: a typed `DEADLINE_EXCEEDED`
    /// reply, or a client-side wait that outlived the per-request
    /// timeout.  Counted separately from `errors` — a missed deadline is
    /// the latency policy working, not the stack breaking.
    pub deadline_misses: usize,
    /// Retries the client layer performed across the run (network runs
    /// only).  Deterministic for a fixed schedule and retry seed.
    pub retries: u64,
}

impl LoadResult {
    /// Latency percentile (`p` in `[0, 100]`); `None` when no request
    /// completed — a run where everything failed must not report a
    /// perfect 0 µs tail.
    pub fn percentile_us(&self, p: f64) -> Option<u64> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        Some(v[rank.min(v.len() - 1)])
    }

    /// Mean latency (µs); `None` when no request completed.
    pub fn mean_us(&self) -> Option<f64> {
        if self.latencies_us.is_empty() {
            return None;
        }
        Some(self.latencies_us.iter().sum::<u64>() as f64 / self.latencies_us.len() as f64)
    }
}

/// Replay a Poisson arrival process of `n` requests at `rate_hz` against
/// the coordinator (images cycled from `pool`).  Submissions happen on
/// schedule regardless of completions (open loop); latencies are measured
/// per request on a collector thread.
pub fn run_open_loop(
    coord: &Coordinator,
    pool: &[Tensor<f32>],
    n: usize,
    rate_hz: f64,
    rng: &mut Rng,
) -> LoadResult {
    run_open_loop_models(coord, &[], pool, n, rate_hz, rng, DEFAULT_REQUEST_TIMEOUT)
}

/// [`run_open_loop`] with per-request model routing: targets cycle
/// through `models` (`None` entries go to the coordinator's default
/// model; an empty slice means all-default).  With several model ids
/// this is the load shape that exercises a sharded coordinator — each
/// model's traffic lands on its own shard, so the merged req/s scales
/// with the pool instead of serializing on one worker.
///
/// `timeout` bounds how long the drain waits on each completion; an
/// expiry (or a typed deadline-exceeded reply) is recorded as a
/// deadline miss, not an abort — the run always reports every request.
pub fn run_open_loop_models(
    coord: &Coordinator,
    models: &[Option<String>],
    pool: &[Tensor<f32>],
    n: usize,
    rate_hz: f64,
    rng: &mut Rng,
    timeout: Duration,
) -> LoadResult {
    assert!(!pool.is_empty());
    let default_models = [None];
    let models: &[Option<String>] = if models.is_empty() { &default_models } else { models };
    let gaps = poisson_schedule(rng, n, rate_hz);
    let started = Instant::now();

    // submit on schedule, keep receivers; per-request latency comes from
    // the coordinator's own timestamps (queue + compute) so that draining
    // the receivers after the run does not inflate the numbers
    let mut inflight = Vec::with_capacity(n);
    let mut next = Instant::now();
    for (i, gap) in gaps.iter().enumerate() {
        next += *gap;
        let now = Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        }
        let submitted = match &models[i % models.len()] {
            Some(name) => coord.submit_to(name, pool[i % pool.len()].clone()),
            None => coord.submit(pool[i % pool.len()].clone()),
        };
        match submitted {
            Ok(rx) => inflight.push(rx),
            Err(_) => {} // coordinator gone; counted as errors below
        }
    }

    let mut latencies = Vec::with_capacity(inflight.len());
    let mut errors = n - inflight.len();
    let mut deadline_misses = 0usize;
    for rx in inflight {
        match rx.recv_timeout(timeout) {
            Ok(Ok(resp)) => latencies.push(resp.queue_us + resp.compute_us),
            Ok(Err(msg)) if msg.contains("deadline exceeded") => deadline_misses += 1,
            Ok(Err(_)) => errors += 1,
            Err(mpsc::RecvTimeoutError::Timeout) => deadline_misses += 1,
            Err(mpsc::RecvTimeoutError::Disconnected) => errors += 1,
        }
    }
    let wall = started.elapsed().as_secs_f64();
    LoadResult {
        offered_hz: rate_hz,
        achieved_hz: latencies.len() as f64 / wall,
        latencies_us: latencies,
        errors,
        overloaded: 0,
        deadline_misses,
        retries: 0,
    }
}

/// Knobs of a network load run ([`run_open_loop_net`]).
#[derive(Clone, Copy, Debug)]
pub struct NetLoadOptions {
    /// Blocking client connections driving the shared schedule.
    pub connections: usize,
    /// Client retry policy; each connection derives its jitter stream
    /// from `retry.seed` plus its connection index, so a fixed seed
    /// replays the whole fleet's backoff schedule.
    pub retry: RetryPolicy,
    /// Relative deadline attached to every request (`None` = none);
    /// typed `DEADLINE_EXCEEDED` replies count as deadline misses.
    pub deadline_ms: Option<u64>,
    /// Client-side bound on each reply wait; an expiry is recorded as a
    /// deadline miss (never retried — the request may still land) and
    /// the connection is reset.
    pub timeout: Duration,
}

impl Default for NetLoadOptions {
    fn default() -> Self {
        NetLoadOptions {
            connections: 4,
            retry: RetryPolicy::none(),
            deadline_ms: None,
            timeout: DEFAULT_REQUEST_TIMEOUT,
        }
    }
}

/// Replay a Poisson arrival process of `n` requests at `rate_hz` against
/// a network serving front-end at `addr`, over `opts.connections`
/// blocking [`Client`]s (images cycled from `pool`, model targets cycled
/// from `models`; an empty `models` slice means every request goes to
/// the server's default model).
///
/// The schedule is shared: workers claim arrival slots from a common
/// counter and sleep until their slot's arrival time, so submissions
/// stay open-loop as long as `opts.connections` exceeds the typical
/// in-flight depth.  Latency is measured from the request's *scheduled*
/// arrival to its reply — a saturated connection pool therefore shows up
/// as latency, exactly like a saturated server, instead of silently
/// stretching the schedule.
pub fn run_open_loop_net(
    addr: &str,
    models: &[Option<String>],
    pool: &[Tensor<f32>],
    n: usize,
    rate_hz: f64,
    opts: NetLoadOptions,
    rng: &mut Rng,
) -> anyhow::Result<LoadResult> {
    anyhow::ensure!(!pool.is_empty(), "image pool is empty");
    anyhow::ensure!(opts.connections >= 1, "need at least one connection");
    let default_models = [None];
    let models: &[Option<String>] = if models.is_empty() { &default_models } else { models };

    // cumulative arrival offsets from the run's start
    let gaps = poisson_schedule(rng, n, rate_hz);
    let mut offsets = Vec::with_capacity(n);
    let mut acc = Duration::ZERO;
    for gap in gaps {
        acc += gap;
        offsets.push(acc);
    }

    // connect up front so a refused connection fails the run loudly
    // instead of skewing the measurement
    let clients: Vec<Client> = (0..opts.connections)
        .map(|i| {
            let retry = RetryPolicy { seed: opts.retry.seed.wrapping_add(i as u64), ..opts.retry };
            Client::connect(addr)
                .and_then(|c| c.with_retry(retry).with_read_timeout(opts.timeout))
                .map_err(|e| anyhow::anyhow!("connect load connection {i} to {addr}: {e}"))
        })
        .collect::<anyhow::Result<_>>()?;

    let next = AtomicUsize::new(0);
    type NetTally = (Vec<u64>, usize, usize, usize, u64);
    let results: Mutex<NetTally> = Mutex::new((Vec::with_capacity(n), 0, 0, 0, 0));
    let started = Instant::now();
    std::thread::scope(|scope| {
        let next = &next;
        let results = &results;
        let offsets = &offsets;
        let opts = &opts;
        for mut client in clients {
            scope.spawn(move || {
                let mut latencies = Vec::new();
                let mut errors = 0usize;
                let mut overloaded = 0usize;
                let mut deadline_misses = 0usize;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let due = started + offsets[i];
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    let model = models[i % models.len()].as_deref();
                    match client.infer_deadline(model, &pool[i % pool.len()], opts.deadline_ms) {
                        Ok(_) => latencies.push(due.elapsed().as_micros() as u64),
                        Err(ClientError::Server(e)) if e.code == ErrorCode::ResourceExhausted => {
                            overloaded += 1;
                        }
                        Err(ClientError::Server(e)) if e.code == ErrorCode::DeadlineExceeded => {
                            deadline_misses += 1;
                        }
                        Err(ClientError::Io(e))
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                            ) =>
                        {
                            // client-side wait expired: a miss, not an
                            // abort; reset so a late reply cannot
                            // mis-match the next request on this stream
                            deadline_misses += 1;
                            let _ = client.reset();
                        }
                        Err(_) => errors += 1,
                    }
                }
                let mut guard = results.lock().unwrap();
                guard.0.extend(latencies);
                guard.1 += errors;
                guard.2 += overloaded;
                guard.3 += deadline_misses;
                guard.4 += client.retries();
            });
        }
    });
    let wall = started.elapsed().as_secs_f64();
    let (latencies_us, errors, overloaded, deadline_misses, retries) =
        results.into_inner().unwrap();
    Ok(LoadResult {
        offered_hz: rate_hz,
        achieved_hz: latencies_us.len() as f64 / wall,
        latencies_us,
        errors,
        overloaded,
        deadline_misses,
        retries,
    })
}

/// Result of one single-connection closed-loop run.
#[derive(Clone, Debug)]
pub struct ClosedLoopResult {
    /// Requests completed (including per-request server errors).
    pub requests: usize,
    /// Requests answered with a typed per-request error frame.
    pub errors: usize,
    /// The window depth actually used (server grant may cap the ask).
    pub window: usize,
    /// Wall time of the run (seconds).
    pub wall_s: f64,
    /// Successful requests divided by wall time (req/s).
    pub req_per_s: f64,
}

/// Drive **one** connection closed-loop with a window of up to `depth`
/// pipelined requests (images cycled from `pool`, all against `model`;
/// `None` = the server's default).  `depth == 1` degenerates to the
/// classic serial closed loop — same connection, same frames — so a
/// depth sweep isolates what pipelining itself buys: with a window of
/// `w`, the per-request round trip is amortized over `w` in-flight
/// requests instead of being paid serially.
///
/// Transport failures abort the run with an error; per-request typed
/// error frames are counted and the loop continues.
pub fn run_closed_loop_pipelined(
    addr: &str,
    model: Option<&str>,
    pool: &[Tensor<f32>],
    n: usize,
    depth: usize,
) -> anyhow::Result<ClosedLoopResult> {
    anyhow::ensure!(!pool.is_empty(), "image pool is empty");
    anyhow::ensure!(n >= 1, "need at least one request");
    anyhow::ensure!(depth >= 1, "window depth must be >= 1");
    let mut client = PipelinedClient::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect pipelined client to {addr}: {e}"))?;
    let window = (depth as u64).min(client.depth()).max(1) as usize;

    let started = Instant::now();
    let mut submitted = 0usize;
    let mut received = 0usize;
    let mut errors = 0usize;
    while received < n {
        while submitted < n && client.in_flight() < window {
            client
                .submit(model, &pool[submitted % pool.len()])
                .map_err(|e| anyhow::anyhow!("submit request {submitted}: {e}"))?;
            submitted += 1;
        }
        let reply = client.recv().map_err(|e| anyhow::anyhow!("receive reply: {e}"))?;
        received += 1;
        if reply.result.is_err() {
            errors += 1;
        }
    }
    let wall_s = started.elapsed().as_secs_f64();
    Ok(ClosedLoopResult {
        requests: received,
        errors,
        window,
        wall_s,
        req_per_s: (received - errors) as f64 / wall_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_mean_matches_rate() {
        let mut rng = Rng::new(42);
        let rate = 1000.0;
        let gaps = poisson_schedule(&mut rng, 20_000, rate);
        let mean_s: f64 =
            gaps.iter().map(Duration::as_secs_f64).sum::<f64>() / gaps.len() as f64;
        let expected = 1.0 / rate;
        assert!(
            (mean_s - expected).abs() < expected * 0.05,
            "mean gap {mean_s} vs expected {expected}"
        );
    }

    #[test]
    fn schedule_is_memoryless_ish() {
        // coefficient of variation of an exponential is 1
        let mut rng = Rng::new(7);
        let gaps = poisson_schedule(&mut rng, 20_000, 500.0);
        let xs: Vec<f64> = gaps.iter().map(Duration::as_secs_f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.1, "cv {cv}");
    }

    #[test]
    fn percentiles_ordered() {
        let r = LoadResult {
            offered_hz: 1.0,
            achieved_hz: 1.0,
            latencies_us: (1..=100).collect(),
            errors: 0,
            overloaded: 0,
            deadline_misses: 0,
            retries: 0,
        };
        assert!(r.percentile_us(50.0) <= r.percentile_us(99.0));
        assert_eq!(r.percentile_us(100.0), Some(100));
        assert!((r.mean_us().unwrap() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_run_has_no_percentiles() {
        let r = LoadResult {
            offered_hz: 1.0,
            achieved_hz: 0.0,
            latencies_us: Vec::new(),
            errors: 5,
            overloaded: 0,
            deadline_misses: 0,
            retries: 0,
        };
        assert_eq!(r.percentile_us(99.0), None, "all-failed run must not report 0 µs");
        assert_eq!(r.mean_us(), None);
    }
}
