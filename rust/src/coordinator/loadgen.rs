//! Open-loop Poisson load generation for serving experiments.
//!
//! Closed-loop clients (fire, wait, fire) hide queueing pathologies; the
//! standard serving methodology is an *open-loop* arrival process at a
//! fixed offered rate.  [`poisson_schedule`] draws exponential
//! inter-arrival gaps from the deterministic [`Rng`], and
//! [`run_open_loop`] replays them against a coordinator, returning
//! per-request end-to-end latencies (`examples/latency_under_load.rs`
//! sweeps the offered rate against capacity); [`run_open_loop_models`]
//! cycles the same schedule across several model ids — the load shape
//! that exercises a **sharded** coordinator pool, where each model's
//! traffic lands on its own shard.  [`run_open_loop_zipf`] skews the
//! model mix with a Zipf law (`s ≈ 1.1`, optionally bursty via
//! [`bursty_schedule`]) — the multi-tenant shape where one hot model
//! saturates its home shard while the rest of the pool idles, which is
//! what cross-shard batch stealing exists to fix.  Every run reports a
//! per-model breakdown in [`LoadResult::per_model`].
//!
//! [`run_open_loop_net`] is the same methodology over **real TCP
//! sockets**: a pool of [`crate::serving::Client`] connections replays
//! the schedule against a running serving front-end, so the measured
//! latency includes framing, the network stack, and the server's
//! admission control (`RESOURCE_EXHAUSTED` rejections are counted
//! separately from hard errors).  [`run_closed_loop_pipelined`] is the
//! single-connection closed-loop complement: it drives **one** socket
//! with a fixed window of pipelined requests, which is how the
//! serial-vs-pipelined comparison in `BENCH_serving.json` isolates the
//! protocol's round-trip amortization from connection-level
//! parallelism.  `cargo bench --bench coordinator` records all of these
//! paths in `BENCH_serving.json`.

use crate::cnn::data::Rng;
use crate::coordinator::metrics::DEFAULT_MODEL_LABEL;
use crate::coordinator::server::Coordinator;
use crate::serving::client::{Client, ClientError, PipelinedClient, RetryPolicy};
use crate::serving::proto::ErrorCode;
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

/// How long [`run_open_loop`] waits on each in-process completion before
/// counting the request as a deadline miss.
pub const DEFAULT_REQUEST_TIMEOUT: Duration = Duration::from_secs(60);

/// Exponential inter-arrival times for `n` requests at `rate_hz`.
pub fn poisson_schedule(rng: &mut Rng, n: usize, rate_hz: f64) -> Vec<Duration> {
    assert!(rate_hz > 0.0);
    (0..n)
        .map(|_| {
            // inverse-CDF sampling; clamp u away from 0 to bound the tail
            let u = rng.uniform().max(1e-7) as f64;
            Duration::from_secs_f64(-u.ln() / rate_hz)
        })
        .collect()
}

/// Exponential inter-arrival times with square-wave bursts: the run is
/// split into eight equal blocks that alternate between `rate_hz ×
/// burst` and `rate_hz / burst`.  The point is pressure spikes — hot
/// blocks push the instantaneous arrival rate past a single shard's
/// capacity so queues actually build — not a calibrated mean; the
/// time-averaged offered rate sits between the two block rates.
pub fn bursty_schedule(rng: &mut Rng, n: usize, rate_hz: f64, burst: f64) -> Vec<Duration> {
    assert!(rate_hz > 0.0);
    assert!(burst >= 1.0, "burst factor must be >= 1");
    let block = (n / 8).max(1);
    (0..n)
        .map(|i| {
            let hot = (i / block) % 2 == 0;
            let rate = if hot { rate_hz * burst } else { rate_hz / burst };
            let u = rng.uniform().max(1e-7) as f64;
            Duration::from_secs_f64(-u.ln() / rate)
        })
        .collect()
}

/// Cumulative distribution of a Zipf(`s`) law over `k` ranks
/// (`w_i ∝ 1/(i+1)^s`, rank 0 hottest).  At `s ≈ 1.1` and hundreds of
/// ranks the head rank alone draws a double-digit share of the traffic —
/// the canonical multi-tenant serving skew.
pub fn zipf_cdf(k: usize, s: f64) -> Vec<f64> {
    assert!(k >= 1, "need at least one rank");
    let mut acc = 0.0;
    let mut cdf: Vec<f64> = (0..k)
        .map(|i| {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            acc
        })
        .collect();
    for c in &mut cdf {
        *c /= acc;
    }
    cdf
}

/// Draw one rank from a [`zipf_cdf`] by inverse-CDF lookup.
pub fn zipf_pick(rng: &mut Rng, cdf: &[f64]) -> usize {
    let u = rng.uniform() as f64;
    cdf.partition_point(|&c| c < u).min(cdf.len() - 1)
}

/// Result of one open-loop run.
#[derive(Clone, Debug)]
pub struct LoadResult {
    /// The arrival rate the schedule was drawn at (req/s).
    pub offered_hz: f64,
    /// Completed requests divided by the run's wall time (req/s).
    pub achieved_hz: f64,
    /// Per-request end-to-end latencies (µs), submission to response.
    pub latencies_us: Vec<u64>,
    /// Requests that failed outright (transport or execution errors),
    /// after any retries were exhausted.
    pub errors: usize,
    /// Requests the server's admission control rejected with a typed
    /// `RESOURCE_EXHAUSTED` frame (network runs only; always 0 for the
    /// in-process path, which has no admission layer).
    pub overloaded: usize,
    /// Requests that missed their deadline: a typed `DEADLINE_EXCEEDED`
    /// reply, or a client-side wait that outlived the per-request
    /// timeout.  Counted separately from `errors` — a missed deadline is
    /// the latency policy working, not the stack breaking.
    pub deadline_misses: usize,
    /// Retries the client layer performed across the run (network runs
    /// only).  Deterministic for a fixed schedule and retry seed.
    pub retries: u64,
    /// Per-model breakdown, keyed by model name (default-model traffic
    /// under [`DEFAULT_MODEL_LABEL`]).  Under a skewed mix the aggregate
    /// percentiles hide the hot model's tail; this is where the
    /// elasticity bench reads the hot model's ceiling from.
    pub per_model: BTreeMap<String, ModelLoad>,
}

impl LoadResult {
    /// Latency percentile (`p` in `[0, 100]`); `None` when no request
    /// completed — a run where everything failed must not report a
    /// perfect 0 µs tail.
    pub fn percentile_us(&self, p: f64) -> Option<u64> {
        percentile_of(&self.latencies_us, p)
    }

    /// Mean latency (µs); `None` when no request completed.
    pub fn mean_us(&self) -> Option<f64> {
        mean_of(&self.latencies_us)
    }
}

/// One model's slice of a [`LoadResult`].
#[derive(Clone, Debug, Default)]
pub struct ModelLoad {
    /// Requests the schedule assigned to this model.
    pub requests: usize,
    /// Completed-request latencies (µs) for this model.
    pub latencies_us: Vec<u64>,
    /// Completed requests divided by the run's wall time (req/s).
    pub achieved_hz: f64,
    /// Hard failures (submission or execution errors).
    pub errors: usize,
    /// Deadline misses (typed reply or client-side wait expiry).
    pub deadline_misses: usize,
}

impl ModelLoad {
    /// Latency percentile for this model; `None` when none of its
    /// requests completed — same no-0-as-no-data rule as the aggregate.
    pub fn percentile_us(&self, p: f64) -> Option<u64> {
        percentile_of(&self.latencies_us, p)
    }

    /// Mean latency (µs); `None` when none of its requests completed.
    pub fn mean_us(&self) -> Option<f64> {
        mean_of(&self.latencies_us)
    }
}

fn percentile_of(latencies: &[u64], p: f64) -> Option<u64> {
    if latencies.is_empty() {
        return None;
    }
    let mut v = latencies.to_vec();
    v.sort_unstable();
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    Some(v[rank.min(v.len() - 1)])
}

fn mean_of(latencies: &[u64]) -> Option<f64> {
    if latencies.is_empty() {
        return None;
    }
    Some(latencies.iter().sum::<u64>() as f64 / latencies.len() as f64)
}

/// Replay a Poisson arrival process of `n` requests at `rate_hz` against
/// the coordinator (images cycled from `pool`).  Submissions happen on
/// schedule regardless of completions (open loop); latencies are measured
/// per request on a collector thread.
pub fn run_open_loop(
    coord: &Coordinator,
    pool: &[Tensor<f32>],
    n: usize,
    rate_hz: f64,
    rng: &mut Rng,
) -> LoadResult {
    run_open_loop_models(coord, &[], pool, n, rate_hz, rng, DEFAULT_REQUEST_TIMEOUT)
}

/// [`run_open_loop`] with per-request model routing: targets cycle
/// through `models` (`None` entries go to the coordinator's default
/// model; an empty slice means all-default).  With several model ids
/// this is the load shape that exercises a sharded coordinator — each
/// model's traffic lands on its own shard, so the merged req/s scales
/// with the pool instead of serializing on one worker.
///
/// `timeout` bounds how long the drain waits on each completion; an
/// expiry (or a typed deadline-exceeded reply) is recorded as a
/// deadline miss, not an abort — the run always reports every request.
pub fn run_open_loop_models(
    coord: &Coordinator,
    models: &[Option<String>],
    pool: &[Tensor<f32>],
    n: usize,
    rate_hz: f64,
    rng: &mut Rng,
    timeout: Duration,
) -> LoadResult {
    assert!(!pool.is_empty());
    let default_models = [None];
    let models: &[Option<String>] = if models.is_empty() { &default_models } else { models };
    let gaps = poisson_schedule(rng, n, rate_hz);
    let assign: Vec<usize> = (0..n).map(|i| i % models.len()).collect();
    run_open_loop_assigned(coord, models, &assign, pool, &gaps, rate_hz, timeout)
}

/// Knobs of a Zipf-skewed open-loop run ([`run_open_loop_zipf`]).
#[derive(Clone, Copy, Debug)]
pub struct ZipfOptions {
    /// Zipf exponent; `s ≈ 1.1` is the canonical multi-tenant skew.
    pub s: f64,
    /// Square-wave burst factor fed to [`bursty_schedule`] (`None` =
    /// stationary Poisson arrivals).
    pub burst: Option<f64>,
    /// Per-completion drain bound, as in [`run_open_loop_models`].
    pub timeout: Duration,
}

impl Default for ZipfOptions {
    fn default() -> Self {
        ZipfOptions { s: 1.1, burst: None, timeout: DEFAULT_REQUEST_TIMEOUT }
    }
}

/// [`run_open_loop_models`] with Zipf-skewed model selection: request
/// targets are drawn per arrival from a Zipf(`opts.s`) law over `models`
/// (slice order is rank order, so `models[0]` is the hot model).  This
/// is the multi-tenant traffic shape of the elasticity bench — one
/// model's queue outruns its home shard while sibling shards idle — and
/// the per-model breakdown in the result is where the hot model's
/// throughput ceiling is read from.
pub fn run_open_loop_zipf(
    coord: &Coordinator,
    models: &[Option<String>],
    pool: &[Tensor<f32>],
    n: usize,
    rate_hz: f64,
    rng: &mut Rng,
    opts: ZipfOptions,
) -> LoadResult {
    assert!(!pool.is_empty());
    assert!(!models.is_empty(), "zipf run needs an explicit model list");
    let gaps = match opts.burst {
        Some(b) => bursty_schedule(rng, n, rate_hz, b),
        None => poisson_schedule(rng, n, rate_hz),
    };
    let cdf = zipf_cdf(models.len(), opts.s);
    let assign: Vec<usize> = (0..n).map(|_| zipf_pick(rng, &cdf)).collect();
    run_open_loop_assigned(coord, models, &assign, pool, &gaps, rate_hz, opts.timeout)
}

/// Shared open-loop driver: replay `gaps`, request `i` targeting
/// `models[assign[i]]`.  Submissions happen on schedule regardless of
/// completions (open loop); per-request latency comes from the
/// coordinator's own timestamps (queue + compute) so that draining the
/// receivers after the run does not inflate the numbers.
fn run_open_loop_assigned(
    coord: &Coordinator,
    models: &[Option<String>],
    assign: &[usize],
    pool: &[Tensor<f32>],
    gaps: &[Duration],
    offered_hz: f64,
    timeout: Duration,
) -> LoadResult {
    let n = gaps.len();
    let label = |mi: usize| -> String {
        models[mi].clone().unwrap_or_else(|| DEFAULT_MODEL_LABEL.to_string())
    };
    let started = Instant::now();

    let mut inflight = Vec::with_capacity(n);
    let mut per_model: BTreeMap<String, ModelLoad> = BTreeMap::new();
    let mut errors = 0usize;
    let mut next = Instant::now();
    for (i, gap) in gaps.iter().enumerate() {
        next += *gap;
        let now = Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        }
        let mi = assign[i];
        per_model.entry(label(mi)).or_default().requests += 1;
        let submitted = match &models[mi] {
            Some(name) => coord.submit_to(name, pool[i % pool.len()].clone()),
            None => coord.submit(pool[i % pool.len()].clone()),
        };
        match submitted {
            Ok(rx) => inflight.push((mi, rx)),
            Err(_) => {
                // coordinator gone; the request never entered a queue
                errors += 1;
                per_model.entry(label(mi)).or_default().errors += 1;
            }
        }
    }

    let mut latencies = Vec::with_capacity(inflight.len());
    let mut deadline_misses = 0usize;
    for (mi, rx) in inflight {
        let m = per_model.entry(label(mi)).or_default();
        match rx.recv_timeout(timeout) {
            Ok(Ok(resp)) => {
                let l = resp.queue_us + resp.compute_us;
                latencies.push(l);
                m.latencies_us.push(l);
            }
            Ok(Err(msg)) if msg.contains("deadline exceeded") => {
                deadline_misses += 1;
                m.deadline_misses += 1;
            }
            Ok(Err(_)) => {
                errors += 1;
                m.errors += 1;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                deadline_misses += 1;
                m.deadline_misses += 1;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                errors += 1;
                m.errors += 1;
            }
        }
    }
    let wall = started.elapsed().as_secs_f64();
    for m in per_model.values_mut() {
        m.achieved_hz = m.latencies_us.len() as f64 / wall;
    }
    LoadResult {
        offered_hz,
        achieved_hz: latencies.len() as f64 / wall,
        latencies_us: latencies,
        errors,
        overloaded: 0,
        deadline_misses,
        retries: 0,
        per_model,
    }
}

/// Knobs of a network load run ([`run_open_loop_net`]).
#[derive(Clone, Copy, Debug)]
pub struct NetLoadOptions {
    /// Blocking client connections driving the shared schedule.
    pub connections: usize,
    /// Client retry policy; each connection derives its jitter stream
    /// from `retry.seed` plus its connection index, so a fixed seed
    /// replays the whole fleet's backoff schedule.
    pub retry: RetryPolicy,
    /// Relative deadline attached to every request (`None` = none);
    /// typed `DEADLINE_EXCEEDED` replies count as deadline misses.
    pub deadline_ms: Option<u64>,
    /// Client-side bound on each reply wait; an expiry is recorded as a
    /// deadline miss (never retried — the request may still land) and
    /// the connection is reset.
    pub timeout: Duration,
    /// When set, model targets are drawn from a Zipf law with this
    /// exponent over `models` (slice order = rank order, `models[0]`
    /// hottest) instead of cycling round-robin.  The draw happens before
    /// the workers start, so the assignment is deterministic for a fixed
    /// schedule seed regardless of connection count.
    pub zipf_s: Option<f64>,
}

impl Default for NetLoadOptions {
    fn default() -> Self {
        NetLoadOptions {
            connections: 4,
            retry: RetryPolicy::none(),
            deadline_ms: None,
            timeout: DEFAULT_REQUEST_TIMEOUT,
            zipf_s: None,
        }
    }
}

/// Replay a Poisson arrival process of `n` requests at `rate_hz` against
/// a network serving front-end at `addr`, over `opts.connections`
/// blocking [`Client`]s (images cycled from `pool`, model targets cycled
/// from `models`; an empty `models` slice means every request goes to
/// the server's default model).
///
/// The schedule is shared: workers claim arrival slots from a common
/// counter and sleep until their slot's arrival time, so submissions
/// stay open-loop as long as `opts.connections` exceeds the typical
/// in-flight depth.  Latency is measured from the request's *scheduled*
/// arrival to its reply — a saturated connection pool therefore shows up
/// as latency, exactly like a saturated server, instead of silently
/// stretching the schedule.
pub fn run_open_loop_net(
    addr: &str,
    models: &[Option<String>],
    pool: &[Tensor<f32>],
    n: usize,
    rate_hz: f64,
    opts: NetLoadOptions,
    rng: &mut Rng,
) -> anyhow::Result<LoadResult> {
    anyhow::ensure!(!pool.is_empty(), "image pool is empty");
    anyhow::ensure!(opts.connections >= 1, "need at least one connection");
    let default_models = [None];
    let models: &[Option<String>] = if models.is_empty() { &default_models } else { models };

    // cumulative arrival offsets from the run's start
    let gaps = poisson_schedule(rng, n, rate_hz);
    let mut offsets = Vec::with_capacity(n);
    let mut acc = Duration::ZERO;
    for gap in gaps {
        acc += gap;
        offsets.push(acc);
    }

    // per-request model assignment, drawn up front so it is
    // deterministic regardless of how workers interleave
    let assign: Vec<usize> = match opts.zipf_s {
        Some(s) => {
            let cdf = zipf_cdf(models.len(), s);
            (0..n).map(|_| zipf_pick(rng, &cdf)).collect()
        }
        None => (0..n).map(|i| i % models.len()).collect(),
    };

    // connect up front so a refused connection fails the run loudly
    // instead of skewing the measurement
    let clients: Vec<Client> = (0..opts.connections)
        .map(|i| {
            let retry = RetryPolicy { seed: opts.retry.seed.wrapping_add(i as u64), ..opts.retry };
            Client::connect(addr)
                .and_then(|c| c.with_retry(retry).with_read_timeout(opts.timeout))
                .map_err(|e| anyhow::anyhow!("connect load connection {i} to {addr}: {e}"))
        })
        .collect::<anyhow::Result<_>>()?;

    let next = AtomicUsize::new(0);
    type NetTally = (Vec<u64>, usize, usize, usize, u64, BTreeMap<usize, ModelLoad>);
    let results: Mutex<NetTally> =
        Mutex::new((Vec::with_capacity(n), 0, 0, 0, 0, BTreeMap::new()));
    let started = Instant::now();
    std::thread::scope(|scope| {
        let next = &next;
        let results = &results;
        let offsets = &offsets;
        let assign = &assign;
        let opts = &opts;
        for mut client in clients {
            scope.spawn(move || {
                let mut latencies = Vec::new();
                let mut errors = 0usize;
                let mut overloaded = 0usize;
                let mut deadline_misses = 0usize;
                let mut tally: BTreeMap<usize, ModelLoad> = BTreeMap::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let due = started + offsets[i];
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    let mi = assign[i];
                    let m = tally.entry(mi).or_default();
                    m.requests += 1;
                    let model = models[mi].as_deref();
                    match client.infer_deadline(model, &pool[i % pool.len()], opts.deadline_ms) {
                        Ok(_) => {
                            let l = due.elapsed().as_micros() as u64;
                            latencies.push(l);
                            m.latencies_us.push(l);
                        }
                        Err(ClientError::Server(e)) if e.code == ErrorCode::ResourceExhausted => {
                            overloaded += 1;
                        }
                        Err(ClientError::Server(e)) if e.code == ErrorCode::DeadlineExceeded => {
                            deadline_misses += 1;
                            m.deadline_misses += 1;
                        }
                        Err(ClientError::Io(e))
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                            ) =>
                        {
                            // client-side wait expired: a miss, not an
                            // abort; reset so a late reply cannot
                            // mis-match the next request on this stream
                            deadline_misses += 1;
                            m.deadline_misses += 1;
                            let _ = client.reset();
                        }
                        Err(_) => {
                            errors += 1;
                            m.errors += 1;
                        }
                    }
                }
                let mut guard = results.lock().unwrap();
                guard.0.extend(latencies);
                guard.1 += errors;
                guard.2 += overloaded;
                guard.3 += deadline_misses;
                guard.4 += client.retries();
                for (mi, ml) in tally {
                    let merged = guard.5.entry(mi).or_default();
                    merged.requests += ml.requests;
                    merged.latencies_us.extend(ml.latencies_us);
                    merged.errors += ml.errors;
                    merged.deadline_misses += ml.deadline_misses;
                }
            });
        }
    });
    let wall = started.elapsed().as_secs_f64();
    let (latencies_us, errors, overloaded, deadline_misses, retries, tally) =
        results.into_inner().unwrap();
    let per_model = tally
        .into_iter()
        .map(|(mi, mut ml)| {
            ml.achieved_hz = ml.latencies_us.len() as f64 / wall;
            let label = models[mi].clone().unwrap_or_else(|| DEFAULT_MODEL_LABEL.to_string());
            (label, ml)
        })
        .collect();
    Ok(LoadResult {
        offered_hz: rate_hz,
        achieved_hz: latencies_us.len() as f64 / wall,
        latencies_us,
        errors,
        overloaded,
        deadline_misses,
        retries,
        per_model,
    })
}

/// Result of one single-connection closed-loop run.
#[derive(Clone, Debug)]
pub struct ClosedLoopResult {
    /// Requests completed (including per-request server errors).
    pub requests: usize,
    /// Requests answered with a typed per-request error frame.
    pub errors: usize,
    /// The window depth actually used (server grant may cap the ask).
    pub window: usize,
    /// Wall time of the run (seconds).
    pub wall_s: f64,
    /// Successful requests divided by wall time (req/s).
    pub req_per_s: f64,
}

/// Drive **one** connection closed-loop with a window of up to `depth`
/// pipelined requests (images cycled from `pool`, all against `model`;
/// `None` = the server's default).  `depth == 1` degenerates to the
/// classic serial closed loop — same connection, same frames — so a
/// depth sweep isolates what pipelining itself buys: with a window of
/// `w`, the per-request round trip is amortized over `w` in-flight
/// requests instead of being paid serially.
///
/// Transport failures abort the run with an error; per-request typed
/// error frames are counted and the loop continues.
pub fn run_closed_loop_pipelined(
    addr: &str,
    model: Option<&str>,
    pool: &[Tensor<f32>],
    n: usize,
    depth: usize,
) -> anyhow::Result<ClosedLoopResult> {
    anyhow::ensure!(!pool.is_empty(), "image pool is empty");
    anyhow::ensure!(n >= 1, "need at least one request");
    anyhow::ensure!(depth >= 1, "window depth must be >= 1");
    let mut client = PipelinedClient::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect pipelined client to {addr}: {e}"))?;
    let window = (depth as u64).min(client.depth()).max(1) as usize;

    let started = Instant::now();
    let mut submitted = 0usize;
    let mut received = 0usize;
    let mut errors = 0usize;
    while received < n {
        while submitted < n && client.in_flight() < window {
            client
                .submit(model, &pool[submitted % pool.len()])
                .map_err(|e| anyhow::anyhow!("submit request {submitted}: {e}"))?;
            submitted += 1;
        }
        let reply = client.recv().map_err(|e| anyhow::anyhow!("receive reply: {e}"))?;
        received += 1;
        if reply.result.is_err() {
            errors += 1;
        }
    }
    let wall_s = started.elapsed().as_secs_f64();
    Ok(ClosedLoopResult {
        requests: received,
        errors,
        window,
        wall_s,
        req_per_s: (received - errors) as f64 / wall_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_mean_matches_rate() {
        let mut rng = Rng::new(42);
        let rate = 1000.0;
        let gaps = poisson_schedule(&mut rng, 20_000, rate);
        let mean_s: f64 =
            gaps.iter().map(Duration::as_secs_f64).sum::<f64>() / gaps.len() as f64;
        let expected = 1.0 / rate;
        assert!(
            (mean_s - expected).abs() < expected * 0.05,
            "mean gap {mean_s} vs expected {expected}"
        );
    }

    #[test]
    fn schedule_is_memoryless_ish() {
        // coefficient of variation of an exponential is 1
        let mut rng = Rng::new(7);
        let gaps = poisson_schedule(&mut rng, 20_000, 500.0);
        let xs: Vec<f64> = gaps.iter().map(Duration::as_secs_f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.1, "cv {cv}");
    }

    #[test]
    fn percentiles_ordered() {
        let r = LoadResult {
            offered_hz: 1.0,
            achieved_hz: 1.0,
            latencies_us: (1..=100).collect(),
            errors: 0,
            overloaded: 0,
            deadline_misses: 0,
            retries: 0,
            per_model: BTreeMap::new(),
        };
        assert!(r.percentile_us(50.0) <= r.percentile_us(99.0));
        assert_eq!(r.percentile_us(100.0), Some(100));
        assert!((r.mean_us().unwrap() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_run_has_no_percentiles() {
        let r = LoadResult {
            offered_hz: 1.0,
            achieved_hz: 0.0,
            latencies_us: Vec::new(),
            errors: 5,
            overloaded: 0,
            deadline_misses: 0,
            retries: 0,
            per_model: BTreeMap::new(),
        };
        assert_eq!(r.percentile_us(99.0), None, "all-failed run must not report 0 µs");
        assert_eq!(r.mean_us(), None);
    }

    #[test]
    fn per_model_percentiles_are_none_without_completions() {
        let m = ModelLoad { requests: 3, errors: 3, ..ModelLoad::default() };
        assert_eq!(m.percentile_us(99.0), None, "all-failed model must not report 0 µs");
        assert_eq!(m.mean_us(), None);
        let done = ModelLoad { requests: 2, latencies_us: vec![10, 30], ..ModelLoad::default() };
        assert_eq!(done.percentile_us(0.0), Some(10));
        assert_eq!(done.percentile_us(100.0), Some(30));
        assert!((done.mean_us().unwrap() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn zipf_cdf_is_monotone_and_head_heavy() {
        let cdf = zipf_cdf(200, 1.1);
        assert_eq!(cdf.len(), 200);
        for w in cdf.windows(2) {
            assert!(w[0] < w[1], "cdf must be strictly increasing");
        }
        assert!((cdf[199] - 1.0).abs() < 1e-12, "cdf must end at 1");
        // rank 0 alone must carry a double-digit share at s = 1.1
        assert!(cdf[0] > 0.10, "head share {}", cdf[0]);
    }

    #[test]
    fn zipf_pick_is_skewed_and_covers_ranks() {
        let mut rng = Rng::new(11);
        let cdf = zipf_cdf(50, 1.1);
        let mut counts = [0usize; 50];
        for _ in 0..20_000 {
            counts[zipf_pick(&mut rng, &cdf)] += 1;
        }
        assert!(counts[0] > counts[1], "rank 0 must dominate rank 1");
        assert!(counts[0] > 2_000, "head rank drew {} of 20000", counts[0]);
        assert!(counts[49] > 0, "tail ranks must still receive traffic");
    }

    #[test]
    fn bursty_schedule_alternates_block_rates() {
        let mut rng = Rng::new(3);
        let gaps = bursty_schedule(&mut rng, 16_000, 1000.0, 4.0);
        assert_eq!(gaps.len(), 16_000);
        let block = 16_000 / 8;
        let mean = |b: usize| -> f64 {
            gaps[b * block..(b + 1) * block].iter().map(Duration::as_secs_f64).sum::<f64>()
                / block as f64
        };
        // hot blocks (even) run at 4000 Hz, cold blocks (odd) at 250 Hz
        assert!(mean(0) < mean(1), "hot block must have shorter gaps");
        assert!(mean(1) / mean(0) > 4.0, "burst contrast too weak");
    }
}
