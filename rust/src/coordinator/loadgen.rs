//! Open-loop Poisson load generation for serving experiments.
//!
//! Closed-loop clients (fire, wait, fire) hide queueing pathologies; the
//! standard serving methodology is an *open-loop* arrival process at a
//! fixed offered rate.  [`poisson_schedule`] draws exponential
//! inter-arrival gaps from the deterministic [`Rng`], and
//! [`run_open_loop`] replays them against a coordinator, returning
//! per-request end-to-end latencies (`examples/latency_under_load.rs`
//! sweeps the offered rate against capacity).

use crate::cnn::data::Rng;
use crate::coordinator::server::Coordinator;
use crate::tensor::Tensor;
use std::time::{Duration, Instant};

/// Exponential inter-arrival times for `n` requests at `rate_hz`.
pub fn poisson_schedule(rng: &mut Rng, n: usize, rate_hz: f64) -> Vec<Duration> {
    assert!(rate_hz > 0.0);
    (0..n)
        .map(|_| {
            // inverse-CDF sampling; clamp u away from 0 to bound the tail
            let u = rng.uniform().max(1e-7) as f64;
            Duration::from_secs_f64(-u.ln() / rate_hz)
        })
        .collect()
}

/// Result of one open-loop run.
#[derive(Clone, Debug)]
pub struct LoadResult {
    pub offered_hz: f64,
    pub achieved_hz: f64,
    /// Per-request end-to-end latencies (µs), submission to response.
    pub latencies_us: Vec<u64>,
    pub errors: usize,
}

impl LoadResult {
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[rank.min(v.len() - 1)]
    }

    pub fn mean_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<u64>() as f64 / self.latencies_us.len() as f64
    }
}

/// Replay a Poisson arrival process of `n` requests at `rate_hz` against
/// the coordinator (images cycled from `pool`).  Submissions happen on
/// schedule regardless of completions (open loop); latencies are measured
/// per request on a collector thread.
pub fn run_open_loop(
    coord: &Coordinator,
    pool: &[Tensor<f32>],
    n: usize,
    rate_hz: f64,
    rng: &mut Rng,
) -> LoadResult {
    assert!(!pool.is_empty());
    let gaps = poisson_schedule(rng, n, rate_hz);
    let started = Instant::now();

    // submit on schedule, keep receivers; per-request latency comes from
    // the coordinator's own timestamps (queue + compute) so that draining
    // the receivers after the run does not inflate the numbers
    let mut inflight = Vec::with_capacity(n);
    let mut next = Instant::now();
    for (i, gap) in gaps.iter().enumerate() {
        next += *gap;
        let now = Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        }
        match coord.submit(pool[i % pool.len()].clone()) {
            Ok(rx) => inflight.push(rx),
            Err(_) => {} // coordinator gone; counted as errors below
        }
    }

    let mut latencies = Vec::with_capacity(inflight.len());
    let mut errors = n - inflight.len();
    for rx in inflight {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(Ok(resp)) => latencies.push(resp.queue_us + resp.compute_us),
            _ => errors += 1,
        }
    }
    let wall = started.elapsed().as_secs_f64();
    LoadResult {
        offered_hz: rate_hz,
        achieved_hz: latencies.len() as f64 / wall,
        latencies_us: latencies,
        errors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_mean_matches_rate() {
        let mut rng = Rng::new(42);
        let rate = 1000.0;
        let gaps = poisson_schedule(&mut rng, 20_000, rate);
        let mean_s: f64 =
            gaps.iter().map(Duration::as_secs_f64).sum::<f64>() / gaps.len() as f64;
        let expected = 1.0 / rate;
        assert!(
            (mean_s - expected).abs() < expected * 0.05,
            "mean gap {mean_s} vs expected {expected}"
        );
    }

    #[test]
    fn schedule_is_memoryless_ish() {
        // coefficient of variation of an exponential is 1
        let mut rng = Rng::new(7);
        let gaps = poisson_schedule(&mut rng, 20_000, 500.0);
        let xs: Vec<f64> = gaps.iter().map(Duration::as_secs_f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.1, "cv {cv}");
    }

    #[test]
    fn percentiles_ordered() {
        let r = LoadResult {
            offered_hz: 1.0,
            achieved_hz: 1.0,
            latencies_us: (1..=100).collect(),
            errors: 0,
        };
        assert!(r.percentile_us(50.0) <= r.percentile_us(99.0));
        assert_eq!(r.percentile_us(100.0), 100);
        assert!((r.mean_us() - 50.5).abs() < 1e-9);
    }
}
