//! Request/response types of the inference coordinator.

use crate::coordinator::cost::HwCost;
use crate::tensor::Tensor;
use std::sync::Arc;
use std::time::Instant;

/// A single-image inference request.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    /// Coordinator-assigned request id (unique per coordinator).
    pub id: u64,
    /// `[C, H, W]` input image (the digits model uses `[1, 12, 12]`).
    pub image: Tensor<f32>,
    /// Registry model this request targets; `None` = the coordinator's
    /// built-in default backend model.  The batcher buckets per model, so
    /// one launched batch never mixes models.
    pub model: Option<Arc<str>>,
    /// When the request entered the system (queue-latency baseline).
    pub enqueued_at: Instant,
    /// Absolute deadline; `None` = wait forever.  The batcher purges
    /// expired requests *before* launch and answers them with a typed
    /// deadline-exceeded error instead of spending compute on a reply
    /// nobody is waiting for.
    pub deadline: Option<Instant>,
    /// Ingress timestamps captured by a network front-end (`None` for
    /// in-process submissions).  Carried on the request so the owning
    /// shard records the `accepted`/`decoded` lifecycle events into its
    /// own trace ring — keeping ring writes single-stage-ordered without
    /// a cross-thread handshake on the hot path.
    pub ingress: Option<Ingress>,
}

/// Front-end ingress timestamps for one request (see
/// [`crate::obs::Stage`]).
#[derive(Clone, Copy, Debug)]
pub struct Ingress {
    /// Frame header fully read off the socket.
    pub accepted: Instant,
    /// Wire frame decoded and validated.
    pub decoded: Instant,
}

impl InferenceRequest {
    /// A request for the default model, enqueued now.
    pub fn new(id: u64, image: Tensor<f32>) -> Self {
        InferenceRequest {
            id,
            image,
            model: None,
            enqueued_at: Instant::now(),
            deadline: None,
            ingress: None,
        }
    }

    /// Target a named registry model instead of the default.
    pub fn with_model(mut self, model: impl Into<Arc<str>>) -> Self {
        self.model = Some(model.into());
        self
    }

    /// Attach an absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Attach front-end ingress timestamps (trace `accepted`/`decoded`).
    pub fn with_ingress(mut self, ingress: Ingress) -> Self {
        self.ingress = Some(ingress);
        self
    }

    /// True once the deadline (if any) has passed at `now`.
    pub fn expired_at(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// The coordinator's answer for one request.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    /// The request's id.
    pub id: u64,
    /// Which model served this request (`None` = the default backend
    /// model) — echoes the request's routing for client-side assertions.
    pub model: Option<Arc<str>>,
    /// Raw logits, one per class.
    pub logits: Vec<f32>,
    /// `argmax(logits)`.
    pub predicted: usize,
    /// Time spent queued before the batch launched.
    pub queue_us: u64,
    /// Backend execute wall time for the whole batch.
    pub compute_us: u64,
    /// Batch this request rode in (bucket size, incl. padding).
    pub batch_size: usize,
    /// Live requests in the batch (excl. padding).
    pub batch_occupancy: usize,
    /// The model's **home** shard: the one its id hashes to, which
    /// owns the model's FIFO queue and formed (and stamped) this batch.
    /// Requests route to shards by a stable hash of the model id, so
    /// one model's traffic always reports the same home shard — even
    /// when the batch itself executed elsewhere (see
    /// [`InferenceResponse::executed_by`]).
    pub shard: usize,
    /// The shard whose engine actually executed the batch.  Equal to
    /// [`InferenceResponse::shard`] except for stolen batches, where an
    /// idle shard ran a formed batch on the home shard's behalf.
    pub executed_by: usize,
    /// The serving shard's batch sequence number (0, 1, 2, ... per
    /// shard).  Within one model this is non-decreasing in submission
    /// order — the observable form of the per-model FIFO guarantee,
    /// pinned by `tests/shard_routing.rs`.
    pub batch_seq: u64,
    /// Simulated hardware cost of this batch on the PASM accelerator.
    pub hw: HwCost,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_carries_image() {
        let img = Tensor::<f32>::zeros(&[1, 12, 12]);
        let r = InferenceRequest::new(7, img);
        assert_eq!(r.id, 7);
        assert_eq!(r.image.dims(), &[1, 12, 12]);
        assert!(r.model.is_none());
    }

    #[test]
    fn request_routes_to_model() {
        let img = Tensor::<f32>::zeros(&[1, 12, 12]);
        let r = InferenceRequest::new(8, img).with_model("digits-b4");
        assert_eq!(r.model.as_deref(), Some("digits-b4"));
    }

    #[test]
    fn deadline_expiry_is_checked_against_now() {
        let img = Tensor::<f32>::zeros(&[1, 12, 12]);
        let now = Instant::now();
        let r = InferenceRequest::new(9, img);
        assert!(!r.expired_at(now + std::time::Duration::from_secs(3600)), "no deadline");
        let r = r.with_deadline(now + std::time::Duration::from_millis(10));
        assert!(!r.expired_at(now));
        assert!(r.expired_at(now + std::time::Duration::from_millis(10)));
        assert!(r.expired_at(now + std::time::Duration::from_secs(1)));
    }
}
