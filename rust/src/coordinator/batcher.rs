//! Bucketed dynamic batching policy.
//!
//! The AOT flow exports the model at fixed batch sizes (1, 8, 16 by
//! default); the batcher picks, for the current queue depth, the largest
//! bucket it can fill — or, if the oldest request has waited past the
//! budget, the largest bucket not exceeding the queue (padding the rest).
//! Pure decision logic, exhaustively unit-tested; the server thread applies
//! it.

use std::time::Duration;

/// Why [`BatchPolicy::decide_reason`] chose to launch a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaunchReason {
    /// The queue held at least a full maximum bucket.
    Filled,
    /// The queue exactly filled a configured bucket.
    ExactFill,
    /// The oldest request exhausted its wait budget; launch underfull,
    /// padding up to the chosen bucket.
    Timeout,
}

/// Batching configuration.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Exported batch sizes, ascending (from the artifact manifest).
    pub buckets: Vec<usize>,
    /// Max time the oldest request may wait before we launch underfull.
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// A policy over `buckets` (sorted + deduped; must be non-empty and
    /// all ≥ 1) with the given wait budget.
    pub fn new(mut buckets: Vec<usize>, max_wait: Duration) -> Self {
        assert!(!buckets.is_empty(), "need at least one batch bucket");
        buckets.sort_unstable();
        buckets.dedup();
        assert!(buckets[0] >= 1);
        BatchPolicy { buckets, max_wait }
    }

    /// The largest configured bucket.
    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Decide what to launch given `queued` requests and whether the wait
    /// budget of the oldest request has expired.
    ///
    /// Returns `Some(bucket)` to launch a batch of that exported size
    /// (taking `min(queued, bucket)` live requests, padding the rest), or
    /// `None` to keep waiting.
    pub fn decide(&self, queued: usize, oldest_expired: bool) -> Option<usize> {
        self.decide_reason(queued, oldest_expired).map(|(bucket, _)| bucket)
    }

    /// [`BatchPolicy::decide`] plus *why* the launch fired — the
    /// observability layer reports the reason next to the chosen bucket
    /// (e.g. the bench's stage breakdown separates timeout launches,
    /// which pay padding, from filled ones).
    pub fn decide_reason(
        &self,
        queued: usize,
        oldest_expired: bool,
    ) -> Option<(usize, LaunchReason)> {
        if queued == 0 {
            return None;
        }
        // a full max bucket always launches immediately
        if queued >= self.max_bucket() {
            return Some((self.max_bucket(), LaunchReason::Filled));
        }
        if !oldest_expired {
            // can we exactly fill some bucket? launch it; otherwise wait
            // for either more requests or the timeout
            return self
                .buckets
                .iter()
                .copied()
                .find(|&b| b == queued)
                .map(|b| (b, LaunchReason::ExactFill));
        }
        // timeout: smallest bucket that fits everything queued, else max
        let bucket = self
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= queued)
            .unwrap_or_else(|| self.max_bucket());
        Some((bucket, LaunchReason::Timeout))
    }
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![1, 8, 16], Duration::from_millis(2))
    }

    #[test]
    fn empty_queue_waits() {
        assert_eq!(policy().decide(0, true), None);
        assert_eq!(policy().decide(0, false), None);
    }

    #[test]
    fn full_bucket_launches_immediately() {
        assert_eq!(policy().decide(16, false), Some(16));
        assert_eq!(policy().decide(40, false), Some(16));
        assert_eq!(policy().decide(8, false), Some(8));
        assert_eq!(policy().decide(1, false), Some(1));
    }

    #[test]
    fn partial_bucket_waits_until_timeout() {
        assert_eq!(policy().decide(5, false), None);
        assert_eq!(policy().decide(5, true), Some(8)); // pad 5 -> 8
        assert_eq!(policy().decide(9, false), None);
        assert_eq!(policy().decide(9, true), Some(16)); // pad 9 -> 16
    }

    #[test]
    fn buckets_sorted_and_deduped() {
        let p = BatchPolicy::new(vec![16, 1, 8, 8], Duration::ZERO);
        assert_eq!(p.buckets, vec![1, 8, 16]);
        assert_eq!(p.max_bucket(), 16);
    }

    #[test]
    fn single_bucket_policy() {
        let p = BatchPolicy::new(vec![4], Duration::ZERO);
        assert_eq!(p.decide(2, false), None);
        assert_eq!(p.decide(2, true), Some(4));
        assert_eq!(p.decide(4, false), Some(4));
        assert_eq!(p.decide(9, false), Some(4));
    }

    #[test]
    #[should_panic]
    fn empty_buckets_rejected() {
        BatchPolicy::new(vec![], Duration::ZERO);
    }

    #[test]
    fn launch_reasons_are_reported() {
        let p = policy();
        assert_eq!(p.decide_reason(0, true), None);
        assert_eq!(p.decide_reason(16, false), Some((16, LaunchReason::Filled)));
        assert_eq!(p.decide_reason(40, false), Some((16, LaunchReason::Filled)));
        assert_eq!(p.decide_reason(8, false), Some((8, LaunchReason::ExactFill)));
        assert_eq!(p.decide_reason(5, false), None);
        assert_eq!(p.decide_reason(5, true), Some((8, LaunchReason::Timeout)));
        // decide() stays the bucket projection of decide_reason()
        for queued in 0..40 {
            for expired in [false, true] {
                assert_eq!(
                    p.decide(queued, expired),
                    p.decide_reason(queued, expired).map(|(b, _)| b)
                );
            }
        }
    }
}
