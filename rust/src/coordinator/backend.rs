//! Execution backends: the numerics substrate behind the coordinator.
//!
//! The paper's thesis is that PASM changes the *silicon*, not the *math* —
//! so the serving path must not be welded to one execution substrate.
//! [`ExecutionBackend`] abstracts "compile this model at a batch size, then
//! execute padded batches" behind a trait, with two implementations:
//!
//! * [`NativeBackend`] — compiles an [`EncodedCnn`] once into a
//!   [`crate::cnn::plan::CompiledCnn`] (flattened indices, pre-encoded
//!   fixed-point state, plan-time overflow proof) and executes batches by
//!   borrowing rows as slices, sharded across a scoped worker pool: f32,
//!   or fixed-point raw-integer dataflows where PASM ≡ WS holds
//!   bit-exactly.  Output is bit-identical to the reference forwards
//!   ([`crate::cnn::conv`]) in every mode.  No artifacts, no external
//!   toolchain — this is the default serving and CI path.
//! * `PjrtBackend` (behind the `pjrt` cargo feature) — wraps the existing
//!   [`crate::runtime`] PJRT/Pallas path: AOT-lowered HLO artifacts
//!   compiled once per exported batch bucket (`make artifacts` first).
//!
//! Hardware *pricing* is deliberately not here — see
//! [`crate::coordinator::cost::CostModel`]; any backend's batches can be
//! priced as Direct / WS-MAC / PASM silicon interchangeably.

use crate::cnn::network::{ConvVariant, EncodedCnn};
use crate::cnn::plan::{CompiledCnn, KernelChoice, Scratch};
use crate::model_store::ModelEntry;
use crate::quant::fixed::QFormat;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::sync::{Arc, Mutex};

/// A model compiled at one fixed batch size.
pub trait Executable {
    /// The batch size this executable was compiled for.
    fn batch(&self) -> usize;

    /// Execute one padded batch: `[N, C, H, W]` images -> `[N, classes]`
    /// logits, where `N == self.batch()`.  Rows at index `>= live` are
    /// zero padding: backends may skip computing them (their logit rows
    /// are never read), but the output must still be `[N, classes]`.
    fn execute(&self, padded: &Tensor<f32>, live: usize) -> Result<Tensor<f32>>;
}

/// A numerics substrate the coordinator can serve from.
///
/// Implementations move into the coordinator's worker thread before any
/// compilation happens (hence `Send`); `compile` is only ever called from
/// that thread.
pub trait ExecutionBackend: Send {
    /// Short label for metrics and logs ("native", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// The dictionary-encoded model this backend serves.
    fn encoded(&self) -> &EncodedCnn;

    /// Input image dims `[C, H, W]`.
    fn in_dims(&self) -> [usize; 3] {
        let arch = &self.encoded().arch;
        [1, arch.in_side, arch.in_side]
    }

    /// Number of output classes.
    fn classes(&self) -> usize {
        self.encoded().arch.classes
    }

    /// Batch buckets this backend prefers (e.g. the sizes an AOT flow
    /// exported).  `None` means any bucket compiles.
    fn preferred_buckets(&self) -> Option<Vec<usize>> {
        None
    }

    /// Compile the model at one batch size.
    fn compile(&self, batch: usize) -> Result<Box<dyn Executable>>;

    /// Clone this backend for another coordinator shard
    /// ([`crate::coordinator::CoordinatorBuilder::shards`]): every shard
    /// of the pool owns an independent backend + engine, so replication
    /// must yield a functionally identical instance.  Cheap, shareable
    /// state (an `Arc`'d model, a compiled plan cache) should be shared,
    /// not recomputed.  The default returns `None` — backends welded to a
    /// single-instance resource (e.g. `PjrtBackend`'s AOT runtime handle)
    /// cannot shard, and the builder then serves from one shard (or fails
    /// startup when more were explicitly requested).
    fn replicate(&self) -> Option<Box<dyn ExecutionBackend>> {
        None
    }

    /// Compile a *registry* model at one batch size — the multi-model
    /// serving path ([`crate::model_store::ModelRegistry`]).  Backends
    /// welded to a single AOT-compiled model (e.g. `PjrtBackend`'s
    /// exported artifacts) keep this default, which rejects every registry
    /// model with a routable error instead of serving the wrong weights.
    fn compile_entry(&self, entry: &ModelEntry, batch: usize) -> Result<Box<dyn Executable>> {
        let _ = batch;
        anyhow::bail!(
            "backend '{}' serves only its built-in model and cannot compile \
             registry model '{}'",
            self.name(),
            entry.name
        )
    }
}

// ---------------------------------------------------------------------------
// NativeBackend: the crate's own reference kernels
// ---------------------------------------------------------------------------

/// Numeric mode of the [`NativeBackend`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NativePrecision {
    /// f32 reference dataflows (`EncodedCnn::forward`) — matches the float
    /// reference forward bit for bit (same code path).
    F32,
    /// Raw-integer fixed-point dataflows (`EncodedCnn::forward_fx`) with
    /// images in the given format — the paper's bit-exact PASM ≡ WS regime.
    Fixed(QFormat),
}

/// In-process backend over the crate's own kernels: serves an
/// [`EncodedCnn`] with no artifacts or external runtime.  Any batch size
/// compiles (the kernels are batch-agnostic; rows execute independently).
///
/// By default the backend compiles the model **once** into a
/// [`CompiledCnn`] plan (flattened indices, pre-encoded fixed-point state,
/// plan-time overflow proof, per-worker scratch arenas) and executes
/// batches by borrowing rows as slices, sharded across a scoped worker
/// pool sized by `available_parallelism` (override with
/// [`NativeBackend::with_threads`]).  Results are bit-identical to the
/// reference forwards in every mode and at every thread count — rows are
/// independent and the plan is exactness-pinned by property tests.
pub struct NativeBackend {
    enc: Arc<EncodedCnn>,
    variant: ConvVariant,
    precision: NativePrecision,
    /// Kernel strategy the compiled plans use for the PASM dataflow.
    kernel: KernelChoice,
    /// Worker threads per batch; `None` = `available_parallelism`.
    threads: Option<usize>,
    /// Serve through the compiled plan (default).  `false` selects the
    /// pre-plan per-request reference path — baseline benchmarking only.
    use_plan: bool,
    /// Plan cache: compiled on the first `compile` call, shared by every
    /// batch-bucket executable (the plan is batch-size-agnostic) — and,
    /// through [`ExecutionBackend::replicate`], by every shard replica:
    /// whichever shard compiles first populates it for the whole pool.
    plan: Arc<Mutex<Option<Arc<CompiledCnn>>>>,
}

impl NativeBackend {
    /// PASM dataflow at f32 precision (matching the reference forward),
    /// with the default [`KernelChoice::Auto`] kernel strategy.
    pub fn new(enc: EncodedCnn) -> Self {
        NativeBackend {
            enc: Arc::new(enc),
            variant: ConvVariant::Pasm,
            precision: NativePrecision::F32,
            kernel: KernelChoice::Auto,
            threads: None,
            use_plan: true,
            plan: Arc::new(Mutex::new(None)),
        }
    }

    /// Select the conv dataflow (PASM or weight-shared MAC).
    pub fn with_variant(mut self, variant: ConvVariant) -> Self {
        self.variant = variant;
        self
    }

    /// Select the numeric mode.
    pub fn with_precision(mut self, precision: NativePrecision) -> Self {
        self.precision = precision;
        // the plan bakes in the fixed-point image format; recompile lazily
        // (a fresh cache — replicas made before this call keep the old one)
        self.plan = Arc::new(Mutex::new(None));
        self
    }

    /// Select the conv kernel strategy (`--kernel per-tap|histogram|auto`):
    /// per-tap mirrors the reference accumulation order, histogram is the
    /// paper's count-then-multiply restructure, and `Auto` (the default)
    /// resolves per layer by the taps-per-bin heuristic.  Results are
    /// bit-identical under every choice; only throughput differs.
    pub fn with_kernel(mut self, kernel: KernelChoice) -> Self {
        self.kernel = kernel;
        // the plan bakes in the kernel layout; recompile lazily
        self.plan = Arc::new(Mutex::new(None));
        self
    }

    /// Fix the per-batch worker pool size (default: `available_parallelism`;
    /// `1` executes batches serially on the coordinator worker).  Only the
    /// compiled-plan path shards rows; with [`NativeBackend::with_plan`]
    /// `(false)` the reference path always runs serially and this setting
    /// has no effect.
    pub fn with_threads(mut self, threads: usize) -> Self {
        assert!(threads >= 1, "thread pool needs at least one worker");
        self.threads = Some(threads);
        self
    }

    /// Disable the compiled plan and serve through the pre-plan
    /// per-request reference path ([`EncodedCnn::forward`] /
    /// [`EncodedCnn::forward_fx`], re-encoding weight state every request).
    /// Only useful as a benchmarking baseline and as an execution
    /// cross-check; production serving should never turn this off.
    pub fn with_plan(mut self, use_plan: bool) -> Self {
        self.use_plan = use_plan;
        self
    }

    /// Image format plans are compiled for under the current precision.
    fn plan_iq(&self) -> QFormat {
        match self.precision {
            NativePrecision::Fixed(iq) => iq,
            NativePrecision::F32 => QFormat::IMAGE32,
        }
    }

    /// One executable over `enc` with `plan` — the single construction
    /// path shared by [`ExecutionBackend::compile`] (default model) and
    /// [`ExecutionBackend::compile_entry`] (registry models), so
    /// precision mapping and thread sizing can never drift between them.
    fn make_executable(
        &self,
        enc: Arc<EncodedCnn>,
        plan: Option<Arc<CompiledCnn>>,
        batch: usize,
    ) -> NativeExecutable {
        let threads = self.threads.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
        let arch = &enc.arch;
        NativeExecutable {
            variant: self.variant,
            precision: self.precision,
            plan,
            threads,
            batch,
            in_dims: [1, arch.in_side, arch.in_side],
            classes: arch.classes,
            enc,
        }
    }
}

impl ExecutionBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn encoded(&self) -> &EncodedCnn {
        &self.enc
    }

    fn compile(&self, batch: usize) -> Result<Box<dyn Executable>> {
        anyhow::ensure!(batch >= 1, "batch must be >= 1");
        let plan = if self.use_plan {
            let mut cached = self.plan.lock().unwrap();
            if cached.is_none() {
                let compiled = CompiledCnn::compile_with(&self.enc, self.plan_iq(), self.kernel)
                    .context("compile layer plans")?;
                *cached = Some(Arc::new(compiled));
            }
            cached.clone()
        } else {
            None
        };
        Ok(Box::new(self.make_executable(Arc::clone(&self.enc), plan, batch)))
    }

    fn compile_entry(&self, entry: &ModelEntry, batch: usize) -> Result<Box<dyn Executable>> {
        anyhow::ensure!(batch >= 1, "batch must be >= 1");
        // The entry caches one compiled plan per (image format, kernel
        // strategy), so every bucket (and every engine) of this model
        // shares plan state — mirroring the single-model plan cache above.
        let plan = if self.use_plan {
            Some(entry.plan_with(self.plan_iq(), self.kernel)?)
        } else {
            None
        };
        Ok(Box::new(self.make_executable(Arc::clone(&entry.enc), plan, batch)))
    }

    fn replicate(&self) -> Option<Box<dyn ExecutionBackend>> {
        // share the model Arc and the plan *cache* itself, so a pool of N
        // shards compiles the default model once, not N times — whichever
        // shard compiles first fills the cache for all (replication
        // happens before any shard has compiled)
        Some(Box::new(NativeBackend {
            enc: Arc::clone(&self.enc),
            variant: self.variant,
            precision: self.precision,
            kernel: self.kernel,
            threads: self.threads,
            use_plan: self.use_plan,
            plan: Arc::clone(&self.plan),
        }))
    }
}

struct NativeExecutable {
    enc: Arc<EncodedCnn>,
    variant: ConvVariant,
    precision: NativePrecision,
    /// `Some` = the compiled-plan fast path; `None` = reference path.
    plan: Option<Arc<CompiledCnn>>,
    threads: usize,
    batch: usize,
    in_dims: [usize; 3],
    classes: usize,
}

impl Executable for NativeExecutable {
    fn batch(&self) -> usize {
        self.batch
    }

    fn execute(&self, padded: &Tensor<f32>, live: usize) -> Result<Tensor<f32>> {
        let want = [self.batch, self.in_dims[0], self.in_dims[1], self.in_dims[2]];
        anyhow::ensure!(
            padded.dims() == want,
            "batch images dims {:?} != {:?}",
            padded.dims(),
            want
        );
        anyhow::ensure!(live <= self.batch, "live {live} exceeds batch {}", self.batch);
        let img_len: usize = self.in_dims.iter().product();
        let mut logits = vec![0f32; self.batch * self.classes];
        // the kernels are batch-agnostic, so padding rows cost nothing here
        // (unlike a fixed-shape compiled batch): compute live rows only
        if live > 0 {
            match &self.plan {
                Some(plan) => {
                    let rows = &padded.data()[..live * img_len];
                    let out = &mut logits[..live * self.classes];
                    self.run_planned(plan, rows, img_len, out);
                }
                None => self.run_reference(padded, live, img_len, &mut logits)?,
            }
        }
        Ok(Tensor::from_vec(&[self.batch, self.classes], logits))
    }
}

impl NativeExecutable {
    /// Planned path: borrow each live row as a slice (no per-row clone or
    /// `Tensor` rebuild) and shard contiguous row ranges across a scoped
    /// worker pool.  Each worker owns one scratch arena; rows write
    /// disjoint logit chunks, so any thread count produces bit-identical
    /// output to the serial order.
    fn run_planned(&self, plan: &CompiledCnn, rows: &[f32], img_len: usize, out: &mut [f32]) {
        let classes = self.classes;
        let live = rows.len() / img_len;
        // threads >= 1 (enforced at construction) and live >= 1 (execute
        // skips empty batches), so workers >= 1
        let workers = self.threads.min(live);
        if workers == 1 {
            let mut scratch = plan.scratch();
            for (row, out_row) in rows.chunks_exact(img_len).zip(out.chunks_exact_mut(classes)) {
                self.run_row(plan, row, &mut scratch, out_row);
            }
            return;
        }
        let rows_per = live.div_ceil(workers);
        std::thread::scope(|scope| {
            let row_chunks = rows.chunks(rows_per * img_len);
            let out_chunks = out.chunks_mut(rows_per * classes);
            for (rchunk, ochunk) in row_chunks.zip(out_chunks) {
                scope.spawn(move || {
                    let mut scratch = plan.scratch();
                    let row_iter = rchunk.chunks_exact(img_len);
                    let out_iter = ochunk.chunks_exact_mut(classes);
                    for (row, out_row) in row_iter.zip(out_iter) {
                        self.run_row(plan, row, &mut scratch, out_row);
                    }
                });
            }
        });
    }

    fn run_row(&self, plan: &CompiledCnn, image: &[f32], scratch: &mut Scratch, out: &mut [f32]) {
        match self.precision {
            NativePrecision::F32 => plan.forward_f32_into(image, self.variant, scratch, out),
            NativePrecision::Fixed(_) => plan.forward_fx_into(image, self.variant, scratch, out),
        }
    }

    /// Pre-plan reference path: rebuild a `Tensor` and re-encode weight
    /// state per request through the golden-oracle forwards.  Kept only as
    /// the benchmarking baseline and execution cross-check
    /// ([`NativeBackend::with_plan`]).
    fn run_reference(
        &self,
        padded: &Tensor<f32>,
        live: usize,
        img_len: usize,
        logits: &mut [f32],
    ) -> Result<()> {
        for i in 0..live {
            let row = &padded.data()[i * img_len..(i + 1) * img_len];
            let image = Tensor::from_vec(&self.in_dims, row.to_vec());
            let out = match self.precision {
                NativePrecision::F32 => self.enc.forward(&image, self.variant),
                NativePrecision::Fixed(iq) => self.enc.forward_fx(&image, self.variant, iq),
            };
            anyhow::ensure!(out.len() == self.classes, "logit length mismatch");
            logits[i * self.classes..(i + 1) * self.classes].copy_from_slice(&out);
        }
        Ok(())
    }
}

/// The build's default backend for `enc`: `PjrtBackend` over
/// `artifacts_dir` when the `pjrt` feature is enabled, else the in-process
/// [`NativeBackend`] (which ignores `artifacts_dir`).  Examples and
/// benches route through here so the policy lives in one place.
pub fn default_backend(artifacts_dir: &str, enc: EncodedCnn) -> Box<dyn ExecutionBackend> {
    #[cfg(feature = "pjrt")]
    {
        Box::new(PjrtBackend::new(artifacts_dir, enc))
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = artifacts_dir;
        Box::new(NativeBackend::new(enc))
    }
}

// ---------------------------------------------------------------------------
// PjrtBackend: the AOT-compiled PJRT/Pallas path (feature `pjrt`)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::{Executable, ExecutionBackend};
    use crate::cnn::network::EncodedCnn;
    use crate::runtime::client::{ModelExecutable, ModelParams};
    use crate::runtime::{ArtifactManifest, Runtime};
    use crate::tensor::Tensor;
    use anyhow::{Context, Result};
    use std::sync::Mutex;

    /// Backend over the PJRT CPU client and the AOT-lowered artifacts.
    ///
    /// Construction is cheap and infallible; the PJRT client is created on
    /// the first `compile` call — i.e. on the coordinator's worker thread
    /// (PJRT handles are not Send-safe to move across threads after use).
    pub struct PjrtBackend {
        dir: String,
        enc: EncodedCnn,
        params: ModelParams,
        runtime: Mutex<Option<Runtime>>,
    }

    impl PjrtBackend {
        /// `artifacts_dir` must contain `manifest.json` (`make artifacts`).
        pub fn new(artifacts_dir: impl Into<String>, enc: EncodedCnn) -> Self {
            let params = ModelParams::from_encoded(&enc);
            PjrtBackend {
                dir: artifacts_dir.into(),
                enc,
                params,
                runtime: Mutex::new(None),
            }
        }
    }

    impl ExecutionBackend for PjrtBackend {
        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn encoded(&self) -> &EncodedCnn {
            &self.enc
        }

        fn preferred_buckets(&self) -> Option<Vec<usize>> {
            ArtifactManifest::load(&self.dir)
                .ok()
                .map(|m| m.model.batch_sizes)
        }

        fn compile(&self, batch: usize) -> Result<Box<dyn Executable>> {
            let mut guard = self.runtime.lock().unwrap();
            if guard.is_none() {
                *guard = Some(Runtime::new(&self.dir).context("create PJRT runtime")?);
            }
            let rt = guard.as_ref().unwrap();
            let exe = rt
                .load_model(batch)
                .with_context(|| format!("compile batch bucket {batch}"))?;
            Ok(Box::new(PjrtExecutable { exe, params: self.params.clone(), batch }))
        }
    }

    struct PjrtExecutable {
        exe: ModelExecutable,
        params: ModelParams,
        batch: usize,
    }

    impl Executable for PjrtExecutable {
        fn batch(&self) -> usize {
            self.batch
        }

        fn execute(&self, padded: &Tensor<f32>, _live: usize) -> Result<Tensor<f32>> {
            // the compiled batch shape is fixed; padding rows execute anyway
            self.exe.run(padded, &self.params)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::{render_digit, Rng};
    use crate::cnn::network::DigitsCnn;

    fn enc() -> EncodedCnn {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(5);
        let params = arch.init(&mut rng);
        EncodedCnn::encode(arch, &params, 8, QFormat::W16)
    }

    #[test]
    fn native_compiles_any_bucket() {
        let b = NativeBackend::new(enc());
        for n in [1usize, 3, 8, 17] {
            let exe = b.compile(n).unwrap();
            assert_eq!(exe.batch(), n);
        }
        assert!(b.compile(0).is_err());
        assert_eq!(b.name(), "native");
        assert_eq!(b.in_dims(), [1, 12, 12]);
        assert_eq!(b.classes(), 10);
        assert!(b.preferred_buckets().is_none());
    }

    #[test]
    fn native_execute_matches_reference_forward() {
        let e = enc();
        let backend = NativeBackend::new(e.clone());
        let exe = backend.compile(3).unwrap();
        let mut rng = Rng::new(9);
        let imgs: Vec<Tensor<f32>> =
            (0..3).map(|d| render_digit(&mut rng, d, 0.05)).collect();
        let mut data = Vec::new();
        for img in &imgs {
            data.extend_from_slice(img.data());
        }
        let batch = Tensor::from_vec(&[3, 1, 12, 12], data);
        let logits = exe.execute(&batch, 3).unwrap();
        assert_eq!(logits.dims(), &[3, 10]);
        for (i, img) in imgs.iter().enumerate() {
            let want = e.forward(img, ConvVariant::Pasm);
            let got = &logits.data()[i * 10..(i + 1) * 10];
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "row {i}"
            );
        }
    }

    #[test]
    fn native_fixed_matches_fx_reference_bitexactly() {
        let e = enc();
        let backend = NativeBackend::new(e.clone())
            .with_precision(NativePrecision::Fixed(QFormat::IMAGE32));
        let exe = backend.compile(1).unwrap();
        let mut rng = Rng::new(13);
        let img = render_digit(&mut rng, 7, 0.05);
        let batch = Tensor::from_vec(&[1, 1, 12, 12], img.data().to_vec());
        let logits = exe.execute(&batch, 1).unwrap();
        let want = e.forward_fx(&img, ConvVariant::Pasm, QFormat::IMAGE32);
        assert_eq!(
            logits.data().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn kernel_override_serves_bitexact_logits() {
        // every kernel strategy must serve identical bits through the
        // backend, in both precisions — the strategy may only change
        // throughput, never an answer.  Also pins that compile_entry
        // threads the choice into the registry's per-(iq, kernel) cache.
        use crate::model_store::ModelRegistry;
        let e = enc();
        let reg = ModelRegistry::new();
        reg.insert("m", e.clone());
        let entry = reg.get("m").unwrap();
        let mut rng = Rng::new(29);
        let img = render_digit(&mut rng, 4, 0.05);
        let batch = Tensor::from_vec(&[1, 1, 12, 12], img.data().to_vec());
        for (precision, want) in [
            (NativePrecision::F32, e.forward(&img, ConvVariant::Pasm)),
            (
                NativePrecision::Fixed(QFormat::IMAGE32),
                e.forward_fx(&img, ConvVariant::Pasm, QFormat::IMAGE32),
            ),
        ] {
            let want: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            for kernel in [KernelChoice::PerTap, KernelChoice::Histogram, KernelChoice::Auto] {
                let backend =
                    NativeBackend::new(e.clone()).with_precision(precision).with_kernel(kernel);
                let logits = backend.compile(1).unwrap().execute(&batch, 1).unwrap();
                let got: Vec<u32> = logits.data().iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "{precision:?} {kernel:?} default-model path");
                let logits =
                    backend.compile_entry(&entry, 1).unwrap().execute(&batch, 1).unwrap();
                let got: Vec<u32> = logits.data().iter().map(|x| x.to_bits()).collect();
                assert_eq!(got, want, "{precision:?} {kernel:?} registry path");
            }
        }
    }

    #[test]
    fn native_skips_padding_rows() {
        let e = enc();
        let exe = NativeBackend::new(e.clone()).compile(4).unwrap();
        let mut rng = Rng::new(17);
        let img = render_digit(&mut rng, 2, 0.05);
        let img_len = 12 * 12;
        let mut data = vec![0f32; 4 * img_len];
        data[..img_len].copy_from_slice(img.data());
        let batch = Tensor::from_vec(&[4, 1, 12, 12], data);
        let logits = exe.execute(&batch, 1).unwrap();
        let want = e.forward(&img, ConvVariant::Pasm);
        assert_eq!(&logits.data()[..10], &want[..]);
        // padding rows are never computed; their logit rows stay zero
        assert!(logits.data()[10..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn native_rejects_wrong_dims() {
        let exe = NativeBackend::new(enc()).compile(2).unwrap();
        let bad = Tensor::<f32>::zeros(&[2, 3, 3, 3]);
        assert!(exe.execute(&bad, 2).is_err());
    }

    fn batch_of(n: usize, live: usize, seed: u64) -> Tensor<f32> {
        let mut rng = Rng::new(seed);
        let img_len = 12 * 12;
        let mut data = vec![0f32; n * img_len];
        for i in 0..live {
            let img = render_digit(&mut rng, i % 10, 0.05);
            data[i * img_len..(i + 1) * img_len].copy_from_slice(img.data());
        }
        Tensor::from_vec(&[n, 1, 12, 12], data)
    }

    fn logits_bits(t: &Tensor<f32>) -> Vec<u32> {
        t.data().iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn parallel_execution_bitexact_serial() {
        // sharding rows across workers must not change a single bit, in
        // either numeric mode, including uneven chunking (5 live rows
        // over 3 workers) and threads > live
        let e = enc();
        let batch = batch_of(8, 5, 41);
        for precision in [NativePrecision::F32, NativePrecision::Fixed(QFormat::IMAGE32)] {
            let serial = NativeBackend::new(e.clone())
                .with_precision(precision)
                .with_threads(1)
                .compile(8)
                .unwrap()
                .execute(&batch, 5)
                .unwrap();
            for threads in [2usize, 3, 8] {
                let parallel = NativeBackend::new(e.clone())
                    .with_precision(precision)
                    .with_threads(threads)
                    .compile(8)
                    .unwrap()
                    .execute(&batch, 5)
                    .unwrap();
                assert_eq!(
                    logits_bits(&parallel),
                    logits_bits(&serial),
                    "{precision:?} threads {threads}"
                );
            }
        }
    }

    #[test]
    fn planned_path_bitexact_reference_path() {
        // the compiled plan must reproduce the pre-plan per-request path
        // bit for bit in both numeric modes
        let e = enc();
        let batch = batch_of(4, 4, 43);
        for precision in [NativePrecision::F32, NativePrecision::Fixed(QFormat::IMAGE32)] {
            let planned = NativeBackend::new(e.clone())
                .with_precision(precision)
                .with_threads(2)
                .compile(4)
                .unwrap()
                .execute(&batch, 4)
                .unwrap();
            let reference = NativeBackend::new(e.clone())
                .with_precision(precision)
                .with_plan(false)
                .compile(4)
                .unwrap()
                .execute(&batch, 4)
                .unwrap();
            assert_eq!(logits_bits(&planned), logits_bits(&reference), "{precision:?}");
        }
    }

    #[test]
    fn replicated_backend_serves_identical_logits() {
        let e = enc();
        let original = NativeBackend::new(e.clone())
            .with_precision(NativePrecision::Fixed(QFormat::IMAGE32));
        // replicas share the plan *cache*, so compile order is free —
        // whichever instance compiles first fills it for both
        let exe = original.compile(1).unwrap();
        let replica = original.replicate().expect("native backends replicate");
        assert_eq!(replica.name(), "native");
        let rexe = replica.compile(1).unwrap();
        let mut rng = Rng::new(19);
        let img = render_digit(&mut rng, 5, 0.05);
        let batch = Tensor::from_vec(&[1, 1, 12, 12], img.data().to_vec());
        let a = exe.execute(&batch, 1).unwrap();
        let b = rexe.execute(&batch, 1).unwrap();
        assert_eq!(logits_bits(&a), logits_bits(&b));
    }

    #[test]
    fn plan_compile_error_surfaces_at_startup() {
        let mut e = enc();
        e.conv2.bin_idx.data_mut()[0] = 200; // codebook has 8 entries
        let b = NativeBackend::new(e);
        assert!(b.compile(1).is_err());
    }

    #[test]
    fn compile_entry_serves_registry_models_bitexactly() {
        use crate::model_store::ModelRegistry;
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(23);
        let params = arch.init(&mut rng);
        let other = EncodedCnn::encode(arch, &params, 4, QFormat::W32);
        let reg = ModelRegistry::new();
        reg.insert("other", other.clone());
        let entry = reg.get("other").unwrap();

        // a backend built around a *different* default model still
        // compiles and serves the registry entry's weights
        let backend = NativeBackend::new(enc());
        let exe = backend.compile_entry(&entry, 2).unwrap();
        assert_eq!(exe.batch(), 2);
        let img = render_digit(&mut rng, 6, 0.05);
        let mut data = img.data().to_vec();
        data.resize(2 * 12 * 12, 0.0);
        let batch = Tensor::from_vec(&[2, 1, 12, 12], data);
        let logits = exe.execute(&batch, 1).unwrap();
        let want = other.forward(&img, ConvVariant::Pasm);
        assert_eq!(
            logits.data()[..10].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn single_model_backends_reject_registry_entries() {
        use crate::model_store::ModelRegistry;
        struct OneTrick(EncodedCnn);
        impl ExecutionBackend for OneTrick {
            fn name(&self) -> &'static str {
                "one-trick"
            }
            fn encoded(&self) -> &EncodedCnn {
                &self.0
            }
            fn compile(&self, _batch: usize) -> Result<Box<dyn Executable>> {
                anyhow::bail!("not under test")
            }
        }
        let reg = ModelRegistry::new();
        reg.insert("m", enc());
        let entry = reg.get("m").unwrap();
        let err = OneTrick(enc()).compile_entry(&entry, 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("registry model 'm'"), "unhelpful error: {msg}");
    }
}
