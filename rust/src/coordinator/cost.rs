//! Hardware cost model: price a served batch on a modeled accelerator.
//!
//! Factored out of the engine so the *numerics* backend and the *cost*
//! accounting are independent axes: the same request stream can be priced
//! as if it ran on a Direct (dense-weight), weight-shared MAC, or PASM
//! accelerator at any [`Tech`] point — the comparison the paper's
//! evaluation makes, and the separation multiplier-less designs like TMA
//! (arXiv:1909.04551) assume.  Cycles come from the latency model of each
//! conv layer, energy from the 45 nm power model.

use crate::accel::conv::{ConvAccel, ConvVariantKind};
use crate::cnn::network::EncodedCnn;
use crate::hw::Tech;
use crate::tensor::ConvShape;

/// Simulated hardware cost of serving work on the modeled accelerator.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HwCost {
    /// Accelerator cycles (all priced layers, all images).
    pub cycles: u64,
    /// Energy at the modeled tech point (J).
    pub energy_j: f64,
    /// Wall time on the modeled accelerator (s).
    pub accel_time_s: f64,
}

impl HwCost {
    /// Cost of `n` independent images at this per-image cost.
    pub fn scale(&self, n: usize) -> HwCost {
        HwCost {
            cycles: self.cycles * n as u64,
            energy_j: self.energy_j * n as f64,
            accel_time_s: self.accel_time_s * n as f64,
        }
    }

    fn plus(&self, other: &HwCost) -> HwCost {
        HwCost {
            cycles: self.cycles + other.cycles,
            energy_j: self.energy_j + other.energy_j,
            accel_time_s: self.accel_time_s + other.accel_time_s,
        }
    }
}

/// Maps (accelerator variant × tech × layer shape × bins × weight width)
/// to a [`HwCost`].
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Which accelerator variant the deployment is priced as.
    pub variant: ConvVariantKind,
    /// Process/clock point of the modeled silicon.
    pub tech: Tech,
}

impl CostModel {
    /// Price deployments as `variant` silicon at the `tech` point.
    pub fn new(variant: ConvVariantKind, tech: Tech) -> Self {
        CostModel { variant, tech }
    }

    /// The paper's headline deployment: PASM at 45 nm / 1 GHz (the default
    /// pricing; note energy is now summed per layer, `Σ Pᵢ·Tᵢ`, fixing the
    /// pre-refactor engine's `(ΣPᵢ)·(ΣTᵢ)` overcount).
    pub fn pasm_asic() -> Self {
        CostModel::new(ConvVariantKind::Pasm, Tech::asic_1ghz())
    }

    /// Weight-shared MAC baseline at 45 nm / 1 GHz.
    pub fn weight_shared_asic() -> Self {
        CostModel::new(ConvVariantKind::WeightShared, Tech::asic_1ghz())
    }

    /// Dense-weight (non-shared) baseline at 45 nm / 1 GHz.
    pub fn direct_asic() -> Self {
        CostModel::new(ConvVariantKind::Direct, Tech::asic_1ghz())
    }

    /// Price one conv layer of the given shape at `bins` shared weights of
    /// width `weight_width`.
    pub fn price_conv(&self, shape: ConvShape, bins: usize, weight_width: u32) -> HwCost {
        let accel = ConvAccel::new(self.variant, shape, bins, weight_width);
        let cycles = accel.latency_cycles();
        let time_s = cycles as f64 * self.tech.period_s();
        HwCost {
            cycles,
            energy_j: accel.power(&self.tech).total_w() * time_s,
            accel_time_s: time_s,
        }
    }

    /// Price one image through both conv layers of the encoded digits CNN
    /// (the dense head is not priced — PASM targets the convolutions).
    /// Each layer is priced at its own codebook's bins/width.
    pub fn price_image(&self, enc: &EncodedCnn) -> HwCost {
        self.price_conv(
            enc.arch.conv1_shape(),
            enc.conv1.codebook.bins(),
            enc.conv1.codebook.wq.width,
        )
        .plus(&self.price_conv(
            enc.arch.conv2_shape(),
            enc.conv2.codebook.bins(),
            enc.conv2.codebook.wq.width,
        ))
    }
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::pasm_asic()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::Rng;
    use crate::cnn::network::DigitsCnn;
    use crate::quant::fixed::QFormat;

    fn enc(bins: usize) -> EncodedCnn {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(77);
        let params = arch.init(&mut rng);
        EncodedCnn::encode(arch, &params, bins, QFormat::W32)
    }

    #[test]
    fn scale_is_linear() {
        let c = CostModel::pasm_asic().price_image(&enc(16));
        let c4 = c.scale(4);
        assert_eq!(c4.cycles, c.cycles * 4);
        assert!((c4.energy_j - c.energy_j * 4.0).abs() < 1e-18);
        assert!((c4.accel_time_s - c.accel_time_s * 4.0).abs() < 1e-15);
    }

    #[test]
    fn pasm_slower_than_ws_same_model() {
        // Fig 14: PASM trades a few percent of latency for the silicon win
        let e = enc(16);
        let pasm = CostModel::pasm_asic().price_image(&e);
        let ws = CostModel::weight_shared_asic().price_image(&e);
        assert!(pasm.cycles > ws.cycles, "pasm {} vs ws {}", pasm.cycles, ws.cycles);
        assert!(pasm.energy_j > 0.0 && ws.energy_j > 0.0);
    }

    #[test]
    fn pasm_cheaper_energy_at_4_bins() {
        // Fig 15 territory: at 4 bins PASM wins power by a wide margin, and
        // the small latency overhead cannot flip the energy comparison
        let e = enc(4);
        let pasm = CostModel::pasm_asic().price_image(&e);
        let ws = CostModel::weight_shared_asic().price_image(&e);
        assert!(pasm.energy_j < ws.energy_j, "pasm {} vs ws {}", pasm.energy_j, ws.energy_j);
    }

    #[test]
    fn all_variants_priceable() {
        let e = enc(8);
        for cm in [
            CostModel::direct_asic(),
            CostModel::weight_shared_asic(),
            CostModel::pasm_asic(),
            CostModel::new(ConvVariantKind::Pasm, Tech::asic_800mhz()),
        ] {
            let c = cm.price_image(&e);
            assert!(c.cycles > 0 && c.energy_j > 0.0 && c.accel_time_s > 0.0);
        }
    }
}
