//! `repro` — CLI for the pasm-accel reproduction.
//!
//! ```text
//! repro report <id>|all          regenerate paper tables/figures
//! repro simulate [--bins B] [--width W] [--variant ws|pasm] [--seed N]
//! repro pack <dir> [--bins B] [--width W] [--name NAME] [--seed N]
//! repro serve [--requests N] [--backend native|pjrt] [--artifacts DIR] [--fixed]
//!             [--threads N] [--no-plan] [--kernel per-tap|histogram|auto] [--shards N]
//! repro serve --models <dir> [--requests N] [--model NAME] [--fixed]
//!             [--poll-ms M] [--pack-midrun NAME=BINS] [--kernel K] [--shards N]
//! repro serve --listen ADDR [--evented] [--models <dir>] [--fixed] [--max-conns N]
//!             [--max-inflight N] [--port-file PATH] [--for-s SECS] [--shards N]
//!             [--steal on|off] [--steal-promote-us US]
//!             [--kernel per-tap|histogram|auto] [--chaos seed=7,panic=0.05,reset=0.02]
//! repro bench-net --addr ADDR [--requests N] [--rate HZ] [--conns C]
//!             [--models a,b,c] [--expect-multi-shard] [--stage-breakdown]
//!             [--zipf S] [--expect-steals] [--pipeline-depth D] [--idle-conns N]
//!             [--retries R] [--retry-seed S] [--deadline-ms MS] [--expect-faults]
//! repro trace --addr ADDR [--id N] [--limit N] [--json] [--require-complete]
//! repro perf-gate --baseline PATH --current PATH [--max-req-regress F]
//!             [--max-p99-growth F] [--allow-regression]
//! repro sweep [--target asic|fpga]
//! repro list                     list report ids
//! ```
//!
//! (clap is unavailable in the offline build; arguments are parsed by
//! hand — flags are `--key value` pairs.)

use anyhow::Context;
use pasm_accel::accel::conv::{ConvAccel, ConvVariantKind};
use pasm_accel::cnn::conv::FxConvInputs;
use pasm_accel::cnn::data::{render_digit, Rng};
use pasm_accel::cnn::network::{DigitsCnn, EncodedCnn};
use pasm_accel::cnn::plan::KernelChoice;
use pasm_accel::coordinator::loadgen::NetLoadOptions;
use pasm_accel::coordinator::{BatchPolicy, CoordinatorBuilder, NativeBackend, NativePrecision};
use pasm_accel::faults::FaultPlan;
use pasm_accel::hw::Tech;
use pasm_accel::model_store::{self, ModelRegistry};
use pasm_accel::obs::{assemble_spans, Span, TraceEvent};
use pasm_accel::quant::codebook::encode_weights;
use pasm_accel::quant::fixed::QFormat;
use pasm_accel::report::{all_report_ids, run_report};
use pasm_accel::runtime::json::{self, Json};
use pasm_accel::serving::net::write_port_file;
#[cfg(unix)]
use pasm_accel::serving::{EventedConfig, EventedServer};
use pasm_accel::serving::{NetCounters, RetryPolicy, Server, ServerConfig};
use pasm_accel::sim::simulate_conv;
use pasm_accel::tensor::Tensor;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{}", USAGE);
        return ExitCode::FAILURE;
    };
    let flags = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "report" => cmd_report(&args),
        "simulate" => cmd_simulate(&flags),
        "pack" => cmd_pack(&args, &flags),
        "serve" => cmd_serve(&flags),
        "bench-net" => cmd_bench_net(&flags),
        "trace" => cmd_trace(&flags),
        "perf-gate" => cmd_perf_gate(&flags),
        "sweep" => cmd_sweep(&flags),
        "list" => {
            for id in all_report_ids() {
                println!("{id}");
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}'\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "usage: repro report|simulate|pack|serve|bench-net|trace|perf-gate|sweep|list
  report all | report fig15      regenerate paper exhibits
  simulate --variant pasm --bins 16 --width 32 --seed 1
  pack <dir> [--bins 16] [--width 32] [--name NAME] [--seed 7]
  serve --requests 64 --backend native|pjrt [--artifacts artifacts] [--fixed]
        [--threads N] [--no-plan] [--kernel per-tap|histogram|auto] [--shards N]
  serve --models <dir> [--requests 64] [--model NAME] [--fixed] [--poll-ms 25]
        [--pack-midrun NAME=BINS] [--kernel per-tap|histogram|auto] [--shards N]
  serve --listen 127.0.0.1:7878 [--evented] [--workers N] [--max-pipeline 32]
        [--models <dir>] [--fixed] [--max-conns 64] [--max-inflight 256]
        [--port-file PATH] [--for-s SECS] [--shards N]
        [--steal on|off] [--steal-promote-us US]
        [--kernel per-tap|histogram|auto] [--chaos seed=7,panic=0.05,reset=0.02]
  bench-net --addr 127.0.0.1:7878 [--requests 256] [--rate 500] [--conns 8]
        [--models digits-b8,digits-b16] [--expect-multi-shard] [--stage-breakdown]
        [--zipf 1.1] [--expect-steals] [--pipeline-depth 32] [--idle-conns 5000]
        [--retries 3] [--retry-seed 29] [--deadline-ms 250] [--expect-faults]
  trace --addr 127.0.0.1:7878 [--id N] [--limit 512] [--json] [--require-complete]
  perf-gate --baseline BENCH_baseline.json --current BENCH_serving.json
        [--max-req-regress 0.10] [--max-p99-growth 0.15] [--allow-regression]
  sweep --target asic|fpga";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), String::from("true"));
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    flags
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Parse `--kernel per-tap|histogram|auto` (default `auto`).  Unlike the
/// lenient [`flag`] helper, an unknown value is a hard error — silently
/// serving with the wrong kernel strategy would invalidate any benchmark
/// built on the flag.
fn kernel_flag(flags: &HashMap<String, String>) -> anyhow::Result<KernelChoice> {
    match flags.get("kernel") {
        Some(v) => v.parse(),
        None => Ok(KernelChoice::Auto),
    }
}

/// Apply `--shards N` to a coordinator builder (absent = the builder's
/// default: `available_parallelism` capped when serving a models
/// registry, one shard otherwise).
fn apply_shards(
    builder: CoordinatorBuilder,
    flags: &HashMap<String, String>,
) -> anyhow::Result<CoordinatorBuilder> {
    match flags.get("shards") {
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| anyhow::anyhow!("--shards expects a positive integer, got '{v}'"))?;
            anyhow::ensure!(n >= 1, "--shards must be >= 1");
            Ok(builder.shards(n))
        }
        None => Ok(builder),
    }
}

/// Apply `--chaos SPEC` (a seeded deterministic fault-injection plan,
/// e.g. `seed=7,panic=0.05,reset=0.02`) to a coordinator builder.
/// Absent, the server runs with no plan at all — the injection hooks
/// are compiled in but inert.
fn apply_chaos(
    builder: CoordinatorBuilder,
    flags: &HashMap<String, String>,
) -> anyhow::Result<CoordinatorBuilder> {
    match flags.get("chaos") {
        Some(spec) => Ok(builder.fault_plan(FaultPlan::parse(spec)?)),
        None => Ok(builder),
    }
}

/// Apply `--steal on|off` (default off: bit-for-bit legacy routing) to a
/// coordinator builder.  Like [`kernel_flag`], an unknown value is a
/// hard error — an elasticity bench that silently ran with stealing
/// disabled would measure nothing.  `--steal-promote-us US` tunes the
/// hot-model promotion threshold (queue depth × EWMA batch cost, in µs;
/// 0 donates every formed batch, which is the deterministic test mode).
fn apply_steal(
    builder: CoordinatorBuilder,
    flags: &HashMap<String, String>,
) -> anyhow::Result<CoordinatorBuilder> {
    let builder = match flags.get("steal").map(String::as_str) {
        Some("on") => builder.steal(true),
        Some("off") | None => builder,
        Some(other) => anyhow::bail!("--steal expects on|off, got '{other}'"),
    };
    match flags.get("steal-promote-us") {
        Some(v) => {
            let us: u64 = v.parse().map_err(|_| {
                anyhow::anyhow!("--steal-promote-us expects a µs threshold, got '{v}'")
            })?;
            Ok(builder.steal_promote_us(us))
        }
        None => Ok(builder),
    }
}

fn cmd_report(args: &[String]) -> anyhow::Result<()> {
    let csv = args.iter().any(|a| a == "--csv");
    let id = args.get(1).map(String::as_str).unwrap_or("all");
    let emit = |r: &pasm_accel::report::Report| {
        if csv {
            print!("{}", pasm_accel::report::csv::to_csv(r));
        } else {
            println!("{}", r.render());
        }
    };
    if id == "all" {
        for rid in all_report_ids() {
            emit(&run_report(rid).unwrap());
        }
        return Ok(());
    }
    match run_report(id) {
        Some(r) => {
            emit(&r);
            Ok(())
        }
        None => Err(anyhow::anyhow!(
            "unknown report '{id}' (try: {})",
            all_report_ids().join(", ")
        )),
    }
}

fn cmd_simulate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let bins: usize = flag(flags, "bins", 16);
    let width: u32 = flag(flags, "width", 32);
    let seed: u64 = flag(flags, "seed", 1);
    let variant = match flags.get("variant").map(String::as_str).unwrap_or("pasm") {
        "ws" => ConvVariantKind::WeightShared,
        "direct" => ConvVariantKind::Direct,
        _ => ConvVariantKind::Pasm,
    };

    let mut rng = Rng::new(seed);
    let image = Tensor::from_fn(&[15, 5, 5], |_| rng.signed() * 4.0);
    let w = Tensor::from_fn(&[2, 15, 3, 3], |_| rng.signed());
    let wq = match width {
        8 => QFormat::W8,
        16 => QFormat::W16,
        _ => QFormat::W32,
    };
    let enc = encode_weights(&w, bins, wq);
    let inputs = FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 1);
    let accel = ConvAccel::paper(variant, bins, width);
    let sim = simulate_conv(&accel, &inputs);
    let tech = Tech::asic_1ghz();

    println!("variant: {variant:?}  bins: {bins}  weight width: {width}");
    println!("cycles: {} (analytical {})", sim.cycles, accel.latency_cycles());
    println!("gates:  {:.0} NAND2", accel.gates(&tech).total());
    let p = accel.power(&tech);
    println!(
        "power:  {:.2} mW total ({:.2} leak + {:.2} dyn) @1GHz",
        p.total_w() * 1e3,
        p.leakage_w * 1e3,
        p.dynamic_w * 1e3
    );
    for (name, act) in &sim.activity.probes {
        println!("activity {name}: {act:.4}");
    }
    println!("out[0..4]: {:?}", &sim.out.data()[..4.min(sim.out.len())]);
    Ok(())
}

/// Build a deterministic digits model and save it as a `.pasm` artifact.
fn cmd_pack(args: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let dir = args
        .get(1)
        .filter(|a| !a.starts_with("--"))
        .context("usage: repro pack <dir> [--bins N] [--width 8|16|32] [--name NAME] [--seed S]")?;
    let bins: usize = flag(flags, "bins", 16);
    let width: u32 = flag(flags, "width", 32);
    let seed: u64 = flag(flags, "seed", 7);
    let wq = match width {
        8 => QFormat::W8,
        16 => QFormat::W16,
        _ => QFormat::W32,
    };
    let name = flags
        .get("name")
        .cloned()
        .unwrap_or_else(|| format!("digits-b{bins}-w{width}"));

    let arch = DigitsCnn::default();
    let mut rng = Rng::new(seed);
    let params = arch.init(&mut rng);
    let enc = EncodedCnn::encode(arch, &params, bins, wq);

    let path = PathBuf::from(dir).join(format!("{name}.pasm"));
    let bytes = model_store::save_file(&path, &enc)?;
    let raw = model_store::raw_dense_bytes(&enc);
    println!(
        "packed {} ({bytes} bytes on disk vs {raw} bytes raw f32 -> {:.1}x)",
        path.display(),
        raw as f64 / bytes as f64
    );
    Ok(())
}

/// Multi-model serving from a models directory: load every `.pasm`
/// artifact into a registry, watch the directory for hot swaps, and
/// round-robin requests across every model id — optionally packing a new
/// variant mid-run to exercise zero-downtime reload end to end.
fn cmd_serve_models(flags: &HashMap<String, String>, dir: &str) -> anyhow::Result<()> {
    let n: usize = flag(flags, "requests", 64);
    let poll_ms: u64 = flag(flags, "poll-ms", 25);
    let dir_path = PathBuf::from(dir);

    let registry = Arc::new(ModelRegistry::load_dir(&dir_path)?);
    anyhow::ensure!(
        !registry.is_empty(),
        "no .pasm artifacts in {dir} (run `repro pack {dir}` first)"
    );
    registry.watch(dir_path.clone(), Duration::from_millis(poll_ms))?;

    let default_name = match flags.get("model") {
        Some(m) => m.clone(),
        None => registry.default_name().expect("registry checked non-empty"),
    };
    let entry = registry
        .get(&default_name)
        .with_context(|| format!("model '{default_name}' is not in {dir}"))?;
    let mut backend = NativeBackend::new((*entry.enc).clone());
    if flags.contains_key("fixed") {
        backend = backend.with_precision(NativePrecision::Fixed(QFormat::IMAGE32));
    }
    backend = backend.with_kernel(kernel_flag(flags)?);
    let builder = CoordinatorBuilder::new()
        .backend(backend)
        .registry(Arc::clone(&registry))
        .default_model(&default_name)
        .batch_policy(BatchPolicy::default());
    let coord = apply_steal(apply_chaos(apply_shards(builder, flags)?, flags)?, flags)?.build()?;
    let mut expected = registry.names();
    // every model (including a --pack-midrun addition) must be reachable
    // in both the pre- and post-swap halves of the round-robin
    let final_models = expected.len() + usize::from(flags.contains_key("pack-midrun"));
    anyhow::ensure!(
        n >= 2 * final_models,
        "--requests {n} cannot cover {final_models} model(s) in both halves \
         (need at least {})",
        2 * final_models
    );
    println!(
        "serving {} model(s) from {dir} on '{}' backend: {expected:?}",
        expected.len(),
        coord.metrics().backend
    );

    let t0 = Instant::now();
    let mut rng = Rng::new(11);
    let mut rxs = Vec::with_capacity(n);
    let first_half = n / 2;
    for i in 0..first_half {
        let name = expected[i % expected.len()].clone();
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit_to(&name, img)?;
        rxs.push((name, rx));
    }

    // hot-swap: pack a new variant into the live dir while the phase-1
    // requests above are still in flight, and wait for the watcher
    if let Some(spec) = flags.get("pack-midrun") {
        let (name, bins_str) = spec
            .split_once('=')
            .context("--pack-midrun expects NAME=BINS, e.g. digits-b4=4")?;
        let bins: usize = bins_str.parse().context("--pack-midrun BINS must be a number")?;
        let gen_before = registry.generation();
        let arch = DigitsCnn::default();
        let mut prng = Rng::new(43);
        let params = arch.init(&mut prng);
        let enc = EncodedCnn::encode(arch, &params, bins, QFormat::W32);
        model_store::save_file(&dir_path.join(format!("{name}.pasm")), &enc)?;
        let deadline = Instant::now() + Duration::from_secs(10);
        while registry.get(name).map(|e| e.generation <= gen_before).unwrap_or(true) {
            anyhow::ensure!(
                Instant::now() < deadline,
                "watcher did not pick up '{name}' within 10s"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        println!(
            "hot-swapped '{name}' (bins={bins}) into the registry, generation {}",
            registry.generation()
        );
        if !expected.iter().any(|e| e == name) {
            expected.push(name.to_string());
        }
    }

    for i in first_half..n {
        let name = expected[i % expected.len()].clone();
        let img = render_digit(&mut rng, i % 10, 0.05);
        let rx = coord.submit_to(&name, img)?;
        rxs.push((name, rx));
    }

    let mut ok_by_model: BTreeMap<String, usize> = BTreeMap::new();
    let mut failed = 0usize;
    for (name, rx) in rxs {
        match rx.recv()? {
            Ok(resp) => {
                anyhow::ensure!(
                    resp.model.as_deref() == Some(name.as_str()),
                    "mis-routed response: asked '{name}', served {:?}",
                    resp.model
                );
                *ok_by_model.entry(name).or_default() += 1;
            }
            Err(e) => {
                eprintln!("request to '{name}' failed: {e}");
                failed += 1;
            }
        }
    }
    let dt = t0.elapsed();
    let m = coord.metrics();
    println!(
        "served {}/{n} requests in {dt:?} ({:.1} req/s)",
        n - failed,
        n as f64 / dt.as_secs_f64()
    );
    for (name, counters) in &m.per_model {
        println!(
            "  model {name}: {} requests in {} batches ({} failed)",
            counters.requests, counters.batches, counters.failed_batches
        );
    }
    for name in &expected {
        anyhow::ensure!(
            ok_by_model.get(name).copied().unwrap_or(0) > 0,
            "model '{name}' answered no requests"
        );
    }
    anyhow::ensure!(failed == 0, "{failed} request(s) failed");
    println!("all {} model id(s) answered", expected.len());
    Ok(())
}

/// Either serving front-end behind one interface, so `serve --listen`
/// drives both the thread-per-connection server and (with `--evented`)
/// the readiness-loop server through identical code.
enum FrontEnd {
    Threaded(Server),
    #[cfg(unix)]
    Evented(EventedServer),
}

impl FrontEnd {
    fn local_addr(&self) -> std::net::SocketAddr {
        match self {
            FrontEnd::Threaded(s) => s.local_addr(),
            #[cfg(unix)]
            FrontEnd::Evented(s) => s.local_addr(),
        }
    }

    fn net_metrics(&self) -> NetCounters {
        match self {
            FrontEnd::Threaded(s) => s.net_metrics(),
            #[cfg(unix)]
            FrontEnd::Evented(s) => s.net_metrics(),
        }
    }

    fn shutdown(&mut self) {
        match self {
            FrontEnd::Threaded(s) => s.shutdown(),
            #[cfg(unix)]
            FrontEnd::Evented(s) => s.shutdown(),
        }
    }
}

#[cfg(unix)]
fn bind_evented(
    addr: &str,
    coord: &Arc<pasm_accel::coordinator::Coordinator>,
    flags: &HashMap<String, String>,
) -> anyhow::Result<FrontEnd> {
    let config = EventedConfig {
        workers: flag(flags, "workers", EventedConfig::default().workers),
        max_connections: flag(flags, "max-conns", 8192),
        max_inflight: flag(flags, "max-inflight", 256),
        max_pipeline: flag(flags, "max-pipeline", 32),
        ..EventedConfig::default()
    };
    // a C100K front-end needs the fds to match: raise the soft limit
    // toward the connection cap (CI runners often default to 1024)
    let want = config.max_connections as u64 + 512;
    if let Ok(limit) = pasm_accel::serving::evented::raise_fd_limit(want) {
        if limit < want {
            eprintln!("note: fd limit {limit} is below max-conns {}", config.max_connections);
        }
    }
    Ok(FrontEnd::Evented(EventedServer::bind(addr, Arc::clone(coord), config)?))
}

#[cfg(not(unix))]
fn bind_evented(
    _addr: &str,
    _coord: &Arc<pasm_accel::coordinator::Coordinator>,
    _flags: &HashMap<String, String>,
) -> anyhow::Result<FrontEnd> {
    anyhow::bail!("--evented requires a unix platform (epoll/poll readiness)")
}

/// Network serving: bind a TCP front-end and serve wire-protocol frames
/// until `--for-s` elapses (or forever).  With `--models DIR` every
/// `.pasm` artifact in DIR is served by name (hot-swappable via the
/// directory watcher); without it a deterministic built-in digits model
/// serves as the default.  `--evented` selects the readiness-loop
/// server (tens of thousands of connections, pipelining) instead of the
/// thread-per-connection one.
fn cmd_serve_listen(flags: &HashMap<String, String>, addr: &str) -> anyhow::Result<()> {
    let builder = CoordinatorBuilder::new().batch_policy(BatchPolicy::default());
    let builder = if let Some(dir) = flags.get("models") {
        let dir_path = PathBuf::from(dir);
        let registry = Arc::new(ModelRegistry::load_dir(&dir_path)?);
        anyhow::ensure!(
            !registry.is_empty(),
            "no .pasm artifacts in {dir} (run `repro pack {dir}` first)"
        );
        let poll_ms: u64 = flag(flags, "poll-ms", 25);
        registry.watch(dir_path, Duration::from_millis(poll_ms))?;
        let default_name = match flags.get("model") {
            Some(m) => m.clone(),
            None => registry.default_name().expect("registry checked non-empty"),
        };
        let entry = registry
            .get(&default_name)
            .with_context(|| format!("model '{default_name}' is not in {dir}"))?;
        let mut backend = NativeBackend::new((*entry.enc).clone());
        if flags.contains_key("fixed") {
            backend = backend.with_precision(NativePrecision::Fixed(QFormat::IMAGE32));
        }
        backend = backend.with_kernel(kernel_flag(flags)?);
        builder.backend(backend).registry(registry).default_model(&default_name)
    } else {
        let bins: usize = flag(flags, "bins", 16);
        let seed: u64 = flag(flags, "seed", 7);
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(seed);
        let params = arch.init(&mut rng);
        let mut backend = NativeBackend::new(EncodedCnn::encode(arch, &params, bins, QFormat::W32));
        if flags.contains_key("fixed") {
            backend = backend.with_precision(NativePrecision::Fixed(QFormat::IMAGE32));
        }
        backend = backend.with_kernel(kernel_flag(flags)?);
        builder.backend(backend)
    };
    let coord =
        Arc::new(apply_steal(apply_chaos(apply_shards(builder, flags)?, flags)?, flags)?.build()?);

    let mut server = if flags.contains_key("evented") {
        bind_evented(addr, &coord, flags)?
    } else {
        let config = ServerConfig {
            max_connections: flag(flags, "max-conns", 64),
            max_inflight: flag(flags, "max-inflight", 256),
            ..ServerConfig::default()
        };
        FrontEnd::Threaded(Server::bind(addr, Arc::clone(&coord), config)?)
    };
    let kind = if flags.contains_key("evented") { "evented" } else { "threaded" };
    println!(
        "listening on {} ({kind} front-end, {} coordinator shard(s))",
        server.local_addr(),
        coord.shards()
    );
    if let Some(path) = flags.get("port-file") {
        write_port_file(std::path::Path::new(path), server.local_addr())?;
    }
    match flags.get("for-s").and_then(|v| v.parse::<u64>().ok()) {
        Some(secs) => {
            std::thread::sleep(Duration::from_secs(secs));
            let net = server.net_metrics();
            let m = coord.metrics();
            println!(
                "shutting down after {secs}s: {} connection(s), {} frame(s) in, \
                 {} ok / {} failed / {} overloaded",
                net.connections_opened,
                net.frames_received,
                net.requests_ok,
                net.requests_failed,
                net.overload_rejections
            );
            println!(
                "coordinator: {} request(s) in {} batch(es), backend '{}', \
                 {} deadline miss(es), {} shard restart(s)",
                m.requests,
                m.batches,
                m.backend,
                m.deadline_misses,
                coord.shard_restarts()
            );
            for (i, s) in coord.shard_counters().iter().enumerate() {
                println!(
                    "  shard {i}: {} request(s) in {} batch(es) ({} failed)",
                    s.requests, s.batches, s.failed_batches
                );
            }
            if let Some(plan) = coord.fault_plan() {
                let f = plan.counters();
                println!(
                    "chaos (seed {}): {} injected fault(s) — {} exec, {} panic, {} latency, \
                     {} kill, {} torn, {} reset",
                    plan.seed(),
                    f.total(),
                    f.exec_errors,
                    f.panics,
                    f.latency_injections,
                    f.worker_kills,
                    f.torn_loads,
                    f.socket_resets
                );
            }
            server.shutdown();
            Ok(())
        }
        None => loop {
            std::thread::park();
        },
    }
}

/// Drive a running `repro serve --listen` server over real sockets with
/// an open-loop Poisson arrival process and report req/s + latency
/// percentiles, plus the server's shard utilization from its `metrics`
/// frame.  Exits nonzero if any request failed outright, or — with
/// `--expect-multi-shard` — if fewer than two coordinator shards served
/// batches (the CI check that sharded serving actually shards).
///
/// `--pipeline-depth D` additionally runs the single-connection
/// closed-loop comparison (serial window of 1 vs a pipelined window of
/// D on the same socket) and fails if either leg errors.
/// `--idle-conns N` is a standalone smoke instead: hold N open idle
/// sockets against the server and require it to keep answering.
///
/// `--retries R` arms client-side retries (R attempts beyond the
/// first, seeded jitter from `--retry-seed`); `--deadline-ms MS`
/// attaches a relative deadline to every request.  `--expect-faults`
/// is the chaos-smoke mode: hard errors are tolerated (the server is
/// injecting them on purpose), but every request must still reach a
/// terminal reply and at least one must succeed.
///
/// `--zipf S` skews the model mix with a Zipf(S) law over `--models`
/// (first id hottest; bare `--zipf` means S = 1.1) — the multi-tenant
/// skew that saturates one home shard.  `--expect-steals` is the
/// elasticity smoke on top: the server's metrics frame must report at
/// least one cross-shard steal, the hot model must have been executed
/// by a thief shard, and at least two shards must have executed
/// batches (fails unless the server runs `--steal on --shards >= 2`).
fn cmd_bench_net(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let addr = flags
        .get("addr")
        .context("usage: repro bench-net --addr HOST:PORT [--requests N] [--rate HZ]")?;
    if let Some(idle) = flags.get("idle-conns") {
        let idle: usize = idle.parse().context("--idle-conns takes a count")?;
        return cmd_idle_conns(addr, idle);
    }
    let n: usize = flag(flags, "requests", 256);
    let rate: f64 = flag(flags, "rate", 500.0);
    let conns: usize = flag(flags, "conns", 8);
    let retries: u32 = flag(flags, "retries", 0);
    let retry_seed: u64 = flag(flags, "retry-seed", 29);
    let deadline_ms: Option<u64> = flags.get("deadline-ms").and_then(|v| v.parse().ok());
    let expect_faults = flags.contains_key("expect-faults");
    let models: Vec<Option<String>> = flags
        .get("models")
        .map(|spec| spec.split(',').map(|s| Some(s.trim().to_string())).collect())
        .unwrap_or_default();
    let zipf_s: Option<f64> = match flags.get("zipf").map(String::as_str) {
        Some("true") => Some(1.1),
        Some(v) => {
            Some(v.parse().map_err(|_| anyhow::anyhow!("--zipf expects an exponent, got '{v}'"))?)
        }
        None => None,
    };
    if zipf_s.is_some() {
        anyhow::ensure!(models.len() >= 2, "--zipf needs --models with at least two ids");
    }

    let mut rng = Rng::new(29);
    let pool: Vec<Tensor<f32>> = (0..64).map(|i| render_digit(&mut rng, i % 10, 0.05)).collect();
    let opts = NetLoadOptions {
        connections: conns,
        retry: RetryPolicy::standard(retries + 1, retry_seed),
        deadline_ms,
        zipf_s,
        ..NetLoadOptions::default()
    };
    let r = pasm_accel::coordinator::loadgen::run_open_loop_net(
        addr, &models, &pool, n, rate, opts, &mut rng,
    )?;
    println!(
        "net bench against {addr}: offered {:.1} req/s, achieved {:.1} req/s over {conns} conn(s)",
        r.offered_hz, r.achieved_hz
    );
    // a run where nothing completed has no percentiles — print "-",
    // the terminal-outcome checks below decide whether that's an error
    let pct = |p: f64| r.percentile_us(p).map_or_else(|| "-".to_string(), |v| v.to_string());
    println!(
        "completed {}: p50 {} us, p90 {} us, p99 {} us \
         ({} overloaded, {} errors, {} deadline miss(es), {} retries)",
        r.latencies_us.len(),
        pct(50.0),
        pct(90.0),
        pct(99.0),
        r.overloaded,
        r.errors,
        r.deadline_misses,
        r.retries
    );
    if zipf_s.is_some() {
        // under a skewed mix the aggregate hides the hot model's tail;
        // show the heaviest models from the per-model breakdown
        let mut by_traffic: Vec<_> = r.per_model.iter().collect();
        by_traffic.sort_by(|a, b| b.1.requests.cmp(&a.1.requests).then(a.0.cmp(b.0)));
        for (name, ml) in by_traffic.iter().take(5) {
            let pct =
                |p: f64| ml.percentile_us(p).map_or_else(|| "-".to_string(), |v| v.to_string());
            println!(
                "  model {name}: {} request(s), {:.1} req/s, p50 {} us, p99 {} us \
                 ({} errors, {} deadline miss(es))",
                ml.requests,
                ml.achieved_hz,
                pct(50.0),
                pct(99.0),
                ml.errors,
                ml.deadline_misses
            );
        }
    }
    // every request must reach a terminal outcome either way; without
    // --expect-faults a hard error also fails the run outright
    let answered = r.latencies_us.len() + r.errors + r.overloaded + r.deadline_misses;
    anyhow::ensure!(answered == n, "{} of {n} request(s) never got a terminal reply", n - answered);
    if !expect_faults {
        anyhow::ensure!(r.errors == 0, "{} request(s) failed", r.errors);
    }
    anyhow::ensure!(!r.latencies_us.is_empty(), "no request completed");

    // shard utilization, straight from the server's metrics frame.  With
    // --expect-faults a chaos plan may reset this very connection before
    // the reply flushes, so the fetch gets a few fresh-connection tries.
    let attempts = if expect_faults { 5 } else { 1 };
    let mut fetched = None;
    let mut last_err = anyhow::anyhow!("metrics fetch never attempted");
    for _ in 0..attempts {
        match pasm_accel::serving::Client::connect(addr.as_str()) {
            Ok(mut client) => match client.metrics() {
                Ok(frame) => {
                    fetched = Some(frame);
                    break;
                }
                Err(e) => last_err = anyhow::anyhow!("fetch metrics: {e}"),
            },
            Err(e) => last_err = anyhow::anyhow!("connect for metrics: {e}"),
        }
    }
    let Some(m) = fetched else { return Err(last_err) };
    let active = m.shards.iter().filter(|s| s.batches > 0).count();
    println!("server shards: {} total, {active} served batches", m.shards.len());
    for (i, s) in m.shards.iter().enumerate() {
        let steal_note = if s.stolen_batches > 0 || s.donated_batches > 0 {
            format!(", {} stolen / {} donated", s.stolen_batches, s.donated_batches)
        } else {
            String::new()
        };
        println!(
            "  shard {i}: {} request(s) in {} batch(es) ({} failed{steal_note})",
            s.requests, s.batches, s.failed_batches
        );
    }
    if flags.contains_key("stage-breakdown") {
        println!("per-stage latency (merged across shards):");
        for (name, h) in m.stages.named() {
            match (h.percentile_us(50.0), h.percentile_us(99.0), h.mean_us()) {
                (Some(p50), Some(p99), Some(mean)) => println!(
                    "  {name:<11} {:>7} sample(s): p50 {p50} us, p99 {p99} us, mean {mean:.1} us",
                    h.count()
                ),
                _ => println!("  {name:<11} no samples"),
            }
        }
        for (i, st) in m.shard_stages.iter().enumerate() {
            println!(
                "  shard {i}: {} executed batch(es), queue p99 {} us, execute p99 {} us",
                st.execute.count(),
                st.queue.percentile_us(99.0).unwrap_or(0),
                st.execute.percentile_us(99.0).unwrap_or(0)
            );
        }
    }
    if flags.contains_key("expect-multi-shard") {
        anyhow::ensure!(
            active >= 2,
            "expected more than one shard to serve batches, but only {active} of {} did \
             (is the server running with --shards > 1 and multiple model ids?)",
            m.shards.len()
        );
    }
    if flags.contains_key("expect-steals") {
        let hot = models
            .first()
            .cloned()
            .flatten()
            .context("--expect-steals needs --models (the first id is the hot model)")?;
        anyhow::ensure!(
            m.stolen_batches >= 1,
            "expected cross-shard steals but the server reports none \
             (is it running --steal on with --shards >= 2?)"
        );
        let hot_stolen = m.per_model.get(&hot).map(|c| c.stolen_batches).unwrap_or(0);
        anyhow::ensure!(
            hot_stolen >= 1,
            "hot model '{hot}' was never executed by a thief shard \
             ({} steal(s) happened, all for other models)",
            m.stolen_batches
        );
        anyhow::ensure!(
            active >= 2,
            "hot-model traffic stayed on {active} shard(s); elasticity needs >= 2 executing"
        );
        println!(
            "steals: {} stolen / {} donated batch(es), hot '{hot}' stolen {hot_stolen}; \
             replicas installed {} / evicted {}",
            m.stolen_batches,
            m.donated_batches,
            m.replicas_installed,
            m.replicas_evicted
        );
    }

    // serial-vs-pipelined closed loop on one connection: what does the
    // pipelined protocol mode itself buy, round-trips amortized over
    // the window, with connection parallelism held at exactly 1?
    if let Some(depth) = flags.get("pipeline-depth") {
        let depth: usize = depth.parse().context("--pipeline-depth takes a window size")?;
        anyhow::ensure!(depth >= 2, "--pipeline-depth below 2 cannot pipeline");
        let model = models.first().cloned().flatten();
        let loadgen = pasm_accel::coordinator::loadgen::run_closed_loop_pipelined;
        let serial = loadgen(addr, model.as_deref(), &pool, n, 1)?;
        let piped = loadgen(addr, model.as_deref(), &pool, n, depth)?;
        println!(
            "one connection, {n} requests: serial {:.1} req/s, pipelined(depth {}) {:.1} req/s \
             ({:.2}x)",
            serial.req_per_s,
            piped.window,
            piped.req_per_s,
            piped.req_per_s / serial.req_per_s.max(1e-9)
        );
        anyhow::ensure!(serial.errors == 0, "{} serial request(s) failed", serial.errors);
        anyhow::ensure!(piped.errors == 0, "{} pipelined request(s) failed", piped.errors);
        anyhow::ensure!(
            piped.window >= 2,
            "server granted no pipelining (window {}); is it running --evented?",
            piped.window
        );
    }
    Ok(())
}

/// `bench-net --idle-conns N`: open and hold N idle sockets, then prove
/// the server still answers new requests — the C100K smoke.  Raises the
/// process fd limit itself so CI runners with a 1024 soft limit work.
fn cmd_idle_conns(addr: &str, n: usize) -> anyhow::Result<()> {
    #[cfg(unix)]
    {
        let limit = pasm_accel::serving::evented::raise_fd_limit(n as u64 + 256)?;
        anyhow::ensure!(
            limit > n as u64 + 64,
            "fd limit {limit} too low for {n} sockets (raise the hard limit with ulimit -Hn)"
        );
    }
    let mut socks = Vec::with_capacity(n);
    for i in 0..n {
        let sock = std::net::TcpStream::connect(addr)
            .with_context(|| format!("open idle connection {i} of {n} to {addr}"))?;
        socks.push(sock);
    }
    // with every socket parked, the server must still accept and answer
    let mut client = pasm_accel::serving::Client::connect(addr)
        .map_err(|e| anyhow::anyhow!("connect while {n} idle sockets held: {e}"))?;
    client.ping().map_err(|e| anyhow::anyhow!("ping while {n} idle sockets held: {e}"))?;
    // the accept thread may still be draining the tail of the burst;
    // give the gauge a moment to cover every socket we hold
    let mut open = 0u64;
    for _ in 0..100 {
        let m = client.metrics().map_err(|e| anyhow::anyhow!("fetch metrics: {e}"))?;
        open = m.net.connections_open;
        if open as usize > n {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("{n} idle connection(s) held, server answers; connections_open = {open}");
    anyhow::ensure!(
        open as usize > n,
        "server reports {open} open connections, expected more than {n}"
    );
    drop(socks);
    Ok(())
}

/// `repro trace --addr HOST:PORT`: pull the server's request-lifecycle
/// trace ring over the wire (`get_trace`), assemble per-request spans,
/// and pretty-print each stage as a delta from the span's first event.
/// `--id N` filters to one request, `--limit N` caps the event count,
/// `--json` dumps raw events + span summaries as one JSON document, and
/// `--require-complete` turns the command into a smoke check: it fails
/// unless at least one span carries every lifecycle stage in order.
fn cmd_trace(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let addr = flags.get("addr").context(
        "usage: repro trace --addr HOST:PORT [--id N] [--limit N] [--json] [--require-complete]",
    )?;
    let id: Option<u64> = flags.get("id").and_then(|v| v.parse().ok());
    let limit: Option<u64> = flags.get("limit").and_then(|v| v.parse().ok());
    let mut client = pasm_accel::serving::Client::connect(addr.as_str())
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let frame = client.trace(id, limit).map_err(|e| anyhow::anyhow!("fetch trace: {e}"))?;
    let events: Vec<TraceEvent> = frame
        .events
        .iter()
        .map(|e| TraceEvent {
            id: e.id,
            shard: e.shard as usize,
            stage: e.stage,
            t_us: e.t_us,
            aux: e.aux,
        })
        .collect();
    let spans = assemble_spans(&events);
    if flags.contains_key("json") {
        print_trace_json(&events, &spans);
    } else {
        print_trace_pretty(&events, &spans);
    }
    if flags.contains_key("require-complete") {
        let complete = spans.iter().filter(|s| s.is_complete()).count();
        anyhow::ensure!(
            complete >= 1,
            "no complete request span in {} event(s) across {} span(s) — is tracing enabled \
             on the server (trace_capacity > 0) and has it served an inference?",
            events.len(),
            spans.len()
        );
        println!("ok: {complete} complete span(s)");
    }
    Ok(())
}

fn print_trace_pretty(events: &[TraceEvent], spans: &[Span]) {
    if spans.is_empty() {
        println!("no request spans recorded (is the server tracing and serving?)");
    }
    for span in spans {
        let t0 = span.events.first().map(|e| e.t_us).unwrap_or(0);
        let last = span.events.last().map(|e| e.t_us.saturating_sub(t0)).unwrap_or(0);
        let status = if span.is_complete() { "complete" } else { "partial" };
        println!("request {} ({status}, {last} us end-to-end):", span.id);
        for e in &span.events {
            let aux = if e.aux != 0 { format!(", aux {}", e.aux) } else { String::new() };
            println!(
                "  {:<13} t+{:<8} us (shard {}{aux})",
                e.stage.as_str(),
                e.t_us.saturating_sub(t0),
                e.shard
            );
        }
    }
    let shard_level = events.iter().filter(|e| e.id == 0).count();
    if shard_level > 0 {
        println!("({shard_level} shard-level event(s) — fault annotations — in --json output)");
    }
}

/// One JSON document: every raw event (including shard-level id-0
/// annotations `assemble_spans` excludes) plus per-span summaries.
fn print_trace_json(events: &[TraceEvent], spans: &[Span]) {
    use std::fmt::Write as _;
    let mut s = String::from("{\"events\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"id\":{},\"shard\":{},\"stage\":\"{}\",\"t_us\":{},\"aux\":{}}}",
            e.id,
            e.shard,
            e.stage.as_str(),
            e.t_us,
            e.aux
        );
    }
    s.push_str("],\"spans\":[");
    for (i, span) in spans.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let t0 = span.events.first().map(|e| e.t_us).unwrap_or(0);
        let last = span.events.last().map(|e| e.t_us.saturating_sub(t0)).unwrap_or(0);
        let _ = write!(
            s,
            "{{\"id\":{},\"complete\":{},\"total_us\":{}}}",
            span.id,
            span.is_complete(),
            last
        );
    }
    s.push_str("]}");
    println!("{s}");
}

/// `repro perf-gate --baseline PATH --current PATH`: the CI perf
/// regression gate.  Both paths are `BENCH_serving.json`-shaped
/// snapshots; the gate compares the **planned** path at the largest
/// load present in both files and fails when req/s regressed more than
/// `--max-req-regress` (default 10%) or p99 grew more than
/// `--max-p99-growth` (default 15%).  It then compares the `kernels`
/// section: for every codebook size present in both files, the
/// histogram-vs-per-tap throughput ratio must not fall more than
/// `--max-req-regress` below the baseline — a kernel regression fails
/// the gate even when the serving-path numbers still pass.
/// `--allow-regression` downgrades a failure to a loud warning — the
/// documented one-off override for a noisy runner; refreshing
/// `BENCH_baseline.json` from a quiet full run is the durable fix (see
/// docs/ARCHITECTURE.md).
fn cmd_perf_gate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let baseline_path = flags.get("baseline").context(
        "usage: repro perf-gate --baseline BENCH_baseline.json --current BENCH_serving.json",
    )?;
    let current_path = flags.get("current").context("perf-gate needs --current PATH")?;
    let max_req_regress: f64 = flag(flags, "max-req-regress", 0.10);
    let max_p99_growth: f64 = flag(flags, "max-p99-growth", 0.15);

    let base_runs = planned_runs(baseline_path)?;
    let cur_runs = planned_runs(current_path)?;
    anyhow::ensure!(
        !cur_runs.is_empty(),
        "{current_path}: no planned-path runs recorded — did the bench actually run?"
    );
    if base_runs.is_empty() {
        // a freshly-seeded repo ships a placeholder baseline; the gate
        // arms itself the first time a measured baseline is committed
        println!(
            "perf gate: {baseline_path} is a placeholder (no planned runs) — passing \
             vacuously.  Arm the gate: run `cargo bench --bench coordinator` on a quiet \
             machine, then `cp BENCH_serving.json BENCH_baseline.json` and commit it."
        );
        return Ok(());
    }
    let load = *base_runs
        .keys()
        .filter(|l| cur_runs.contains_key(l))
        .max()
        .context("no common planned-path load between baseline and current run sets")?;
    let (b_req, b_p99) = base_runs[&load];
    let (c_req, c_p99) = cur_runs[&load];
    anyhow::ensure!(b_req > 0.0 && b_p99 > 0.0, "{baseline_path}: zero baseline measurements");
    let req_regress = (b_req - c_req) / b_req;
    let p99_growth = (c_p99 - b_p99) / b_p99;
    println!("perf gate, planned path at load {load}:");
    println!(
        "  req/s: baseline {b_req:.1} -> current {c_req:.1} ({:+.1}%)",
        -req_regress * 100.0
    );
    println!(
        "  p99:   baseline {b_p99:.0} us -> current {c_p99:.0} us ({:+.1}%)",
        p99_growth * 100.0
    );
    let allow = flags.contains_key("allow-regression");
    if req_regress <= max_req_regress && p99_growth <= max_p99_growth {
        println!(
            "ok: within gate (req/s regression <= {:.0}%, p99 growth <= {:.0}%)",
            max_req_regress * 100.0,
            max_p99_growth * 100.0
        );
    } else if allow {
        println!(
            "REGRESSION beyond gate tolerated by --allow-regression — if the new numbers are \
             intended, refresh BENCH_baseline.json from a full quiet-machine run"
        );
    } else {
        anyhow::bail!(
            "perf regression beyond gate: req/s {:+.1}% (limit -{:.0}%), p99 {:+.1}% \
             (limit +{:.0}%)\n\
             if this change intentionally trades throughput, refresh the baseline: run\n\
             `cargo bench --bench coordinator` on a quiet machine, then\n\
             `cp BENCH_serving.json BENCH_baseline.json` and commit both; for a one-off noisy\n\
             runner, re-run with --allow-regression (see docs/ARCHITECTURE.md, Observability)",
            -req_regress * 100.0,
            max_req_regress * 100.0,
            p99_growth * 100.0,
            max_p99_growth * 100.0
        );
    }
    check_kernels_gate(baseline_path, current_path, max_req_regress, allow)
}

/// Kernel-comparison leg of the perf gate: at every codebook size B
/// present in both snapshots, the histogram-vs-per-tap throughput ratio
/// must not fall more than `max_regress` below the baseline ratio.
/// Ratios of two same-machine measurements are far less noisy than the
/// absolute req/s, so this catches a histogram-kernel regression even
/// on runners whose absolute throughput drifts.  Vacuous when either
/// file predates the `kernels` section (e.g. a placeholder baseline).
fn check_kernels_gate(
    baseline_path: &str,
    current_path: &str,
    max_regress: f64,
    allow: bool,
) -> anyhow::Result<()> {
    let base = kernel_ratios(baseline_path)?;
    let cur = kernel_ratios(current_path)?;
    if base.is_empty() || cur.is_empty() {
        println!("perf gate, kernels: no measured kernel rows on both sides — skipping");
        return Ok(());
    }
    let mut failed = Vec::new();
    for (bins, b) in &base {
        let Some(c) = cur.get(bins) else { continue };
        let regress = (b - c) / b;
        println!(
            "perf gate, kernels B={bins}: histogram/per-tap ratio baseline {b:.2} -> \
             current {c:.2} ({:+.1}%)",
            -regress * 100.0
        );
        if regress > max_regress {
            failed.push(*bins);
        }
    }
    if failed.is_empty() {
        println!("ok: kernel ratios within gate (regression <= {:.0}%)", max_regress * 100.0);
        return Ok(());
    }
    if allow {
        println!("kernel ratio REGRESSION at B={failed:?} tolerated by --allow-regression");
        return Ok(());
    }
    anyhow::bail!(
        "kernel regression: histogram/per-tap ratio fell more than {:.0}% at B={failed:?} — \
         the count-then-multiply kernel lost ground; profile before refreshing the baseline",
        max_regress * 100.0
    );
}

/// `kernels` rows of a `BENCH_serving.json` snapshot: bins → measured
/// histogram/per-tap throughput ratio.  Empty when the file carries no
/// `kernels` array (placeholder or pre-section snapshot).
fn kernel_ratios(path: &str) -> anyhow::Result<BTreeMap<u64, f64>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let Some(rows) = doc.get("kernels").and_then(Json::as_arr) else {
        return Ok(BTreeMap::new());
    };
    let mut out = BTreeMap::new();
    for r in rows {
        let field = |k: &str| {
            r.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("{path}: kernel row missing numeric '{k}'"))
        };
        let bins = field("bins")? as u64;
        let per_tap = field("per_tap_req_s")?;
        let hist = field("histogram_req_s")?;
        anyhow::ensure!(per_tap > 0.0, "{path}: zero per-tap throughput at B={bins}");
        out.insert(bins, hist / per_tap);
    }
    Ok(out)
}

/// Planned-path rows of a `BENCH_serving.json` snapshot: load →
/// (req_s, p99_us).
fn planned_runs(path: &str) -> anyhow::Result<BTreeMap<u64, (f64, f64)>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let runs = doc
        .get("runs")
        .and_then(Json::as_arr)
        .with_context(|| format!("{path}: no 'runs' array — not a BENCH_serving.json?"))?;
    let mut out = BTreeMap::new();
    for r in runs {
        if r.get("config").and_then(Json::as_str) != Some("planned") {
            continue;
        }
        let field = |k: &str| {
            r.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("{path}: planned run missing numeric '{k}'"))
        };
        out.insert(field("load")? as u64, (field("req_s")?, field("p99_us")?));
    }
    Ok(out)
}

fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    if let Some(addr) = flags.get("listen") {
        return cmd_serve_listen(flags, addr);
    }
    if let Some(models_dir) = flags.get("models") {
        return cmd_serve_models(flags, models_dir);
    }
    let n: usize = flag(flags, "requests", 64);
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let bins: usize = flag(flags, "bins", 16);
    let backend_kind = flags
        .get("backend")
        .cloned()
        .unwrap_or_else(|| "native".to_string());

    let arch = DigitsCnn::default();
    let mut rng = Rng::new(7);
    let params = arch.init(&mut rng);
    let enc = EncodedCnn::encode(arch, &params, bins, QFormat::W32);

    let builder = CoordinatorBuilder::new().batch_policy(BatchPolicy::default());
    let builder = match backend_kind.as_str() {
        "native" => {
            let mut backend = NativeBackend::new(enc);
            if flags.contains_key("fixed") {
                backend = backend.with_precision(NativePrecision::Fixed(QFormat::IMAGE32));
            }
            backend = backend.with_kernel(kernel_flag(flags)?);
            if let Some(threads) = flags.get("threads").and_then(|v| v.parse().ok()) {
                backend = backend.with_threads(threads);
            }
            if flags.contains_key("no-plan") {
                // pre-plan reference path: baseline benchmarking only
                backend = backend.with_plan(false);
            }
            let _ = &dir;
            builder.backend(backend)
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => builder.backend(pasm_accel::coordinator::PjrtBackend::new(dir, enc)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => anyhow::bail!("pjrt backend not compiled in (build with --features pjrt)"),
        other => anyhow::bail!("unknown backend '{other}' (native|pjrt)"),
    };
    let coord = apply_steal(apply_chaos(apply_shards(builder, flags)?, flags)?, flags)?.build()?;
    println!("serving on '{}' backend ({} shard(s))", coord.metrics().backend, coord.shards());

    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        let img = pasm_accel::cnn::data::render_digit(&mut rng, i % 10, 0.05);
        rxs.push(coord.submit(img)?);
    }
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let dt = t0.elapsed();
    let m = coord.metrics();
    println!(
        "served {ok}/{n} requests in {dt:?} ({:.1} req/s)",
        n as f64 / dt.as_secs_f64()
    );
    println!(
        "batches: {} (mean occupancy {:.1}, padding {:.1}%)",
        m.batches,
        m.mean_occupancy(),
        m.padding_fraction() * 100.0
    );
    for p in [50.0, 90.0, 99.0] {
        if let Some(us) = m.percentile_us(p) {
            println!("p{p:.0} latency: {us} us");
        }
    }
    println!(
        "simulated accelerator: {} cycles, {:.3} uJ total",
        m.sim_cycles,
        m.sim_energy_j * 1e6
    );
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let target = flags.get("target").map(String::as_str).unwrap_or("asic");
    match target {
        "fpga" => {
            let dev = pasm_accel::fpga::Device::xc7z045();
            println!("FPGA sweep on {} @200MHz", dev.name);
            println!("{:<24} {:>6} {:>8} {:>10} {:>12}", "config", "DSP", "BRAM", "LUT", "power");
            for bins in [4usize, 8, 16] {
                for ww in [8u32, 32] {
                    for variant in [ConvVariantKind::WeightShared, ConvVariantKind::Pasm] {
                        let d =
                            pasm_accel::fpga::map_conv_accel(&ConvAccel::paper(variant, bins, ww));
                        let p = pasm_accel::fpga::fpga_power(&d, &dev);
                        println!(
                            "{:<24} {:>6} {:>8} {:>10} {:>11.0}mW",
                            format!("{variant:?}/{ww}b/{bins}bin"),
                            d.util.dsp,
                            d.util.bram18,
                            d.util.luts,
                            p.total_w() * 1e3
                        );
                    }
                }
            }
        }
        _ => {
            let tech = Tech::asic_1ghz();
            println!("ASIC sweep @1GHz (paper tile)");
            println!("{:<24} {:>12} {:>12} {:>10}", "config", "gates", "power", "latency");
            for bins in [4usize, 8, 16] {
                for ww in [8u32, 32] {
                    for variant in [ConvVariantKind::WeightShared, ConvVariantKind::Pasm] {
                        let a = ConvAccel::paper(variant, bins, ww);
                        println!(
                            "{:<24} {:>12.0} {:>10.2}mW {:>10}",
                            format!("{variant:?}/{ww}b/{bins}bin"),
                            a.gates(&tech).total(),
                            a.power(&tech).total_w() * 1e3,
                            a.latency_cycles()
                        );
                    }
                }
            }
        }
    }
    Ok(())
}
