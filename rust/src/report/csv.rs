//! CSV rendering of reports (for external plotting).

use crate::report::figures::Report;

/// Escape one CSV cell (RFC 4180).
fn escape(cell: &str) -> String {
    if cell.contains([',', '"', '\n']) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Render a report as CSV (header row + data rows).
pub fn to_csv(report: &Report) -> String {
    let mut out = String::new();
    out.push_str(
        &report
            .headers
            .iter()
            .map(|h| escape(h))
            .collect::<Vec<_>>()
            .join(","),
    );
    out.push('\n');
    for row in &report.rows {
        out.push_str(&row.iter().map(|c| escape(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::run_report;

    #[test]
    fn csv_wellformed_for_every_report() {
        for id in crate::report::all_report_ids() {
            let r = run_report(id).unwrap();
            let csv = to_csv(&r);
            let lines: Vec<&str> = csv.lines().collect();
            assert_eq!(lines.len(), r.rows.len() + 1, "{id}");
            let ncols = lines[0].split(',').count();
            // (cells containing commas are quoted; our reports don't use them)
            for l in &lines {
                assert_eq!(l.split(',').count(), ncols, "{id}: ragged row {l}");
            }
        }
    }

    #[test]
    fn quoting() {
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("a,b"), "\"a,b\"");
        assert_eq!(escape("say \"hi\""), "\"say \"\"hi\"\"\"");
    }
}
