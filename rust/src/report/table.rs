//! Plain-text table rendering for reports.

/// Render `headers` + `rows` as an aligned text table.
pub fn render(headers: &[String], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncols, "row arity mismatch");
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    let sep: String = widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+");
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!(" {c:<w$} "))
            .collect::<Vec<_>>()
            .join("|")
    };
    let mut out = String::new();
    out.push_str(&fmt_row(headers));
    out.push('\n');
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

/// Format helpers for report cells.
pub fn fmt_gates(g: f64) -> String {
    if g >= 1e6 {
        format!("{:.2}M", g / 1e6)
    } else if g >= 1e3 {
        format!("{:.1}k", g / 1e3)
    } else {
        format!("{g:.0}")
    }
}

/// Format a power value with an auto-selected W/mW/uW unit.
pub fn fmt_power(w: f64) -> String {
    if w >= 1.0 {
        format!("{w:.2}W")
    } else if w >= 1e-3 {
        format!("{:.2}mW", w * 1e3)
    } else {
        format!("{:.1}uW", w * 1e6)
    }
}

/// Format a fraction as a signed percentage.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:+.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let h = vec!["name".to_string(), "value".to_string()];
        let rows = vec![
            vec!["a".to_string(), "1".to_string()],
            vec!["longer".to_string(), "22".to_string()],
        ];
        let out = render(&h, &rows);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines equal width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(out.contains("longer"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        render(&["a".to_string()], &[vec!["1".to_string(), "2".to_string()]]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_gates(1234.0), "1.2k");
        assert_eq!(fmt_gates(2_500_000.0), "2.50M");
        assert_eq!(fmt_gates(42.0), "42");
        assert_eq!(fmt_power(0.0215), "21.50mW");
        assert_eq!(fmt_power(1.5), "1.50W");
        assert_eq!(fmt_power(42e-6), "42.0uW");
        assert_eq!(fmt_pct(-0.478), "-47.8%");
        assert_eq!(fmt_pct(0.1275), "+12.8%");
    }
}
