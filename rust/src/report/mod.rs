//! Regeneration of every table and figure in the paper's evaluation.
//!
//! Each `fig*`/`table*` function in [`figures`] rebuilds one exhibit from
//! the models and the simulator and returns a [`Report`]: headers, rows,
//! and a paper-vs-measured note.  `repro report <id>` prints them; the
//! `figures` bench regenerates all of them; EXPERIMENTS.md records the
//! residuals.
//!
//! * [`table`] — plain-text table rendering.
//! * [`chart`] — ASCII horizontal bar charts (the paper's bar figures).
//! * [`figures`] — the exhibits themselves.
//! * [`bench`] — a minimal wall-clock micro-bench harness (criterion is
//!   unavailable offline); used by the `cargo bench` targets.

pub mod bench;
pub mod chart;
pub mod csv;
pub mod figures;
pub mod table;

pub use figures::{all_report_ids, run_report, Report};
