//! The paper's exhibits, regenerated from the models and simulator.
//!
//! Every table and figure of the evaluation section has a function here
//! (see DESIGN.md §5 for the index).  Reports carry the paper's claim next
//! to the measured result so the residual is visible at a glance.

use crate::accel::conv::{ConvAccel, ConvVariantKind};
use crate::accel::standalone::StandaloneUnit;
use crate::cnn::data::Rng;
use crate::cnn::shapes;
use crate::fpga::{fpga_power, map_conv_accel, Device};
use crate::hw::Tech;
use crate::report::table::{fmt_gates, fmt_pct, fmt_power, render};
use crate::sim::standalone::{random_streams, simulate_standalone};

/// One regenerated exhibit.
#[derive(Clone, Debug)]
pub struct Report {
    /// Stable exhibit id (e.g. "fig15", "table4").
    pub id: &'static str,
    /// Human-readable exhibit title.
    pub title: String,
    /// What the paper claims, verbatim enough to compare.
    pub paper_claim: String,
    /// Table column headers.
    pub headers: Vec<String>,
    /// Table rows (pre-formatted cells).
    pub rows: Vec<Vec<String>>,
    /// Measured-result notes printed under the table.
    pub notes: Vec<String>,
}

impl Report {
    /// Render the report as printable text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} — {}\n", self.id, self.title));
        out.push_str(&format!("paper: {}\n\n", self.paper_claim));
        out.push_str(&render(&self.headers, &self.rows));
        for n in &self.notes {
            out.push_str(&format!("measured: {n}\n"));
        }
        out
    }
}

fn s(v: impl ToString) -> String {
    v.to_string()
}

/// All report ids in paper order.
pub fn all_report_ids() -> Vec<&'static str> {
    vec![
        "table1", "table2", "fig7", "fig8", "fig9", "fig10", "fig14", "fig15", "fig16",
        "fig17", "fig18", "fig19", "fig20", "fig21", "fig22",
    ]
}

/// Run one report by id.
pub fn run_report(id: &str) -> Option<Report> {
    Some(match id {
        "table1" => table1(),
        "table2" => table2(),
        "fig7" => fig7(),
        "fig8" => fig8(),
        "fig9" => fig9(),
        "fig10" => fig10(),
        "fig14" => fig14(),
        "fig15" => fig_asic(15, 4, 32, "-47.8% gates, -53.2% power vs WS"),
        "fig16" => fig_asic(16, 8, 32, "-8.1% gates, -15.2% power vs WS"),
        "fig17" => fig_asic(17, 16, 32, "PASM worse than WS at 1 GHz (tools upsize to meet timing)"),
        "fig18" => fig_asic(18, 4, 8, "-19.8% gates, -31.3% power vs WS"),
        "fig19" => fig_fpga(19, 4, 32, "-99% DSP, -28% BRAM, -64% power vs WS"),
        "fig20" => fig_fpga(20, 8, 32, "-99% DSP, -28% BRAM, -41.6% power vs WS"),
        "fig21" => fig_fpga(21, 16, 32, "-99% DSP, -28% BRAM, -18% power vs WS"),
        "fig22" => fig_fpga(22, 8, 8, "-99% DSP, ~same BRAM, -18.3% power vs WS"),
        _ => return None,
    })
}

// ---------------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------------

fn table1() -> Report {
    use crate::hw::gates::{adder_rca, multiplier, regfile, register};
    let w = 32u32;
    let b = 16usize;
    let rows = vec![
        vec![s("Adder"), s("O(W)"), s("1"), s("1"), s("1"), fmt_gates(adder_rca(w).gates.total())],
        vec![s("Multiplier"), s("O(W^2)"), s("1"), s("1"), s("-"), fmt_gates(multiplier(w, w).gates.total())],
        vec![s("Weight Register"), s("O(W)"), s("0"), s("B"), s("-"), fmt_gates(register(w).gates.total())],
        vec![s("Accumulation Register"), s("O(W)"), s("1"), s("1"), s("B"), fmt_gates(register(w).gates.total())],
        vec![s("File Port"), s("O(WB)"), s("-"), s("1"), s("2"), fmt_gates(regfile(b, w, 1, 1).gates.total() - register(w).gates.total() * b as f64)],
    ];
    Report {
        id: "table1",
        title: "Complexity of MAC, Weight-shared MAC and PAS sub-components".into(),
        paper_claim: "multiplier O(W^2) dominates; PAS replaces it with B accumulators + ports O(WB)".into(),
        headers: ["Sub Component", "Gates", "Simple MAC", "WS MAC", "PAS", format!("model @W={w} B={b}").as_str()]
            .iter().map(|h| h.to_string()).collect(),
        rows,
        notes: vec![format!(
            "multiplier({w}x{w}) = {} NAND2 vs adder = {} NAND2: the {}x gap PASM exploits",
            fmt_gates(multiplier(w, w).gates.total()),
            fmt_gates(adder_rca(w).gates.total()),
            (multiplier(w, w).gates.total() / adder_rca(w).gates.total()).round()
        )],
    }
}

fn table2() -> Report {
    let mut rows = Vec::new();
    for &k in &shapes::TABLE2_KERNELS {
        let mut row = vec![format!("{k}x{k}")];
        for &c in &shapes::TABLE2_CHANNELS {
            row.push(s(shapes::table2_macs(c, k)));
        }
        rows.push(row);
    }
    Report {
        id: "table2",
        title: "Typical numbers of MAC operations per output".into(),
        paper_claim: "C*KX*KY from 32 (C=32,1x1) to 25088 (C=512,7x7); must dominate B for PASM".into(),
        headers: vec![s("kernel"), s("C=32"), s("C=128"), s("C=512")],
        rows,
        notes: vec![s("exact match: deterministic arithmetic")],
    }
}

// ---------------------------------------------------------------------------
// Standalone unit figures (7-10)
// ---------------------------------------------------------------------------

fn standalone_pair(w: u32, b: usize) -> (StandaloneUnit, StandaloneUnit) {
    (StandaloneUnit::mac16(w, b), StandaloneUnit::pas16mac4(w, b))
}

fn fig7() -> Report {
    let t = Tech::asic_100mhz();
    let mut rows = Vec::new();
    let mut note = String::new();
    for w in [4u32, 8, 16, 32] {
        let (mac, pasm) = standalone_pair(w, 16);
        let (g1, g2) = (mac.gates(&t), pasm.gates(&t));
        rows.push(vec![
            format!("W={w}"),
            fmt_gates(g1.sequential), fmt_gates(g2.sequential),
            fmt_gates(g1.inverter), fmt_gates(g2.inverter),
            fmt_gates(g1.buffer), fmt_gates(g2.buffer),
            fmt_gates(g1.logic), fmt_gates(g2.logic),
            fmt_gates(g1.total()), fmt_gates(g2.total()),
            fmt_pct(g2.total() / g1.total() - 1.0),
        ]);
        if w == 32 {
            note = format!(
                "W=32/B=16 total gates: {} vs {} ({} for PASM)",
                fmt_gates(g1.total()), fmt_gates(g2.total()),
                fmt_pct(g2.total() / g1.total() - 1.0)
            );
        }
    }
    Report {
        id: "fig7",
        title: "Standalone gate count, 16-MAC vs 16-PAS-4-MAC, B=16, W sweep".into(),
        paper_claim: "W=32: PASM 66% fewer total gates (35% seq, 78% inv, 61% buf, 68% logic)".into(),
        headers: ["", "seq MAC", "seq PASM", "inv MAC", "inv PASM", "buf MAC", "buf PASM",
                  "logic MAC", "logic PASM", "total MAC", "total PASM", "delta"]
            .iter().map(|h| h.to_string()).collect(),
        rows,
        notes: vec![note],
    }
}

fn measured_activity(unit: &StandaloneUnit) -> f64 {
    let mut rng = Rng::new(99);
    let streams = random_streams(&mut rng, unit.lanes, 512, unit.bins, 1 << 20);
    let cb: Vec<i64> = (0..unit.bins).map(|_| (rng.signed() * 1e5) as i64).collect();
    simulate_standalone(unit, &streams, &cb).activity.mean()
}

fn fig8() -> Report {
    let t = Tech::asic_100mhz();
    let mut rows = Vec::new();
    let mut note = String::new();
    for w in [4u32, 8, 16, 32] {
        let (mac, pasm) = standalone_pair(w, 16);
        let (p1, p2) = (mac.power(&t), pasm.power(&t));
        rows.push(vec![
            format!("W={w}"),
            fmt_power(p1.leakage_w), fmt_power(p2.leakage_w),
            fmt_power(p1.dynamic_w), fmt_power(p2.dynamic_w),
            fmt_power(p1.total_w()), fmt_power(p2.total_w()),
            fmt_pct(p2.total_w() / p1.total_w() - 1.0),
            format!("{:.3}", measured_activity(&pasm)),
        ]);
        if w == 32 {
            note = format!(
                "W=32/B=16: {} for PASM total power",
                fmt_pct(p2.total_w() / p1.total_w() - 1.0)
            );
        }
    }
    Report {
        id: "fig8",
        title: "Standalone power, 16-MAC vs 16-PAS-4-MAC, B=16, W sweep (100 MHz)".into(),
        paper_claim: "W=32: PASM 60% less leakage, 70% less dynamic, 70% less total".into(),
        headers: ["", "leak MAC", "leak PASM", "dyn MAC", "dyn PASM", "tot MAC", "tot PASM",
                  "delta", "sim activity"]
            .iter().map(|h| h.to_string()).collect(),
        rows,
        notes: vec![note],
    }
}

fn fig9() -> Report {
    let t = Tech::asic_100mhz();
    let mut rows = Vec::new();
    let mut crossover = String::new();
    for b in [4usize, 16, 64, 256] {
        let (mac, pasm) = standalone_pair(32, b);
        let (g1, g2) = (mac.gates(&t), pasm.gates(&t));
        rows.push(vec![
            format!("B={b}"),
            fmt_gates(g1.sequential), fmt_gates(g2.sequential),
            fmt_gates(g1.buffer), fmt_gates(g2.buffer),
            fmt_gates(g1.logic), fmt_gates(g2.logic),
            fmt_gates(g1.total()), fmt_gates(g2.total()),
            fmt_pct(g2.total() / g1.total() - 1.0),
        ]);
        if b == 256 && g2.sequential > g1.sequential {
            crossover = s("B=256: PASM sequential exceeds MAC (register-file cost) — crossover reproduced");
        }
    }
    Report {
        id: "fig9",
        title: "Standalone gate count, B sweep at W=32".into(),
        paper_claim: "B=16: 66% fewer total; at B=256 PASM registers/buffers less efficient than MAC".into(),
        headers: ["", "seq MAC", "seq PASM", "buf MAC", "buf PASM", "logic MAC", "logic PASM",
                  "total MAC", "total PASM", "delta"]
            .iter().map(|h| h.to_string()).collect(),
        rows,
        notes: vec![crossover],
    }
}

fn fig10() -> Report {
    let t = Tech::asic_100mhz();
    let mut rows = Vec::new();
    for b in [4usize, 16, 64, 256] {
        let (mac, pasm) = standalone_pair(32, b);
        let (p1, p2) = (mac.power(&t), pasm.power(&t));
        rows.push(vec![
            format!("B={b}"),
            fmt_power(p1.leakage_w), fmt_power(p2.leakage_w),
            fmt_power(p1.dynamic_w), fmt_power(p2.dynamic_w),
            fmt_power(p1.total_w()), fmt_power(p2.total_w()),
            fmt_pct(p2.total_w() / p1.total_w() - 1.0),
        ]);
    }
    Report {
        id: "fig10",
        title: "Standalone power, B sweep at W=32 (100 MHz)".into(),
        paper_claim: "B=16: 61% less leakage, 70% less dynamic/total; advantage shrinks with B".into(),
        headers: ["", "leak MAC", "leak PASM", "dyn MAC", "dyn PASM", "tot MAC", "tot PASM", "delta"]
            .iter().map(|h| h.to_string()).collect(),
        rows,
        notes: vec![s("savings monotonically shrink with B — trend reproduced")],
    }
}

// ---------------------------------------------------------------------------
// Conv accelerator figures (14-18 ASIC, 19-22 FPGA)
// ---------------------------------------------------------------------------

fn fig14() -> Report {
    let mut rows = Vec::new();
    for bins in [4usize, 8, 16] {
        let ws = ConvAccel::paper(ConvVariantKind::WeightShared, bins, 32);
        let pasm = ConvAccel::paper(ConvVariantKind::Pasm, bins, 32);
        let mut relaxed = pasm.clone();
        relaxed.hls = relaxed.hls.with_postpass_muls(4);
        rows.push(vec![
            format!("B={bins}"),
            format!("{:.1}", ws.latency_cycles_exact()),
            format!("{:.1}", pasm.latency_cycles_exact()),
            fmt_pct(pasm.latency_cycles_exact() / ws.latency_cycles_exact() - 1.0),
            format!("{:.1}", relaxed.latency_cycles_exact()),
            fmt_pct(relaxed.latency_cycles_exact() / ws.latency_cycles_exact() - 1.0),
        ]);
    }
    Report {
        id: "fig14",
        title: "Conv-accelerator latency: WS+PASM vs WS (paper tile)".into(),
        paper_claim: "PASM +8.5% (4-bin) to +12.75% (16-bin); relaxing ALLOCATION cuts it".into(),
        headers: ["", "WS cycles", "PASM cycles", "overhead", "PASM 4-mul cycles", "overhead 4-mul"]
            .iter().map(|h| h.to_string()).collect(),
        rows,
        notes: vec![s("overhead grows with B; extra post-pass multipliers reduce it — both trends reproduced")],
    }
}

fn fig_asic(n: u32, bins: usize, ww: u32, claim: &str) -> Report {
    let t = Tech::asic_1ghz();
    let mut rows = Vec::new();
    let mut pasm_vs_ws = (0.0, 0.0);
    for (name, variant) in [
        ("non-weight-shared", ConvVariantKind::Direct),
        ("weight-shared", ConvVariantKind::WeightShared),
        ("weight-shared+PASM", ConvVariantKind::Pasm),
    ] {
        let a = ConvAccel::paper(variant, bins, ww);
        let g = a.gates(&t);
        let p = a.power(&t);
        rows.push(vec![
            s(name),
            fmt_gates(g.sequential),
            fmt_gates(g.logic + g.inverter + g.buffer),
            fmt_gates(g.total()),
            fmt_power(p.leakage_w),
            fmt_power(p.dynamic_w),
            fmt_power(p.total_w()),
            format!("{:.2}", a.path_utilization(&t)),
        ]);
        match variant {
            ConvVariantKind::WeightShared => pasm_vs_ws.0 = g.total(),
            ConvVariantKind::Pasm => pasm_vs_ws.1 = g.total(),
            _ => {}
        }
    }
    let ws_p = ConvAccel::paper(ConvVariantKind::WeightShared, bins, ww).power(&t).total_w();
    let pasm_p = ConvAccel::paper(ConvVariantKind::Pasm, bins, ww).power(&t).total_w();
    let id: &'static str = match n {
        15 => "fig15",
        16 => "fig16",
        17 => "fig17",
        _ => "fig18",
    };
    Report {
        id,
        title: format!("ASIC gates+power, {ww}-bit kernels, {bins}-bin, 1 GHz (paper tile)"),
        paper_claim: claim.into(),
        headers: ["variant", "seq", "comb", "total gates", "leakage", "dynamic", "total power", "path util"]
            .iter().map(|h| h.to_string()).collect(),
        rows,
        notes: vec![format!(
            "PASM vs WS: {} gates, {} power",
            fmt_pct(pasm_vs_ws.1 / pasm_vs_ws.0 - 1.0),
            fmt_pct(pasm_p / ws_p - 1.0),
        )],
    }
}

fn fig_fpga(n: u32, bins: usize, ww: u32, claim: &str) -> Report {
    let dev = Device::xc7z045();
    let mut rows = Vec::new();
    let mut ws_tot = (0u64, 0u64, 0.0f64);
    let mut pasm_tot = (0u64, 0u64, 0.0f64);
    for (name, variant) in [
        ("non-weight-shared", ConvVariantKind::Direct),
        ("weight-shared", ConvVariantKind::WeightShared),
        ("weight-shared+PASM", ConvVariantKind::Pasm),
    ] {
        let design = map_conv_accel(&ConvAccel::paper(variant, bins, ww));
        let p = fpga_power(&design, &dev);
        rows.push(vec![
            s(name),
            s(design.util.dsp),
            s(design.util.bram18),
            s(design.util.luts),
            s(design.util.ffs),
            fmt_power(p.static_w),
            fmt_power(p.dynamic_w),
            fmt_power(p.total_w()),
        ]);
        match variant {
            ConvVariantKind::WeightShared => ws_tot = (design.util.dsp, design.util.bram18, p.total_w()),
            ConvVariantKind::Pasm => pasm_tot = (design.util.dsp, design.util.bram18, p.total_w()),
            _ => {}
        }
    }
    let id: &'static str = match n {
        19 => "fig19",
        20 => "fig20",
        21 => "fig21",
        _ => "fig22",
    };
    Report {
        id,
        title: format!("FPGA utilization+power, {ww}-bit kernels, {bins}-bin, XC7Z045 @200 MHz"),
        paper_claim: claim.into(),
        headers: ["variant", "DSP", "BRAM18", "LUT", "FF", "static", "dynamic", "total power"]
            .iter().map(|h| h.to_string()).collect(),
        rows,
        notes: vec![format!(
            "PASM vs WS: {} DSPs, {} BRAMs, {} power",
            fmt_pct(pasm_tot.0 as f64 / ws_tot.0 as f64 - 1.0),
            fmt_pct(pasm_tot.1 as f64 / ws_tot.1 as f64 - 1.0),
            fmt_pct(pasm_tot.2 / ws_tot.2 - 1.0),
        )],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_produces_a_report() {
        for id in all_report_ids() {
            let r = run_report(id).unwrap_or_else(|| panic!("no report for {id}"));
            assert_eq!(r.id, id);
            assert!(!r.rows.is_empty(), "{id} has no rows");
            let text = r.render();
            assert!(text.contains("paper:"), "{id} missing paper claim");
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(run_report("fig99").is_none());
    }

    #[test]
    fn fig7_shows_pasm_winning_at_w32() {
        let r = fig7();
        let last = r.rows.last().unwrap();
        let delta = last.last().unwrap();
        assert!(delta.starts_with('-'), "W=32 delta should be negative: {delta}");
    }

    #[test]
    fn fig17_shows_pasm_losing() {
        let r = run_report("fig17").unwrap();
        let note = &r.notes[0];
        assert!(note.contains("+"), "16-bin 1 GHz should show PASM worse: {note}");
    }

    #[test]
    fn fpga_reports_dsp_saving() {
        let r = run_report("fig19").unwrap();
        assert!(r.notes[0].contains("-99"), "{}", r.notes[0]);
    }
}
