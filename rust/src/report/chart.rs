//! ASCII horizontal bar charts (the paper's bar figures, in a terminal).

/// Render labelled values as horizontal bars scaled to `width` chars.
pub fn bars(items: &[(String, f64)], width: usize) -> String {
    if items.is_empty() {
        return String::new();
    }
    let max = items.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-30);
    let label_w = items.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (label, v) in items {
        let n = ((v / max) * width as f64).round().max(0.0) as usize;
        out.push_str(&format!(
            "{label:<label_w$} | {} {v:.3e}\n",
            "#".repeat(n.min(width))
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_to_max() {
        let out = bars(
            &[("a".to_string(), 10.0), ("b".to_string(), 5.0)],
            20,
        );
        let lines: Vec<&str> = out.lines().collect();
        let count = |l: &str| l.matches('#').count();
        assert_eq!(count(lines[0]), 20);
        assert_eq!(count(lines[1]), 10);
    }

    #[test]
    fn empty_ok() {
        assert_eq!(bars(&[], 10), "");
    }

    #[test]
    fn zero_values_no_bar() {
        let out = bars(&[("z".to_string(), 0.0), ("x".to_string(), 1.0)], 10);
        assert!(out.lines().next().unwrap().matches('#').count() == 0);
    }
}
