//! Minimal wall-clock micro-bench harness (criterion is unavailable in the
//! offline build).  Used by the `cargo bench` targets under `rust/benches/`.
//!
//! Methodology: warm up, then run timed batches until both a minimum
//! duration and a minimum iteration count are reached; report mean,
//! best-batch mean, and throughput.  Results print in a stable
//! grep-friendly format: `bench <name>: <mean> per iter (<iters> iters)`.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Total timed iterations.
    pub iters: u64,
    /// Total timed wall clock.
    pub total: Duration,
    /// Mean per-iteration time of the fastest batch.
    pub best_batch_per_iter: Duration,
}

impl BenchResult {
    /// Mean wall clock per iteration.
    pub fn per_iter(&self) -> Duration {
        Duration::from_nanos((self.total.as_nanos() / self.iters.max(1) as u128) as u64)
    }

    /// Iterations per second.
    pub fn per_second(&self) -> f64 {
        self.iters as f64 / self.total.as_secs_f64()
    }

    /// Print the stable one-line summary.
    pub fn print(&self) {
        println!(
            "bench {}: {:?} per iter, best {:?} ({} iters, {:.1}/s)",
            self.name,
            self.per_iter(),
            self.best_batch_per_iter,
            self.iters,
            self.per_second()
        );
    }
}

/// Run `f` repeatedly for at least `min_time` and `min_iters`.
pub fn bench<F: FnMut()>(name: &str, min_time: Duration, min_iters: u64, mut f: F) -> BenchResult {
    // warm-up
    for _ in 0..3 {
        f();
    }
    let mut iters = 0u64;
    let mut total = Duration::ZERO;
    let mut best = Duration::MAX;
    let batch = 8u64;
    while total < min_time || iters < min_iters {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed();
        iters += batch;
        total += dt;
        best = best.min(dt / batch as u32);
    }
    BenchResult { name: name.to_string(), iters, total, best_batch_per_iter: best }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_enough_iterations() {
        let mut n = 0u64;
        let r = bench("noop", Duration::from_millis(5), 100, || n += 1);
        assert!(r.iters >= 100);
        assert!(n >= r.iters); // warmup + timed
        assert!(r.per_second() > 0.0);
    }

    #[test]
    fn per_iter_consistent() {
        let r = bench("sleepless", Duration::from_millis(1), 16, || {
            black_box(1 + 1);
        });
        assert!(r.per_iter() <= r.total);
        assert!(r.best_batch_per_iter <= r.per_iter().max(Duration::from_nanos(1)) * 4);
    }
}
