//! The `.pasm` model artifact format: a dictionary-encoded CNN as a
//! durable, compressed, integrity-checked binary file.
//!
//! This is the paper's §2.1 compression chain made persistent: each conv
//! layer is stored as its `B`-entry codebook plus a **Huffman-coded
//! bin-index stream** (canonical code, only the length table stored — the
//! form a hardware decoder table loads), alongside the fixed-point weight
//! format ([`QFormat`]) the accelerator computes in.  The dense head and
//! biases stay dense f32, as in the paper.  [`pack`] → [`load`] round-trips
//! an [`EncodedCnn`] **bit-exactly**: f32 values travel as raw bit
//! patterns, bin indices through the lossless Huffman layer.
//!
//! ## Layout (all little-endian)
//!
//! | section | contents |
//! |---|---|
//! | header | magic `"PASM"`, format version `u16`, flags `u16`, payload length `u64` |
//! | arch | `in_side, conv1_m, conv2_m, kernel, classes` as `u32` |
//! | conv1, conv2 | weight `QFormat` (`width u8, frac u8`), `B u32`, codebook `B × f32`, k-means MSE `f64`, bin-index dims `rank u8 + rank × u32`, index count `u64`, Huffman length table `B × u8`, bit count `u64`, coded stream bytes, bias `len u32 + len × f32` |
//! | dense | dims `2 × u32`, weights `f32`s, bias `len u32 + len × f32` |
//! | trailer | CRC-32 (IEEE) over header + payload |
//!
//! ## Integrity
//!
//! The loader verifies magic, version, exact length, and the CRC **before**
//! parsing, then re-validates every structural invariant (formats, shapes,
//! Kraft-valid Huffman tables, bias/dense dimensions against the declared
//! architecture) with bounds-checked reads.  A corrupted or truncated file
//! is always a typed error, never a panic — the property suite
//! (`tests/model_store_roundtrip.rs`) flips and truncates bytes to pin
//! this down.

use crate::cnn::network::{DigitsCnn, EncodedCnn};
use crate::quant::codebook::{Codebook, EncodedWeights};
use crate::quant::fixed::QFormat;
use crate::quant::huffman::{self, BitStream, HuffmanCode};
use crate::tensor::Tensor;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// File magic: the first four bytes of every artifact.
pub const MAGIC: [u8; 4] = *b"PASM";
/// Current format version.
pub const FORMAT_VERSION: u16 = 1;
/// Fixed header size: magic + version + flags + payload length.
const HEADER_LEN: usize = 4 + 2 + 2 + 8;
/// Largest accepted value for any architecture dimension.
const MAX_ARCH_DIM: u64 = 4096;
/// Largest accepted codebook (`u16` bin indices).
const MAX_BINS: usize = 1 << 16;

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected) — no external deps in the offline build
// ---------------------------------------------------------------------------

fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

// ---------------------------------------------------------------------------
// Little-endian writer / bounds-checked reader
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("artifact: offset overflow")?;
        ensure!(
            end <= self.buf.len(),
            "artifact truncated: wanted {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n.checked_mul(4).context("artifact: f32 run overflow")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }

    fn finish(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "artifact: {} trailing bytes after payload",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// pack
// ---------------------------------------------------------------------------

/// Serialize an [`EncodedCnn`] into `.pasm` bytes (see module docs for the
/// layout).  Errors on degenerate encodings (empty codebooks, codebooks
/// beyond the `u16` index space, Huffman pathologies) instead of writing
/// an unloadable file.
pub fn pack(enc: &EncodedCnn) -> Result<Vec<u8>> {
    let mut payload = Writer::default();
    let arch = &enc.arch;
    payload.u32(u32::try_from(arch.in_side).context("in_side")?);
    payload.u32(u32::try_from(arch.conv1_m).context("conv1_m")?);
    payload.u32(u32::try_from(arch.conv2_m).context("conv2_m")?);
    payload.u32(u32::try_from(arch.kernel).context("kernel")?);
    payload.u32(u32::try_from(arch.classes).context("classes")?);

    write_layer(&mut payload, &enc.conv1, &enc.conv1_b).context("pack conv1")?;
    write_layer(&mut payload, &enc.conv2, &enc.conv2_b).context("pack conv2")?;

    let ddims = enc.dense_w.dims();
    ensure!(ddims.len() == 2, "dense weights must be rank 2, got {:?}", ddims);
    payload.u32(u32::try_from(ddims[0]).context("dense rows")?);
    payload.u32(u32::try_from(ddims[1]).context("dense cols")?);
    for &v in enc.dense_w.data() {
        payload.f32(v);
    }
    payload.u32(u32::try_from(enc.dense_b.len()).context("dense bias len")?);
    for &v in &enc.dense_b {
        payload.f32(v);
    }

    let mut out = Writer::default();
    out.bytes(&MAGIC);
    out.u16(FORMAT_VERSION);
    out.u16(0); // flags, reserved
    out.u64(payload.buf.len() as u64);
    out.bytes(&payload.buf);
    let crc = crc32(&out.buf);
    out.u32(crc);
    Ok(out.buf)
}

fn write_layer(w: &mut Writer, enc: &EncodedWeights, bias: &[f32]) -> Result<()> {
    let bins = enc.codebook.bins();
    ensure!(bins <= MAX_BINS, "codebook of {bins} bins exceeds the u16 index space");
    w.u8(u8::try_from(enc.codebook.wq.width).context("weight width")?);
    w.u8(u8::try_from(enc.codebook.wq.frac).context("weight frac")?);
    w.u32(bins as u32);
    for &v in &enc.codebook.values {
        w.f32(v);
    }
    w.f64(enc.mse);

    let dims = enc.bin_idx.dims();
    w.u8(u8::try_from(dims.len()).context("bin_idx rank")?);
    for &d in dims {
        w.u32(u32::try_from(d).context("bin_idx dim")?);
    }
    w.u64(enc.bin_idx.len() as u64);

    // Huffman-code the index stream from its occupancy histogram; the
    // canonical length table is all a decoder needs.
    let freqs = enc.occupancy();
    let code = huffman::build(&freqs).context("huffman code for bin indices")?;
    let stream = code.encode(enc.bin_idx.data()).context("huffman-encode bin indices")?;
    for &l in &code.lengths {
        w.u8(l);
    }
    w.u64(stream.len_bits() as u64);
    w.bytes(stream.as_bytes());

    w.u32(u32::try_from(bias.len()).context("bias len")?);
    for &v in bias {
        w.f32(v);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// load
// ---------------------------------------------------------------------------

/// Deserialize `.pasm` bytes back into an [`EncodedCnn`].
///
/// Verifies magic, version, exact length, and CRC before parsing; every
/// subsequent read is bounds-checked and every structural invariant
/// re-validated, so corrupted or truncated input is always an error and
/// never a panic.
pub fn load(bytes: &[u8]) -> Result<EncodedCnn> {
    ensure!(
        bytes.len() >= HEADER_LEN + 4,
        "artifact truncated: {} bytes is smaller than the fixed header",
        bytes.len()
    );
    ensure!(bytes[..4] == MAGIC, "not a .pasm artifact (bad magic)");
    let body = &bytes[..bytes.len() - 4];
    let tail = &bytes[bytes.len() - 4..];
    let stored_crc = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
    ensure!(
        crc32(body) == stored_crc,
        "artifact checksum mismatch (corrupted or torn write)"
    );

    let mut header = Reader::new(&bytes[4..HEADER_LEN]);
    let version = header.u16()?;
    ensure!(
        version == FORMAT_VERSION,
        "unsupported .pasm format version {version} (this build reads {FORMAT_VERSION})"
    );
    let _flags = header.u16()?;
    let payload_len = header.u64()?;
    let want_total = (HEADER_LEN as u64)
        .checked_add(payload_len)
        .and_then(|n| n.checked_add(4))
        .context("artifact: declared payload length overflows")?;
    ensure!(
        want_total == bytes.len() as u64,
        "artifact length {} does not match declared payload ({} expected)",
        bytes.len(),
        want_total
    );

    let mut r = Reader::new(&bytes[HEADER_LEN..bytes.len() - 4]);
    let arch = read_arch(&mut r)?;
    let s1 = arch.conv1_shape();
    let s2 = arch.conv2_shape();
    let (conv1, conv1_b) =
        read_layer(&mut r, s1.weight_shape().dims(), arch.conv1_m).context("load conv1")?;
    let (conv2, conv2_b) =
        read_layer(&mut r, s2.weight_shape().dims(), arch.conv2_m).context("load conv2")?;

    let drows = r.u32()? as usize;
    let dcols = r.u32()? as usize;
    ensure!(
        drows == arch.feature_dim() && dcols == arch.classes,
        "dense dims [{drows}, {dcols}] do not match architecture [{}, {}]",
        arch.feature_dim(),
        arch.classes
    );
    let dense_len = drows.checked_mul(dcols).context("dense size overflow")?;
    let dense = r.f32_vec(dense_len).context("dense weights")?;
    let dense_w = Tensor::from_vec(&[drows, dcols], dense);
    let dblen = r.u32()? as usize;
    ensure!(dblen == arch.classes, "dense bias length {dblen} != classes {}", arch.classes);
    let dense_b = r.f32_vec(dblen).context("dense bias")?;
    r.finish()?;

    Ok(EncodedCnn { arch, conv1, conv1_b, conv2, conv2_b, dense_w, dense_b })
}

fn read_arch(r: &mut Reader) -> Result<DigitsCnn> {
    let in_side = r.u32()? as u64;
    let conv1_m = r.u32()? as u64;
    let conv2_m = r.u32()? as u64;
    let kernel = r.u32()? as u64;
    let classes = r.u32()? as u64;
    for (name, v) in [
        ("in_side", in_side),
        ("conv1_m", conv1_m),
        ("conv2_m", conv2_m),
        ("kernel", kernel),
        ("classes", classes),
    ] {
        ensure!(
            (1..=MAX_ARCH_DIM).contains(&v),
            "architecture field {name} = {v} outside [1, {MAX_ARCH_DIM}]"
        );
    }
    ensure!(kernel <= in_side, "kernel {kernel} larger than input side {in_side}");
    let conv1_out = in_side - kernel + 1;
    ensure!(conv1_out >= 2, "conv1 output side {conv1_out} leaves nothing to pool");
    let pooled = conv1_out / 2;
    ensure!(
        pooled >= kernel,
        "pooled side {pooled} smaller than kernel {kernel} (conv2 is empty)"
    );
    Ok(DigitsCnn {
        in_side: in_side as usize,
        conv1_m: conv1_m as usize,
        conv2_m: conv2_m as usize,
        kernel: kernel as usize,
        classes: classes as usize,
    })
}

fn read_layer(
    r: &mut Reader,
    want_dims: &[usize],
    kernels: usize,
) -> Result<(EncodedWeights, Vec<f32>)> {
    let width = r.u8()? as u32;
    let frac = r.u8()? as u32;
    ensure!(
        (2..=32).contains(&width) && frac < width,
        "invalid weight format W{width}.{frac}"
    );
    let wq = QFormat { width, frac };

    let bins = r.u32()? as usize;
    ensure!((1..=MAX_BINS).contains(&bins), "codebook of {bins} bins outside [1, {MAX_BINS}]");
    let values = r.f32_vec(bins).context("codebook values")?;
    let mse = r.f64()?;

    let rank = r.u8()? as usize;
    ensure!(rank == want_dims.len(), "bin_idx rank {rank} != {}", want_dims.len());
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        dims.push(r.u32()? as usize);
    }
    ensure!(
        dims == want_dims,
        "bin_idx dims {dims:?} do not match architecture {want_dims:?}"
    );
    let count = usize::try_from(r.u64()?).context("index count overflows usize")?;
    let product = dims
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .context("bin_idx volume overflow")?;
    ensure!(count == product, "index count {count} != bin_idx volume {product}");

    let lengths = r.take(bins)?.to_vec();
    let code = HuffmanCode::from_lengths(&lengths).context("huffman length table")?;
    let bit_len = usize::try_from(r.u64()?).context("bit length overflows usize")?;
    let stream_bytes = r.take(bit_len.div_ceil(8))?;
    let stream = BitStream::from_bytes(stream_bytes.to_vec(), bit_len)
        .context("huffman stream framing")?;
    let symbols = code.decode(&stream, count).context("huffman-decode bin indices")?;
    let bin_idx = Tensor::from_vec(&dims, symbols);

    let blen = r.u32()? as usize;
    ensure!(blen == kernels, "bias length {blen} != kernels {kernels}");
    let bias = r.f32_vec(blen).context("bias")?;

    Ok((EncodedWeights { codebook: Codebook::new(values, wq), bin_idx, mse }, bias))
}

// ---------------------------------------------------------------------------
// file helpers + compression accounting
// ---------------------------------------------------------------------------

/// Pack `enc` and write it to `path` atomically (temp file + rename, so a
/// polling registry watcher never observes a torn artifact).  Returns the
/// artifact size in bytes.
pub fn save_file(path: &Path, enc: &EncodedCnn) -> Result<u64> {
    let bytes = pack(enc)?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create artifact dir {}", parent.display()))?;
        }
    }
    let tmp = path.with_extension("pasm.tmp");
    std::fs::write(&tmp, &bytes).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} into place", tmp.display()))?;
    Ok(bytes.len() as u64)
}

/// Read and parse a `.pasm` artifact from disk.
pub fn load_file(path: &Path) -> Result<EncodedCnn> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read artifact {}", path.display()))?;
    load(&bytes).with_context(|| format!("parse artifact {}", path.display()))
}

/// Bytes the same model would occupy as raw dense f32 parameters (every
/// conv weight materialized, plus biases and the dense head) — the
/// denominator of the paper's compression-ratio headline.
pub fn raw_dense_bytes(enc: &EncodedCnn) -> u64 {
    let params = enc.conv1.bin_idx.len()
        + enc.conv1_b.len()
        + enc.conv2.bin_idx.len()
        + enc.conv2_b.len()
        + enc.dense_w.len()
        + enc.dense_b.len();
    (params as u64) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::Rng;
    use crate::cnn::network::ConvVariant;

    fn encoded(seed: u64, bins: usize, wq: QFormat) -> EncodedCnn {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(seed);
        let params = arch.init(&mut rng);
        EncodedCnn::encode(arch, &params, bins, wq)
    }

    fn assert_bit_identical(a: &EncodedCnn, b: &EncodedCnn) {
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.conv1.codebook.values), bits(&b.conv1.codebook.values));
        assert_eq!(bits(&a.conv2.codebook.values), bits(&b.conv2.codebook.values));
        assert_eq!(a.conv1.codebook.wq, b.conv1.codebook.wq);
        assert_eq!(a.conv2.codebook.wq, b.conv2.codebook.wq);
        assert_eq!(a.conv1.bin_idx.data(), b.conv1.bin_idx.data());
        assert_eq!(a.conv2.bin_idx.data(), b.conv2.bin_idx.data());
        assert_eq!(a.conv1.mse.to_bits(), b.conv1.mse.to_bits());
        assert_eq!(bits(&a.conv1_b), bits(&b.conv1_b));
        assert_eq!(bits(&a.conv2_b), bits(&b.conv2_b));
        assert_eq!(bits(a.dense_w.data()), bits(b.dense_w.data()));
        assert_eq!(bits(&a.dense_b), bits(&b.dense_b));
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let enc = encoded(11, 16, QFormat::W16);
        let bytes = pack(&enc).unwrap();
        let back = load(&bytes).unwrap();
        assert_bit_identical(&enc, &back);
        // and the forwards agree bit for bit
        let mut rng = Rng::new(3);
        let img = crate::cnn::data::render_digit(&mut rng, 4, 0.05);
        let a = enc.forward(&img, ConvVariant::Pasm);
        let b = back.forward(&img, ConvVariant::Pasm);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn artifact_beats_raw_f32_bytes() {
        // the compression headline: huffman-coded indices + codebook is far
        // smaller than dense f32 conv weights (dense head dominates both
        // sides equally and is excluded from the claim here)
        let enc = encoded(12, 16, QFormat::W32);
        let bytes = pack(&enc).unwrap();
        assert!(
            (bytes.len() as u64) < raw_dense_bytes(&enc),
            "{} artifact vs {} raw",
            bytes.len(),
            raw_dense_bytes(&enc)
        );
    }

    #[test]
    fn every_single_byte_corruption_is_detected() {
        let enc = encoded(13, 8, QFormat::W16);
        let bytes = pack(&enc).unwrap();
        // flip one bit in every 37th byte (cheap but thorough coverage of
        // header, codebook, stream, and trailer regions)
        for pos in (0..bytes.len()).step_by(37) {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            assert!(load(&bad).is_err(), "corruption at byte {pos} went undetected");
        }
    }

    #[test]
    fn truncations_error_cleanly() {
        let enc = encoded(14, 4, QFormat::W8);
        let bytes = pack(&enc).unwrap();
        for keep in [0, 1, 3, 4, 15, 16, 17, bytes.len() / 2, bytes.len() - 1] {
            assert!(load(&bytes[..keep]).is_err(), "truncation to {keep} bytes accepted");
        }
    }

    #[test]
    fn wrong_magic_and_version_rejected() {
        let enc = encoded(15, 4, QFormat::W16);
        let mut bytes = pack(&enc).unwrap();
        let mut wrong_magic = bytes.clone();
        wrong_magic[0] = b'X';
        assert!(load(&wrong_magic).is_err());
        // bump version and re-seal the CRC so only the version check fires
        bytes[4] = 0xFF;
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]).to_le_bytes();
        bytes[body_len..].copy_from_slice(&crc);
        let err = format!("{:#}", load(&bytes).unwrap_err());
        assert!(err.contains("version"), "unexpected error: {err}");
    }

    #[test]
    fn save_and_load_file() {
        let dir = std::env::temp_dir().join(format!("pasm_fmt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("digits.pasm");
        let enc = encoded(16, 16, QFormat::W32);
        let n = save_file(&path, &enc).unwrap();
        assert_eq!(n, std::fs::metadata(&path).unwrap().len());
        let back = load_file(&path).unwrap();
        assert_bit_identical(&enc, &back);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crc32_reference_vector() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
