//! Multi-model registry: many named model variants, atomically
//! hot-swappable, each lazily compiled to a [`CompiledCnn`] plan.
//!
//! The registry holds an immutable **snapshot** (`name → ModelEntry`)
//! behind a mutex that is only ever taken to *clone or swap an `Arc`* —
//! every swap builds a fresh map and publishes it with a single pointer
//! store, so readers never observe a half-updated registry and executing
//! batches keep the old snapshot alive through their own `Arc`s.  The
//! steady-state read path is **lock-free**: a monotonically increasing
//! [`ModelRegistry::generation`] counter (one atomic load) tells the
//! serving engine whether its cached [`ModelEntry`] handles are still
//! current; only an actual change forces a re-resolve through the lock.
//!
//! [`ModelRegistry::sync_dir`] reconciles the registry against a models
//! directory of `.pasm` artifacts (new file → added, changed mtime/len →
//! reloaded + generation bump, file gone → removed); a parse failure —
//! e.g. a torn half-copied artifact — keeps the previous version serving
//! and reports the error instead of dropping the model.
//! [`ModelRegistry::watch`] runs that reconcile on a poll interval from a
//! background thread, which is how a new artifact dropped into the models
//! dir goes live with zero coordinator restarts.

use crate::cnn::network::EncodedCnn;
use crate::cnn::plan::{CompiledCnn, KernelChoice};
use crate::faults::{FaultPlan, FaultSite};
use crate::model_store::format;
use crate::quant::fixed::QFormat;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, SystemTime};

/// Where a registry entry came from on disk (for change detection).
#[derive(Clone, Debug)]
pub struct SourceMeta {
    /// Artifact file path.
    pub path: PathBuf,
    /// File length at load time (bytes).
    pub len: u64,
    /// File mtime at load time, when the filesystem reports one.
    pub mtime: Option<SystemTime>,
}

/// One loaded model variant: the encoded network plus lazily compiled
/// execution plans (one per fixed-point image format x kernel strategy
/// requested).
#[derive(Debug)]
pub struct ModelEntry {
    /// Model name (the artifact's file stem, or the inserted name).
    pub name: String,
    /// The dictionary-encoded network this entry serves.
    pub enc: Arc<EncodedCnn>,
    /// Registry generation at which this entry was (re)loaded; engines key
    /// their per-model executables on it.
    pub generation: u64,
    /// Artifact provenance; `None` for programmatically inserted models.
    pub source: Option<SourceMeta>,
    plans: Mutex<HashMap<(QFormat, KernelChoice), Arc<CompiledCnn>>>,
}

impl ModelEntry {
    fn new(name: String, enc: EncodedCnn, generation: u64, source: Option<SourceMeta>) -> Self {
        ModelEntry {
            name,
            enc: Arc::new(enc),
            generation,
            source,
            plans: Mutex::new(HashMap::new()),
        }
    }

    /// The compiled plan for image format `iq` with the default
    /// [`KernelChoice::Auto`] strategy (see [`ModelEntry::plan_with`]).
    pub fn plan(&self, iq: QFormat) -> Result<Arc<CompiledCnn>> {
        self.plan_with(iq, KernelChoice::Auto)
    }

    /// The compiled plan for image format `iq` and kernel strategy
    /// `kernel`, built on first use and shared by every executable of this
    /// entry requesting the same combination thereafter.
    pub fn plan_with(&self, iq: QFormat, kernel: KernelChoice) -> Result<Arc<CompiledCnn>> {
        let mut plans = self.plans.lock().unwrap();
        if let Some(p) = plans.get(&(iq, kernel)) {
            return Ok(Arc::clone(p));
        }
        let compiled = CompiledCnn::compile_with(&self.enc, iq, kernel)
            .with_context(|| format!("compile plan for model '{}'", self.name))?;
        let compiled = Arc::new(compiled);
        plans.insert((iq, kernel), Arc::clone(&compiled));
        Ok(compiled)
    }

    /// Artifact size on disk, if this entry was loaded from a file.
    pub fn artifact_bytes(&self) -> Option<u64> {
        self.source.as_ref().map(|s| s.len)
    }
}

type Snapshot = BTreeMap<String, Arc<ModelEntry>>;

/// What one [`ModelRegistry::sync_dir`] reconcile changed.
#[derive(Clone, Debug, Default)]
pub struct SyncReport {
    /// Models loaded from artifacts not previously in the registry.
    pub added: Vec<String>,
    /// Models reloaded because their artifact changed.
    pub updated: Vec<String>,
    /// Models dropped because their artifact vanished.
    pub removed: Vec<String>,
    /// Artifacts that failed to load (path, error); the previous version
    /// of the model, if any, keeps serving.
    pub errors: Vec<(PathBuf, String)>,
}

impl SyncReport {
    /// Did this reconcile change the registry at all?
    pub fn changed(&self) -> bool {
        !self.added.is_empty() || !self.updated.is_empty() || !self.removed.is_empty()
    }
}

/// A concurrently readable, atomically hot-swappable set of named models.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    snapshot: Mutex<Arc<Snapshot>>,
    generation: AtomicU64,
    stop: AtomicBool,
    faults: Mutex<Option<Arc<FaultPlan>>>,
}

impl ModelRegistry {
    /// An empty registry at generation 0.
    pub fn new() -> Self {
        ModelRegistry::default()
    }

    /// Create a registry pre-loaded from every `.pasm` artifact in `dir`.
    pub fn load_dir(dir: &Path) -> Result<ModelRegistry> {
        let reg = ModelRegistry::new();
        reg.sync_dir(dir)?;
        Ok(reg)
    }

    /// Monotonic change counter: bumped on every insert, reload, or
    /// removal.  A single atomic load — the lock-free fast path engines
    /// poll per batch to decide whether their cached entries are current.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Resolve a model by name (clones the entry handle out of the
    /// current snapshot).
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.snapshot.lock().unwrap().get(name).cloned()
    }

    /// All model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.snapshot.lock().unwrap().keys().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.snapshot.lock().unwrap().len()
    }

    /// Whether no model is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The default model: alphabetically first (deterministic across
    /// restarts for a given models dir).
    pub fn default_name(&self) -> Option<String> {
        self.snapshot.lock().unwrap().keys().next().cloned()
    }

    /// Insert (or hot-swap) a model programmatically.  Returns the new
    /// registry generation.
    pub fn insert(&self, name: &str, enc: EncodedCnn) -> u64 {
        let mut guard = self.snapshot.lock().unwrap();
        let generation = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
        let mut next = (**guard).clone();
        next.insert(
            name.to_string(),
            Arc::new(ModelEntry::new(name.to_string(), enc, generation, None)),
        );
        *guard = Arc::new(next);
        generation
    }

    /// Remove a model by name; returns whether it existed.
    pub fn remove(&self, name: &str) -> bool {
        let mut guard = self.snapshot.lock().unwrap();
        if !guard.contains_key(name) {
            return false;
        }
        self.generation.fetch_add(1, Ordering::SeqCst);
        let mut next = (**guard).clone();
        next.remove(name);
        *guard = Arc::new(next);
        true
    }

    /// Load one artifact file as model `file_stem` (hot-swapping any
    /// existing model of that name).  Returns the model name.
    pub fn load_file(&self, path: &Path) -> Result<String> {
        let name = artifact_name(path)
            .with_context(|| format!("{} has no usable file stem", path.display()))?;
        let enc = self.load_artifact(path)?;
        let meta = std::fs::metadata(path)
            .with_context(|| format!("stat artifact {}", path.display()))?;
        let source = SourceMeta {
            path: path.to_path_buf(),
            len: meta.len(),
            mtime: meta.modified().ok(),
        };
        let mut guard = self.snapshot.lock().unwrap();
        let generation = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
        let mut next = (**guard).clone();
        next.insert(
            name.clone(),
            Arc::new(ModelEntry::new(name.clone(), enc, generation, Some(source))),
        );
        *guard = Arc::new(next);
        Ok(name)
    }

    /// Reconcile against the `.pasm` artifacts in `dir`: load new and
    /// changed files, drop models whose artifact vanished, keep
    /// programmatic entries untouched.  Unparseable artifacts (e.g. a
    /// half-written file the watcher raced) leave the previous version
    /// serving and are reported in [`SyncReport::errors`].
    pub fn sync_dir(&self, dir: &Path) -> Result<SyncReport> {
        let mut report = SyncReport::default();
        let mut files: BTreeMap<String, SourceMeta> = BTreeMap::new();
        let rd = std::fs::read_dir(dir)
            .with_context(|| format!("read models dir {}", dir.display()))?;
        for entry in rd {
            let entry = entry.with_context(|| format!("list models dir {}", dir.display()))?;
            let path = entry.path();
            let Some(name) = artifact_name(&path) else { continue };
            match entry.metadata() {
                Ok(m) => {
                    files.insert(
                        name,
                        SourceMeta { path, len: m.len(), mtime: m.modified().ok() },
                    );
                }
                Err(e) => report.errors.push((path, e.to_string())),
            }
        }

        let mut guard = self.snapshot.lock().unwrap();
        let current = Arc::clone(&guard);
        let mut next: Snapshot = BTreeMap::new();
        for (name, entry) in current.iter() {
            match &entry.source {
                // programmatic entries are not governed by the directory
                None => {
                    next.insert(name.clone(), Arc::clone(entry));
                }
                Some(src) if !files.contains_key(name) => {
                    if src.path.parent() == Some(dir) {
                        // this dir owned the artifact and it vanished
                        report.removed.push(name.clone());
                    } else {
                        // loaded from elsewhere; this dir does not govern it
                        next.insert(name.clone(), Arc::clone(entry));
                    }
                }
                // present in the dir scan: reconciled in the loop below
                Some(_) => {}
            }
        }
        for (name, meta) in files {
            if let Some(old) = current.get(&name) {
                if let Some(src) = &old.source {
                    if src.path == meta.path && src.len == meta.len && src.mtime == meta.mtime {
                        next.insert(name, Arc::clone(old));
                        continue;
                    }
                }
            }
            match self.load_artifact(&meta.path) {
                Ok(enc) => {
                    let generation = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
                    if current.contains_key(&name) {
                        report.updated.push(name.clone());
                    } else {
                        report.added.push(name.clone());
                    }
                    next.insert(
                        name.clone(),
                        Arc::new(ModelEntry::new(name, enc, generation, Some(meta))),
                    );
                }
                Err(e) => {
                    report.errors.push((meta.path.clone(), format!("{e:#}")));
                    if let Some(old) = current.get(&name) {
                        next.insert(name, Arc::clone(old));
                    }
                }
            }
        }
        if !report.removed.is_empty() {
            self.generation.fetch_add(1, Ordering::SeqCst);
        }
        *guard = Arc::new(next);
        Ok(report)
    }

    /// Spawn a background thread that [`ModelRegistry::sync_dir`]s every
    /// `interval`.  The thread holds only a `Weak` handle: it exits when
    /// the last `Arc<ModelRegistry>` drops (or after
    /// [`ModelRegistry::stop_watching`]), so watching never leaks the
    /// registry.  Call on an `Arc`: `registry.watch(dir, interval)?`.
    pub fn watch(self: &Arc<Self>, dir: impl Into<PathBuf>, interval: Duration) -> Result<()> {
        let weak = Arc::downgrade(self);
        let dir = dir.into();
        std::thread::Builder::new()
            .name("pasm-model-watcher".into())
            .spawn(move || loop {
                std::thread::sleep(interval);
                let Some(reg) = weak.upgrade() else { return };
                if reg.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Err(e) = reg.sync_dir(&dir) {
                    eprintln!("model watcher: {e:#}");
                }
            })
            .context("spawn model watcher thread")?;
        Ok(())
    }

    /// Ask any watcher threads to exit at their next poll tick.
    pub fn stop_watching(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Attach a deterministic fault-injection plan (see [`crate::faults`]):
    /// artifact loads roll the [`FaultSite::TornLoad`] stream and fail with
    /// a typed error when it fires, exercising the keep-previous-version
    /// path without writing garbage to disk.
    /// [`crate::coordinator::CoordinatorBuilder::fault_plan`] calls this
    /// automatically for an attached registry.
    pub fn set_fault_plan(&self, plan: Arc<FaultPlan>) {
        *self.faults.lock().unwrap_or_else(|e| e.into_inner()) = Some(plan);
    }

    /// Load an artifact through the fault plan, if one is attached: a
    /// TornLoad hit replaces the result with a typed error, feeding the
    /// same error path a half-copied artifact would.
    fn load_artifact(&self, path: &Path) -> Result<EncodedCnn> {
        let torn = self
            .faults
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .is_some_and(|p| p.should(FaultSite::TornLoad));
        if torn {
            anyhow::bail!("injected fault: torn artifact load of {}", path.display());
        }
        format::load_file(path)
    }
}

/// Model name for an artifact path: the file stem of `*.pasm` files.
fn artifact_name(path: &Path) -> Option<String> {
    if path.extension().and_then(|e| e.to_str()) != Some("pasm") {
        return None;
    }
    path.file_stem().and_then(|s| s.to_str()).map(str::to_string)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::Rng;
    use crate::cnn::network::DigitsCnn;

    fn encoded(seed: u64, bins: usize) -> EncodedCnn {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(seed);
        let params = arch.init(&mut rng);
        EncodedCnn::encode(arch, &params, bins, QFormat::W16)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pasm_reg_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn insert_get_swap_bumps_generation() {
        let reg = ModelRegistry::new();
        assert_eq!(reg.generation(), 0);
        assert!(reg.is_empty());
        let g1 = reg.insert("a", encoded(1, 4));
        assert_eq!(g1, 1);
        let first = reg.get("a").unwrap();
        assert_eq!(first.generation, 1);
        // hot-swap the same name: new entry, new generation
        let g2 = reg.insert("a", encoded(2, 8));
        assert_eq!(g2, 2);
        let second = reg.get("a").unwrap();
        assert_eq!(second.generation, 2);
        assert_eq!(second.enc.conv1.codebook.bins(), 8);
        // the old handle stays alive and unchanged for in-flight work
        assert_eq!(first.enc.conv1.codebook.bins(), 4);
        assert!(reg.get("missing").is_none());
        assert!(reg.remove("a"));
        assert!(!reg.remove("a"));
        assert_eq!(reg.generation(), 3);
    }

    #[test]
    fn plans_are_cached_per_format() {
        let reg = ModelRegistry::new();
        reg.insert("m", encoded(3, 8));
        let entry = reg.get("m").unwrap();
        let p1 = entry.plan(QFormat::IMAGE32).unwrap();
        let p2 = entry.plan(QFormat::IMAGE32).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "same format must share one plan");
        let p3 = entry.plan(QFormat::new(16, 8)).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p3), "different formats compile separately");
        // the kernel strategy is part of the cache key: an explicit
        // override compiles its own plan, and repeats share it
        let h1 = entry.plan_with(QFormat::IMAGE32, KernelChoice::Histogram).unwrap();
        let h2 = entry.plan_with(QFormat::IMAGE32, KernelChoice::Histogram).unwrap();
        assert!(!Arc::ptr_eq(&p1, &h1), "kernel choices compile separately");
        assert!(Arc::ptr_eq(&h1, &h2), "same (format, kernel) must share one plan");
        assert!(Arc::ptr_eq(
            &entry.plan_with(QFormat::IMAGE32, KernelChoice::Auto).unwrap(),
            &p1
        ));
    }

    #[test]
    fn sync_dir_adds_updates_removes() {
        let dir = tmpdir("sync");
        let reg = ModelRegistry::new();
        reg.insert("programmatic", encoded(4, 4));

        format::save_file(&dir.join("a.pasm"), &encoded(5, 4)).unwrap();
        format::save_file(&dir.join("b.pasm"), &encoded(6, 8)).unwrap();
        let r = reg.sync_dir(&dir).unwrap();
        assert_eq!(r.added, vec!["a".to_string(), "b".to_string()]);
        assert_eq!(reg.names(), vec!["a", "b", "programmatic"]);
        assert_eq!(reg.default_name().as_deref(), Some("a"));

        // unchanged files are not reloaded
        let before = reg.generation();
        let r = reg.sync_dir(&dir).unwrap();
        assert!(!r.changed(), "{r:?}");
        assert_eq!(reg.generation(), before);

        // overwrite one artifact -> update + generation bump
        format::save_file(&dir.join("a.pasm"), &encoded(7, 16)).unwrap();
        let r = reg.sync_dir(&dir).unwrap();
        assert_eq!(r.updated, vec!["a".to_string()]);
        assert!(reg.generation() > before);
        assert_eq!(reg.get("a").unwrap().enc.conv1.codebook.bins(), 16);

        // delete one -> removed; programmatic entry survives
        std::fs::remove_file(dir.join("b.pasm")).unwrap();
        let r = reg.sync_dir(&dir).unwrap();
        assert_eq!(r.removed, vec!["b".to_string()]);
        assert_eq!(reg.names(), vec!["a", "programmatic"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_artifact_keeps_previous_version() {
        let dir = tmpdir("corrupt");
        let reg = ModelRegistry::new();
        format::save_file(&dir.join("m.pasm"), &encoded(8, 8)).unwrap();
        reg.sync_dir(&dir).unwrap();
        let old = reg.get("m").unwrap();

        std::fs::write(dir.join("m.pasm"), b"garbage, not an artifact").unwrap();
        let r = reg.sync_dir(&dir).unwrap();
        assert_eq!(r.errors.len(), 1, "{r:?}");
        let kept = reg.get("m").expect("previous version must keep serving");
        assert!(Arc::ptr_eq(&old, &kept));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_torn_loads_keep_the_previous_version() {
        let dir = tmpdir("torn");
        let reg = ModelRegistry::new();
        format::save_file(&dir.join("m.pasm"), &encoded(10, 8)).unwrap();
        reg.sync_dir(&dir).unwrap();
        let old = reg.get("m").unwrap();

        reg.set_fault_plan(Arc::new(FaultPlan::seeded(5).with(FaultSite::TornLoad, 1.0)));
        // the rewritten artifact is perfectly valid on disk — only the
        // injected tear fails it, driving the keep-previous-version path
        format::save_file(&dir.join("m.pasm"), &encoded(11, 16)).unwrap();
        let r = reg.sync_dir(&dir).unwrap();
        assert_eq!(r.errors.len(), 1, "{r:?}");
        assert!(r.errors[0].1.contains("injected fault"), "{r:?}");
        let kept = reg.get("m").expect("previous version must keep serving");
        assert!(Arc::ptr_eq(&old, &kept));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn watcher_picks_up_new_artifacts() {
        let dir = tmpdir("watch");
        let reg = Arc::new(ModelRegistry::new());
        reg.watch(&dir, Duration::from_millis(10)).unwrap();
        format::save_file(&dir.join("late.pasm"), &encoded(9, 4)).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while reg.get("late").is_none() {
            assert!(std::time::Instant::now() < deadline, "watcher never loaded the artifact");
            std::thread::sleep(Duration::from_millis(5));
        }
        reg.stop_watching();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
