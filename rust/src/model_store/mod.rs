//! Layer-2.5 model artifact store: durable compressed model artifacts and
//! the multi-model registry the coordinator serves from.
//!
//! The paper's premise (§2.1) is that weight-shared models *live
//! compressed*: pruning → K-means weight sharing → Huffman coding of the
//! bin indices is what makes a decoder-table accelerator viable at all.
//! This module makes that the system's storage story:
//!
//! * [`format`] — the `.pasm` binary artifact: versioned header, per-layer
//!   codebooks + Huffman-coded bin-index streams (consuming
//!   [`crate::quant::huffman`]), fixed-point metadata, and CRC-32
//!   integrity.  `pack` → `load` round-trips an
//!   [`crate::cnn::network::EncodedCnn`] bit-exactly; corrupt or truncated
//!   files are typed errors, never panics.
//! * [`registry`] — [`ModelRegistry`]: many named model variants
//!   (different bin counts, weight widths, even architectures) held
//!   concurrently behind an atomically swapped snapshot with a lock-free
//!   generation fast path; entries lazily compile to
//!   [`crate::cnn::plan::CompiledCnn`] plans on first use; a poll-based
//!   directory watcher hot-swaps artifacts dropped into the models dir
//!   with zero downtime.
//!
//! The serving stack threads model identity end to end: requests carry a
//! model id, the coordinator batches per model, and
//! [`crate::coordinator::Engine`] keys its per-model executables on the
//! registry generation so a swap invalidates exactly the stale state.

pub mod format;
pub mod registry;

pub use format::{load, load_file, pack, raw_dense_bytes, save_file};
pub use registry::{ModelEntry, ModelRegistry, SourceMeta, SyncReport};
