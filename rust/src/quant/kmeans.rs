//! Lloyd's 1-D K-means — the weight-sharing codebook construction.
//!
//! Matches the deep-compression recipe (Han et al. 2015) used by the paper:
//! cluster the layer's trained weights around `B` centroids, deterministic
//! quantile initialisation, empty clusters keep their previous centroid so
//! the codebook always has exactly `B` entries (the hardware register file
//! is fixed-size regardless of occupancy).
//!
//! Independent of (and tested against the same invariants as) the python
//! implementation in `python/compile/quantize.py`.

/// Result of a K-means run over a flat weight slice.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    /// Centroid values, exactly `bins` entries (unsorted — bin identity is
    /// positional, as in the hardware dictionary).
    pub codebook: Vec<f32>,
    /// Per-input nearest-centroid index, each `< bins`.
    pub assignments: Vec<u16>,
    /// Mean squared reconstruction error.
    pub mse: f64,
    /// Lloyd iterations actually executed.
    pub iterations: usize,
}

/// Deterministic quantile initialisation (density-aware seeding).
fn quantile_init(sorted: &[f32], bins: usize) -> Vec<f32> {
    let n = sorted.len();
    (0..bins)
        .map(|b| {
            let q = (b as f64 + 0.5) / bins as f64;
            let pos = q * (n - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            let t = pos - lo as f64;
            (sorted[lo] as f64 * (1.0 - t) + sorted[hi] as f64 * t) as f32
        })
        .collect()
}

/// Lloyd's K-means on a flat slice. `iters` is an upper bound; the loop
/// exits early on convergence (no assignment changes).
pub fn kmeans_1d(data: &[f32], bins: usize, iters: usize) -> KmeansResult {
    assert!(bins >= 1, "bins must be >= 1");
    assert!(!data.is_empty(), "kmeans over empty data");
    assert!(bins <= u16::MAX as usize + 1, "bins must fit u16 indices");
    assert!(data.iter().all(|x| x.is_finite()), "non-finite weight");

    let mut sorted: Vec<f32> = data.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut centroids = quantile_init(&sorted, bins);

    let mut assign = vec![0u16; data.len()];
    let mut sums = vec![0f64; bins];
    let mut counts = vec![0usize; bins];
    let mut executed = 0;

    for _ in 0..iters.max(1) {
        executed += 1;
        let mut changed = false;
        sums.iter_mut().for_each(|s| *s = 0.0);
        counts.iter_mut().for_each(|c| *c = 0);

        for (i, &x) in data.iter().enumerate() {
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for (b, &c) in centroids.iter().enumerate() {
                let d = (x - c).abs();
                if d < best_d {
                    best_d = d;
                    best = b;
                }
            }
            if assign[i] != best as u16 {
                assign[i] = best as u16;
                changed = true;
            }
            sums[best] += x as f64;
            counts[best] += 1;
        }

        for b in 0..bins {
            if counts[b] > 0 {
                centroids[b] = (sums[b] / counts[b] as f64) as f32;
            } // empty cluster keeps previous centroid
        }

        if !changed && executed > 1 {
            break;
        }
    }

    let mse = data
        .iter()
        .zip(&assign)
        .map(|(&x, &a)| {
            let e = (x - centroids[a as usize]) as f64;
            e * e
        })
        .sum::<f64>()
        / data.len() as f64;

    KmeansResult { codebook: centroids, assignments: assign, mse, iterations: executed }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(seed: &mut u64) -> f32 {
        // deterministic pseudo-random in [-1, 1)
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (((*seed >> 33) as f64 / (1u64 << 31) as f64) - 1.0) as f32
    }

    #[test]
    fn recovers_separated_clusters() {
        let centers = [-3.0f32, -1.0, 1.0, 3.0];
        let mut seed = 7u64;
        let data: Vec<f32> = (0..400)
            .map(|i| centers[i % 4] + lcg(&mut seed) * 1e-3)
            .collect();
        let r = kmeans_1d(&data, 4, 50);
        let mut cb = r.codebook.clone();
        cb.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (got, want) in cb.iter().zip(centers.iter()) {
            assert!((got - want).abs() < 1e-2, "{got} vs {want}");
        }
        assert!(r.mse < 1e-5);
    }

    #[test]
    fn single_bin_is_mean() {
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let r = kmeans_1d(&data, 1, 10);
        assert!((r.codebook[0] - 2.5).abs() < 1e-6);
        assert!(r.assignments.iter().all(|&a| a == 0));
    }

    #[test]
    fn assignments_are_nearest() {
        let mut seed = 3u64;
        let data: Vec<f32> = (0..200).map(|_| lcg(&mut seed) * 2.0).collect();
        let r = kmeans_1d(&data, 8, 30);
        for (&x, &a) in data.iter().zip(&r.assignments) {
            let d_assigned = (x - r.codebook[a as usize]).abs();
            for &c in &r.codebook {
                assert!(d_assigned <= (x - c).abs() + 1e-6);
            }
        }
    }

    #[test]
    fn mse_nonincreasing_in_bins() {
        let mut seed = 11u64;
        let data: Vec<f32> = (0..300).map(|_| lcg(&mut seed)).collect();
        let mut prev = f64::INFINITY;
        for bins in [2usize, 4, 8, 16, 32] {
            let r = kmeans_1d(&data, bins, 40);
            assert!(r.mse <= prev * 1.05, "bins={bins}: {} > {prev}", r.mse);
            prev = r.mse;
        }
    }

    #[test]
    fn more_bins_than_points() {
        let data = [1.0f32, 2.0];
        let r = kmeans_1d(&data, 8, 10);
        assert_eq!(r.codebook.len(), 8);
        assert!(r.assignments.iter().all(|&a| (a as usize) < 8));
        // every point reconstructs exactly
        assert!(r.mse < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_data_panics() {
        kmeans_1d(&[], 4, 10);
    }
}
