//! Magnitude pruning (step 1 of Han et al.'s deep compression).
//!
//! The paper's weight-sharing assumes the deep-compression pipeline:
//! prune small weights, retrain, *then* cluster the survivors.  Pruning
//! also skews the bin histogram (a dedicated zero bin dominates), which is
//! what makes the Huffman stage effective.  `examples/deep_compression.rs`
//! runs the whole chain on the digits CNN.

use crate::tensor::Tensor;

/// A pruning mask: `true` = weight survives.
#[derive(Clone, Debug)]
pub struct PruneMask {
    /// Per-weight survival flags, same shape as the weight tensor.
    pub mask: Tensor<bool>,
    /// Number of surviving weights.
    pub kept: usize,
}

impl PruneMask {
    /// Fraction of weights kept.
    pub fn density(&self) -> f64 {
        self.kept as f64 / self.mask.len() as f64
    }

    /// Apply in place: zero out pruned weights.
    pub fn apply(&self, weights: &mut Tensor<f32>) {
        assert_eq!(weights.dims(), self.mask.dims());
        for (w, &keep) in weights.data_mut().iter_mut().zip(self.mask.data()) {
            if !keep {
                *w = 0.0;
            }
        }
    }
}

/// Prune the smallest-magnitude `fraction` of weights.
pub fn magnitude_prune(weights: &Tensor<f32>, fraction: f64) -> PruneMask {
    assert!((0.0..1.0).contains(&fraction), "fraction in [0,1)");
    let n = weights.len();
    let drop = (n as f64 * fraction).floor() as usize;
    let mut mags: Vec<(f32, usize)> = weights
        .data()
        .iter()
        .enumerate()
        .map(|(i, &w)| (w.abs(), i))
        .collect();
    mags.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut keep = vec![true; n];
    for &(_, i) in mags.iter().take(drop) {
        keep[i] = false;
    }
    PruneMask { mask: Tensor::from_vec(weights.dims(), keep), kept: n - drop }
}

/// Index-stream statistics after pruning + weight sharing: pruned weights
/// all land in the zero bin, skewing the histogram (better Huffman codes)
/// and silencing their PAS accumulations (activity drops).
pub fn pruned_bin_histogram(bin_idx: &[u16], mask: &[bool], bins: usize, zero_bin: u16) -> Vec<usize> {
    assert_eq!(bin_idx.len(), mask.len());
    let mut h = vec![0usize; bins];
    for (&b, &keep) in bin_idx.iter().zip(mask) {
        let eff = if keep { b } else { zero_bin };
        h[eff as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tensor<f32> {
        Tensor::from_vec(&[2, 4], vec![0.1, -2.0, 0.05, 1.5, -0.2, 0.01, 3.0, -0.5])
    }

    #[test]
    fn prunes_smallest_magnitudes() {
        let w = toy();
        let m = magnitude_prune(&w, 0.5);
        assert_eq!(m.kept, 4);
        // survivors are the 4 largest magnitudes: -2.0, 1.5, 3.0, -0.5
        let mut pruned = w.clone();
        m.apply(&mut pruned);
        let alive: Vec<f32> = pruned.data().iter().copied().filter(|&x| x != 0.0).collect();
        assert_eq!(alive.len(), 4);
        for v in [-2.0f32, 1.5, 3.0, -0.5] {
            assert!(alive.contains(&v), "{v} should survive");
        }
    }

    #[test]
    fn zero_fraction_keeps_all() {
        let w = toy();
        let m = magnitude_prune(&w, 0.0);
        assert_eq!(m.kept, 8);
        assert!((m.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn density_matches_fraction() {
        let w = Tensor::from_fn(&[100], |i| (i as f32 - 50.0) / 10.0);
        let m = magnitude_prune(&w, 0.9);
        assert!((m.density() - 0.1).abs() < 0.02);
    }

    #[test]
    fn histogram_routes_pruned_to_zero_bin() {
        let bin_idx = vec![0u16, 1, 2, 3];
        let mask = vec![true, false, true, false];
        let h = pruned_bin_histogram(&bin_idx, &mask, 4, 2);
        assert_eq!(h, vec![1, 0, 3, 0]); // bins 1 and 3 rerouted to 2
        assert_eq!(h.iter().sum::<usize>(), 4);
    }

    #[test]
    #[should_panic]
    fn full_prune_rejected() {
        magnitude_prune(&toy(), 1.0);
    }
}
