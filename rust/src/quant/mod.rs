//! Fixed-point arithmetic and weight sharing (dictionary encoding).
//!
//! The paper's accelerators compute in integer/fixed point (§4: 32-bit
//! images, 8/16/32-bit weights), with weights K-means-clustered into
//! `B ∈ [4, 256]` bins (Han et al.'s deep compression).  This module
//! provides:
//!
//! * [`QFormat`] / [`fixed`] — signed fixed-point encode/decode/multiply
//!   with explicit bit widths, matching the datapath widths the gate model
//!   costs out.
//! * [`kmeans`] — Lloyd's scalar K-means, the codebook construction.
//! * [`codebook`] — dictionary encoding of a weight tensor into
//!   `(codebook[B], bin_idx)` and its fixed-point form used by the
//!   simulator.

pub mod codebook;
pub mod fixed;
pub mod huffman;
pub mod kmeans;
pub mod prune;

pub use codebook::{encode_weights, Codebook, EncodedWeights};
pub use fixed::QFormat;
pub use huffman::{HuffmanCode, HuffmanError};
pub use kmeans::{kmeans_1d, KmeansResult};
