//! Canonical Huffman coding of the dictionary-index stream.
//!
//! The paper's §2.1 compression chain (Han et al.'s deep compression) is
//! pruning → K-means weight sharing → **Huffman coding** of the bin
//! indices; the combination reaches 35× (AlexNet) / 49× (VGG-16).  Weight
//! sharing alone gives `W / WCI`; Huffman exploits the skew of the bin
//! histogram (K-means on a bell-shaped weight distribution leaves the
//! central bins far more populated).
//!
//! Canonical codes: only the code lengths are stored (B entries), the
//! codebook is reconstructed deterministically — the form a hardware
//! decoder table would use.

/// A canonical Huffman code over `B` symbols.
#[derive(Clone, Debug)]
pub struct HuffmanCode {
    /// Code length in bits per symbol (0 = symbol never occurs).
    pub lengths: Vec<u8>,
    /// Canonical codewords (valid where `lengths > 0`).
    codes: Vec<u32>,
}

/// Build a Huffman code from symbol frequencies (length-limited to 32).
pub fn build(freqs: &[usize]) -> HuffmanCode {
    let n = freqs.len();
    assert!(n >= 1, "empty alphabet");
    let alive: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    let mut lengths = vec![0u8; n];

    match alive.len() {
        0 => {}
        1 => lengths[alive[0]] = 1, // degenerate: one symbol still needs a bit
        _ => {
            // package-merge-free simple heap Huffman (depths stay << 32 for
            // realistic bin histograms)
            #[derive(PartialEq, Eq)]
            struct Node {
                weight: usize,
                id: usize,
            }
            impl Ord for Node {
                fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                    // min-heap via reverse; tie-break on id for determinism
                    o.weight.cmp(&self.weight).then(o.id.cmp(&self.id))
                }
            }
            impl PartialOrd for Node {
                fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                    Some(self.cmp(o))
                }
            }
            let mut heap = std::collections::BinaryHeap::new();
            // tree arena: leaves 0..n, internal nodes appended
            let mut parent: Vec<usize> = vec![usize::MAX; n];
            for &i in &alive {
                heap.push(Node { weight: freqs[i], id: i });
            }
            let mut next_id = n;
            while heap.len() > 1 {
                let a = heap.pop().unwrap();
                let b = heap.pop().unwrap();
                parent.push(usize::MAX);
                let p = next_id;
                next_id += 1;
                if a.id < parent.len() {
                    parent[a.id] = p;
                }
                if b.id < parent.len() {
                    parent[b.id] = p;
                }
                // ensure capacity for ids beyond current len
                while parent.len() <= a.id.max(b.id) {
                    parent.push(usize::MAX);
                }
                parent[a.id] = p;
                parent[b.id] = p;
                heap.push(Node { weight: a.weight + b.weight, id: p });
            }
            let root = heap.pop().unwrap().id;
            for &i in &alive {
                let mut d = 0u8;
                let mut cur = i;
                while cur != root {
                    cur = parent[cur];
                    d += 1;
                }
                lengths[i] = d.max(1);
            }
        }
    }

    HuffmanCode { codes: canonical_codes(&lengths), lengths }
}

/// Assign canonical codewords from lengths (shorter codes first, then
/// symbol order).
fn canonical_codes(lengths: &[u8]) -> Vec<u32> {
    let mut order: Vec<usize> = (0..lengths.len()).filter(|&i| lengths[i] > 0).collect();
    order.sort_by_key(|&i| (lengths[i], i));
    let mut codes = vec![0u32; lengths.len()];
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &i in &order {
        code <<= lengths[i] - prev_len;
        codes[i] = code;
        code += 1;
        prev_len = lengths[i];
    }
    codes
}

/// A packed bitstream.
#[derive(Clone, Debug, Default)]
pub struct BitStream {
    bytes: Vec<u8>,
    bits: usize,
}

impl BitStream {
    pub fn len_bits(&self) -> usize {
        self.bits
    }

    fn push(&mut self, code: u32, len: u8) {
        for i in (0..len).rev() {
            let bit = (code >> i) & 1;
            if self.bits % 8 == 0 {
                self.bytes.push(0);
            }
            if bit == 1 {
                *self.bytes.last_mut().unwrap() |= 1 << (7 - self.bits % 8);
            }
            self.bits += 1;
        }
    }

    fn get(&self, pos: usize) -> u32 {
        ((self.bytes[pos / 8] >> (7 - pos % 8)) & 1) as u32
    }
}

impl HuffmanCode {
    /// Mean code length under the given frequency distribution (bits).
    pub fn mean_bits(&self, freqs: &[usize]) -> f64 {
        let total: usize = freqs.iter().sum();
        if total == 0 {
            return 0.0;
        }
        freqs
            .iter()
            .zip(&self.lengths)
            .map(|(&f, &l)| f as f64 * l as f64)
            .sum::<f64>()
            / total as f64
    }

    /// Encode a symbol stream.
    pub fn encode(&self, symbols: &[u16]) -> BitStream {
        let mut bs = BitStream::default();
        for &s in symbols {
            let s = s as usize;
            assert!(self.lengths[s] > 0, "symbol {s} has no code (freq 0)");
            bs.push(self.codes[s], self.lengths[s]);
        }
        bs
    }

    /// Decode `count` symbols from a bitstream.
    pub fn decode(&self, bs: &BitStream, count: usize) -> Vec<u16> {
        // build (length, code) -> symbol lookup
        let mut table: std::collections::HashMap<(u8, u32), u16> = Default::default();
        for (i, (&l, &c)) in self.lengths.iter().zip(&self.codes).enumerate() {
            if l > 0 {
                table.insert((l, c), i as u16);
            }
        }
        let mut out = Vec::with_capacity(count);
        let mut pos = 0usize;
        for _ in 0..count {
            let mut code = 0u32;
            let mut len = 0u8;
            loop {
                assert!(pos < bs.len_bits(), "bitstream exhausted");
                code = (code << 1) | bs.get(pos);
                pos += 1;
                len += 1;
                if let Some(&sym) = table.get(&(len, code)) {
                    out.push(sym);
                    break;
                }
                assert!(len < 33, "code too long / corrupt stream");
            }
        }
        out
    }
}

/// Shannon entropy of a frequency histogram (bits/symbol) — the lower
/// bound Huffman approaches within 1 bit.
pub fn entropy_bits(freqs: &[usize]) -> f64 {
    let total: usize = freqs.iter().sum();
    if total == 0 {
        return 0.0;
    }
    freqs
        .iter()
        .filter(|&&f| f > 0)
        .map(|&f| {
            let p = f as f64 / total as f64;
            -p * p.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_uniform() {
        let freqs = vec![10usize; 16];
        let code = build(&freqs);
        let symbols: Vec<u16> = (0..160).map(|i| (i % 16) as u16).collect();
        let bs = code.encode(&symbols);
        assert_eq!(code.decode(&bs, symbols.len()), symbols);
        // uniform over 16 symbols -> exactly 4 bits each
        assert!((code.mean_bits(&freqs) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn skewed_beats_fixed_width() {
        // heavily skewed histogram (like K-means bins over gaussian weights)
        let freqs = vec![1000usize, 500, 250, 120, 60, 30, 20, 10, 4, 2, 1, 1, 1, 1, 1, 1];
        let code = build(&freqs);
        let mean = code.mean_bits(&freqs);
        assert!(mean < 4.0, "mean {mean} should beat the 4-bit fixed code");
        // and within 1 bit of entropy
        let h = entropy_bits(&freqs);
        assert!(mean < h + 1.0, "mean {mean} vs entropy {h}");
        assert!(mean >= h - 1e-9);
    }

    #[test]
    fn roundtrip_skewed_stream() {
        let freqs = vec![100usize, 50, 10, 5, 2, 1, 1, 1];
        let code = build(&freqs);
        let mut symbols = Vec::new();
        for (s, &f) in freqs.iter().enumerate() {
            symbols.extend(std::iter::repeat(s as u16).take(f));
        }
        let bs = code.encode(&symbols);
        assert_eq!(code.decode(&bs, symbols.len()), symbols);
    }

    #[test]
    fn single_symbol_alphabet() {
        let freqs = vec![0usize, 42, 0, 0];
        let code = build(&freqs);
        let symbols = vec![1u16; 42];
        let bs = code.encode(&symbols);
        assert_eq!(bs.len_bits(), 42); // 1 bit each
        assert_eq!(code.decode(&bs, 42), symbols);
    }

    #[test]
    fn kraft_inequality_holds() {
        let freqs = vec![7usize, 3, 3, 2, 1, 1, 0, 5];
        let code = build(&freqs);
        let kraft: f64 = code
            .lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        assert!(kraft <= 1.0 + 1e-12, "kraft {kraft}");
    }

    #[test]
    #[should_panic]
    fn encoding_unseen_symbol_panics() {
        let freqs = vec![5usize, 0];
        let code = build(&freqs);
        code.encode(&[1u16]);
    }

    #[test]
    fn deterministic_codes() {
        let freqs = vec![3usize, 3, 2, 2];
        let a = build(&freqs);
        let b = build(&freqs);
        assert_eq!(a.lengths, b.lengths);
        assert_eq!(a.codes, b.codes);
    }
}
