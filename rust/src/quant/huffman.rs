//! Canonical Huffman coding of the dictionary-index stream.
//!
//! The paper's §2.1 compression chain (Han et al.'s deep compression) is
//! pruning → K-means weight sharing → **Huffman coding** of the bin
//! indices; the combination reaches 35× (AlexNet) / 49× (VGG-16).  Weight
//! sharing alone gives `W / WCI`; Huffman exploits the skew of the bin
//! histogram (K-means on a bell-shaped weight distribution leaves the
//! central bins far more populated).
//!
//! Canonical codes: only the code lengths are stored (B entries), the
//! codebook is reconstructed deterministically — the form a hardware
//! decoder table would use, and the form the `.pasm` model artifact
//! ([`crate::model_store::format`]) persists on disk.  Because decoder
//! input now arrives from disk, every entry point returns a typed
//! [`HuffmanError`] instead of panicking: degenerate alphabets, corrupt
//! length tables (Kraft violations), exhausted or undecodable bitstreams
//! are all recoverable errors.

use std::fmt;

/// Typed failure modes of Huffman construction and (de)coding.
///
/// Decoder input comes from disk artifacts, so none of these may panic:
/// a corrupt file must surface as an error the caller can report.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HuffmanError {
    /// `build` was given zero symbols.
    EmptyAlphabet,
    /// `build` was given more symbols than a `u16` index can address.
    AlphabetTooLarge { symbols: usize },
    /// Every frequency was zero — there is nothing to code.
    EmptyHistogram,
    /// A code length exceeded the 32-bit decoder limit (pathologically
    /// skewed histogram, or a corrupt on-disk length table).
    CodeTooDeep { length: u32 },
    /// The length table violates the Kraft inequality (over-subscribed
    /// code space — not a prefix code; corrupt length table).
    KraftViolation,
    /// `encode` met a symbol whose frequency was zero at build time.
    UnseenSymbol { symbol: u16 },
    /// `encode` met a symbol outside the alphabet.
    SymbolOutOfRange { symbol: u16, alphabet: usize },
    /// `decode` ran off the end of the bitstream mid-symbol.
    StreamExhausted { decoded: usize, expected: usize },
    /// `decode` consumed 32 bits without matching any codeword (corrupt
    /// stream or mismatched code).
    Undecodable { decoded: usize },
    /// A serialized bitstream's byte length does not match its bit count.
    BitLengthMismatch { bits: usize, bytes: usize },
}

impl fmt::Display for HuffmanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HuffmanError::EmptyAlphabet => write!(f, "huffman: empty alphabet"),
            HuffmanError::AlphabetTooLarge { symbols } => {
                write!(f, "huffman: {symbols} symbols exceed the u16 index space")
            }
            HuffmanError::EmptyHistogram => {
                write!(f, "huffman: all frequencies are zero")
            }
            HuffmanError::CodeTooDeep { length } => {
                write!(f, "huffman: code length {length} exceeds the 32-bit decoder limit")
            }
            HuffmanError::KraftViolation => {
                write!(f, "huffman: length table violates the Kraft inequality (corrupt)")
            }
            HuffmanError::UnseenSymbol { symbol } => {
                write!(f, "huffman: symbol {symbol} has no code (frequency was 0)")
            }
            HuffmanError::SymbolOutOfRange { symbol, alphabet } => {
                write!(f, "huffman: symbol {symbol} outside alphabet of {alphabet}")
            }
            HuffmanError::StreamExhausted { decoded, expected } => {
                write!(f, "huffman: bitstream exhausted after {decoded}/{expected} symbols")
            }
            HuffmanError::Undecodable { decoded } => {
                write!(f, "huffman: no codeword matched after symbol {decoded} (corrupt stream)")
            }
            HuffmanError::BitLengthMismatch { bits, bytes } => {
                write!(f, "huffman: bit length {bits} does not fit {bytes} bytes")
            }
        }
    }
}

impl std::error::Error for HuffmanError {}

/// A canonical Huffman code over `B` symbols.
#[derive(Clone, Debug)]
pub struct HuffmanCode {
    /// Code length in bits per symbol (0 = symbol never occurs).
    pub lengths: Vec<u8>,
    /// Canonical codewords (valid where `lengths > 0`).
    codes: Vec<u32>,
}

/// Build a Huffman code from symbol frequencies.
///
/// Typed errors on degenerate inputs: an empty alphabet, an alphabet too
/// large for `u16` symbols, an all-zero histogram, or a histogram so
/// skewed the optimal code exceeds 32 bits (the decoder table limit).
pub fn build(freqs: &[usize]) -> Result<HuffmanCode, HuffmanError> {
    let n = freqs.len();
    if n == 0 {
        return Err(HuffmanError::EmptyAlphabet);
    }
    if n > (u16::MAX as usize) + 1 {
        return Err(HuffmanError::AlphabetTooLarge { symbols: n });
    }
    let alive: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    let mut lengths = vec![0u8; n];

    match alive.len() {
        0 => return Err(HuffmanError::EmptyHistogram),
        1 => lengths[alive[0]] = 1, // degenerate: one symbol still needs a bit
        _ => {
            // simple heap Huffman; depths stay far below 32 for realistic
            // bin histograms, and deeper trees are rejected below
            #[derive(PartialEq, Eq)]
            struct Node {
                weight: usize,
                id: usize,
            }
            impl Ord for Node {
                fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                    // min-heap via reverse; tie-break on id for determinism
                    o.weight.cmp(&self.weight).then(o.id.cmp(&self.id))
                }
            }
            impl PartialOrd for Node {
                fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                    Some(self.cmp(o))
                }
            }
            let mut heap = std::collections::BinaryHeap::new();
            // tree arena: leaves 0..n, internal nodes appended
            let mut parent: Vec<usize> = vec![usize::MAX; n];
            for &i in &alive {
                heap.push(Node { weight: freqs[i], id: i });
            }
            while heap.len() > 1 {
                let a = heap.pop().unwrap();
                let b = heap.pop().unwrap();
                let p = parent.len();
                parent.push(usize::MAX);
                parent[a.id] = p;
                parent[b.id] = p;
                heap.push(Node { weight: a.weight.saturating_add(b.weight), id: p });
            }
            let root = heap.pop().unwrap().id;
            for &i in &alive {
                let mut d = 0u32;
                let mut cur = i;
                while cur != root {
                    cur = parent[cur];
                    d += 1;
                }
                if d > 32 {
                    return Err(HuffmanError::CodeTooDeep { length: d });
                }
                lengths[i] = (d as u8).max(1);
            }
        }
    }

    Ok(HuffmanCode { codes: canonical_codes(&lengths), lengths })
}

/// Assign canonical codewords from lengths (shorter codes first, then
/// symbol order).  Computed in u64 so a maximal 32-bit code (which a
/// Kraft-valid on-disk length table may legitimately declare) cannot
/// overflow the shift.
fn canonical_codes(lengths: &[u8]) -> Vec<u32> {
    let mut order: Vec<usize> = (0..lengths.len()).filter(|&i| lengths[i] > 0).collect();
    order.sort_by_key(|&i| (lengths[i], i));
    let mut codes = vec![0u32; lengths.len()];
    let mut code = 0u64;
    let mut prev_len = 0u8;
    for &i in &order {
        code <<= lengths[i] - prev_len;
        codes[i] = code as u32;
        code += 1;
        prev_len = lengths[i];
    }
    codes
}

/// A packed bitstream.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BitStream {
    bytes: Vec<u8>,
    bits: usize,
}

impl BitStream {
    /// Number of valid bits in the stream.
    pub fn len_bits(&self) -> usize {
        self.bits
    }

    /// The packed bytes (MSB-first within each byte); the final byte is
    /// zero-padded.  Together with [`BitStream::len_bits`] this is the
    /// serialized form the model artifact stores.
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Rebuild a bitstream from its serialized form; `bytes.len()` must be
    /// exactly `ceil(bits / 8)`.
    pub fn from_bytes(bytes: Vec<u8>, bits: usize) -> Result<BitStream, HuffmanError> {
        if bytes.len() != bits.div_ceil(8) {
            return Err(HuffmanError::BitLengthMismatch { bits, bytes: bytes.len() });
        }
        Ok(BitStream { bytes, bits })
    }

    fn push(&mut self, code: u32, len: u8) {
        for i in (0..len).rev() {
            let bit = (code >> i) & 1;
            if self.bits % 8 == 0 {
                self.bytes.push(0);
            }
            if bit == 1 {
                *self.bytes.last_mut().unwrap() |= 1 << (7 - self.bits % 8);
            }
            self.bits += 1;
        }
    }

    fn get(&self, pos: usize) -> u32 {
        ((self.bytes[pos / 8] >> (7 - pos % 8)) & 1) as u32
    }
}

impl HuffmanCode {
    /// Reconstruct a canonical code from its length table alone (the form
    /// a decoder loads from disk).  Rejects corrupt tables: lengths over
    /// 32 bits, or sets violating the Kraft inequality (not a prefix
    /// code).  An all-zero table is a valid *empty* code — it decodes
    /// only zero-symbol streams.
    pub fn from_lengths(lengths: &[u8]) -> Result<HuffmanCode, HuffmanError> {
        if lengths.is_empty() {
            return Err(HuffmanError::EmptyAlphabet);
        }
        if lengths.len() > (u16::MAX as usize) + 1 {
            return Err(HuffmanError::AlphabetTooLarge { symbols: lengths.len() });
        }
        // Kraft: sum of 2^-len over coded symbols must not exceed 1.
        // Computed in units of 2^-32 to stay in integers.
        let mut kraft: u64 = 0;
        for &l in lengths {
            if l > 32 {
                return Err(HuffmanError::CodeTooDeep { length: l as u32 });
            }
            if l > 0 {
                kraft += 1u64 << (32 - l as u32);
            }
        }
        if kraft > 1u64 << 32 {
            return Err(HuffmanError::KraftViolation);
        }
        Ok(HuffmanCode { codes: canonical_codes(lengths), lengths: lengths.to_vec() })
    }

    /// Mean code length under the given frequency distribution (bits).
    pub fn mean_bits(&self, freqs: &[usize]) -> f64 {
        let total: usize = freqs.iter().sum();
        if total == 0 {
            return 0.0;
        }
        freqs
            .iter()
            .zip(&self.lengths)
            .map(|(&f, &l)| f as f64 * l as f64)
            .sum::<f64>()
            / total as f64
    }

    /// Encode a symbol stream.
    pub fn encode(&self, symbols: &[u16]) -> Result<BitStream, HuffmanError> {
        let mut bs = BitStream::default();
        for &s in symbols {
            let i = s as usize;
            if i >= self.lengths.len() {
                return Err(HuffmanError::SymbolOutOfRange {
                    symbol: s,
                    alphabet: self.lengths.len(),
                });
            }
            if self.lengths[i] == 0 {
                return Err(HuffmanError::UnseenSymbol { symbol: s });
            }
            bs.push(self.codes[i], self.lengths[i]);
        }
        Ok(bs)
    }

    /// Decode `count` symbols from a bitstream.  Corrupt streams surface
    /// as [`HuffmanError::StreamExhausted`] / [`HuffmanError::Undecodable`],
    /// never as a panic.
    pub fn decode(&self, bs: &BitStream, count: usize) -> Result<Vec<u16>, HuffmanError> {
        // build (length, code) -> symbol lookup
        let mut table: std::collections::HashMap<(u8, u32), u16> = Default::default();
        for (i, (&l, &c)) in self.lengths.iter().zip(&self.codes).enumerate() {
            if l > 0 {
                table.insert((l, c), i as u16);
            }
        }
        let mut out = Vec::with_capacity(count.min(bs.len_bits().max(1)));
        let mut pos = 0usize;
        for k in 0..count {
            let mut code = 0u32;
            let mut len = 0u8;
            loop {
                if pos >= bs.len_bits() {
                    return Err(HuffmanError::StreamExhausted { decoded: k, expected: count });
                }
                code = (code << 1) | bs.get(pos);
                pos += 1;
                len += 1;
                if let Some(&sym) = table.get(&(len, code)) {
                    out.push(sym);
                    break;
                }
                if len >= 32 {
                    return Err(HuffmanError::Undecodable { decoded: k });
                }
            }
        }
        Ok(out)
    }
}

/// Shannon entropy of a frequency histogram (bits/symbol) — the lower
/// bound Huffman approaches within 1 bit.
pub fn entropy_bits(freqs: &[usize]) -> f64 {
    let total: usize = freqs.iter().sum();
    if total == 0 {
        return 0.0;
    }
    freqs
        .iter()
        .filter(|&&f| f > 0)
        .map(|&f| {
            let p = f as f64 / total as f64;
            -p * p.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_uniform() {
        let freqs = vec![10usize; 16];
        let code = build(&freqs).unwrap();
        let symbols: Vec<u16> = (0..160).map(|i| (i % 16) as u16).collect();
        let bs = code.encode(&symbols).unwrap();
        assert_eq!(code.decode(&bs, symbols.len()).unwrap(), symbols);
        // uniform over 16 symbols -> exactly 4 bits each
        assert!((code.mean_bits(&freqs) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn skewed_beats_fixed_width() {
        // heavily skewed histogram (like K-means bins over gaussian weights)
        let freqs = vec![1000usize, 500, 250, 120, 60, 30, 20, 10, 4, 2, 1, 1, 1, 1, 1, 1];
        let code = build(&freqs).unwrap();
        let mean = code.mean_bits(&freqs);
        assert!(mean < 4.0, "mean {mean} should beat the 4-bit fixed code");
        // and within 1 bit of entropy
        let h = entropy_bits(&freqs);
        assert!(mean < h + 1.0, "mean {mean} vs entropy {h}");
        assert!(mean >= h - 1e-9);
    }

    #[test]
    fn roundtrip_skewed_stream() {
        let freqs = vec![100usize, 50, 10, 5, 2, 1, 1, 1];
        let code = build(&freqs).unwrap();
        let mut symbols = Vec::new();
        for (s, &f) in freqs.iter().enumerate() {
            symbols.resize(symbols.len() + f, s as u16);
        }
        let bs = code.encode(&symbols).unwrap();
        assert_eq!(code.decode(&bs, symbols.len()).unwrap(), symbols);
    }

    #[test]
    fn single_symbol_alphabet() {
        let freqs = vec![0usize, 42, 0, 0];
        let code = build(&freqs).unwrap();
        let symbols = vec![1u16; 42];
        let bs = code.encode(&symbols).unwrap();
        assert_eq!(bs.len_bits(), 42); // 1 bit each
        assert_eq!(code.decode(&bs, 42).unwrap(), symbols);
    }

    #[test]
    fn kraft_inequality_holds() {
        let freqs = vec![7usize, 3, 3, 2, 1, 1, 0, 5];
        let code = build(&freqs).unwrap();
        let kraft: f64 = code
            .lengths
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        assert!(kraft <= 1.0 + 1e-12, "kraft {kraft}");
    }

    #[test]
    fn degenerate_inputs_are_typed_errors() {
        assert!(matches!(build(&[]), Err(HuffmanError::EmptyAlphabet)));
        assert!(matches!(build(&[0, 0, 0]), Err(HuffmanError::EmptyHistogram)));
        let huge = vec![1usize; (u16::MAX as usize) + 2];
        assert!(matches!(build(&huge), Err(HuffmanError::AlphabetTooLarge { .. })));
    }

    #[test]
    fn encoding_unseen_symbol_is_error() {
        let freqs = vec![5usize, 0];
        let code = build(&freqs).unwrap();
        assert_eq!(code.encode(&[1u16]), Err(HuffmanError::UnseenSymbol { symbol: 1 }));
        assert_eq!(
            code.encode(&[9u16]),
            Err(HuffmanError::SymbolOutOfRange { symbol: 9, alphabet: 2 })
        );
    }

    #[test]
    fn decode_corrupt_streams_error_not_panic() {
        let freqs = vec![8usize, 4, 2, 1, 1];
        let code = build(&freqs).unwrap();
        let bs = code.encode(&[0u16, 1, 2, 3, 4]).unwrap();
        // asking for more symbols than the stream holds
        assert!(matches!(
            code.decode(&bs, 100),
            Err(HuffmanError::StreamExhausted { .. })
        ));
        // a code with one deep symbol: feed it bits that never match
        let deep = HuffmanCode::from_lengths(&[1, 0, 0]).unwrap();
        let junk = BitStream::from_bytes(vec![0xFF; 8], 64).unwrap();
        assert!(matches!(
            deep.decode(&junk, 2),
            Err(HuffmanError::Undecodable { .. }) | Err(HuffmanError::StreamExhausted { .. })
        ));
    }

    #[test]
    fn from_lengths_reconstructs_canonical_codes() {
        let freqs = vec![100usize, 50, 10, 5, 2, 1, 1, 1];
        let built = build(&freqs).unwrap();
        let rebuilt = HuffmanCode::from_lengths(&built.lengths).unwrap();
        assert_eq!(built.lengths, rebuilt.lengths);
        assert_eq!(built.codes, rebuilt.codes);
        let stream: Vec<u16> = (0..8).collect();
        let bs = built.encode(&stream).unwrap();
        assert_eq!(rebuilt.decode(&bs, stream.len()).unwrap(), stream);
    }

    #[test]
    fn from_lengths_rejects_corrupt_tables() {
        // over-subscribed code space: three 1-bit codes
        assert!(matches!(
            HuffmanCode::from_lengths(&[1, 1, 1]),
            Err(HuffmanError::KraftViolation)
        ));
        assert!(matches!(
            HuffmanCode::from_lengths(&[33]),
            Err(HuffmanError::CodeTooDeep { .. })
        ));
        assert!(matches!(HuffmanCode::from_lengths(&[]), Err(HuffmanError::EmptyAlphabet)));
        // an all-zero table is a valid empty code
        let empty = HuffmanCode::from_lengths(&[0, 0]).unwrap();
        assert_eq!(empty.decode(&BitStream::default(), 0).unwrap(), Vec::<u16>::new());
    }

    #[test]
    fn bitstream_serialization_roundtrip() {
        let freqs = vec![10usize, 7, 3, 1];
        let code = build(&freqs).unwrap();
        let symbols = vec![0u16, 1, 2, 3, 0, 0, 1];
        let bs = code.encode(&symbols).unwrap();
        let rt = BitStream::from_bytes(bs.as_bytes().to_vec(), bs.len_bits()).unwrap();
        assert_eq!(rt, bs);
        assert_eq!(code.decode(&rt, symbols.len()).unwrap(), symbols);
        assert!(matches!(
            BitStream::from_bytes(vec![0u8; 2], 64),
            Err(HuffmanError::BitLengthMismatch { .. })
        ));
    }

    #[test]
    fn deterministic_codes() {
        let freqs = vec![3usize, 3, 2, 2];
        let a = build(&freqs).unwrap();
        let b = build(&freqs).unwrap();
        assert_eq!(a.lengths, b.lengths);
        assert_eq!(a.codes, b.codes);
    }
}
