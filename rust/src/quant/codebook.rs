//! Dictionary encoding of weight tensors (the paper's "weight sharing").
//!
//! A trained `[M, C, KY, KX]` weight tensor becomes a `B`-entry [`Codebook`]
//! plus a same-shaped tensor of bin indices.  The fixed-point view
//! (`raw_codebook`) is what the hardware register file holds and what the
//! cycle-accurate simulator multiplies with.

use crate::quant::fixed::QFormat;
use crate::quant::kmeans::kmeans_1d;
use crate::tensor::Tensor;

/// A shared-weight dictionary: `B` float centroids and their fixed-point
/// encoding in the weight format `wq`.
#[derive(Clone, Debug)]
pub struct Codebook {
    /// Centroid values (positional identity — index b is "bin b").
    pub values: Vec<f32>,
    /// Weight fixed-point format (the paper sweeps W = 8/16/32).
    pub wq: QFormat,
}

impl Codebook {
    /// A codebook over the given centroids (must be non-empty).
    pub fn new(values: Vec<f32>, wq: QFormat) -> Self {
        assert!(!values.is_empty());
        Codebook { values, wq }
    }

    /// Number of dictionary entries `B`.
    pub fn bins(&self) -> usize {
        self.values.len()
    }

    /// Bits needed for a bin index: `WCI = ceil(log2(B))` (paper §2.4).
    pub fn index_bits(&self) -> u32 {
        crate::quant::fixed::ceil_log2(self.bins()).max(1)
    }

    /// Fixed-point raw codebook entries (what the register file stores).
    pub fn raw(&self) -> Vec<i64> {
        self.values.iter().map(|&v| self.wq.encode(v as f64)).collect()
    }

    /// Dictionary-decoded float value of bin `b` *after* fixed-point
    /// rounding — the value the hardware actually multiplies with.
    pub fn decoded(&self, b: usize) -> f64 {
        self.wq.decode(self.raw()[b])
    }
}

/// A weight tensor in dictionary-encoded form.
#[derive(Clone, Debug)]
pub struct EncodedWeights {
    /// The shared-weight dictionary.
    pub codebook: Codebook,
    /// Bin index per weight, same shape as the original tensor.
    pub bin_idx: Tensor<u16>,
    /// K-means reconstruction MSE (before fixed-point rounding).
    pub mse: f64,
}

impl EncodedWeights {
    /// Decode back to a float tensor (`codebook[bin_idx]`) — the weights the
    /// weight-shared accelerator effectively computes with.
    pub fn decode(&self) -> Tensor<f32> {
        let cb = &self.codebook.values;
        self.bin_idx.map(|b| cb[b as usize])
    }

    /// Decode to the fixed-point-rounded float weights (hardware numerics).
    pub fn decode_fx(&self) -> Tensor<f32> {
        let raw = self.codebook.raw();
        let wq = self.codebook.wq;
        self.bin_idx.map(|b| wq.decode(raw[b as usize]) as f32)
    }

    /// Bin occupancy histogram — feeds the activity model (bins that never
    /// occur contribute no PAS accumulator toggling).
    pub fn occupancy(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.codebook.bins()];
        for &b in self.bin_idx.data() {
            h[b as usize] += 1;
        }
        h
    }

    /// Compression ratio of the index stream vs dense W-bit weights
    /// (ignoring the B-entry codebook itself, as the paper does for large
    /// layers): `W / WCI`.
    pub fn index_compression(&self) -> f64 {
        self.codebook.wq.width as f64 / self.codebook.index_bits() as f64
    }
}

/// K-means-encode a weight tensor into `bins` shared values.
pub fn encode_weights(weights: &Tensor<f32>, bins: usize, wq: QFormat) -> EncodedWeights {
    let r = kmeans_1d(weights.data(), bins, 50);
    let bin_idx = Tensor::from_vec(
        weights.dims(),
        r.assignments.clone(),
    );
    EncodedWeights {
        codebook: Codebook::new(r.codebook, wq),
        bin_idx,
        mse: r.mse,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_weights() -> Tensor<f32> {
        // 2x2x2x2 tensor with 4 distinct values -> exactly recoverable at B=4
        let vals = [0.5f32, -0.5, 1.5, -1.5];
        Tensor::from_fn(&[2, 2, 2, 2], |i| vals[i % 4])
    }

    #[test]
    fn exact_recovery_at_b4() {
        let w = toy_weights();
        let enc = encode_weights(&w, 4, QFormat::W32);
        let dec = enc.decode();
        assert!(w.max_abs_diff(&dec) < 1e-6);
        assert!(enc.mse < 1e-12);
    }

    #[test]
    fn index_bits_matches_paper() {
        // paper §2.4: 2^2 bits for 4 weights up to 2^4 bits for 16 weights
        for (bins, want) in [(4usize, 2u32), (8, 3), (16, 4), (256, 8)] {
            let cb = Codebook::new(vec![0.0; bins], QFormat::W32);
            assert_eq!(cb.index_bits(), want);
        }
    }

    #[test]
    fn occupancy_sums_to_len() {
        let w = toy_weights();
        let enc = encode_weights(&w, 4, QFormat::W32);
        assert_eq!(enc.occupancy().iter().sum::<usize>(), w.len());
    }

    #[test]
    fn fx_decode_rounds_to_format() {
        let w = Tensor::from_vec(&[2], vec![0.3f32, -0.7]);
        let enc = encode_weights(&w, 2, QFormat::W8);
        let dec = enc.decode_fx();
        for &v in dec.data() {
            // every decoded value is a multiple of the ulp
            let ulp = QFormat::W8.ulp() as f32;
            assert!((v / ulp - (v / ulp).round()).abs() < 1e-5);
        }
    }

    #[test]
    fn compression_ratio() {
        let w = toy_weights();
        let enc = encode_weights(&w, 16, QFormat::W32);
        assert!((enc.index_compression() - 8.0).abs() < 1e-9); // 32 / 4
    }
}
