//! Signed fixed-point formats with explicit bit widths.
//!
//! The gate/power models cost a datapath by its width `W`; this module is
//! the *numerics* of that same datapath: values are stored as `i64` holding
//! a W-bit two's-complement integer scaled by `2^-frac`.  A `W x W` multiply
//! produces `2W` bits and the accumulators are sized
//! `2W + ceil(log2(taps))` — the simulator asserts no silent overflow, the
//! same discipline an RTL designer applies when sizing the PAS bins.

/// A signed fixed-point format: `width` total bits (incl. sign), `frac`
/// fractional bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QFormat {
    /// Total bits, including the sign bit.
    pub width: u32,
    /// Fractional (sub-integer) bits.
    pub frac: u32,
}

impl QFormat {
    /// A format of `width` total bits with `frac` fractional bits
    /// (compile-time checked: `2 <= width <= 32`, `frac < width`).
    pub const fn new(width: u32, frac: u32) -> Self {
        assert!(width >= 2 && width <= 32, "supported widths: 2..=32");
        assert!(frac < width);
        QFormat { width, frac }
    }

    /// The paper's image format: 32-bit int, 16 fractional bits.
    pub const IMAGE32: QFormat = QFormat::new(32, 16);
    /// 8-bit weight format swept in the paper.
    pub const W8: QFormat = QFormat::new(8, 4);
    /// 16-bit weight format swept in the paper.
    pub const W16: QFormat = QFormat::new(16, 8);
    /// 32-bit weight format swept in the paper.
    pub const W32: QFormat = QFormat::new(32, 16);

    /// Scale factor `2^frac`.
    #[inline]
    pub fn scale(&self) -> f64 {
        (1u64 << self.frac) as f64
    }

    /// Largest representable raw value.
    #[inline]
    pub fn max_raw(&self) -> i64 {
        (1i64 << (self.width - 1)) - 1
    }

    /// Smallest representable raw value.
    #[inline]
    pub fn min_raw(&self) -> i64 {
        -(1i64 << (self.width - 1))
    }

    /// Encode an f64 to the nearest representable raw value (saturating).
    pub fn encode(&self, x: f64) -> i64 {
        let raw = (x * self.scale()).round() as i64;
        raw.clamp(self.min_raw(), self.max_raw())
    }

    /// Decode a raw value back to f64.
    pub fn decode(&self, raw: i64) -> f64 {
        raw as f64 / self.scale()
    }

    /// Quantization step size (1 ulp).
    pub fn ulp(&self) -> f64 {
        1.0 / self.scale()
    }

    /// Does `raw` fit this format without saturation?
    pub fn fits(&self, raw: i64) -> bool {
        raw >= self.min_raw() && raw <= self.max_raw()
    }

    /// Accumulator width needed for `taps` summands of a `self x other`
    /// product: `W_a + W_b + ceil(log2(taps))` bits (RTL sizing rule; the
    /// paper's PAS bins accumulate bare image values so pass
    /// `other.width = 0` via [`QFormat::acc_width_accumulate_only`]).
    pub fn acc_width_product(&self, other: &QFormat, taps: usize) -> u32 {
        self.width + other.width + ceil_log2(taps.max(1))
    }

    /// Accumulator width for summing `taps` bare values of this format
    /// (the PAS bin registers: image values only, no product growth).
    pub fn acc_width_accumulate_only(&self, taps: usize) -> u32 {
        self.width + ceil_log2(taps.max(1))
    }
}

/// ceil(log2(n)) for n >= 1.
pub fn ceil_log2(n: usize) -> u32 {
    assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()).min(63)
}

/// Fixed-point multiply: raw product has `a.frac + b.frac` fractional bits.
/// Returns the wide (un-narrowed) product — narrowing policy is the
/// caller's (the simulator keeps products wide through accumulation, as the
/// paper's accumulator registers do).
#[inline]
pub fn fx_mul(a_raw: i64, b_raw: i64) -> i64 {
    a_raw
        .checked_mul(b_raw)
        .expect("fixed-point product overflowed i64 (widths must be <= 32)")
}

/// Encode per-channel float biases to raw values carrying `frac`
/// fractional bits (round-to-nearest).  The reference fixed-point forward
/// (`EncodedCnn::forward_fx`) and the compiled plan (`cnn::plan`) must both
/// use exactly this function: their bit-exactness contract depends on a
/// single rounding rule.
pub fn encode_bias_raw(bias: &[f32], frac: u32) -> Vec<i64> {
    let scale = (1u64 << frac) as f64;
    bias.iter().map(|&b| (b as f64 * scale).round() as i64).collect()
}

/// Rescale a raw value with `from_frac` fractional bits to `to_frac`
/// (arithmetic shift, round-to-negative-infinity on narrowing — the
/// behaviour of a hardware right-shift).
#[inline]
pub fn fx_rescale(raw: i64, from_frac: u32, to_frac: u32) -> i64 {
    if from_frac >= to_frac {
        raw >> (from_frac - to_frac)
    } else {
        raw << (to_frac - from_frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let q = QFormat::new(16, 8);
        for x in [-1.5, 0.0, 0.25, 3.75, -100.0] {
            let raw = q.encode(x);
            assert!((q.decode(raw) - x).abs() <= q.ulp() / 2.0 + 1e-12, "{x}");
        }
    }

    #[test]
    fn encode_saturates() {
        let q = QFormat::new(8, 4);
        assert_eq!(q.encode(1e9), q.max_raw());
        assert_eq!(q.encode(-1e9), q.min_raw());
        assert_eq!(q.max_raw(), 127);
        assert_eq!(q.min_raw(), -128);
    }

    #[test]
    fn mul_fracs_add() {
        let a = QFormat::new(16, 8);
        let b = QFormat::new(16, 8);
        // 1.5 * 2.5 = 3.75
        let p = fx_mul(a.encode(1.5), b.encode(2.5));
        let dec = p as f64 / ((1u64 << (a.frac + b.frac)) as f64);
        assert!((dec - 3.75).abs() < 1e-9);
    }

    #[test]
    fn bias_raw_rounds_to_nearest() {
        assert_eq!(encode_bias_raw(&[0.5, -0.25, 0.0], 8), vec![128, -64, 0]);
        // ties round away from zero (f64::round)
        assert_eq!(encode_bias_raw(&[0.001953125], 8), vec![1]);
    }

    #[test]
    fn rescale_shifts() {
        assert_eq!(fx_rescale(256, 8, 4), 16);
        assert_eq!(fx_rescale(16, 4, 8), 256);
        assert_eq!(fx_rescale(-1, 4, 4), -1);
        // arithmetic shift: round toward -inf
        assert_eq!(fx_rescale(-3, 1, 0), -2);
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(800), 10);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_log2(1025), 11);
    }

    #[test]
    fn acc_widths() {
        let img = QFormat::IMAGE32;
        let w = QFormat::W32;
        // paper's C=32, 5x5 => 800 taps: 32+32+10 = 74 bits of product acc
        assert_eq!(img.acc_width_product(&w, 800), 74);
        // PAS bins accumulate bare 32-bit image values: 32+10 = 42 bits
        assert_eq!(img.acc_width_accumulate_only(800), 42);
    }

    #[test]
    #[should_panic]
    fn mul_overflow_guard() {
        fx_mul(i64::MAX / 2, 4);
    }
}
