//! Synthetic 10-class digit dataset.
//!
//! The paper's accelerator is evaluated on AlexNet-style workloads we cannot
//! ship; the e2e example instead trains on procedurally generated 12x12
//! digit glyphs (template bitmaps + per-sample jitter + noise).  This
//! exercises the identical code path — trained weights -> K-means codebook
//! -> dictionary-encoded inference — with a learnable, reproducible task
//! (DESIGN.md §1 substitution map).

use crate::tensor::Tensor;

/// Deterministic PRNG (xorshift*) so datasets are reproducible across runs.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    /// Seeded generator (seed 0 is mapped to 1; xorshift needs a
    /// non-zero state).
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [-1, 1).
    pub fn signed(&mut self) -> f32 {
        self.uniform() * 2.0 - 1.0
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

/// 8x8 glyph templates for digits 0-9 (1 = ink).
const GLYPHS: [[u8; 8]; 10] = [
    // each byte is a row bitmask, MSB = leftmost pixel
    [0x3C, 0x42, 0x46, 0x4A, 0x52, 0x62, 0x42, 0x3C], // 0
    [0x08, 0x18, 0x28, 0x08, 0x08, 0x08, 0x08, 0x3E], // 1
    [0x3C, 0x42, 0x02, 0x0C, 0x30, 0x40, 0x40, 0x7E], // 2
    [0x3C, 0x42, 0x02, 0x1C, 0x02, 0x02, 0x42, 0x3C], // 3
    [0x04, 0x0C, 0x14, 0x24, 0x44, 0x7E, 0x04, 0x04], // 4
    [0x7E, 0x40, 0x40, 0x7C, 0x02, 0x02, 0x42, 0x3C], // 5
    [0x1C, 0x20, 0x40, 0x7C, 0x42, 0x42, 0x42, 0x3C], // 6
    [0x7E, 0x02, 0x04, 0x08, 0x10, 0x10, 0x10, 0x10], // 7
    [0x3C, 0x42, 0x42, 0x3C, 0x42, 0x42, 0x42, 0x3C], // 8
    [0x3C, 0x42, 0x42, 0x3E, 0x02, 0x04, 0x08, 0x30], // 9
];

/// Image side length produced by the generator (matches ModelConfig.in_h).
pub const IMAGE_SIDE: usize = 12;

/// One labelled sample.
#[derive(Clone, Debug)]
pub struct Sample {
    /// `[1, 12, 12]` image, ink ~1.0 on ~0.0 background plus noise.
    pub image: Tensor<f32>,
    /// Ground-truth digit (0-9).
    pub label: usize,
}

/// Render one digit with sub-cell jitter and additive noise.
pub fn render_digit(rng: &mut Rng, digit: usize, noise: f32) -> Tensor<f32> {
    assert!(digit < 10);
    let mut img = Tensor::zeros(&[1, IMAGE_SIDE, IMAGE_SIDE]);
    // random placement of the 8x8 glyph within the 12x12 frame
    let oy = rng.below(IMAGE_SIDE - 8 + 1);
    let ox = rng.below(IMAGE_SIDE - 8 + 1);
    for (r, rowmask) in GLYPHS[digit].iter().enumerate() {
        for c in 0..8 {
            if rowmask & (0x80 >> c) != 0 {
                let ink = 0.8 + 0.2 * rng.uniform();
                *img.at_mut(&[0, oy + r, ox + c]) = ink;
            }
        }
    }
    if noise > 0.0 {
        for v in img.data_mut() {
            *v += rng.signed() * noise;
        }
    }
    img
}

/// Generate a balanced dataset of `n` samples.
pub fn generate(rng: &mut Rng, n: usize, noise: f32) -> Vec<Sample> {
    (0..n)
        .map(|i| {
            let label = i % 10;
            Sample { image: render_digit(rng, label, noise), label }
        })
        .collect()
}

/// Deterministic train/test split sizes used by the e2e example.
pub fn train_test(seed: u64, n_train: usize, n_test: usize, noise: f32) -> (Vec<Sample>, Vec<Sample>) {
    let mut rng = Rng::new(seed);
    let train = generate(&mut rng, n_train, noise);
    let test = generate(&mut rng, n_test, noise);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = render_digit(&mut Rng::new(42), 3, 0.05);
        let b = render_digit(&mut Rng::new(42), 3, 0.05);
        assert!(a.max_abs_diff(&b) == 0.0);
    }

    #[test]
    fn shapes_and_range() {
        let mut rng = Rng::new(1);
        for d in 0..10 {
            let img = render_digit(&mut rng, d, 0.1);
            assert_eq!(img.dims(), &[1, IMAGE_SIDE, IMAGE_SIDE]);
            assert!(img.all_finite());
        }
    }

    #[test]
    fn glyphs_are_distinct() {
        // no two noiseless digit renders at the same position are identical
        for a in 0..10 {
            for b in (a + 1)..10 {
                assert_ne!(GLYPHS[a], GLYPHS[b], "glyphs {a} and {b} identical");
            }
        }
    }

    #[test]
    fn balanced_labels() {
        let mut rng = Rng::new(7);
        let ds = generate(&mut rng, 100, 0.0);
        let mut counts = [0usize; 10];
        for s in &ds {
            counts[s.label] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn noise_changes_pixels() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let clean = render_digit(&mut r1, 0, 0.0);
        let noisy = render_digit(&mut r2, 0, 0.2);
        assert!(clean.max_abs_diff(&noisy) > 0.0);
    }

    #[test]
    fn rng_uniform_bounds() {
        let mut rng = Rng::new(9);
        for _ in 0..1000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }
}
