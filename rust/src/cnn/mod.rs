//! CNN functional substrate: the three accelerator dataflows plus the tiny
//! trainable network the end-to-end example serves.
//!
//! * [`conv`] — bit-exact reference implementations of the paper's three
//!   accelerators: direct (Fig 1), weight-shared MAC (Fig 3/4) and PASM
//!   (Fig 5/6/13), in both f32 and fixed-point (`i64`) arithmetic.  The
//!   fixed-point PASM and WS paths are *bit-identical* (paper §5.3) — the
//!   property tests enforce it.
//! * [`layer`] — bias / ReLU / max-pool / dense building blocks (tensor
//!   conveniences delegating to slice workers the planned path reuses).
//! * [`network`] — the digits CNN (conv-relu-pool ×2 + dense) mirroring
//!   `python/compile/model.py`, with float and dictionary-encoded forms.
//! * [`plan`] — the plan/execute split: [`plan::CompiledCnn`] compiles an
//!   [`network::EncodedCnn`] once (flattened indices, pre-encoded
//!   codebooks/biases, plan-time overflow proof, reusable scratch) so a
//!   steady-state forward allocates nothing; bit-identical to the
//!   reference forwards and served by the coordinator's `NativeBackend`.
//! * [`train`] — a small SGD trainer (backprop written out by hand) used by
//!   the e2e example to get real trained weights to quantize.
//! * [`data`] — synthetic 10-class digit dataset generator.
//! * [`shapes`] — layer-shape tables (paper Table 2, AlexNet-like configs).

pub mod conv;
pub mod data;
pub mod dense_ws;
pub mod layer;
pub mod network;
pub mod plan;
pub mod shapes;
pub mod train;

pub use conv::{direct_conv_f32, pasm_conv_fx, pasm_conv_f32, ws_conv_f32, ws_conv_fx, FxConvInputs};
pub use network::{DigitsCnn, EncodedCnn, NetworkParams};
pub use plan::{CompiledCnn, KernelChoice, KernelKind, LayerPlan, Scratch};
