//! Weight-shared dense (GEMV) layers with PASM — the paper's conclusion
//! hook made concrete.
//!
//! §7: "Weight sharing is used in other types of networks such as
//! regional-CNNs, RNNs and LSTMs so PASM may be a good fit there too."
//! Fully-connected / recurrent layers are matrix-vector products — the
//! workload EIE (Han et al. 2016) accelerates.  The PASM permutation
//! applies verbatim: per output neuron, scatter the input activations
//! into `B` bins by the weight's dictionary index, then one `B`-length
//! post-pass.  Amortization is `K / B` where `K` is the input dimension —
//! usually *better* than convolutions (K is thousands in LSTM gates).

use crate::quant::codebook::EncodedWeights;
use crate::quant::fixed::fx_mul;
use crate::tensor::Tensor;

/// Weight-shared dense forward: `y[j] = Σ_i x[i] * cb[bi[j,i]]`.
/// `bin_idx` is `[N, K]` (N output neurons, K inputs).
pub fn ws_dense_f32(x: &[f32], bin_idx: &Tensor<u16>, codebook: &[f32]) -> Vec<f32> {
    let (n, k) = dense_dims(bin_idx, x.len());
    let bi = bin_idx.data();
    (0..n)
        .map(|j| {
            let row = &bi[j * k..(j + 1) * k];
            row.iter()
                .zip(x)
                .map(|(&b, &xv)| xv * codebook[b as usize])
                .sum()
        })
        .collect()
}

/// PASM dense forward: bin-accumulate then post-pass multiply.
pub fn pasm_dense_f32(x: &[f32], bin_idx: &Tensor<u16>, codebook: &[f32]) -> Vec<f32> {
    let (n, k) = dense_dims(bin_idx, x.len());
    let bi = bin_idx.data();
    let bins = codebook.len();
    let mut out = Vec::with_capacity(n);
    let mut acc = vec![0f32; bins];
    for j in 0..n {
        acc.iter_mut().for_each(|a| *a = 0.0);
        let row = &bi[j * k..(j + 1) * k];
        for (&b, &xv) in row.iter().zip(x) {
            acc[b as usize] += xv; // PAS phase
        }
        out.push(acc.iter().zip(codebook).map(|(&a, &w)| a * w).sum());
    }
    out
}

/// Fixed-point PASM dense — bit-exact against the WS form (§5.3 extended
/// to GEMV; enforced by tests).
pub fn pasm_dense_fx(x_raw: &[i64], enc: &EncodedWeights) -> Vec<i64> {
    let (n, k) = dense_dims(&enc.bin_idx, x_raw.len());
    let bi = enc.bin_idx.data();
    let cb = enc.codebook.raw();
    let mut out = Vec::with_capacity(n);
    let mut acc = vec![0i64; cb.len()];
    for j in 0..n {
        acc.iter_mut().for_each(|a| *a = 0);
        let row = &bi[j * k..(j + 1) * k];
        for (&b, &xv) in row.iter().zip(x_raw) {
            acc[b as usize] = acc[b as usize].checked_add(xv).expect("PAS bin overflow");
        }
        let mut y = 0i64;
        for (&a, &w) in acc.iter().zip(&cb) {
            y = y.checked_add(fx_mul(a, w)).expect("post-pass overflow");
        }
        out.push(y);
    }
    out
}

/// Fixed-point WS dense.
pub fn ws_dense_fx(x_raw: &[i64], enc: &EncodedWeights) -> Vec<i64> {
    let (n, k) = dense_dims(&enc.bin_idx, x_raw.len());
    let bi = enc.bin_idx.data();
    let cb = enc.codebook.raw();
    (0..n)
        .map(|j| {
            let row = &bi[j * k..(j + 1) * k];
            let mut y = 0i64;
            for (&b, &xv) in row.iter().zip(x_raw) {
                y = y
                    .checked_add(fx_mul(xv, cb[b as usize]))
                    .expect("WS dense overflow");
            }
            y
        })
        .collect()
}

/// Cycles for one GEMV on streaming hardware: WS = N·K; PASM = N·(K + B)
/// (the paper's §4 formula applied to dense layers).
pub fn dense_cycles(n: usize, k: usize, bins: usize, pasm: bool) -> u64 {
    if pasm {
        (n * (k + bins)) as u64
    } else {
        (n * k) as u64
    }
}

fn dense_dims(bin_idx: &Tensor<u16>, x_len: usize) -> (usize, usize) {
    assert_eq!(bin_idx.dims().len(), 2, "bin_idx must be [N, K]");
    let (n, k) = (bin_idx.dims()[0], bin_idx.dims()[1]);
    assert_eq!(k, x_len, "input length mismatch");
    (n, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::Rng;
    use crate::quant::codebook::encode_weights;
    use crate::quant::fixed::QFormat;

    fn case(seed: u64, n: usize, k: usize, bins: usize) -> (Vec<f32>, Tensor<u16>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..k).map(|_| rng.signed() * 2.0).collect();
        let bi = Tensor::from_fn(&[n, k], |_| rng.below(bins) as u16);
        let cb: Vec<f32> = (0..bins).map(|_| rng.signed()).collect();
        (x, bi, cb)
    }

    #[test]
    fn pasm_matches_ws_f32() {
        let (x, bi, cb) = case(1, 32, 256, 16);
        let a = ws_dense_f32(&x, &bi, &cb);
        let b = pasm_dense_f32(&x, &bi, &cb);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-3, "{u} vs {v}");
        }
    }

    #[test]
    fn fx_bitexact_random_sweep() {
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let n = 1 + rng.below(16);
            let k = 8 + rng.below(256);
            let bins = 1usize << (1 + rng.below(6));
            let w = Tensor::from_fn(&[n, k], |_| rng.signed());
            let enc = encode_weights(&w, bins, QFormat::W16);
            let x_raw: Vec<i64> = (0..k)
                .map(|_| QFormat::IMAGE32.encode((rng.signed() * 3.0) as f64))
                .collect();
            assert_eq!(pasm_dense_fx(&x_raw, &enc), ws_dense_fx(&x_raw, &enc));
        }
    }

    #[test]
    fn lstm_scale_amortization() {
        // an LSTM gate GEMV: K = 1024 inputs, B = 16 bins -> 64x
        // amortization; latency overhead B/K = 1.6% (vs ~12% for the
        // paper's C=15 conv tile) — dense layers suit PASM *better*
        let (n, k, bins) = (256usize, 1024usize, 16usize);
        let ws = dense_cycles(n, k, bins, false);
        let pasm = dense_cycles(n, k, bins, true);
        let overhead = pasm as f64 / ws as f64 - 1.0;
        assert!((overhead - bins as f64 / k as f64).abs() < 1e-12);
        assert!(overhead < 0.02, "overhead {overhead}");
    }

    #[test]
    fn degenerate_single_output() {
        let (x, bi, cb) = case(3, 1, 8, 4);
        let y = pasm_dense_f32(&x, &bi, &cb);
        assert_eq!(y.len(), 1);
        let manual: f32 = x
            .iter()
            .zip(bi.data())
            .map(|(&xv, &b)| xv * cb[b as usize])
            .sum();
        assert!((y[0] - manual).abs() < 1e-4);
    }

    #[test]
    #[should_panic]
    fn input_length_checked() {
        let (_, bi, cb) = case(4, 2, 8, 4);
        pasm_dense_f32(&[1.0; 5], &bi, &cb);
    }
}
