//! The digits CNN: float form (trainable) and dictionary-encoded form
//! (what the accelerator serves).
//!
//! Architecture (mirrors `python/compile/model.py` exactly — same layer
//! order, same flatten order — so the PJRT artifact and this code accept the
//! same parameter tensors):
//!
//! ```text
//! [1,12,12] -conv1(8,3x3)-> [8,10,10] -+bias,relu-> -pool2-> [8,5,5]
//!          -conv2(16,3x3)-> [16,3,3] -+bias,relu-> flatten(144) -dense-> 10
//! ```

use crate::cnn::conv::{
    direct_conv_f32, pasm_conv_f32, pasm_conv_fx, ws_conv_f32, ws_conv_fx, FxConvInputs,
};
use crate::cnn::layer::{
    add_bias, add_bias_fx, argmax, dense, maxpool2, maxpool2_fx, relu, relu_fx,
};
use crate::quant::codebook::{encode_weights, EncodedWeights};
use crate::quant::fixed::{encode_bias_raw, fx_rescale, QFormat};
use crate::tensor::{ConvShape, Tensor};

/// Float parameters of the digits CNN.
#[derive(Clone, Debug)]
pub struct NetworkParams {
    /// Conv1 weights `[conv1_m, 1, K, K]` (default `[8, 1, 3, 3]`).
    pub conv1_w: Tensor<f32>,
    /// Conv1 bias, one per kernel.
    pub conv1_b: Vec<f32>,
    /// Conv2 weights `[conv2_m, conv1_m, K, K]` (default `[16, 8, 3, 3]`).
    pub conv2_w: Tensor<f32>,
    /// Conv2 bias, one per kernel.
    pub conv2_b: Vec<f32>,
    /// Dense head weights `[feature_dim, classes]`.
    pub dense_w: Tensor<f32>,
    /// Dense head bias, one per class.
    pub dense_b: Vec<f32>,
}

/// Static architecture description (must match `configs.E2E_MODEL`).
#[derive(Clone, Copy, Debug)]
pub struct DigitsCnn {
    /// Input image side length (images are `[1, in_side, in_side]`).
    pub in_side: usize,
    /// Conv1 kernel count `M1`.
    pub conv1_m: usize,
    /// Conv2 kernel count `M2`.
    pub conv2_m: usize,
    /// Square kernel side `K` for both conv layers.
    pub kernel: usize,
    /// Output class count.
    pub classes: usize,
}

impl Default for DigitsCnn {
    fn default() -> Self {
        DigitsCnn { in_side: 12, conv1_m: 8, conv2_m: 16, kernel: 3, classes: 10 }
    }
}

impl DigitsCnn {
    /// Conv1 layer shape.
    pub fn conv1_shape(&self) -> ConvShape {
        ConvShape::new(1, self.in_side, self.in_side, self.kernel, self.kernel, self.conv1_m, 1)
    }

    /// Conv2 layer shape (after the 2x2 max-pool).
    pub fn conv2_shape(&self) -> ConvShape {
        let side = self.conv1_shape().out_h() / 2; // after 2x2 pool
        ConvShape::new(self.conv1_m, side, side, self.kernel, self.kernel, self.conv2_m, 1)
    }

    /// Flattened feature length entering the dense head.
    pub fn feature_dim(&self) -> usize {
        let s2 = self.conv2_shape();
        self.conv2_m * s2.out_pixels()
    }

    /// Random (Xavier-ish) initial parameters.
    pub fn init(&self, rng: &mut crate::cnn::data::Rng) -> NetworkParams {
        let s1 = self.conv1_shape();
        let s2 = self.conv2_shape();
        let fan1 = (s1.taps() as f32).sqrt();
        let fan2 = (s2.taps() as f32).sqrt();
        let fan3 = (self.feature_dim() as f32).sqrt();
        NetworkParams {
            conv1_w: Tensor::from_fn(s1.weight_shape().dims(), |_| rng.signed() / fan1),
            conv1_b: vec![0.0; self.conv1_m],
            conv2_w: Tensor::from_fn(s2.weight_shape().dims(), |_| rng.signed() / fan2),
            conv2_b: vec![0.0; self.conv2_m],
            dense_w: Tensor::from_fn(&[self.feature_dim(), self.classes], |_| rng.signed() / fan3),
            dense_b: vec![0.0; self.classes],
        }
    }

    /// Float forward pass -> logits.
    pub fn forward(&self, params: &NetworkParams, image: &Tensor<f32>) -> Vec<f32> {
        let mut h = direct_conv_f32(image, &params.conv1_w, 1);
        add_bias(&mut h, &params.conv1_b);
        relu(&mut h);
        let h = maxpool2(&h);
        let mut h = direct_conv_f32(&h, &params.conv2_w, 1);
        add_bias(&mut h, &params.conv2_b);
        relu(&mut h);
        let feat = h.into_vec();
        dense(&feat, &params.dense_w, &params.dense_b)
    }

    /// Classification accuracy over a dataset.
    pub fn accuracy(&self, params: &NetworkParams, data: &[crate::cnn::data::Sample]) -> f64 {
        let correct = data
            .iter()
            .filter(|s| argmax(&self.forward(params, &s.image)) == s.label)
            .count();
        correct as f64 / data.len().max(1) as f64
    }
}

/// Which conv dataflow the encoded network uses for inference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvVariant {
    /// Weight-shared MAC (paper Fig 3/4).
    WeightShared,
    /// Weight-shared with PASM (paper Fig 5/6).
    Pasm,
}

/// Dictionary-encoded form of the network (both conv layers weight-shared).
#[derive(Clone, Debug)]
pub struct EncodedCnn {
    /// The architecture the weights belong to.
    pub arch: DigitsCnn,
    /// Conv1 weights in dictionary-encoded form.
    pub conv1: EncodedWeights,
    /// Conv1 bias (stays float).
    pub conv1_b: Vec<f32>,
    /// Conv2 weights in dictionary-encoded form.
    pub conv2: EncodedWeights,
    /// Conv2 bias (stays float).
    pub conv2_b: Vec<f32>,
    /// Dense head weights (stay dense, as in the paper).
    pub dense_w: Tensor<f32>,
    /// Dense head bias.
    pub dense_b: Vec<f32>,
}

impl EncodedCnn {
    /// K-means-encode trained float parameters to `bins` shared weights per
    /// conv layer (the dense head stays dense, as in the paper — PASM
    /// targets the convolution layers that dominate compute).
    pub fn encode(arch: DigitsCnn, params: &NetworkParams, bins: usize, wq: QFormat) -> Self {
        EncodedCnn {
            arch,
            conv1: encode_weights(&params.conv1_w, bins, wq),
            conv1_b: params.conv1_b.clone(),
            conv2: encode_weights(&params.conv2_w, bins, wq),
            conv2_b: params.conv2_b.clone(),
            dense_w: params.dense_w.clone(),
            dense_b: params.dense_b.clone(),
        }
    }

    /// Forward with the selected dataflow -> logits.
    pub fn forward(&self, image: &Tensor<f32>, variant: ConvVariant) -> Vec<f32> {
        let conv = |img: &Tensor<f32>, enc: &EncodedWeights| match variant {
            ConvVariant::WeightShared => {
                ws_conv_f32(img, &enc.bin_idx, &enc.codebook.values, 1)
            }
            ConvVariant::Pasm => pasm_conv_f32(img, &enc.bin_idx, &enc.codebook.values, 1),
        };
        let mut h = conv(image, &self.conv1);
        add_bias(&mut h, &self.conv1_b);
        relu(&mut h);
        let h = maxpool2(&h);
        let mut h = conv(&h, &self.conv2);
        add_bias(&mut h, &self.conv2_b);
        relu(&mut h);
        let feat = h.into_vec();
        dense(&feat, &self.dense_w, &self.dense_b)
    }

    /// Compile this model into a [`crate::cnn::plan::CompiledCnn`] for
    /// repeated execution: all weight-derived state (flattened indices,
    /// fixed-point codebooks at image format `iq`, raw biases) is computed
    /// once, and steady-state forwards allocate nothing.  The serving path
    /// (`NativeBackend`) goes through this; `forward`/`forward_fx` below
    /// stay as the allocating golden oracle the plan is pinned against.
    pub fn compile(&self, iq: QFormat) -> anyhow::Result<crate::cnn::plan::CompiledCnn> {
        crate::cnn::plan::CompiledCnn::compile(self, iq)
    }

    /// Fixed-point forward: both conv layers run the raw-integer dataflows
    /// (`ws_conv_fx` / `pasm_conv_fx`) with images in format `iq`,
    /// activations requantized back to `iq` between layers, and the dense
    /// head in float (as in the paper — PASM targets the conv layers).
    ///
    /// Because integer addition commutes, the PASM and WS variants of this
    /// forward are **bit-identical** end to end (paper §5.3 lifted from one
    /// layer to the whole network); the coordinator's `NativeBackend` serves
    /// exactly this function in its fixed-point mode.
    pub fn forward_fx(&self, image: &Tensor<f32>, variant: ConvVariant, iq: QFormat) -> Vec<f32> {
        let conv = |inp: &FxConvInputs| match variant {
            ConvVariant::WeightShared => ws_conv_fx(inp),
            ConvVariant::Pasm => pasm_conv_fx(inp),
        };
        let inp1 = FxConvInputs::encode(image, &self.conv1, iq, 1);
        let frac1 = inp1.out_frac();
        let mut h = conv(&inp1);
        add_bias_fx(&mut h, &encode_bias_raw(&self.conv1_b, frac1));
        relu_fx(&mut h);
        let h = maxpool2_fx(&h);

        // requantize activations back to the image format for conv2,
        // saturating to the format's width (the narrowing a hardware
        // output stage performs)
        let inp2 = FxConvInputs {
            image_raw: h
                .map(|r| fx_rescale(r, frac1, iq.frac).clamp(iq.min_raw(), iq.max_raw())),
            bin_idx: self.conv2.bin_idx.clone(),
            codebook_raw: self.conv2.codebook.raw(),
            iq,
            wq: self.conv2.codebook.wq,
            stride: 1,
        };
        let frac2 = inp2.out_frac();
        let mut h = conv(&inp2);
        add_bias_fx(&mut h, &encode_bias_raw(&self.conv2_b, frac2));
        relu_fx(&mut h);

        let scale2 = (1u64 << frac2) as f64;
        let feat: Vec<f32> = h.data().iter().map(|&r| (r as f64 / scale2) as f32).collect();
        dense(&feat, &self.dense_w, &self.dense_b)
    }

    /// Classification accuracy over a labelled sample set.
    pub fn accuracy(&self, data: &[crate::cnn::data::Sample], variant: ConvVariant) -> f64 {
        let correct = data
            .iter()
            .filter(|s| argmax(&self.forward(&s.image, variant)) == s.label)
            .count();
        correct as f64 / data.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::{render_digit, Rng};

    #[test]
    fn architecture_dims() {
        let arch = DigitsCnn::default();
        assert_eq!(arch.conv1_shape().out_h(), 10);
        assert_eq!(arch.conv2_shape().in_h, 5);
        assert_eq!(arch.conv2_shape().out_h(), 3);
        assert_eq!(arch.feature_dim(), 144);
    }

    #[test]
    fn forward_shapes_and_finite() {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(1);
        let params = arch.init(&mut rng);
        let img = render_digit(&mut rng, 5, 0.1);
        let logits = arch.forward(&params, &img);
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn encoded_variants_agree() {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(2);
        let params = arch.init(&mut rng);
        let enc = EncodedCnn::encode(arch, &params, 16, QFormat::W16);
        let img = render_digit(&mut rng, 3, 0.1);
        let a = enc.forward(&img, ConvVariant::WeightShared);
        let b = enc.forward(&img, ConvVariant::Pasm);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn fx_forward_pasm_bitexact_ws() {
        // §5.3 lifted to the whole network: raw-integer PASM and WS
        // forwards are the same function, bit for bit
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(11);
        let params = arch.init(&mut rng);
        let enc = EncodedCnn::encode(arch, &params, 16, QFormat::W16);
        for d in 0..5usize {
            let img = render_digit(&mut rng, d, 0.1);
            let a = enc.forward_fx(&img, ConvVariant::WeightShared, QFormat::IMAGE32);
            let b = enc.forward_fx(&img, ConvVariant::Pasm, QFormat::IMAGE32);
            let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "digit {d}");
        }
    }

    #[test]
    fn fx_forward_close_to_f32_forward() {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(12);
        let params = arch.init(&mut rng);
        let enc = EncodedCnn::encode(arch, &params, 32, QFormat::W32);
        let img = render_digit(&mut rng, 4, 0.05);
        let f = enc.forward(&img, ConvVariant::Pasm);
        let fx = enc.forward_fx(&img, ConvVariant::Pasm, QFormat::IMAGE32);
        for (x, y) in f.iter().zip(&fx) {
            assert!((x - y).abs() < 1e-2, "{x} vs {y}");
        }
    }

    #[test]
    fn encoding_preserves_logits_approximately() {
        // with B=64 bins over ~200 weights, quantization error is small
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(3);
        let params = arch.init(&mut rng);
        let enc = EncodedCnn::encode(arch, &params, 64, QFormat::W32);
        let img = render_digit(&mut rng, 7, 0.05);
        let dense_logits = arch.forward(&params, &img);
        let enc_logits = enc.forward(&img, ConvVariant::Pasm);
        for (x, y) in dense_logits.iter().zip(&enc_logits) {
            assert!((x - y).abs() < 0.35, "{x} vs {y}");
        }
    }
}
