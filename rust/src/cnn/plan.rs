//! Compiled inference plans: the plan/execute split for native serving.
//!
//! The paper's PAS phase separates cheap accumulation from the shared
//! multiply; the software hot path should exploit the same structure.
//! Everything *weight-derived* is computed once at plan time — flattened
//! bin indices, fixed-point codebooks, raw biases, shapes/strides, and an
//! accumulator overflow bound — so a steady-state forward only streams
//! activations through preassembled state (the way Deep Compression
//! amortizes codebook decode across inference).
//!
//! * [`LayerPlan`] — one convolution layer, compiled: pre-flattened
//!   `bin_idx`, pre-encoded codebook/bias, and a **plan-time overflow
//!   proof**: if `taps · max|image_raw| · max|codebook_raw| + max|bias|`
//!   fits in `i64` for every image representable in the input format, the
//!   per-tap `checked_add` of the reference kernels becomes a plain add
//!   (plus `debug_assert`), not a branch per tap.  Codebooks that defeat
//!   the proof fall back to checked arithmetic — never to silence.
//! * [`CompiledCnn`] — an [`EncodedCnn`] compiled end to end, executing
//!   into caller-provided [`Scratch`] arenas: a steady-state
//!   `forward_*_into` call performs **zero heap allocation**.
//!
//! Exactness contract: the planned forwards are **bit-identical** to the
//! reference [`EncodedCnn::forward`] / [`EncodedCnn::forward_fx`] — in
//! fixed point because integer addition commutes (paper §5.3), in f32
//! because the planned path performs the identical sequence of IEEE
//! operations (the non-conv stages literally share the slice workers in
//! [`crate::cnn::layer`], and the conv loops mirror the reference
//! accumulation order).  Property tests pin both claims.

use crate::cnn::layer::{
    add_bias_fx_slice, add_bias_slice, dense_into, maxpool2_fx_into, maxpool2_into, relu_fx_slice,
    relu_slice,
};
use crate::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use crate::quant::codebook::EncodedWeights;
use crate::quant::fixed::{encode_bias_raw, fx_rescale, QFormat};
use crate::tensor::{ConvShape, Tensor};
use anyhow::{ensure, Result};

/// One convolution layer compiled for repeated execution.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    shape: ConvShape,
    /// Bin indices flattened to `[kernels * taps]` row-major.
    bin_idx: Vec<u16>,
    /// Float codebook (positional identity with `codebook_raw`).
    codebook_f32: Vec<f32>,
    /// Fixed-point raw codebook in `wq`.
    codebook_raw: Vec<i64>,
    /// Float per-kernel bias.
    bias_f32: Vec<f32>,
    /// Raw bias carrying `out_frac` fractional bits.
    bias_raw: Vec<i64>,
    iq: QFormat,
    wq: QFormat,
    /// Plan-time proof that no accumulator can overflow `i64` for any
    /// image representable in `iq` — lets the fixed-point kernels run
    /// branch-free.
    proved_no_overflow: bool,
}

impl LayerPlan {
    /// Compile one layer: validate the encoding (out-of-range bins are a
    /// hard error), pre-encode the fixed-point state, and establish the
    /// accumulator overflow bound.
    pub fn compile(
        shape: ConvShape,
        enc: &EncodedWeights,
        bias: &[f32],
        iq: QFormat,
    ) -> Result<LayerPlan> {
        ensure!(
            enc.bin_idx.dims() == shape.weight_shape().dims(),
            "bin_idx dims {:?} do not match layer weight shape {:?}",
            enc.bin_idx.dims(),
            shape.weight_shape().dims()
        );
        ensure!(
            bias.len() == shape.kernels,
            "bias length {} != kernels {}",
            bias.len(),
            shape.kernels
        );
        let codebook_raw = enc.codebook.raw();
        let max_bin = enc.bin_idx.data().iter().copied().max().unwrap_or(0) as usize;
        ensure!(
            max_bin < codebook_raw.len(),
            "bin index {} out of range for codebook with {} entries",
            max_bin,
            codebook_raw.len()
        );
        let wq = enc.codebook.wq;
        let bias_raw = encode_bias_raw(bias, iq.frac + wq.frac);

        // Overflow proof over *actual* codebook magnitudes (format-max
        // would be hopelessly conservative for W32): the WS/post-pass
        // accumulator is bounded by taps * max|img| * max|cb| + max|bias|,
        // the PAS bins by taps * max|img|.
        let taps = shape.taps() as i128;
        let max_img = iq.max_raw().unsigned_abs().max(iq.min_raw().unsigned_abs()) as i128;
        let max_cb = codebook_raw.iter().map(|c| c.unsigned_abs()).max().unwrap_or(0) as i128;
        let max_bias = bias_raw.iter().map(|b| b.unsigned_abs()).max().unwrap_or(0) as i128;
        let acc_bound = taps * max_img * max_cb + max_bias;
        let pas_bound = taps * max_img;
        let proved_no_overflow = acc_bound <= i64::MAX as i128 && pas_bound <= i64::MAX as i128;

        Ok(LayerPlan {
            shape,
            bin_idx: enc.bin_idx.data().to_vec(),
            codebook_f32: enc.codebook.values.clone(),
            codebook_raw,
            bias_f32: bias.to_vec(),
            bias_raw,
            iq,
            wq,
            proved_no_overflow,
        })
    }

    /// The conv shape this layer plan was compiled for.
    pub fn shape(&self) -> &ConvShape {
        &self.shape
    }

    /// Codebook entries (`B`).
    pub fn bins(&self) -> usize {
        self.codebook_raw.len()
    }

    /// Fractional bits of the raw conv output (`iq.frac + wq.frac`).
    pub fn out_frac(&self) -> u32 {
        self.iq.frac + self.wq.frac
    }

    /// Raw per-kernel bias at [`LayerPlan::out_frac`] fractional bits.
    pub fn bias_raw(&self) -> &[i64] {
        &self.bias_raw
    }

    /// Float per-kernel bias.
    pub fn bias_f32(&self) -> &[f32] {
        &self.bias_f32
    }

    /// Did the plan-time bound prove the fixed-point kernels overflow-free?
    pub fn proved_no_overflow(&self) -> bool {
        self.proved_no_overflow
    }

    /// Fixed-point convolution (no bias/activation) into `out`
    /// (`[kernels, OH, OW]` flattened).  `bins` is PASM scratch with at
    /// least [`LayerPlan::bins`] slots; bit-identical to
    /// [`crate::cnn::conv::ws_conv_fx`] / `pasm_conv_fx` on the same
    /// encoded inputs.
    pub fn conv_fx_into(
        &self,
        variant: ConvVariant,
        img: &[i64],
        bins: &mut [i64],
        out: &mut [i64],
    ) {
        match (variant, self.proved_no_overflow) {
            (ConvVariant::WeightShared, true) => self.ws_fx::<false>(img, out),
            (ConvVariant::WeightShared, false) => self.ws_fx::<true>(img, out),
            (ConvVariant::Pasm, true) => self.pasm_fx::<false>(img, bins, out),
            (ConvVariant::Pasm, false) => self.pasm_fx::<true>(img, bins, out),
        }
    }

    /// f32 convolution (no bias/activation) into `out`; performs the
    /// identical IEEE operation sequence as
    /// [`crate::cnn::conv::ws_conv_f32`] / `pasm_conv_f32`.
    pub fn conv_f32_into(
        &self,
        variant: ConvVariant,
        img: &[f32],
        bins: &mut [f32],
        out: &mut [f32],
    ) {
        match variant {
            ConvVariant::WeightShared => self.ws_f32(img, out),
            ConvVariant::Pasm => self.pasm_f32(img, bins, out),
        }
    }

    fn check_lens(&self, img_len: usize, out_len: usize) {
        let s = &self.shape;
        assert_eq!(img_len, s.channels * s.in_h * s.in_w, "image length mismatch");
        assert_eq!(out_len, s.kernels * s.out_pixels(), "output length mismatch");
    }

    fn ws_fx<const CHECKED: bool>(&self, img: &[i64], out: &mut [i64]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let (ih_w, k_w) = (s.in_w, s.kernel_w);
        let plane = s.in_h * ih_w;
        let taps = s.taps();
        let (oh, ow) = (s.out_h(), s.out_w());
        let cb = &self.codebook_raw;
        for m in 0..s.kernels {
            let bi_m = &self.bin_idx[m * taps..(m + 1) * taps];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    let mut t = 0usize;
                    let base = oy * s.stride * ih_w + ox * s.stride;
                    for c in 0..s.channels {
                        let cplane = &img[c * plane..(c + 1) * plane];
                        for ky in 0..s.kernel_h {
                            let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                            for &iv in row {
                                let b = bi_m[t] as usize;
                                acc = acc_add::<CHECKED>(acc, mul::<CHECKED>(iv, cb[b]));
                                t += 1;
                            }
                        }
                    }
                    out[m * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
    }

    fn pasm_fx<const CHECKED: bool>(&self, img: &[i64], bins: &mut [i64], out: &mut [i64]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let cb = &self.codebook_raw;
        let bins = &mut bins[..cb.len()];
        let (ih_w, k_w) = (s.in_w, s.kernel_w);
        let plane = s.in_h * ih_w;
        let taps = s.taps();
        let (oh, ow) = (s.out_h(), s.out_w());
        for m in 0..s.kernels {
            let bi_m = &self.bin_idx[m * taps..(m + 1) * taps];
            for oy in 0..oh {
                for ox in 0..ow {
                    bins.fill(0);
                    let mut t = 0usize;
                    let base = oy * s.stride * ih_w + ox * s.stride;
                    // PAS phase: weighted histogram of dictionary indices
                    for c in 0..s.channels {
                        let cplane = &img[c * plane..(c + 1) * plane];
                        for ky in 0..s.kernel_h {
                            let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                            for &iv in row {
                                let b = bi_m[t] as usize;
                                bins[b] = acc_add::<CHECKED>(bins[b], iv);
                                t += 1;
                            }
                        }
                    }
                    // post-pass MAC: B multiplies, shared unit
                    let mut acc = 0i64;
                    for (bv, &cv) in bins.iter().zip(cb.iter()) {
                        acc = acc_add::<CHECKED>(acc, mul::<CHECKED>(*bv, cv));
                    }
                    out[m * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
    }

    fn ws_f32(&self, img: &[f32], out: &mut [f32]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let (ih_w, k_w) = (s.in_w, s.kernel_w);
        let plane = s.in_h * ih_w;
        let taps = s.taps();
        let (oh, ow) = (s.out_h(), s.out_w());
        let cb = &self.codebook_f32;
        for m in 0..s.kernels {
            let bi_m = &self.bin_idx[m * taps..(m + 1) * taps];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0f32;
                    let mut t = 0usize;
                    let base = oy * s.stride * ih_w + ox * s.stride;
                    for c in 0..s.channels {
                        let cplane = &img[c * plane..(c + 1) * plane];
                        for ky in 0..s.kernel_h {
                            let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                            for &iv in row {
                                acc += iv * cb[bi_m[t] as usize];
                                t += 1;
                            }
                        }
                    }
                    out[m * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
    }

    fn pasm_f32(&self, img: &[f32], bins: &mut [f32], out: &mut [f32]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let cb = &self.codebook_f32;
        let bins = &mut bins[..cb.len()];
        let (ih_w, k_w) = (s.in_w, s.kernel_w);
        let plane = s.in_h * ih_w;
        let taps = s.taps();
        let (oh, ow) = (s.out_h(), s.out_w());
        for m in 0..s.kernels {
            let bi_m = &self.bin_idx[m * taps..(m + 1) * taps];
            for oy in 0..oh {
                for ox in 0..ow {
                    bins.fill(0.0);
                    let mut t = 0usize;
                    let base = oy * s.stride * ih_w + ox * s.stride;
                    for c in 0..s.channels {
                        let cplane = &img[c * plane..(c + 1) * plane];
                        for ky in 0..s.kernel_h {
                            let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                            for &iv in row {
                                bins[bi_m[t] as usize] += iv;
                                t += 1;
                            }
                        }
                    }
                    let mut acc = 0f32;
                    for (bv, &cv) in bins.iter().zip(cb.iter()) {
                        acc += *bv * cv;
                    }
                    out[m * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
    }
}

#[inline(always)]
fn acc_add<const CHECKED: bool>(a: i64, b: i64) -> i64 {
    if CHECKED {
        a.checked_add(b).expect("planned accumulator overflow")
    } else {
        debug_assert!(a.checked_add(b).is_some(), "plan-time overflow bound violated (add)");
        a.wrapping_add(b)
    }
}

#[inline(always)]
fn mul<const CHECKED: bool>(a: i64, b: i64) -> i64 {
    if CHECKED {
        a.checked_mul(b).expect("planned product overflow")
    } else {
        debug_assert!(a.checked_mul(b).is_some(), "plan-time overflow bound violated (mul)");
        a.wrapping_mul(b)
    }
}

/// Reusable per-worker scratch arenas: every intermediate buffer a forward
/// pass touches, allocated once.  A steady-state `forward_*_into` call
/// performs zero heap allocation.
#[derive(Clone, Debug)]
pub struct Scratch {
    img_fx: Vec<i64>,
    conv1_fx: Vec<i64>,
    pooled_fx: Vec<i64>,
    conv2_fx: Vec<i64>,
    bins_fx: Vec<i64>,
    feat: Vec<f32>,
    conv1_f32: Vec<f32>,
    pooled_f32: Vec<f32>,
    conv2_f32: Vec<f32>,
    bins_f32: Vec<f32>,
}

/// An [`EncodedCnn`] compiled once for repeated execution: per-layer
/// [`LayerPlan`]s plus the dense head, driven over a [`Scratch`] arena.
///
/// Sits between [`EncodedCnn`] (the model) and the execution backends (the
/// serving substrate): `NativeBackend` compiles one of these at startup and
/// every request thereafter only streams activations.
#[derive(Clone, Debug)]
pub struct CompiledCnn {
    arch: DigitsCnn,
    conv1: LayerPlan,
    conv2: LayerPlan,
    dense_w: Tensor<f32>,
    dense_b: Vec<f32>,
    iq: QFormat,
}

impl CompiledCnn {
    /// Compile `enc` with images in fixed-point format `iq` (the f32 path
    /// ignores `iq`).  Fails on inconsistent shapes or out-of-range bin
    /// indices — startup errors, never mid-request surprises.
    pub fn compile(enc: &EncodedCnn, iq: QFormat) -> Result<CompiledCnn> {
        let arch = enc.arch;
        let s1 = arch.conv1_shape();
        let s2 = arch.conv2_shape();
        ensure!(
            s2.channels == s1.kernels && s2.in_h == s1.out_h() / 2 && s2.in_w == s1.out_w() / 2,
            "conv2 input shape does not match pooled conv1 output"
        );
        let conv1 = LayerPlan::compile(s1, &enc.conv1, &enc.conv1_b, iq)?;
        let conv2 = LayerPlan::compile(s2, &enc.conv2, &enc.conv2_b, iq)?;
        ensure!(
            enc.dense_w.dims() == [arch.feature_dim(), arch.classes],
            "dense weight dims {:?} != [{}, {}]",
            enc.dense_w.dims(),
            arch.feature_dim(),
            arch.classes
        );
        ensure!(
            enc.dense_b.len() == arch.classes,
            "dense bias length {} != classes {}",
            enc.dense_b.len(),
            arch.classes
        );
        Ok(CompiledCnn {
            arch,
            conv1,
            conv2,
            dense_w: enc.dense_w.clone(),
            dense_b: enc.dense_b.clone(),
            iq,
        })
    }

    /// The architecture the plan was compiled from.
    pub fn arch(&self) -> &DigitsCnn {
        &self.arch
    }

    /// Image fixed-point format the fixed-point path was compiled for.
    pub fn iq(&self) -> QFormat {
        self.iq
    }

    /// Flattened input image length (`C * IH * IW`).
    pub fn in_len(&self) -> usize {
        let s = self.conv1.shape();
        s.channels * s.in_h * s.in_w
    }

    /// Output class count.
    pub fn classes(&self) -> usize {
        self.arch.classes
    }

    /// The per-layer plans (conv1, conv2).
    pub fn layers(&self) -> (&LayerPlan, &LayerPlan) {
        (&self.conv1, &self.conv2)
    }

    /// Allocate a scratch arena sized for this plan.  One per worker
    /// thread; reuse it across requests for allocation-free forwards.
    pub fn scratch(&self) -> Scratch {
        let s1 = self.conv1.shape();
        let s2 = self.conv2.shape();
        let in_len = s1.channels * s1.in_h * s1.in_w;
        let c1_len = s1.kernels * s1.out_pixels();
        let pool_len = s2.channels * s2.in_h * s2.in_w;
        let c2_len = s2.kernels * s2.out_pixels();
        let bins = self.conv1.bins().max(self.conv2.bins());
        Scratch {
            img_fx: vec![0; in_len],
            conv1_fx: vec![0; c1_len],
            pooled_fx: vec![0; pool_len],
            conv2_fx: vec![0; c2_len],
            bins_fx: vec![0; bins],
            feat: vec![0.0; c2_len],
            conv1_f32: vec![0.0; c1_len],
            pooled_f32: vec![0.0; pool_len],
            conv2_f32: vec![0.0; c2_len],
            bins_f32: vec![0.0; bins],
        }
    }

    /// Fixed-point forward into `logits` — bit-identical to
    /// [`EncodedCnn::forward_fx`] with the plan's `iq`, for either variant
    /// (and across variants: paper §5.3).
    pub fn forward_fx_into(
        &self,
        image: &[f32],
        variant: ConvVariant,
        s: &mut Scratch,
        logits: &mut [f32],
    ) {
        assert_eq!(image.len(), self.in_len(), "image length mismatch");
        assert_eq!(logits.len(), self.arch.classes, "logit buffer length mismatch");
        let s1 = self.conv1.shape();
        let s2 = self.conv2.shape();
        // encode into iq (same op as the reference `map(|x| iq.encode(x))`)
        for (dst, &x) in s.img_fx.iter_mut().zip(image) {
            *dst = self.iq.encode(x as f64);
        }
        self.conv1.conv_fx_into(variant, &s.img_fx, &mut s.bins_fx, &mut s.conv1_fx);
        add_bias_fx_slice(&mut s.conv1_fx, s1.out_pixels(), self.conv1.bias_raw());
        relu_fx_slice(&mut s.conv1_fx);
        maxpool2_fx_into(&s.conv1_fx, s1.kernels, s1.out_h(), s1.out_w(), &mut s.pooled_fx);
        // requantize pooled activations back to the image format, saturating
        // to its width (the narrowing a hardware output stage performs)
        let frac1 = self.conv1.out_frac();
        let (lo, hi) = (self.iq.min_raw(), self.iq.max_raw());
        for v in &mut s.pooled_fx {
            *v = fx_rescale(*v, frac1, self.iq.frac).clamp(lo, hi);
        }
        self.conv2.conv_fx_into(variant, &s.pooled_fx, &mut s.bins_fx, &mut s.conv2_fx);
        add_bias_fx_slice(&mut s.conv2_fx, s2.out_pixels(), self.conv2.bias_raw());
        relu_fx_slice(&mut s.conv2_fx);
        let scale2 = (1u64 << self.conv2.out_frac()) as f64;
        for (f, &r) in s.feat.iter_mut().zip(s.conv2_fx.iter()) {
            *f = (r as f64 / scale2) as f32;
        }
        dense_into(&s.feat, &self.dense_w, &self.dense_b, logits);
    }

    /// f32 forward into `logits` — bit-identical to [`EncodedCnn::forward`]
    /// (identical IEEE operation sequence; the non-conv stages share the
    /// reference slice workers outright).
    pub fn forward_f32_into(
        &self,
        image: &[f32],
        variant: ConvVariant,
        s: &mut Scratch,
        logits: &mut [f32],
    ) {
        assert_eq!(image.len(), self.in_len(), "image length mismatch");
        assert_eq!(logits.len(), self.arch.classes, "logit buffer length mismatch");
        let s1 = self.conv1.shape();
        let s2 = self.conv2.shape();
        self.conv1.conv_f32_into(variant, image, &mut s.bins_f32, &mut s.conv1_f32);
        add_bias_slice(&mut s.conv1_f32, s1.out_pixels(), self.conv1.bias_f32());
        relu_slice(&mut s.conv1_f32);
        maxpool2_into(&s.conv1_f32, s1.kernels, s1.out_h(), s1.out_w(), &mut s.pooled_f32);
        self.conv2.conv_f32_into(variant, &s.pooled_f32, &mut s.bins_f32, &mut s.conv2_f32);
        add_bias_slice(&mut s.conv2_f32, s2.out_pixels(), self.conv2.bias_f32());
        relu_slice(&mut s.conv2_f32);
        dense_into(&s.conv2_f32, &self.dense_w, &self.dense_b, logits);
    }

    /// Allocating convenience over [`CompiledCnn::forward_fx_into`].
    pub fn forward_fx(&self, image: &Tensor<f32>, variant: ConvVariant) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut logits = vec![0f32; self.arch.classes];
        self.forward_fx_into(image.data(), variant, &mut scratch, &mut logits);
        logits
    }

    /// Allocating convenience over [`CompiledCnn::forward_f32_into`].
    pub fn forward_f32(&self, image: &Tensor<f32>, variant: ConvVariant) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut logits = vec![0f32; self.arch.classes];
        self.forward_f32_into(image.data(), variant, &mut scratch, &mut logits);
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::conv::{ws_conv_fx, FxConvInputs};
    use crate::cnn::data::{render_digit, Rng};
    use crate::quant::codebook::{encode_weights, Codebook, EncodedWeights};

    fn encoded_net(seed: u64, bins: usize, wq: QFormat) -> EncodedCnn {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(seed);
        let params = arch.init(&mut rng);
        EncodedCnn::encode(arch, &params, bins, wq)
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn compiled_fx_bitexact_reference() {
        let enc = encoded_net(21, 16, QFormat::W16);
        let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
        let mut rng = Rng::new(5);
        for d in 0..6usize {
            let img = render_digit(&mut rng, d, 0.1);
            for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
                let got = plan.forward_fx(&img, variant);
                let want = enc.forward_fx(&img, variant, QFormat::IMAGE32);
                assert_eq!(bits(&got), bits(&want), "digit {d} {variant:?}");
            }
        }
    }

    #[test]
    fn compiled_f32_bitexact_reference() {
        let enc = encoded_net(22, 16, QFormat::W32);
        let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
        let mut rng = Rng::new(6);
        for d in 0..6usize {
            let img = render_digit(&mut rng, d, 0.1);
            for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
                let got = plan.forward_f32(&img, variant);
                let want = enc.forward(&img, variant);
                assert_eq!(bits(&got), bits(&want), "digit {d} {variant:?}");
            }
        }
    }

    #[test]
    fn scratch_reuse_is_pure() {
        // a dirty scratch from a previous request must not leak into the
        // next forward
        let enc = encoded_net(23, 8, QFormat::W16);
        let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
        let mut rng = Rng::new(7);
        let imgs: Vec<_> = (0..4).map(|d| render_digit(&mut rng, d, 0.1)).collect();
        let mut scratch = plan.scratch();
        let mut logits = vec![0f32; plan.classes()];
        for img in &imgs {
            plan.forward_fx_into(img.data(), ConvVariant::Pasm, &mut scratch, &mut logits);
            let fresh = plan.forward_fx(img, ConvVariant::Pasm);
            assert_eq!(bits(&logits), bits(&fresh));
            plan.forward_f32_into(img.data(), ConvVariant::Pasm, &mut scratch, &mut logits);
            let fresh = plan.forward_f32(img, ConvVariant::Pasm);
            assert_eq!(bits(&logits), bits(&fresh));
        }
    }

    #[test]
    fn paper_formats_prove_overflow_free() {
        // IMAGE32 x W16 and IMAGE32 x W32 with realistic (|w| ~ 1)
        // codebooks must take the branch-free path
        for wq in [QFormat::W16, QFormat::W32] {
            let enc = encoded_net(24, 16, wq);
            let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
            let (l1, l2) = plan.layers();
            assert!(l1.proved_no_overflow(), "{wq:?} conv1");
            assert!(l2.proved_no_overflow(), "{wq:?} conv2");
        }
    }

    #[test]
    fn unprovable_codebook_falls_back_to_checked() {
        // a full-scale W32 codebook defeats the plan-time bound; the layer
        // must fall back to checked arithmetic and still match the
        // reference kernel bit for bit on benign inputs
        let shape = ConvShape::new(1, 4, 4, 3, 3, 1, 1);
        let values = vec![30000.0f32, -30000.0];
        let enc = EncodedWeights {
            codebook: Codebook::new(values, QFormat::W32),
            bin_idx: Tensor::from_fn(&[1, 1, 3, 3], |i| (i % 2) as u16),
            mse: 0.0,
        };
        let plan = LayerPlan::compile(shape, &enc, &[0.0], QFormat::IMAGE32).unwrap();
        assert!(!plan.proved_no_overflow());
        let mut rng = Rng::new(9);
        let image = Tensor::from_fn(&[1, 4, 4], |_| rng.signed());
        let inp = FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 1);
        let want = ws_conv_fx(&inp);
        let mut out = vec![0i64; 4];
        let mut bins = vec![0i64; plan.bins()];
        plan.conv_fx_into(ConvVariant::WeightShared, inp.image_raw.data(), &mut bins, &mut out);
        assert_eq!(out.as_slice(), want.data());
        plan.conv_fx_into(ConvVariant::Pasm, inp.image_raw.data(), &mut bins, &mut out);
        assert_eq!(out.as_slice(), want.data());
    }

    #[test]
    fn compile_rejects_out_of_range_bins() {
        let mut enc = encoded_net(25, 4, QFormat::W16);
        enc.conv1.bin_idx.data_mut()[0] = 100; // codebook has 4 entries
        assert!(CompiledCnn::compile(&enc, QFormat::IMAGE32).is_err());
    }

    #[test]
    fn layer_conv_matches_reference_kernel() {
        // standalone LayerPlan conv vs the reference fx kernel on a
        // non-default shape (stride 2)
        let mut rng = Rng::new(31);
        let shape = ConvShape::new(3, 9, 9, 3, 3, 2, 2);
        let w = Tensor::from_fn(&[2, 3, 3, 3], |_| rng.signed());
        let enc = encode_weights(&w, 8, QFormat::W16);
        let image = Tensor::from_fn(&[3, 9, 9], |_| rng.signed() * 4.0);
        let inp = FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 2);
        let plan = LayerPlan::compile(shape, &enc, &[0.0, 0.0], QFormat::IMAGE32).unwrap();
        let want = ws_conv_fx(&inp);
        let mut out = vec![0i64; want.len()];
        let mut bins = vec![0i64; plan.bins()];
        plan.conv_fx_into(ConvVariant::Pasm, inp.image_raw.data(), &mut bins, &mut out);
        assert_eq!(out.as_slice(), want.data());
    }
}
