//! Compiled inference plans: the plan/execute split for native serving.
//!
//! The paper's PAS phase separates cheap accumulation from the shared
//! multiply; the software hot path should exploit the same structure.
//! Everything *weight-derived* is computed once at plan time — flattened
//! bin indices, fixed-point codebooks, raw biases, shapes/strides, and an
//! accumulator overflow bound — so a steady-state forward only streams
//! activations through preassembled state (the way Deep Compression
//! amortizes codebook decode across inference).
//!
//! * [`LayerPlan`] — one convolution layer, compiled: pre-flattened
//!   `bin_idx`, pre-encoded codebook/bias, and a **plan-time overflow
//!   proof**: if `taps · max|image_raw| · max|codebook_raw| + max|bias|`
//!   fits in `i64` for every image representable in the input format, the
//!   per-tap `checked_add` of the reference kernels becomes a plain add
//!   (plus `debug_assert`), not a branch per tap.  Codebooks that defeat
//!   the proof fall back to checked arithmetic — never to silence.
//! * [`CompiledCnn`] — an [`EncodedCnn`] compiled end to end, executing
//!   into caller-provided [`Scratch`] arenas: a steady-state
//!   `forward_*_into` call performs **zero heap allocation**.
//! * [`KernelChoice`] — per-plan execution strategy for the PASM dataflow:
//!   the **per-tap** kernels mirror the reference accumulation order (one
//!   multiply per tap), the **histogram** kernels implement the paper's
//!   count-then-multiply restructure in software — accumulate activations
//!   into `B` per-bin partial sums over a cache-blocked tile of adjacent
//!   output pixels (a structure-of-arrays layout, [`HistLayout`], groups
//!   each conv kernel's taps by bin so the inner accumulate loop is a
//!   contiguous slice add the compiler autovectorizes), then finish with
//!   `B` multiplies against the codebook.  [`KernelChoice::Auto`] picks
//!   per layer by comparing taps-per-output against the bin count.
//!
//! Exactness contract: the planned forwards are **bit-identical** to the
//! reference [`EncodedCnn::forward`] / [`EncodedCnn::forward_fx`] — in
//! fixed point because integer addition commutes (paper §5.3; the
//! histogram kernels are exactly the reordering that commutativity
//! licenses), in f32 because the planned path performs the identical
//! sequence of IEEE operations (the non-conv stages literally share the
//! slice workers in [`crate::cnn::layer`]; the per-tap conv loops mirror
//! the reference accumulation order, and the histogram f32 kernel
//! preserves the original tap order *within* each bin, so every per-bin
//! accumulator and the final codebook contraction see the same IEEE
//! additions as the reference PASM kernel).  Property tests pin all of it
//! (`tests/plan_equivalence.rs`).

use crate::cnn::conv::bin_range_violation;
use crate::cnn::layer::{
    acc_add, acc_mul, acc_tile_f32, acc_tile_fx, add_bias_fx_slice, add_bias_slice, dense_into,
    mac_tile_f32, mac_tile_fx, maxpool2_fx_into, maxpool2_into, relu_fx_slice, relu_slice,
};
use crate::cnn::network::{ConvVariant, DigitsCnn, EncodedCnn};
use crate::quant::codebook::EncodedWeights;
use crate::quant::fixed::{encode_bias_raw, fx_rescale, QFormat};
use crate::tensor::{ConvShape, Tensor};
use anyhow::{ensure, Result};

/// Cache-block width of the histogram kernels: per-bin partial sums are
/// materialized for this many adjacent output pixels at once, so the PAS
/// inner loop is a contiguous `tile`-wide slice add and the whole
/// `B x tile` accumulator block stays L1-resident (64 x 64 x 8 B = 32 KiB
/// at the maximum supported bin count).
pub const HIST_TILE: usize = 64;

/// [`KernelChoice::Auto`] threshold: a layer runs the histogram kernel
/// when `taps >= HIST_AUTO_TAPS_PER_BIN * bins`.  The histogram
/// restructure replaces `taps` multiply-adds per output with `taps` adds
/// plus `bins` multiply-adds, so it pays off once each codebook entry is
/// reused by at least a couple of taps (the paper's B << taps regime).
pub const HIST_AUTO_TAPS_PER_BIN: usize = 2;

/// Requested execution strategy for the PASM dataflow's conv kernels.
///
/// This is the *execution* axis, orthogonal to
/// [`ConvVariant`]: the variant says which reference dataflow the plan
/// must be bit-identical to, the kernel choice says how the PASM dataflow
/// is scheduled on the CPU.  The `WeightShared` variant always runs
/// per-tap — in f32 its accumulation order cannot be reproduced by a
/// histogram (one running accumulator across taps of *different* bins),
/// and keeping fixed point symmetric means one dispatch rule, not two.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelChoice {
    /// One multiply per tap, mirroring the reference accumulation order.
    PerTap,
    /// Count-then-multiply: per-bin partial sums, then `B` multiplies.
    Histogram,
    /// Resolve per layer: histogram when
    /// `taps >= HIST_AUTO_TAPS_PER_BIN * bins`, per-tap otherwise.
    #[default]
    Auto,
}

impl KernelChoice {
    /// Resolve the choice for a layer with `taps` taps per output and
    /// `bins` codebook entries.
    pub fn resolve(self, taps: usize, bins: usize) -> KernelKind {
        match self {
            KernelChoice::PerTap => KernelKind::PerTap,
            KernelChoice::Histogram => KernelKind::Histogram,
            KernelChoice::Auto => {
                if taps >= HIST_AUTO_TAPS_PER_BIN * bins {
                    KernelKind::Histogram
                } else {
                    KernelKind::PerTap
                }
            }
        }
    }
}

impl std::str::FromStr for KernelChoice {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<KernelChoice> {
        match s {
            "per-tap" => Ok(KernelChoice::PerTap),
            "histogram" => Ok(KernelChoice::Histogram),
            "auto" => Ok(KernelChoice::Auto),
            other => {
                anyhow::bail!("unknown kernel choice '{other}' (expected per-tap|histogram|auto)")
            }
        }
    }
}

impl std::fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            KernelChoice::PerTap => "per-tap",
            KernelChoice::Histogram => "histogram",
            KernelChoice::Auto => "auto",
        })
    }
}

/// The kernel a layer actually compiled to ([`KernelChoice`] with `Auto`
/// resolved against the layer's taps/bins ratio).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// One multiply per tap.
    PerTap,
    /// Per-bin partial sums, then `B` multiplies.
    Histogram,
}

/// Structure-of-arrays bin-stream layout for the histogram kernels, built
/// once at plan time.
///
/// For each conv kernel `m`, the `taps` window offsets are grouped by bin
/// in CSR form — `tap_offsets[bin_starts[m*(B+1) + b] .. bin_starts[m*(B+1)
/// + b + 1]]` are the image offsets (relative to the output pixel's window
/// origin, so independent of the pixel) of every tap of `m` that uses
/// codebook entry `b`.  Grouping is *stable*: within a bin, taps keep the
/// reference `(channel, ky, kx)` order, which is what makes the f32
/// histogram kernel replay the reference PASM kernel's per-accumulator
/// IEEE addition sequence exactly.
#[derive(Clone, Debug)]
struct HistLayout {
    /// `[kernels * (bins + 1)]` CSR row starts into `tap_offsets`.
    bin_starts: Vec<u32>,
    /// `[kernels * taps]` window-relative image offsets, grouped by bin.
    tap_offsets: Vec<u32>,
}

impl HistLayout {
    fn build(shape: &ConvShape, bin_idx: &[u16], bins: usize) -> HistLayout {
        let taps = shape.taps();
        let plane = shape.in_h * shape.in_w;
        // Window-relative offset of each tap in reference (c, ky, kx) order.
        let mut rel = Vec::with_capacity(taps);
        for c in 0..shape.channels {
            for ky in 0..shape.kernel_h {
                for kx in 0..shape.kernel_w {
                    rel.push((c * plane + ky * shape.in_w + kx) as u32);
                }
            }
        }
        let mut bin_starts = Vec::with_capacity(shape.kernels * (bins + 1));
        let mut tap_offsets = Vec::with_capacity(shape.kernels * taps);
        for m in 0..shape.kernels {
            let bi_m = &bin_idx[m * taps..(m + 1) * taps];
            bin_starts.push(tap_offsets.len() as u32);
            for b in 0..bins {
                // Stable grouping: keep reference tap order within the bin.
                for (t, &bt) in bi_m.iter().enumerate() {
                    if bt as usize == b {
                        tap_offsets.push(rel[t]);
                    }
                }
                bin_starts.push(tap_offsets.len() as u32);
            }
        }
        HistLayout { bin_starts, tap_offsets }
    }
}

/// One convolution layer compiled for repeated execution.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    shape: ConvShape,
    /// Bin indices flattened to `[kernels * taps]` row-major.
    bin_idx: Vec<u16>,
    /// Float codebook (positional identity with `codebook_raw`).
    codebook_f32: Vec<f32>,
    /// Fixed-point raw codebook in `wq`.
    codebook_raw: Vec<i64>,
    /// Float per-kernel bias.
    bias_f32: Vec<f32>,
    /// Raw bias carrying `out_frac` fractional bits.
    bias_raw: Vec<i64>,
    iq: QFormat,
    wq: QFormat,
    /// Plan-time proof that no accumulator can overflow `i64` for any
    /// image representable in `iq` — lets the fixed-point kernels run
    /// branch-free.
    proved_no_overflow: bool,
    /// Resolved execution strategy for the PASM dataflow.
    kernel: KernelKind,
    /// SoA bin streams, present iff `kernel == KernelKind::Histogram`.
    hist: Option<HistLayout>,
}

impl LayerPlan {
    /// Compile one layer with the default [`KernelChoice::Auto`] strategy.
    pub fn compile(
        shape: ConvShape,
        enc: &EncodedWeights,
        bias: &[f32],
        iq: QFormat,
    ) -> Result<LayerPlan> {
        LayerPlan::compile_with(shape, enc, bias, iq, KernelChoice::Auto)
    }

    /// Compile one layer: validate the encoding (out-of-range bins are a
    /// hard error *before* any kernel layout is built), pre-encode the
    /// fixed-point state, establish the accumulator overflow bound, and
    /// resolve + materialize the requested kernel strategy.
    pub fn compile_with(
        shape: ConvShape,
        enc: &EncodedWeights,
        bias: &[f32],
        iq: QFormat,
        choice: KernelChoice,
    ) -> Result<LayerPlan> {
        ensure!(
            enc.bin_idx.dims() == shape.weight_shape().dims(),
            "bin_idx dims {:?} do not match layer weight shape {:?}",
            enc.bin_idx.dims(),
            shape.weight_shape().dims()
        );
        ensure!(
            bias.len() == shape.kernels,
            "bias length {} != kernels {}",
            bias.len(),
            shape.kernels
        );
        let codebook_raw = enc.codebook.raw();
        // The same strict scan the reference kernels assert on: rejects
        // `bin == len` as firmly as `bin >> len`, and runs before the
        // per-tap or histogram layouts exist, so neither kernel family can
        // ever index out of bounds.
        if let Some(max_bin) = bin_range_violation(enc.bin_idx.data(), codebook_raw.len()) {
            anyhow::bail!(
                "bin index {} out of range for codebook with {} entries",
                max_bin,
                codebook_raw.len()
            );
        }
        let wq = enc.codebook.wq;
        let bias_raw = encode_bias_raw(bias, iq.frac + wq.frac);

        // Overflow proof over *actual* codebook magnitudes (format-max
        // would be hopelessly conservative for W32): the WS/post-pass
        // accumulator is bounded by taps * max|img| * max|cb| + max|bias|,
        // the PAS bins by taps * max|img|.  The histogram kernels only
        // *reorder* the same summands, so the identical bounds cover them:
        // each per-bin partial sum accumulates a subset of the taps
        // (<= pas_bound), each `bin_sum * cb[b]` product and every partial
        // sum of the B-term codebook contraction is bounded by
        // sum_b taps_b * max|img| * max|cb| = taps * max|img| * max|cb|
        // (<= acc_bound).  One proof, both accumulation orders.
        let taps = shape.taps() as i128;
        let max_img = iq.max_raw().unsigned_abs().max(iq.min_raw().unsigned_abs()) as i128;
        let max_cb = codebook_raw.iter().map(|c| c.unsigned_abs()).max().unwrap_or(0) as i128;
        let max_bias = bias_raw.iter().map(|b| b.unsigned_abs()).max().unwrap_or(0) as i128;
        let acc_bound = taps * max_img * max_cb + max_bias;
        let pas_bound = taps * max_img;
        let proved_no_overflow = acc_bound <= i64::MAX as i128 && pas_bound <= i64::MAX as i128;

        let kernel = choice.resolve(shape.taps(), codebook_raw.len());
        let hist = match kernel {
            KernelKind::Histogram => {
                Some(HistLayout::build(&shape, enc.bin_idx.data(), codebook_raw.len()))
            }
            KernelKind::PerTap => None,
        };

        Ok(LayerPlan {
            shape,
            bin_idx: enc.bin_idx.data().to_vec(),
            codebook_f32: enc.codebook.values.clone(),
            codebook_raw,
            bias_f32: bias.to_vec(),
            bias_raw,
            iq,
            wq,
            proved_no_overflow,
            kernel,
            hist,
        })
    }

    /// The conv shape this layer plan was compiled for.
    pub fn shape(&self) -> &ConvShape {
        &self.shape
    }

    /// Codebook entries (`B`).
    pub fn bins(&self) -> usize {
        self.codebook_raw.len()
    }

    /// Fractional bits of the raw conv output (`iq.frac + wq.frac`).
    pub fn out_frac(&self) -> u32 {
        self.iq.frac + self.wq.frac
    }

    /// Raw per-kernel bias at [`LayerPlan::out_frac`] fractional bits.
    pub fn bias_raw(&self) -> &[i64] {
        &self.bias_raw
    }

    /// Float per-kernel bias.
    pub fn bias_f32(&self) -> &[f32] {
        &self.bias_f32
    }

    /// Did the plan-time bound prove the fixed-point kernels overflow-free?
    pub fn proved_no_overflow(&self) -> bool {
        self.proved_no_overflow
    }

    /// The kernel this layer resolved to (`Auto` applies the
    /// [`HIST_AUTO_TAPS_PER_BIN`] heuristic at compile time).
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Conv scratch slots the kernels need: `bins()` per-bin accumulators
    /// for the per-tap PASM kernel, a `bins() * HIST_TILE` tile block for
    /// the histogram kernel.
    pub fn scratch_len(&self) -> usize {
        match self.kernel {
            KernelKind::PerTap => self.bins(),
            KernelKind::Histogram => self.bins() * HIST_TILE,
        }
    }

    /// Fixed-point convolution (no bias/activation) into `out`
    /// (`[kernels, OH, OW]` flattened).  `bins` is kernel scratch with at
    /// least [`LayerPlan::scratch_len`] slots; bit-identical to
    /// [`crate::cnn::conv::ws_conv_fx`] / `pasm_conv_fx` on the same
    /// encoded inputs, for either kernel strategy (integer addition
    /// commutes — paper §5.3).  The `WeightShared` variant always runs
    /// per-tap (see [`KernelChoice`]).
    pub fn conv_fx_into(
        &self,
        variant: ConvVariant,
        img: &[i64],
        bins: &mut [i64],
        out: &mut [i64],
    ) {
        match (variant, self.kernel, self.proved_no_overflow) {
            (ConvVariant::WeightShared, _, true) => self.ws_fx::<false>(img, out),
            (ConvVariant::WeightShared, _, false) => self.ws_fx::<true>(img, out),
            (ConvVariant::Pasm, KernelKind::PerTap, true) => self.pasm_fx::<false>(img, bins, out),
            (ConvVariant::Pasm, KernelKind::PerTap, false) => self.pasm_fx::<true>(img, bins, out),
            (ConvVariant::Pasm, KernelKind::Histogram, true) => {
                self.hist_fx::<false>(img, bins, out)
            }
            (ConvVariant::Pasm, KernelKind::Histogram, false) => {
                self.hist_fx::<true>(img, bins, out)
            }
        }
    }

    /// f32 convolution (no bias/activation) into `out`; performs the
    /// identical IEEE operation sequence as
    /// [`crate::cnn::conv::ws_conv_f32`] / `pasm_conv_f32` — the histogram
    /// kernel included, because its stable-by-bin tap grouping feeds every
    /// per-bin accumulator the same additions in the same order as the
    /// reference PASM scatter.  The `WeightShared` variant always runs
    /// per-tap (its single running accumulator mixes bins, an order no
    /// histogram can replay in f32).
    pub fn conv_f32_into(
        &self,
        variant: ConvVariant,
        img: &[f32],
        bins: &mut [f32],
        out: &mut [f32],
    ) {
        match (variant, self.kernel) {
            (ConvVariant::WeightShared, _) => self.ws_f32(img, out),
            (ConvVariant::Pasm, KernelKind::PerTap) => self.pasm_f32(img, bins, out),
            (ConvVariant::Pasm, KernelKind::Histogram) => self.hist_f32(img, bins, out),
        }
    }

    fn check_lens(&self, img_len: usize, out_len: usize) {
        let s = &self.shape;
        assert_eq!(img_len, s.channels * s.in_h * s.in_w, "image length mismatch");
        assert_eq!(out_len, s.kernels * s.out_pixels(), "output length mismatch");
    }

    fn ws_fx<const CHECKED: bool>(&self, img: &[i64], out: &mut [i64]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let (ih_w, k_w) = (s.in_w, s.kernel_w);
        let plane = s.in_h * ih_w;
        let taps = s.taps();
        let (oh, ow) = (s.out_h(), s.out_w());
        let cb = &self.codebook_raw;
        for m in 0..s.kernels {
            let bi_m = &self.bin_idx[m * taps..(m + 1) * taps];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    let mut t = 0usize;
                    let base = oy * s.stride * ih_w + ox * s.stride;
                    for c in 0..s.channels {
                        let cplane = &img[c * plane..(c + 1) * plane];
                        for ky in 0..s.kernel_h {
                            let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                            for &iv in row {
                                let b = bi_m[t] as usize;
                                acc = acc_add::<CHECKED>(acc, acc_mul::<CHECKED>(iv, cb[b]));
                                t += 1;
                            }
                        }
                    }
                    out[m * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
    }

    fn pasm_fx<const CHECKED: bool>(&self, img: &[i64], bins: &mut [i64], out: &mut [i64]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let cb = &self.codebook_raw;
        let bins = &mut bins[..cb.len()];
        let (ih_w, k_w) = (s.in_w, s.kernel_w);
        let plane = s.in_h * ih_w;
        let taps = s.taps();
        let (oh, ow) = (s.out_h(), s.out_w());
        for m in 0..s.kernels {
            let bi_m = &self.bin_idx[m * taps..(m + 1) * taps];
            for oy in 0..oh {
                for ox in 0..ow {
                    bins.fill(0);
                    let mut t = 0usize;
                    let base = oy * s.stride * ih_w + ox * s.stride;
                    // PAS phase: weighted histogram of dictionary indices
                    for c in 0..s.channels {
                        let cplane = &img[c * plane..(c + 1) * plane];
                        for ky in 0..s.kernel_h {
                            let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                            for &iv in row {
                                let b = bi_m[t] as usize;
                                bins[b] = acc_add::<CHECKED>(bins[b], iv);
                                t += 1;
                            }
                        }
                    }
                    // post-pass MAC: B multiplies, shared unit
                    let mut acc = 0i64;
                    for (bv, &cv) in bins.iter().zip(cb.iter()) {
                        acc = acc_add::<CHECKED>(acc, acc_mul::<CHECKED>(*bv, cv));
                    }
                    out[m * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
    }

    fn ws_f32(&self, img: &[f32], out: &mut [f32]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let (ih_w, k_w) = (s.in_w, s.kernel_w);
        let plane = s.in_h * ih_w;
        let taps = s.taps();
        let (oh, ow) = (s.out_h(), s.out_w());
        let cb = &self.codebook_f32;
        for m in 0..s.kernels {
            let bi_m = &self.bin_idx[m * taps..(m + 1) * taps];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0f32;
                    let mut t = 0usize;
                    let base = oy * s.stride * ih_w + ox * s.stride;
                    for c in 0..s.channels {
                        let cplane = &img[c * plane..(c + 1) * plane];
                        for ky in 0..s.kernel_h {
                            let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                            for &iv in row {
                                acc += iv * cb[bi_m[t] as usize];
                                t += 1;
                            }
                        }
                    }
                    out[m * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
    }

    fn pasm_f32(&self, img: &[f32], bins: &mut [f32], out: &mut [f32]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let cb = &self.codebook_f32;
        let bins = &mut bins[..cb.len()];
        let (ih_w, k_w) = (s.in_w, s.kernel_w);
        let plane = s.in_h * ih_w;
        let taps = s.taps();
        let (oh, ow) = (s.out_h(), s.out_w());
        for m in 0..s.kernels {
            let bi_m = &self.bin_idx[m * taps..(m + 1) * taps];
            for oy in 0..oh {
                for ox in 0..ow {
                    bins.fill(0.0);
                    let mut t = 0usize;
                    let base = oy * s.stride * ih_w + ox * s.stride;
                    for c in 0..s.channels {
                        let cplane = &img[c * plane..(c + 1) * plane];
                        for ky in 0..s.kernel_h {
                            let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                            for &iv in row {
                                bins[bi_m[t] as usize] += iv;
                                t += 1;
                            }
                        }
                    }
                    let mut acc = 0f32;
                    for (bv, &cv) in bins.iter().zip(cb.iter()) {
                        acc += *bv * cv;
                    }
                    out[m * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
    }

    /// Histogram (count-then-multiply) f32 kernel.  For a tile of up to
    /// [`HIST_TILE`] adjacent output pixels, accumulate the image values
    /// of each bin's taps into a `B x tile` block of per-bin partial sums
    /// (PAS phase — at stride 1 each tap contributes one *contiguous*
    /// image slice, which is what makes the inner loop a vector add), then
    /// contract the block against the codebook (`B` multiplies per
    /// output).  Bit-identical to [`LayerPlan::pasm_f32`]: stable-by-bin
    /// tap grouping preserves each accumulator's IEEE addition order, and
    /// the contraction walks all `B` bins from `0.0` exactly like the
    /// reference post-pass.
    fn hist_f32(&self, img: &[f32], bins: &mut [f32], out: &mut [f32]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let cb = &self.codebook_f32;
        let nb = cb.len();
        let hist = self.hist.as_ref().expect("histogram kernel without layout");
        let (oh, ow) = (s.out_h(), s.out_w());
        let (ih_w, stride) = (s.in_w, s.stride);
        for m in 0..s.kernels {
            let starts = &hist.bin_starts[m * (nb + 1)..(m + 1) * (nb + 1)];
            let out_m = &mut out[m * oh * ow..(m + 1) * oh * ow];
            for oy in 0..oh {
                let row0 = oy * stride * ih_w;
                let out_row = &mut out_m[oy * ow..(oy + 1) * ow];
                let mut ox0 = 0usize;
                while ox0 < ow {
                    let tile = HIST_TILE.min(ow - ox0);
                    let acc = &mut bins[..nb * tile];
                    acc.fill(0.0);
                    // PAS phase: per-bin partial sums for `tile` outputs.
                    for b in 0..nb {
                        let offs = &hist.tap_offsets[starts[b] as usize..starts[b + 1] as usize];
                        let acc_b = &mut acc[b * tile..(b + 1) * tile];
                        if stride == 1 {
                            for &o in offs {
                                let src0 = row0 + o as usize + ox0;
                                acc_tile_f32(acc_b, &img[src0..src0 + tile]);
                            }
                        } else {
                            for &o in offs {
                                let p0 = row0 + o as usize;
                                for (j, a) in acc_b.iter_mut().enumerate() {
                                    *a += img[p0 + (ox0 + j) * stride];
                                }
                            }
                        }
                    }
                    // Post-pass: B multiplies per output, shared unit.
                    let out_t = &mut out_row[ox0..ox0 + tile];
                    out_t.fill(0.0);
                    for (b, &cv) in cb.iter().enumerate() {
                        mac_tile_f32(out_t, &acc[b * tile..(b + 1) * tile], cv);
                    }
                    ox0 += tile;
                }
            }
        }
    }

    /// Histogram (count-then-multiply) fixed-point kernel — same schedule
    /// as [`LayerPlan::hist_f32`]; bit-identical to every other
    /// fixed-point kernel because integer addition commutes (paper §5.3),
    /// and covered by the same plan-time overflow proof (the reorder only
    /// regroups the identical summands — see
    /// [`LayerPlan::compile_with`]).
    fn hist_fx<const CHECKED: bool>(&self, img: &[i64], bins: &mut [i64], out: &mut [i64]) {
        self.check_lens(img.len(), out.len());
        let s = &self.shape;
        let cb = &self.codebook_raw;
        let nb = cb.len();
        let hist = self.hist.as_ref().expect("histogram kernel without layout");
        let (oh, ow) = (s.out_h(), s.out_w());
        let (ih_w, stride) = (s.in_w, s.stride);
        for m in 0..s.kernels {
            let starts = &hist.bin_starts[m * (nb + 1)..(m + 1) * (nb + 1)];
            let out_m = &mut out[m * oh * ow..(m + 1) * oh * ow];
            for oy in 0..oh {
                let row0 = oy * stride * ih_w;
                let out_row = &mut out_m[oy * ow..(oy + 1) * ow];
                let mut ox0 = 0usize;
                while ox0 < ow {
                    let tile = HIST_TILE.min(ow - ox0);
                    let acc = &mut bins[..nb * tile];
                    acc.fill(0);
                    for b in 0..nb {
                        let offs = &hist.tap_offsets[starts[b] as usize..starts[b + 1] as usize];
                        let acc_b = &mut acc[b * tile..(b + 1) * tile];
                        if stride == 1 {
                            for &o in offs {
                                let src0 = row0 + o as usize + ox0;
                                acc_tile_fx::<CHECKED>(acc_b, &img[src0..src0 + tile]);
                            }
                        } else {
                            for &o in offs {
                                let p0 = row0 + o as usize;
                                for (j, a) in acc_b.iter_mut().enumerate() {
                                    *a = acc_add::<CHECKED>(*a, img[p0 + (ox0 + j) * stride]);
                                }
                            }
                        }
                    }
                    let out_t = &mut out_row[ox0..ox0 + tile];
                    out_t.fill(0);
                    for (b, &cv) in cb.iter().enumerate() {
                        mac_tile_fx::<CHECKED>(out_t, &acc[b * tile..(b + 1) * tile], cv);
                    }
                    ox0 += tile;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Autovectorization probes.
//
// "The inner accumulate loop autovectorizes" is a claim about emitted
// machine code, so it is *tested* against emitted machine code:
// `tests/kernel_vectorization.rs` disassembles the release test binary and
// checks these symbols for vector adds.  Each probe is a `#[no_mangle]`
// non-generic wrapper around the exact `#[inline(always)]` tile worker the
// histogram kernels run, giving the disassembler a stable symbol whose body
// is the same LLVM loop shape as the kernel's inner loop.
// ---------------------------------------------------------------------------

/// Disassembly probe for the f32 histogram PAS inner loop
/// (`acc[j] += src[j]`).  Not part of the public API.
///
/// # Safety
///
/// `acc` and `src` must each point to `n` valid, properly aligned,
/// non-overlapping elements.
#[doc(hidden)]
#[no_mangle]
pub unsafe extern "C" fn pasm_hist_acc_tile_f32_probe(acc: *mut f32, src: *const f32, n: usize) {
    let acc = unsafe { std::slice::from_raw_parts_mut(acc, n) };
    let src = unsafe { std::slice::from_raw_parts(src, n) };
    acc_tile_f32(acc, src);
}

/// Disassembly probe for the fixed-point histogram PAS inner loop in its
/// proved-no-overflow (wrapping-add) instantiation.  Not part of the
/// public API.
///
/// # Safety
///
/// `acc` and `src` must each point to `n` valid, properly aligned,
/// non-overlapping elements.
#[doc(hidden)]
#[no_mangle]
pub unsafe extern "C" fn pasm_hist_acc_tile_fx_probe(acc: *mut i64, src: *const i64, n: usize) {
    let acc = unsafe { std::slice::from_raw_parts_mut(acc, n) };
    let src = unsafe { std::slice::from_raw_parts(src, n) };
    acc_tile_fx::<false>(acc, src);
}

/// Reusable per-worker scratch arenas: every intermediate buffer a forward
/// pass touches, allocated once.  A steady-state `forward_*_into` call
/// performs zero heap allocation.
#[derive(Clone, Debug)]
pub struct Scratch {
    img_fx: Vec<i64>,
    conv1_fx: Vec<i64>,
    pooled_fx: Vec<i64>,
    conv2_fx: Vec<i64>,
    bins_fx: Vec<i64>,
    feat: Vec<f32>,
    conv1_f32: Vec<f32>,
    pooled_f32: Vec<f32>,
    conv2_f32: Vec<f32>,
    bins_f32: Vec<f32>,
}

/// An [`EncodedCnn`] compiled once for repeated execution: per-layer
/// [`LayerPlan`]s plus the dense head, driven over a [`Scratch`] arena.
///
/// Sits between [`EncodedCnn`] (the model) and the execution backends (the
/// serving substrate): `NativeBackend` compiles one of these at startup and
/// every request thereafter only streams activations.
#[derive(Clone, Debug)]
pub struct CompiledCnn {
    arch: DigitsCnn,
    conv1: LayerPlan,
    conv2: LayerPlan,
    dense_w: Tensor<f32>,
    dense_b: Vec<f32>,
    iq: QFormat,
    kernel: KernelChoice,
}

impl CompiledCnn {
    /// Compile `enc` with the default [`KernelChoice::Auto`] strategy —
    /// each layer picks per-tap or histogram by the taps-per-bin
    /// heuristic.
    pub fn compile(enc: &EncodedCnn, iq: QFormat) -> Result<CompiledCnn> {
        CompiledCnn::compile_with(enc, iq, KernelChoice::Auto)
    }

    /// Compile `enc` with images in fixed-point format `iq` (the f32 path
    /// ignores `iq`) and an explicit kernel strategy.  Fails on
    /// inconsistent shapes or out-of-range bin indices — startup errors,
    /// never mid-request surprises.
    pub fn compile_with(
        enc: &EncodedCnn,
        iq: QFormat,
        kernel: KernelChoice,
    ) -> Result<CompiledCnn> {
        let arch = enc.arch;
        let s1 = arch.conv1_shape();
        let s2 = arch.conv2_shape();
        ensure!(
            s2.channels == s1.kernels && s2.in_h == s1.out_h() / 2 && s2.in_w == s1.out_w() / 2,
            "conv2 input shape does not match pooled conv1 output"
        );
        let conv1 = LayerPlan::compile_with(s1, &enc.conv1, &enc.conv1_b, iq, kernel)?;
        let conv2 = LayerPlan::compile_with(s2, &enc.conv2, &enc.conv2_b, iq, kernel)?;
        ensure!(
            enc.dense_w.dims() == [arch.feature_dim(), arch.classes],
            "dense weight dims {:?} != [{}, {}]",
            enc.dense_w.dims(),
            arch.feature_dim(),
            arch.classes
        );
        ensure!(
            enc.dense_b.len() == arch.classes,
            "dense bias length {} != classes {}",
            enc.dense_b.len(),
            arch.classes
        );
        Ok(CompiledCnn {
            arch,
            conv1,
            conv2,
            dense_w: enc.dense_w.clone(),
            dense_b: enc.dense_b.clone(),
            iq,
            kernel,
        })
    }

    /// The architecture the plan was compiled from.
    pub fn arch(&self) -> &DigitsCnn {
        &self.arch
    }

    /// Image fixed-point format the fixed-point path was compiled for.
    pub fn iq(&self) -> QFormat {
        self.iq
    }

    /// The kernel strategy the plan was compiled with (per layer, `Auto`
    /// resolves via [`LayerPlan::kernel`]).
    pub fn kernel_choice(&self) -> KernelChoice {
        self.kernel
    }

    /// Flattened input image length (`C * IH * IW`).
    pub fn in_len(&self) -> usize {
        let s = self.conv1.shape();
        s.channels * s.in_h * s.in_w
    }

    /// Output class count.
    pub fn classes(&self) -> usize {
        self.arch.classes
    }

    /// The per-layer plans (conv1, conv2).
    pub fn layers(&self) -> (&LayerPlan, &LayerPlan) {
        (&self.conv1, &self.conv2)
    }

    /// Allocate a scratch arena sized for this plan.  One per worker
    /// thread; reuse it across requests for allocation-free forwards.
    pub fn scratch(&self) -> Scratch {
        let s1 = self.conv1.shape();
        let s2 = self.conv2.shape();
        let in_len = s1.channels * s1.in_h * s1.in_w;
        let c1_len = s1.kernels * s1.out_pixels();
        let pool_len = s2.channels * s2.in_h * s2.in_w;
        let c2_len = s2.kernels * s2.out_pixels();
        let bins = self.conv1.scratch_len().max(self.conv2.scratch_len());
        Scratch {
            img_fx: vec![0; in_len],
            conv1_fx: vec![0; c1_len],
            pooled_fx: vec![0; pool_len],
            conv2_fx: vec![0; c2_len],
            bins_fx: vec![0; bins],
            feat: vec![0.0; c2_len],
            conv1_f32: vec![0.0; c1_len],
            pooled_f32: vec![0.0; pool_len],
            conv2_f32: vec![0.0; c2_len],
            bins_f32: vec![0.0; bins],
        }
    }

    /// Fixed-point forward into `logits` — bit-identical to
    /// [`EncodedCnn::forward_fx`] with the plan's `iq`, for either variant
    /// (and across variants: paper §5.3).
    pub fn forward_fx_into(
        &self,
        image: &[f32],
        variant: ConvVariant,
        s: &mut Scratch,
        logits: &mut [f32],
    ) {
        assert_eq!(image.len(), self.in_len(), "image length mismatch");
        assert_eq!(logits.len(), self.arch.classes, "logit buffer length mismatch");
        let s1 = self.conv1.shape();
        let s2 = self.conv2.shape();
        // encode into iq (same op as the reference `map(|x| iq.encode(x))`)
        for (dst, &x) in s.img_fx.iter_mut().zip(image) {
            *dst = self.iq.encode(x as f64);
        }
        self.conv1.conv_fx_into(variant, &s.img_fx, &mut s.bins_fx, &mut s.conv1_fx);
        add_bias_fx_slice(&mut s.conv1_fx, s1.out_pixels(), self.conv1.bias_raw());
        relu_fx_slice(&mut s.conv1_fx);
        maxpool2_fx_into(&s.conv1_fx, s1.kernels, s1.out_h(), s1.out_w(), &mut s.pooled_fx);
        // requantize pooled activations back to the image format, saturating
        // to its width (the narrowing a hardware output stage performs)
        let frac1 = self.conv1.out_frac();
        let (lo, hi) = (self.iq.min_raw(), self.iq.max_raw());
        for v in &mut s.pooled_fx {
            *v = fx_rescale(*v, frac1, self.iq.frac).clamp(lo, hi);
        }
        self.conv2.conv_fx_into(variant, &s.pooled_fx, &mut s.bins_fx, &mut s.conv2_fx);
        add_bias_fx_slice(&mut s.conv2_fx, s2.out_pixels(), self.conv2.bias_raw());
        relu_fx_slice(&mut s.conv2_fx);
        let scale2 = (1u64 << self.conv2.out_frac()) as f64;
        for (f, &r) in s.feat.iter_mut().zip(s.conv2_fx.iter()) {
            *f = (r as f64 / scale2) as f32;
        }
        dense_into(&s.feat, &self.dense_w, &self.dense_b, logits);
    }

    /// f32 forward into `logits` — bit-identical to [`EncodedCnn::forward`]
    /// (identical IEEE operation sequence; the non-conv stages share the
    /// reference slice workers outright).
    pub fn forward_f32_into(
        &self,
        image: &[f32],
        variant: ConvVariant,
        s: &mut Scratch,
        logits: &mut [f32],
    ) {
        assert_eq!(image.len(), self.in_len(), "image length mismatch");
        assert_eq!(logits.len(), self.arch.classes, "logit buffer length mismatch");
        let s1 = self.conv1.shape();
        let s2 = self.conv2.shape();
        self.conv1.conv_f32_into(variant, image, &mut s.bins_f32, &mut s.conv1_f32);
        add_bias_slice(&mut s.conv1_f32, s1.out_pixels(), self.conv1.bias_f32());
        relu_slice(&mut s.conv1_f32);
        maxpool2_into(&s.conv1_f32, s1.kernels, s1.out_h(), s1.out_w(), &mut s.pooled_f32);
        self.conv2.conv_f32_into(variant, &s.pooled_f32, &mut s.bins_f32, &mut s.conv2_f32);
        add_bias_slice(&mut s.conv2_f32, s2.out_pixels(), self.conv2.bias_f32());
        relu_slice(&mut s.conv2_f32);
        dense_into(&s.conv2_f32, &self.dense_w, &self.dense_b, logits);
    }

    /// Allocating convenience over [`CompiledCnn::forward_fx_into`].
    pub fn forward_fx(&self, image: &Tensor<f32>, variant: ConvVariant) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut logits = vec![0f32; self.arch.classes];
        self.forward_fx_into(image.data(), variant, &mut scratch, &mut logits);
        logits
    }

    /// Allocating convenience over [`CompiledCnn::forward_f32_into`].
    pub fn forward_f32(&self, image: &Tensor<f32>, variant: ConvVariant) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut logits = vec![0f32; self.arch.classes];
        self.forward_f32_into(image.data(), variant, &mut scratch, &mut logits);
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::conv::{ws_conv_fx, FxConvInputs};
    use crate::cnn::data::{render_digit, Rng};
    use crate::quant::codebook::{encode_weights, Codebook, EncodedWeights};

    fn encoded_net(seed: u64, bins: usize, wq: QFormat) -> EncodedCnn {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(seed);
        let params = arch.init(&mut rng);
        EncodedCnn::encode(arch, &params, bins, wq)
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn compiled_fx_bitexact_reference() {
        let enc = encoded_net(21, 16, QFormat::W16);
        let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
        let mut rng = Rng::new(5);
        for d in 0..6usize {
            let img = render_digit(&mut rng, d, 0.1);
            for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
                let got = plan.forward_fx(&img, variant);
                let want = enc.forward_fx(&img, variant, QFormat::IMAGE32);
                assert_eq!(bits(&got), bits(&want), "digit {d} {variant:?}");
            }
        }
    }

    #[test]
    fn compiled_f32_bitexact_reference() {
        let enc = encoded_net(22, 16, QFormat::W32);
        let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
        let mut rng = Rng::new(6);
        for d in 0..6usize {
            let img = render_digit(&mut rng, d, 0.1);
            for variant in [ConvVariant::WeightShared, ConvVariant::Pasm] {
                let got = plan.forward_f32(&img, variant);
                let want = enc.forward(&img, variant);
                assert_eq!(bits(&got), bits(&want), "digit {d} {variant:?}");
            }
        }
    }

    #[test]
    fn scratch_reuse_is_pure() {
        // a dirty scratch from a previous request must not leak into the
        // next forward
        let enc = encoded_net(23, 8, QFormat::W16);
        let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
        let mut rng = Rng::new(7);
        let imgs: Vec<_> = (0..4).map(|d| render_digit(&mut rng, d, 0.1)).collect();
        let mut scratch = plan.scratch();
        let mut logits = vec![0f32; plan.classes()];
        for img in &imgs {
            plan.forward_fx_into(img.data(), ConvVariant::Pasm, &mut scratch, &mut logits);
            let fresh = plan.forward_fx(img, ConvVariant::Pasm);
            assert_eq!(bits(&logits), bits(&fresh));
            plan.forward_f32_into(img.data(), ConvVariant::Pasm, &mut scratch, &mut logits);
            let fresh = plan.forward_f32(img, ConvVariant::Pasm);
            assert_eq!(bits(&logits), bits(&fresh));
        }
    }

    #[test]
    fn paper_formats_prove_overflow_free() {
        // IMAGE32 x W16 and IMAGE32 x W32 with realistic (|w| ~ 1)
        // codebooks must take the branch-free path
        for wq in [QFormat::W16, QFormat::W32] {
            let enc = encoded_net(24, 16, wq);
            let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
            let (l1, l2) = plan.layers();
            assert!(l1.proved_no_overflow(), "{wq:?} conv1");
            assert!(l2.proved_no_overflow(), "{wq:?} conv2");
        }
    }

    #[test]
    fn unprovable_codebook_falls_back_to_checked() {
        // a full-scale W32 codebook defeats the plan-time bound; the layer
        // must fall back to checked arithmetic and still match the
        // reference kernel bit for bit on benign inputs — for the per-tap
        // *and* histogram fx kernels (the checked instantiations of both
        // accumulation orders actually execute here)
        let shape = ConvShape::new(1, 4, 4, 3, 3, 1, 1);
        let values = vec![30000.0f32, -30000.0];
        let enc = EncodedWeights {
            codebook: Codebook::new(values, QFormat::W32),
            bin_idx: Tensor::from_fn(&[1, 1, 3, 3], |i| (i % 2) as u16),
            mse: 0.0,
        };
        let mut rng = Rng::new(9);
        let image = Tensor::from_fn(&[1, 4, 4], |_| rng.signed());
        let inp = FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 1);
        let want = ws_conv_fx(&inp);
        for choice in [KernelChoice::PerTap, KernelChoice::Histogram] {
            let plan =
                LayerPlan::compile_with(shape, &enc, &[0.0], QFormat::IMAGE32, choice).unwrap();
            assert!(!plan.proved_no_overflow(), "{choice:?}");
            let mut out = vec![0i64; 4];
            let mut bins = vec![0i64; plan.scratch_len()];
            plan.conv_fx_into(ConvVariant::WeightShared, inp.image_raw.data(), &mut bins, &mut out);
            assert_eq!(out.as_slice(), want.data(), "{choice:?} ws");
            plan.conv_fx_into(ConvVariant::Pasm, inp.image_raw.data(), &mut bins, &mut out);
            assert_eq!(out.as_slice(), want.data(), "{choice:?} pasm");
        }
    }

    #[test]
    fn compile_rejects_out_of_range_bins() {
        let mut enc = encoded_net(25, 4, QFormat::W16);
        enc.conv1.bin_idx.data_mut()[0] = 100; // codebook has 4 entries
        assert!(CompiledCnn::compile(&enc, QFormat::IMAGE32).is_err());
    }

    #[test]
    fn compile_rejects_bin_equal_to_codebook_len_for_every_kernel_choice() {
        // boundary value: index == len is one past the end and must fail
        // compilation — before either kernel layout is built — under all
        // three strategies, so no kernel (per-tap or histogram, f32 or fx)
        // can ever be reached with it
        let mut enc = encoded_net(26, 4, QFormat::W16);
        enc.conv2.bin_idx.data_mut()[0] = 4; // == codebook len
        for choice in [KernelChoice::PerTap, KernelChoice::Histogram, KernelChoice::Auto] {
            let err = CompiledCnn::compile_with(&enc, QFormat::IMAGE32, choice)
                .err()
                .map(|e| format!("{e:#}"))
                .unwrap_or_else(|| panic!("{choice:?} accepted bin == codebook len"));
            assert!(err.contains("out of range"), "{choice:?}: {err}");
        }
    }

    #[test]
    fn auto_choice_resolves_by_taps_per_bin() {
        // default digits net, B=16: conv1 has 9 taps (9 < 32 -> per-tap),
        // conv2 has 72 taps (72 >= 32 -> histogram)
        let enc = encoded_net(27, 16, QFormat::W16);
        let plan = CompiledCnn::compile(&enc, QFormat::IMAGE32).unwrap();
        let (l1, l2) = plan.layers();
        assert_eq!(l1.kernel(), KernelKind::PerTap);
        assert_eq!(l2.kernel(), KernelKind::Histogram);
        assert_eq!(plan.kernel_choice(), KernelChoice::Auto);
        // explicit overrides force both layers
        let forced =
            CompiledCnn::compile_with(&enc, QFormat::IMAGE32, KernelChoice::Histogram).unwrap();
        let (f1, f2) = forced.layers();
        assert_eq!(f1.kernel(), KernelKind::Histogram);
        assert_eq!(f2.kernel(), KernelKind::Histogram);
        assert!(f1.scratch_len() >= f1.bins() * HIST_TILE);
    }

    #[test]
    fn kernel_choice_parses_and_displays() {
        for (s, want) in [
            ("per-tap", KernelChoice::PerTap),
            ("histogram", KernelChoice::Histogram),
            ("auto", KernelChoice::Auto),
        ] {
            let got: KernelChoice = s.parse().unwrap();
            assert_eq!(got, want);
            assert_eq!(got.to_string(), s);
        }
        assert!("Histogram".parse::<KernelChoice>().is_err());
        assert!("".parse::<KernelChoice>().is_err());
    }

    #[test]
    fn layer_conv_matches_reference_kernel() {
        // standalone LayerPlan conv vs the reference fx kernel on a
        // non-default shape (stride 2 — exercises the histogram kernel's
        // strided gather path, not just the stride-1 slice fast path)
        let mut rng = Rng::new(31);
        let shape = ConvShape::new(3, 9, 9, 3, 3, 2, 2);
        let w = Tensor::from_fn(&[2, 3, 3, 3], |_| rng.signed());
        let enc = encode_weights(&w, 8, QFormat::W16);
        let image = Tensor::from_fn(&[3, 9, 9], |_| rng.signed() * 4.0);
        let inp = FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 2);
        let want = ws_conv_fx(&inp);
        for choice in [KernelChoice::PerTap, KernelChoice::Histogram] {
            let plan = LayerPlan::compile_with(shape, &enc, &[0.0, 0.0], QFormat::IMAGE32, choice)
                .unwrap();
            let mut out = vec![0i64; want.len()];
            let mut bins = vec![0i64; plan.scratch_len()];
            plan.conv_fx_into(ConvVariant::Pasm, inp.image_raw.data(), &mut bins, &mut out);
            assert_eq!(out.as_slice(), want.data(), "{choice:?}");
        }
    }

    #[test]
    fn histogram_f32_bitexact_per_tap_pasm_on_full_net() {
        // the f32 exactness claim at network scale: the histogram plan's
        // forward must be bit-identical to the per-tap plan's (and hence
        // to the reference) for the PASM variant
        let enc = encoded_net(33, 16, QFormat::W32);
        let per_tap =
            CompiledCnn::compile_with(&enc, QFormat::IMAGE32, KernelChoice::PerTap).unwrap();
        let hist =
            CompiledCnn::compile_with(&enc, QFormat::IMAGE32, KernelChoice::Histogram).unwrap();
        let mut rng = Rng::new(11);
        for d in 0..6usize {
            let img = render_digit(&mut rng, d, 0.1);
            let want = enc.forward(&img, ConvVariant::Pasm);
            assert_eq!(bits(&per_tap.forward_f32(&img, ConvVariant::Pasm)), bits(&want));
            assert_eq!(bits(&hist.forward_f32(&img, ConvVariant::Pasm)), bits(&want));
        }
    }
}
