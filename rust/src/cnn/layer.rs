//! Non-convolution layer building blocks: bias, ReLU, max-pool, dense.
//!
//! The paper's accelerator includes stride, bias and ReLU in the datapath
//! (§4: "the activation function and bias parameters are not shared"); the
//! pool/dense layers complete the digits CNN used by the e2e example.
//!
//! Each op exists in two forms: a tensor-level convenience and a
//! slice-level `*_slice` / `*_into` worker the convenience delegates to.
//! The workers are what [`crate::cnn::plan::CompiledCnn`] drives over its
//! scratch arenas — one code path means the planned forward is
//! bit-identical to the reference forward by construction, not by luck.

use crate::tensor::Tensor;

/// Add a per-output-channel bias in place: `x[m,·,·] += bias[m]`.
pub fn add_bias(x: &mut Tensor<f32>, bias: &[f32]) {
    let dims = x.dims().to_vec();
    assert_eq!(dims.len(), 3, "bias expects [M,H,W]");
    let plane = dims[1] * dims[2];
    add_bias_slice(x.data_mut(), plane, bias);
}

/// Slice worker for [`add_bias`]: `x` is `[M,H,W]` flattened row-major with
/// `plane = H * W`.
pub fn add_bias_slice(x: &mut [f32], plane: usize, bias: &[f32]) {
    assert_eq!(x.len(), plane * bias.len(), "bias length mismatch");
    for (m, &b) in bias.iter().enumerate() {
        for v in &mut x[m * plane..(m + 1) * plane] {
            *v += b;
        }
    }
}

/// ReLU in place.
pub fn relu(x: &mut Tensor<f32>) {
    relu_slice(x.data_mut());
}

/// Slice worker for [`relu`].
pub fn relu_slice(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// 2x2 stride-2 VALID max-pool over `[C,H,W]` (odd trailing row/col dropped,
/// matching `ref.maxpool2` on the python side).
pub fn maxpool2(x: &Tensor<f32>) -> Tensor<f32> {
    let dims = x.dims();
    assert_eq!(dims.len(), 3);
    let (c, h, w) = (dims[0], dims[1], dims[2]);
    let mut out = Tensor::zeros(&[c, h / 2, w / 2]);
    maxpool2_into(x.data(), c, h, w, out.data_mut());
    out
}

/// Slice worker for [`maxpool2`]: `x` is `[C,H,W]` flattened, `out` must be
/// `[C, H/2, W/2]` flattened.
pub fn maxpool2_into(x: &[f32], c: usize, h: usize, w: usize, out: &mut [f32]) {
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(x.len(), c * h * w, "maxpool input length mismatch");
    assert_eq!(out.len(), c * oh * ow, "maxpool output length mismatch");
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..2 {
                    for dx in 0..2 {
                        m = m.max(x[ci * h * w + (oy * 2 + dy) * w + (ox * 2 + dx)]);
                    }
                }
                out[ci * oh * ow + oy * ow + ox] = m;
            }
        }
    }
}

/// Max-pool backward helper: argmax mask positions (training path).
pub fn maxpool2_with_argmax(x: &Tensor<f32>) -> (Tensor<f32>, Vec<usize>) {
    let dims = x.dims();
    let (c, h, w) = (dims[0], dims[1], dims[2]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = Tensor::zeros(&[c, oh, ow]);
    let mut arg = vec![0usize; c * oh * ow];
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = f32::NEG_INFINITY;
                let mut mi = 0usize;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let iy = oy * 2 + dy;
                        let ix = ox * 2 + dx;
                        let v = x.at(&[ci, iy, ix]);
                        if v > m {
                            m = v;
                            mi = ci * h * w + iy * w + ix;
                        }
                    }
                }
                *out.at_mut(&[ci, oy, ox]) = m;
                arg[ci * oh * ow + oy * ow + ox] = mi;
            }
        }
    }
    (out, arg)
}

/// Dense layer: `feat [K] @ w [K,N] + b [N]`.
pub fn dense(feat: &[f32], w: &Tensor<f32>, b: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; b.len()];
    dense_into(feat, w, b, &mut out);
    out
}

/// Slice worker for [`dense`]: writes the logits into a caller-provided
/// buffer (the zero-allocation serving path).
pub fn dense_into(feat: &[f32], w: &Tensor<f32>, b: &[f32], out: &mut [f32]) {
    let dims = w.dims();
    assert_eq!(dims.len(), 2);
    let (k, n) = (dims[0], dims[1]);
    assert_eq!(feat.len(), k, "feature dim mismatch");
    assert_eq!(b.len(), n);
    assert_eq!(out.len(), n, "logit buffer length mismatch");
    out.copy_from_slice(b);
    for (i, &f) in feat.iter().enumerate() {
        let row = &w.data()[i * n..(i + 1) * n];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += f * wv;
        }
    }
}

// ---------------------------------------------------------------------------
// Fixed-point (raw integer) layer variants — the datapath the accelerator
// actually implements.  Bias/ReLU/max-pool are order-preserving on raw
// two's-complement values, so the paper's §5.3 PASM ≡ WS bit-exactness
// carries through the whole network, not just the conv layers.
// ---------------------------------------------------------------------------

/// Add a per-output-channel raw bias in place: `x[m,·,·] += bias_raw[m]`.
/// `bias_raw` must carry the same fractional bits as `x`.
pub fn add_bias_fx(x: &mut Tensor<i64>, bias_raw: &[i64]) {
    let dims = x.dims().to_vec();
    assert_eq!(dims.len(), 3, "bias expects [M,H,W]");
    let plane = dims[1] * dims[2];
    add_bias_fx_slice(x.data_mut(), plane, bias_raw);
}

/// Slice worker for [`add_bias_fx`].
pub fn add_bias_fx_slice(x: &mut [i64], plane: usize, bias_raw: &[i64]) {
    assert_eq!(x.len(), plane * bias_raw.len(), "bias length mismatch");
    for (m, &b) in bias_raw.iter().enumerate() {
        for v in &mut x[m * plane..(m + 1) * plane] {
            *v = v.checked_add(b).expect("bias add overflow");
        }
    }
}

/// ReLU in place on raw values (sign test is format-independent).
pub fn relu_fx(x: &mut Tensor<i64>) {
    relu_fx_slice(x.data_mut());
}

/// Slice worker for [`relu_fx`].
pub fn relu_fx_slice(x: &mut [i64]) {
    for v in x {
        if *v < 0 {
            *v = 0;
        }
    }
}

/// 2x2 stride-2 VALID max-pool over raw `[C,H,W]` values.  Max commutes
/// with the (monotonic) fixed-point encoding, so this matches [`maxpool2`]
/// on the decoded values exactly.
pub fn maxpool2_fx(x: &Tensor<i64>) -> Tensor<i64> {
    let dims = x.dims();
    assert_eq!(dims.len(), 3);
    let (c, h, w) = (dims[0], dims[1], dims[2]);
    let mut out = Tensor::zeros(&[c, h / 2, w / 2]);
    maxpool2_fx_into(x.data(), c, h, w, out.data_mut());
    out
}

/// Slice worker for [`maxpool2_fx`].
pub fn maxpool2_fx_into(x: &[i64], c: usize, h: usize, w: usize, out: &mut [i64]) {
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(x.len(), c * h * w, "maxpool input length mismatch");
    assert_eq!(out.len(), c * oh * ow, "maxpool output length mismatch");
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = i64::MIN;
                for dy in 0..2 {
                    for dx in 0..2 {
                        m = m.max(x[ci * h * w + (oy * 2 + dy) * w + (ox * 2 + dx)]);
                    }
                }
                out[ci * oh * ow + oy * ow + ox] = m;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shared fixed-point accumulator primitives and histogram tile workers.
//
// The compiled kernels in `cnn::plan` come in two execution shapes: the
// per-tap kernels (one multiply per tap, mirroring the reference
// accumulation order) and the histogram kernels (the paper's
// count-then-multiply restructure: accumulate activations into B per-bin
// partial sums, then finish with B multiplies against the codebook).
// Both shapes share these primitives, so the checked/wrapping overflow
// policy lives in exactly one place.
//
// The tile workers are the histogram kernels' inner loops over a
// cache-blocked run of adjacent output pixels.  They are written as
// exact-length slice zips — the shape LLVM's autovectorizer reliably
// turns into vector adds.  That claim is *checked*, not hoped:
// `tests/kernel_vectorization.rs` disassembles the `#[no_mangle]` probe
// wrappers in `cnn::plan` and fails if the emitted loop is scalar.
// ---------------------------------------------------------------------------

/// Accumulator add under the plan-time overflow policy: `CHECKED` keeps
/// `checked_add` (codebooks that defeat the overflow proof), `!CHECKED`
/// is a plain wrapping add guarded by a `debug_assert` (the proof showed
/// no representable input can overflow).
#[inline(always)]
pub(crate) fn acc_add<const CHECKED: bool>(a: i64, b: i64) -> i64 {
    if CHECKED {
        a.checked_add(b).expect("planned accumulator overflow")
    } else {
        debug_assert!(a.checked_add(b).is_some(), "plan-time overflow bound violated (add)");
        a.wrapping_add(b)
    }
}

/// Multiply under the plan-time overflow policy (see [`acc_add`]).
#[inline(always)]
pub(crate) fn acc_mul<const CHECKED: bool>(a: i64, b: i64) -> i64 {
    if CHECKED {
        a.checked_mul(b).expect("planned product overflow")
    } else {
        debug_assert!(a.checked_mul(b).is_some(), "plan-time overflow bound violated (mul)");
        a.wrapping_mul(b)
    }
}

/// Histogram PAS inner loop, f32: `acc[j] += src[j]` over an exact-length
/// tile of adjacent output pixels (element-wise, no reduction — trivially
/// vectorizable without reassociating IEEE additions).
#[inline(always)]
pub(crate) fn acc_tile_f32(acc: &mut [f32], src: &[f32]) {
    for (a, &v) in acc.iter_mut().zip(src) {
        *a += v;
    }
}

/// Histogram PAS inner loop, fixed point: `acc[j] += src[j]` under the
/// plan-time overflow policy.  The `!CHECKED` instantiation is a plain
/// `i64` vector add in release builds.
#[inline(always)]
pub(crate) fn acc_tile_fx<const CHECKED: bool>(acc: &mut [i64], src: &[i64]) {
    for (a, &v) in acc.iter_mut().zip(src) {
        *a = acc_add::<CHECKED>(*a, v);
    }
}

/// Histogram post-pass MAC, f32: `out[j] += acc[j] * cv` — one codebook
/// entry broadcast against a tile of per-bin partial sums.
#[inline(always)]
pub(crate) fn mac_tile_f32(out: &mut [f32], acc: &[f32], cv: f32) {
    for (o, &a) in out.iter_mut().zip(acc) {
        *o += a * cv;
    }
}

/// Histogram post-pass MAC, fixed point (see [`mac_tile_f32`]).
#[inline(always)]
pub(crate) fn mac_tile_fx<const CHECKED: bool>(out: &mut [i64], acc: &[i64], cv: i64) {
    for (o, &a) in out.iter_mut().zip(acc) {
        *o = acc_add::<CHECKED>(*o, acc_mul::<CHECKED>(a, cv));
    }
}

/// Numerically-stable softmax.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

/// Cross-entropy loss of softmax(logits) against a class label.
pub fn cross_entropy(logits: &[f32], label: usize) -> f32 {
    let p = softmax(logits);
    -(p[label].max(1e-12)).ln()
}

/// argmax index.
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_per_channel() {
        let mut x = Tensor::zeros(&[2, 2, 2]);
        add_bias(&mut x, &[1.0, -2.0]);
        assert_eq!(x.at(&[0, 1, 1]), 1.0);
        assert_eq!(x.at(&[1, 0, 0]), -2.0);
    }

    #[test]
    fn relu_clamps() {
        let mut x = Tensor::from_vec(&[4], vec![-1.0, 0.0, 2.0, -0.5]);
        relu(&mut x);
        assert_eq!(x.data(), &[0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn maxpool_picks_max() {
        let x = Tensor::from_vec(&[1, 2, 4], vec![1.0, 2.0, 5.0, 6.0, 3.0, 4.0, 7.0, 8.0]);
        let p = maxpool2(&x);
        assert_eq!(p.dims(), &[1, 1, 2]);
        assert_eq!(p.data(), &[4.0, 8.0]);
    }

    #[test]
    fn maxpool_odd_dims_dropped() {
        let x = Tensor::from_fn(&[1, 5, 5], |i| i as f32);
        let p = maxpool2(&x);
        assert_eq!(p.dims(), &[1, 2, 2]);
    }

    #[test]
    fn argmax_mask_positions() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, 9.0, 3.0, 4.0]);
        let (p, arg) = maxpool2_with_argmax(&x);
        assert_eq!(p.data(), &[9.0]);
        assert_eq!(arg, vec![1]);
    }

    #[test]
    fn dense_matvec() {
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
        let out = dense(&[2.0, 3.0], &w, &[0.1, 0.2, 0.3]);
        assert_eq!(out, vec![2.1, 3.2, 0.3]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn cross_entropy_prefers_correct() {
        assert!(cross_entropy(&[5.0, 0.0], 0) < cross_entropy(&[5.0, 0.0], 1));
    }

    #[test]
    fn softmax_large_values_stable() {
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn fx_bias_relu_match_float() {
        // raw-integer bias/ReLU agree with the float path after decoding
        let frac = 8u32;
        let scale = (1i64 << frac) as f32;
        let vals = [-1.5f32, 0.25, 2.0, -0.125];
        let mut xf = Tensor::from_vec(&[2, 1, 2], vals.to_vec());
        let mut xr = xf.map(|v| (v * scale) as i64);
        add_bias(&mut xf, &[0.5, -1.0]);
        relu(&mut xf);
        add_bias_fx(&mut xr, &[(0.5 * scale as f64) as i64, (-1.0 * scale as f64) as i64]);
        relu_fx(&mut xr);
        for (r, f) in xr.data().iter().zip(xf.data()) {
            assert!((*r as f32 / scale - f).abs() < 1e-6);
        }
    }

    #[test]
    fn fx_maxpool_matches_float_order() {
        let x = Tensor::from_vec(&[1, 2, 4], vec![1i64, 2, 5, 6, 3, 4, 7, 8]);
        let p = maxpool2_fx(&x);
        assert_eq!(p.dims(), &[1, 1, 2]);
        assert_eq!(p.data(), &[4, 8]);
    }

    #[test]
    fn fx_maxpool_negative_values() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![-9i64, -1, -4, -2]);
        let p = maxpool2_fx(&x);
        assert_eq!(p.data(), &[-1]);
    }
}
