//! Hand-written SGD trainer for the digits CNN.
//!
//! The e2e example needs *real trained weights* to quantize (the weight
//! distribution is what K-means clusters), so we train the float network
//! here — forward and backward written out explicitly for the fixed
//! architecture.  This is the "training" the paper assumes has already
//! happened before weight sharing is applied.

use crate::cnn::conv::direct_conv_f32;
use crate::cnn::data::Sample;
use crate::cnn::layer::{add_bias, dense, maxpool2_with_argmax, softmax};
use crate::cnn::network::{DigitsCnn, NetworkParams};
use crate::tensor::Tensor;

/// Training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    /// Passes over the training set.
    pub epochs: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// SGD momentum coefficient.
    pub momentum: f32,
    /// Print a log line every N epochs (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 8, lr: 0.05, momentum: 0.9, log_every: 0 }
    }
}

/// Gradient buffers (same shapes as the parameters).
struct Grads {
    conv1_w: Tensor<f32>,
    conv1_b: Vec<f32>,
    conv2_w: Tensor<f32>,
    conv2_b: Vec<f32>,
    dense_w: Tensor<f32>,
    dense_b: Vec<f32>,
}

impl Grads {
    fn zeros_like(p: &NetworkParams) -> Self {
        Grads {
            conv1_w: Tensor::zeros(p.conv1_w.dims()),
            conv1_b: vec![0.0; p.conv1_b.len()],
            conv2_w: Tensor::zeros(p.conv2_w.dims()),
            conv2_b: vec![0.0; p.conv2_b.len()],
            dense_w: Tensor::zeros(p.dense_w.dims()),
            dense_b: vec![0.0; p.dense_b.len()],
        }
    }
}

/// One training epoch log entry.
#[derive(Clone, Copy, Debug)]
pub struct EpochStats {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean cross-entropy loss over the epoch.
    pub mean_loss: f64,
    /// Accuracy on the training set after the epoch.
    pub train_accuracy: f64,
}

/// Convolution gradient wrt weights: `gw[m,c,ky,kx] += Σ x[c,oy+ky,ox+kx] * go[m,oy,ox]`.
fn conv_grad_w(x: &Tensor<f32>, go: &Tensor<f32>, gw: &mut Tensor<f32>) {
    let (m_n, c_n) = (gw.dims()[0], gw.dims()[1]);
    let (ky_n, kx_n) = (gw.dims()[2], gw.dims()[3]);
    let (oh, ow) = (go.dims()[1], go.dims()[2]);
    for m in 0..m_n {
        for c in 0..c_n {
            for ky in 0..ky_n {
                for kx in 0..kx_n {
                    let mut g = 0f32;
                    for oy in 0..oh {
                        for ox in 0..ow {
                            g += x.at(&[c, oy + ky, ox + kx]) * go.at(&[m, oy, ox]);
                        }
                    }
                    *gw.at_mut(&[m, c, ky, kx]) += g;
                }
            }
        }
    }
}

/// Convolution gradient wrt input: full correlation with flipped kernel.
fn conv_grad_x(w: &Tensor<f32>, go: &Tensor<f32>, x_dims: &[usize]) -> Tensor<f32> {
    let (m_n, c_n) = (w.dims()[0], w.dims()[1]);
    let (ky_n, kx_n) = (w.dims()[2], w.dims()[3]);
    let (oh, ow) = (go.dims()[1], go.dims()[2]);
    let mut gx = Tensor::zeros(x_dims);
    for m in 0..m_n {
        for oy in 0..oh {
            for ox in 0..ow {
                let g = go.at(&[m, oy, ox]);
                if g == 0.0 {
                    continue;
                }
                for c in 0..c_n {
                    for ky in 0..ky_n {
                        for kx in 0..kx_n {
                            *gx.at_mut(&[c, oy + ky, ox + kx]) += w.at(&[m, c, ky, kx]) * g;
                        }
                    }
                }
            }
        }
    }
    gx
}

/// Forward + backward for one sample; accumulates into `grads`, returns loss.
fn backprop(
    arch: &DigitsCnn,
    params: &NetworkParams,
    grads: &mut Grads,
    sample: &Sample,
) -> f32 {
    // ---- forward, keeping intermediates ----
    let mut a1 = direct_conv_f32(&sample.image, &params.conv1_w, 1); // [8,10,10]
    add_bias(&mut a1, &params.conv1_b);
    let relu1_mask: Vec<bool> = a1.data().iter().map(|&v| v > 0.0).collect();
    for v in a1.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    let (p1, argmax1) = maxpool2_with_argmax(&a1); // [8,5,5]
    let mut a2 = direct_conv_f32(&p1, &params.conv2_w, 1); // [16,3,3]
    add_bias(&mut a2, &params.conv2_b);
    let relu2_mask: Vec<bool> = a2.data().iter().map(|&v| v > 0.0).collect();
    for v in a2.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    let feat = a2.clone().into_vec(); // [144]
    let logits = dense(&feat, &params.dense_w, &params.dense_b);
    let probs = softmax(&logits);
    let loss = -(probs[sample.label].max(1e-12)).ln();

    // ---- backward ----
    // d logits
    let mut dl = probs;
    dl[sample.label] -= 1.0;
    // dense grads
    let n = arch.classes;
    for (i, &f) in feat.iter().enumerate() {
        for (j, &d) in dl.iter().enumerate() {
            grads.dense_w.data_mut()[i * n + j] += f * d;
        }
    }
    for (gb, &d) in grads.dense_b.iter_mut().zip(&dl) {
        *gb += d;
    }
    // d feat
    let mut dfeat = vec![0f32; feat.len()];
    for (i, df) in dfeat.iter_mut().enumerate() {
        let row = &params.dense_w.data()[i * n..(i + 1) * n];
        *df = row.iter().zip(&dl).map(|(&w, &d)| w * d).sum();
    }
    // through relu2
    let mut da2 = Tensor::from_vec(a2.dims(), dfeat);
    for (v, &m) in da2.data_mut().iter_mut().zip(&relu2_mask) {
        if !m {
            *v = 0.0;
        }
    }
    // conv2 grads
    conv_grad_w(&p1, &da2, &mut grads.conv2_w);
    let plane2 = da2.dims()[1] * da2.dims()[2];
    for m in 0..arch.conv2_m {
        grads.conv2_b[m] += da2.data()[m * plane2..(m + 1) * plane2].iter().sum::<f32>();
    }
    // d p1
    let dp1 = conv_grad_x(&params.conv2_w, &da2, p1.dims());
    // through maxpool (route to argmax positions)
    let mut da1 = Tensor::zeros(a1.dims());
    for (i, &src) in argmax1.iter().enumerate() {
        da1.data_mut()[src] += dp1.data()[i];
    }
    // through relu1
    for (v, &m) in da1.data_mut().iter_mut().zip(&relu1_mask) {
        if !m {
            *v = 0.0;
        }
    }
    // conv1 grads
    conv_grad_w(&sample.image, &da1, &mut grads.conv1_w);
    let plane1 = da1.dims()[1] * da1.dims()[2];
    for m in 0..arch.conv1_m {
        grads.conv1_b[m] += da1.data()[m * plane1..(m + 1) * plane1].iter().sum::<f32>();
    }
    loss
}

/// SGD with momentum over the dataset. Returns per-epoch stats.
pub fn train(
    arch: &DigitsCnn,
    params: &mut NetworkParams,
    data: &[Sample],
    cfg: &TrainConfig,
) -> Vec<EpochStats> {
    assert!(!data.is_empty());
    let mut vel = Grads::zeros_like(params);
    let mut stats = Vec::new();
    let batch = 16usize;

    for epoch in 0..cfg.epochs {
        let mut total_loss = 0f64;
        let mut correct = 0usize;
        for chunk in data.chunks(batch) {
            let mut grads = Grads::zeros_like(params);
            for s in chunk {
                let loss = backprop(arch, params, &mut grads, s);
                total_loss += loss as f64;
                let logits = arch.forward(params, &s.image);
                if crate::cnn::layer::argmax(&logits) == s.label {
                    correct += 1;
                }
            }
            let scale = cfg.lr / chunk.len() as f32;
            let mu = cfg.momentum;
            // momentum update, one tensor at a time
            macro_rules! upd {
                ($vp:expr, $gp:expr, $pp:expr) => {
                    for ((v, g), p) in $vp.iter_mut().zip($gp.iter()).zip($pp.iter_mut()) {
                        *v = mu * *v - scale * *g;
                        *p += *v;
                    }
                };
            }
            upd!(vel.conv1_w.data_mut(), grads.conv1_w.data(), params.conv1_w.data_mut());
            upd!(vel.conv1_b, grads.conv1_b, params.conv1_b);
            upd!(vel.conv2_w.data_mut(), grads.conv2_w.data(), params.conv2_w.data_mut());
            upd!(vel.conv2_b, grads.conv2_b, params.conv2_b);
            upd!(vel.dense_w.data_mut(), grads.dense_w.data(), params.dense_w.data_mut());
            upd!(vel.dense_b, grads.dense_b, params.dense_b);
        }
        let st = EpochStats {
            epoch,
            mean_loss: total_loss / data.len() as f64,
            train_accuracy: correct as f64 / data.len() as f64,
        };
        if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
            eprintln!(
                "epoch {:>3}  loss {:.4}  acc {:.1}%",
                st.epoch,
                st.mean_loss,
                st.train_accuracy * 100.0
            );
        }
        stats.push(st);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::data::{train_test, Rng};

    #[test]
    fn loss_decreases_on_tiny_set() {
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(11);
        let mut params = arch.init(&mut rng);
        let (train_set, _) = train_test(3, 40, 1, 0.02);
        let cfg = TrainConfig { epochs: 15, lr: 0.05, momentum: 0.9, log_every: 0 };
        let stats = train(&arch, &mut params, &train_set, &cfg);
        assert!(
            stats.last().unwrap().mean_loss < stats[0].mean_loss * 0.8,
            "loss did not decrease: {:?} -> {:?}",
            stats[0].mean_loss,
            stats.last().unwrap().mean_loss
        );
    }

    #[test]
    fn gradients_numerically_correct() {
        // finite-difference check on a few conv1 weights
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(13);
        let params = arch.init(&mut rng);
        let (ds, _) = train_test(5, 1, 1, 0.05);
        let s = &ds[0];

        let mut grads = Grads::zeros_like(&params);
        backprop(&arch, &params, &mut grads, s);

        let eps = 1e-3f32;
        for &probe in &[0usize, 7, 33, 70] {
            let mut p_plus = params.clone();
            p_plus.conv1_w.data_mut()[probe] += eps;
            let mut p_minus = params.clone();
            p_minus.conv1_w.data_mut()[probe] -= eps;
            let l_plus = {
                let logits = arch.forward(&p_plus, &s.image);
                crate::cnn::layer::cross_entropy(&logits, s.label)
            };
            let l_minus = {
                let logits = arch.forward(&p_minus, &s.image);
                crate::cnn::layer::cross_entropy(&logits, s.label)
            };
            let numeric = (l_plus - l_minus) / (2.0 * eps);
            let analytic = grads.conv1_w.data()[probe];
            assert!(
                (numeric - analytic).abs() < 2e-2_f32.max(0.2 * numeric.abs()),
                "probe {probe}: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn training_reaches_high_accuracy() {
        // small but real: 300 samples, 20 epochs -> should fit well
        let arch = DigitsCnn::default();
        let mut rng = Rng::new(17);
        let mut params = arch.init(&mut rng);
        let (train_set, test_set) = train_test(7, 300, 60, 0.05);
        let cfg = TrainConfig { epochs: 20, lr: 0.05, momentum: 0.9, log_every: 0 };
        train(&arch, &mut params, &train_set, &cfg);
        let acc = arch.accuracy(&params, &test_set);
        assert!(acc > 0.8, "test accuracy too low: {acc}");
    }
}
