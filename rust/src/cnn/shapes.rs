//! Layer-shape tables: paper Table 2 and representative network configs.
//!
//! Table 2 tabulates the MAC operations per output element (`C·KX·KY`) for
//! typical channel counts and kernel sizes — the quantity that must dominate
//! the bin count `B` for PASM to win.  The AlexNet-like table drives the
//! design-space sweep example.

use crate::tensor::ConvShape;

/// Paper Table 2 grid: the channel counts swept.
pub const TABLE2_CHANNELS: [usize; 3] = [32, 128, 512];
/// Paper Table 2 grid: the kernel sizes swept.
pub const TABLE2_KERNELS: [usize; 4] = [1, 3, 5, 7];

/// One Table 2 cell: MAC ops per output element.
pub fn table2_macs(channels: usize, kernel: usize) -> usize {
    channels * kernel * kernel
}

/// The full Table 2 as (channels, kernel, macs) rows, row-major like the
/// paper (kernel rows, channel columns).
pub fn table2() -> Vec<(usize, usize, usize)> {
    let mut rows = Vec::new();
    for &k in &TABLE2_KERNELS {
        for &c in &TABLE2_CHANNELS {
            rows.push((c, k, table2_macs(c, k)));
        }
    }
    rows
}

/// PASM efficiency precondition (paper §3/§4): the number of accumulations
/// per output must be much larger than the bin count. We expose the ratio;
/// callers decide the threshold (the paper's examples use >= a few x).
pub fn pasm_amortization(shape: &ConvShape, bins: usize) -> f64 {
    shape.taps() as f64 / bins as f64
}

/// A named convolution layer in a network table.
#[derive(Clone, Debug)]
pub struct LayerSpec {
    /// Layer label (e.g. "conv3").
    pub name: &'static str,
    /// The layer's convolution shape.
    pub shape: ConvShape,
}

/// AlexNet-like convolution stack (channel/kernel progression of
/// Krizhevsky et al. 2012, spatial dims scaled to keep the sweep fast; the
/// gate/power model depends only on C, K, M, B, W — not on the spatial
/// extent — and the latency model scales linearly with output pixels).
pub fn alexnet_like() -> Vec<LayerSpec> {
    vec![
        LayerSpec { name: "conv1", shape: ConvShape::new(3, 31, 31, 11, 11, 96, 4) },
        LayerSpec { name: "conv2", shape: ConvShape::new(96, 15, 15, 5, 5, 256, 1) },
        LayerSpec { name: "conv3", shape: ConvShape::new(256, 8, 8, 3, 3, 384, 1) },
        LayerSpec { name: "conv4", shape: ConvShape::new(384, 8, 8, 3, 3, 384, 1) },
        LayerSpec { name: "conv5", shape: ConvShape::new(384, 8, 8, 3, 3, 256, 1) },
    ]
}

/// VGG-16-like stack (3x3 kernels throughout).
pub fn vgg_like() -> Vec<LayerSpec> {
    vec![
        LayerSpec { name: "conv1_1", shape: ConvShape::new(3, 16, 16, 3, 3, 64, 1) },
        LayerSpec { name: "conv2_1", shape: ConvShape::new(64, 12, 12, 3, 3, 128, 1) },
        LayerSpec { name: "conv3_1", shape: ConvShape::new(128, 10, 10, 3, 3, 256, 1) },
        LayerSpec { name: "conv4_1", shape: ConvShape::new(256, 8, 8, 3, 3, 512, 1) },
        LayerSpec { name: "conv5_1", shape: ConvShape::new(512, 6, 6, 3, 3, 512, 1) },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        // spot-check the paper's printed values
        assert_eq!(table2_macs(32, 5), 800);
        assert_eq!(table2_macs(512, 7), 25088);
        assert_eq!(table2_macs(128, 3), 1152);
        let t = table2();
        assert_eq!(t.len(), 12);
        assert!(t.contains(&(32, 1, 32)));
        assert!(t.contains(&(512, 5, 12800)));
    }

    #[test]
    fn amortization_regimes() {
        // paper tile: 135 taps vs 16 bins -> ~8.4x amortization
        let tile = ConvShape::paper_tile();
        let r = pasm_amortization(&tile, 16);
        assert!(r > 8.0 && r < 9.0, "{r}");
        // 1x1 conv with 32 channels vs 256 bins -> PASM not viable
        let bad = ConvShape::new(32, 4, 4, 1, 1, 1, 1);
        assert!(pasm_amortization(&bad, 256) < 1.0);
    }

    #[test]
    fn network_tables_valid() {
        for spec in alexnet_like().iter().chain(vgg_like().iter()) {
            spec.shape.validate();
            assert!(spec.shape.taps() > 0);
        }
    }

    #[test]
    fn alexnet_taps_progression() {
        let net = alexnet_like();
        // conv2 of AlexNet: 96 channels, 5x5 -> 2400 taps
        assert_eq!(net[1].shape.taps(), 2400);
        // conv3: 256 channels, 3x3 -> 2304
        assert_eq!(net[2].shape.taps(), 2304);
    }
}
