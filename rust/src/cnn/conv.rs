//! The three convolution dataflows, functionally (no timing).
//!
//! These are the golden reference for both the Pallas kernels (via the PJRT
//! cross-check integration test) and the cycle-accurate simulator (which
//! must produce the same fixed-point outputs cycle by cycle).
//!
//! Float versions mirror `python/compile/kernels/ref.py`; fixed-point
//! versions compute in raw integer space where PASM ≡ WS-MAC holds
//! **bit-exactly** (integer addition is associative/commutative — the
//! paper's §5.3 claim).

use crate::quant::codebook::EncodedWeights;
use crate::quant::fixed::{fx_mul, QFormat};
use crate::tensor::{ConvShape, Tensor};

// ---------------------------------------------------------------------------
// f32 reference dataflows
// ---------------------------------------------------------------------------

/// Direct convolution (paper Fig 1 pseudo-code). `image [C,IH,IW]`,
/// `weights [M,C,KY,KX]` -> `[M,OH,OW]`.
pub fn direct_conv_f32(image: &Tensor<f32>, weights: &Tensor<f32>, stride: usize) -> Tensor<f32> {
    let shape = conv_shapes(image.dims(), weights.dims(), stride);
    let mut out = Tensor::zeros(shape.out_shape().dims());
    for m in 0..shape.kernels {
        for oy in 0..shape.out_h() {
            for ox in 0..shape.out_w() {
                let mut acc = 0f32;
                for c in 0..shape.channels {
                    for ky in 0..shape.kernel_h {
                        for kx in 0..shape.kernel_w {
                            let iv = image.at(&[c, oy * shape.stride + ky, ox * shape.stride + kx]);
                            let wv = weights.at(&[m, c, ky, kx]);
                            acc += iv * wv;
                        }
                    }
                }
                *out.at_mut(&[m, oy, ox]) = acc;
            }
        }
    }
    out
}

/// Weight-shared MAC convolution (Fig 3/4): decode `codebook[bin_idx]` per
/// tap, multiply-accumulate — the indirection of the weights register file.
///
/// Panics if any bin index is out of range for `codebook` (a corrupt
/// encoding must be a hard error, not a silent wild read).
pub fn ws_conv_f32(
    image: &Tensor<f32>,
    bin_idx: &Tensor<u16>,
    codebook: &[f32],
    stride: usize,
) -> Tensor<f32> {
    let shape = conv_shapes(image.dims(), bin_idx.dims(), stride);
    assert_bins_in_range(bin_idx.data(), codebook.len());
    let mut out = Tensor::zeros(shape.out_shape().dims());
    for m in 0..shape.kernels {
        for oy in 0..shape.out_h() {
            for ox in 0..shape.out_w() {
                let mut acc = 0f32;
                for c in 0..shape.channels {
                    for ky in 0..shape.kernel_h {
                        for kx in 0..shape.kernel_w {
                            let iv = image.at(&[c, oy * shape.stride + ky, ox * shape.stride + kx]);
                            let b = bin_idx.at(&[m, c, ky, kx]) as usize;
                            acc += iv * codebook[b];
                        }
                    }
                }
                *out.at_mut(&[m, oy, ox]) = acc;
            }
        }
    }
    out
}

/// PASM convolution (Fig 5/6, SystemC of Fig 13): phase 1 accumulates image
/// values into `B` bins keyed by the tap's dictionary index (the PAS), phase
/// 2 multiplies each bin once with its codebook weight (shared post-pass
/// MAC).
pub fn pasm_conv_f32(
    image: &Tensor<f32>,
    bin_idx: &Tensor<u16>,
    codebook: &[f32],
    stride: usize,
) -> Tensor<f32> {
    let shape = conv_shapes(image.dims(), bin_idx.dims(), stride);
    assert_bins_in_range(bin_idx.data(), codebook.len());
    let b_total = codebook.len();
    let mut out = Tensor::zeros(shape.out_shape().dims());
    let mut image_bin = vec![0f32; b_total];
    for m in 0..shape.kernels {
        for oy in 0..shape.out_h() {
            for ox in 0..shape.out_w() {
                image_bin.iter_mut().for_each(|b| *b = 0.0); // reset bins
                // PAS phase: weighted histogram of dictionary indices.
                for c in 0..shape.channels {
                    for ky in 0..shape.kernel_h {
                        for kx in 0..shape.kernel_w {
                            let iv = image.at(&[c, oy * shape.stride + ky, ox * shape.stride + kx]);
                            let b = bin_idx.at(&[m, c, ky, kx]) as usize;
                            image_bin[b] += iv;
                        }
                    }
                }
                // Post-pass MAC: B multiplies, shared unit.
                let mut acc = 0f32;
                for b in 0..b_total {
                    acc += image_bin[b] * codebook[b];
                }
                *out.at_mut(&[m, oy, ox]) = acc;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fixed-point (bit-exact) dataflows
// ---------------------------------------------------------------------------

/// Inputs to the fixed-point dataflows, pre-encoded to raw integers.
///
/// `image_raw` is in the image format `iq`; `codebook_raw` in the weight
/// format `wq`.  Outputs carry `iq.frac + wq.frac` fractional bits (wide
/// accumulator — the narrowing back to an output format is a separate,
/// explicitly-audited step, as in the RTL).
#[derive(Clone, Debug)]
pub struct FxConvInputs {
    /// Image in raw fixed point (format `iq`).
    pub image_raw: Tensor<i64>,
    /// Per-weight dictionary bin indices `[M, C, KY, KX]`.
    pub bin_idx: Tensor<u16>,
    /// Dictionary entries in raw fixed point (format `wq`).
    pub codebook_raw: Vec<i64>,
    /// Image fixed-point format.
    pub iq: QFormat,
    /// Weight fixed-point format.
    pub wq: QFormat,
    /// Convolution stride.
    pub stride: usize,
}

impl FxConvInputs {
    /// Encode float inputs into the given fixed-point formats.
    ///
    /// Internal/reference-path only: this clones `bin_idx` and re-derives
    /// the raw codebook on **every call**, which is exactly the per-request
    /// overhead the serving path must not pay.  Serving code goes through
    /// [`crate::cnn::plan::CompiledCnn`], which precomputes all weight-derived
    /// state once; this constructor stays as the golden-oracle input builder
    /// for tests and the cycle-accurate simulator.
    #[doc(hidden)]
    pub fn encode(
        image: &Tensor<f32>,
        enc: &EncodedWeights,
        iq: QFormat,
        stride: usize,
    ) -> Self {
        FxConvInputs {
            image_raw: image.map(|x| iq.encode(x as f64)),
            bin_idx: enc.bin_idx.clone(),
            codebook_raw: enc.codebook.raw(),
            iq,
            wq: enc.codebook.wq,
            stride,
        }
    }

    /// The conv shape these inputs describe.
    pub fn shape(&self) -> ConvShape {
        conv_shapes(self.image_raw.dims(), self.bin_idx.dims(), self.stride)
    }

    /// Fractional bits of the raw output values.
    pub fn out_frac(&self) -> u32 {
        self.iq.frac + self.wq.frac
    }
}

/// Fixed-point weight-shared MAC convolution: per tap
/// `acc += image_raw * codebook_raw[bin]` in exact integer arithmetic.
///
/// Hot path (§Perf): indices are flattened by hand — the generic
/// `Tensor::at` costs three multiplies per tap, which dominates the loop.
pub fn ws_conv_fx(inp: &FxConvInputs) -> Tensor<i64> {
    let shape = inp.shape();
    assert_bins_in_range(inp.bin_idx.data(), inp.codebook_raw.len());
    let (ih_w, k_w) = (shape.in_w, shape.kernel_w);
    let plane = shape.in_h * ih_w;
    let taps = shape.taps();
    let img = inp.image_raw.data();
    let bi = inp.bin_idx.data();
    let cb = &inp.codebook_raw;
    let mut out = Tensor::zeros(shape.out_shape().dims());
    let out_data = out.data_mut();
    let (oh, ow) = (shape.out_h(), shape.out_w());
    for m in 0..shape.kernels {
        let bi_m = &bi[m * taps..(m + 1) * taps];
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0i64;
                let mut t = 0usize;
                let base = oy * shape.stride * ih_w + ox * shape.stride;
                for c in 0..shape.channels {
                    let cplane = &img[c * plane..(c + 1) * plane];
                    for ky in 0..shape.kernel_h {
                        let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                        for &iv in row {
                            let b = bi_m[t] as usize;
                            acc = acc
                                .checked_add(fx_mul(iv, cb[b]))
                                .expect("WS accumulator overflow");
                            t += 1;
                        }
                    }
                }
                out_data[m * oh * ow + oy * ow + ox] = acc;
            }
        }
    }
    out
}

/// Fixed-point PASM convolution. Bit-identical to [`ws_conv_fx`] because
/// integer addition commutes — this is the paper's §5.3 exactness claim and
/// is enforced by property tests.
pub fn pasm_conv_fx(inp: &FxConvInputs) -> Tensor<i64> {
    let shape = inp.shape();
    assert_bins_in_range(inp.bin_idx.data(), inp.codebook_raw.len());
    let b_total = inp.codebook_raw.len();
    let (ih_w, k_w) = (shape.in_w, shape.kernel_w);
    let plane = shape.in_h * ih_w;
    let taps = shape.taps();
    let img = inp.image_raw.data();
    let bi = inp.bin_idx.data();
    let cb = &inp.codebook_raw;
    let mut out = Tensor::zeros(shape.out_shape().dims());
    let out_data = out.data_mut();
    let (oh, ow) = (shape.out_h(), shape.out_w());
    let mut image_bin = vec![0i64; b_total];
    for m in 0..shape.kernels {
        let bi_m = &bi[m * taps..(m + 1) * taps];
        for oy in 0..oh {
            for ox in 0..ow {
                image_bin.iter_mut().for_each(|b| *b = 0);
                let mut t = 0usize;
                let base = oy * shape.stride * ih_w + ox * shape.stride;
                // PAS phase (flattened hot loop, see ws_conv_fx)
                for c in 0..shape.channels {
                    let cplane = &img[c * plane..(c + 1) * plane];
                    for ky in 0..shape.kernel_h {
                        let row = &cplane[base + ky * ih_w..base + ky * ih_w + k_w];
                        for &iv in row {
                            let b = bi_m[t] as usize;
                            image_bin[b] =
                                image_bin[b].checked_add(iv).expect("PAS bin overflow");
                            t += 1;
                        }
                    }
                }
                // post-pass MAC
                let mut acc = 0i64;
                for (b, &v) in image_bin.iter().enumerate() {
                    acc = acc
                        .checked_add(fx_mul(v, cb[b]))
                        .expect("post-pass accumulator overflow");
                }
                out_data[m * oh * ow + oy * ow + ox] = acc;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------

/// Validate and derive the conv shape from image dims `[C,IH,IW]` and kernel
/// dims `[M,C,KY,KX]`.
fn conv_shapes(image_dims: &[usize], kernel_dims: &[usize], stride: usize) -> ConvShape {
    assert_eq!(image_dims.len(), 3, "image must be [C,IH,IW]");
    assert_eq!(kernel_dims.len(), 4, "kernel must be [M,C,KY,KX]");
    assert_eq!(image_dims[0], kernel_dims[1], "channel mismatch");
    ConvShape::new(
        image_dims[0],
        image_dims[1],
        image_dims[2],
        kernel_dims[2],
        kernel_dims[3],
        kernel_dims[0],
        stride,
    )
}

/// Scan the (small) bin-index stream for its real maximum and return it if
/// any index fails `max_bin < codebook_len` — the *strict* bound, so an
/// index *equal* to the codebook length is rejected too.  This is the one
/// scan every dataflow shares: the reference kernels assert on it via
/// [`assert_bins_in_range`], and `cnn::plan` runs it at compile time before
/// either the per-tap streams or the histogram (count-then-multiply) layout
/// are built, so no kernel — per-tap or histogram, f32 or fixed-point —
/// ever indexes a codebook with an out-of-range bin.
pub(crate) fn bin_range_violation(bin_idx: &[u16], codebook_len: usize) -> Option<usize> {
    let max_bin = bin_idx.iter().copied().max().unwrap_or(0) as usize;
    (max_bin >= codebook_len).then_some(max_bin)
}

/// Hard-error on any bin index outside the codebook: runs
/// [`bin_range_violation`] before the hot loops, so a corrupt encoding
/// fails loudly in both the f32 and fixed-point dataflows rather than
/// indexing out of bounds mid-convolution.
pub(crate) fn assert_bins_in_range(bin_idx: &[u16], codebook_len: usize) {
    if let Some(max_bin) = bin_range_violation(bin_idx, codebook_len) {
        panic!("bin index {max_bin} out of range for codebook with {codebook_len} entries");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::encode_weights;

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (((*seed >> 33) as f64 / (1u64 << 31) as f64) - 1.0) as f32
    }

    fn random_case(
        seed: u64,
        c: usize,
        ih: usize,
        iw: usize,
        ky: usize,
        kx: usize,
        m: usize,
        bins: usize,
    ) -> (Tensor<f32>, Tensor<u16>, Vec<f32>) {
        let mut s = seed;
        let image = Tensor::from_fn(&[c, ih, iw], |_| lcg(&mut s) * 4.0);
        let bin_idx = Tensor::from_fn(&[m, c, ky, kx], |_| {
            (lcg(&mut s).abs() * bins as f32) as u16 % bins as u16
        });
        let codebook: Vec<f32> = (0..bins).map(|_| lcg(&mut s)).collect();
        (image, bin_idx, codebook)
    }

    #[test]
    fn paper_fig4_fig6_worked_example() {
        // 5 taps: (26.7,b0) (3.4,b1) (4.8,b2) (17.7,b3) (6.1,b0); cb [1.7,0.4,1.3,2.0]
        let image = Tensor::from_vec(&[5, 1, 1], vec![26.7, 3.4, 4.8, 17.7, 6.1]);
        let bin_idx = Tensor::from_vec(&[1, 5, 1, 1], vec![0u16, 1, 2, 3, 0]);
        let cb = vec![1.7f32, 0.4, 1.3, 2.0];
        let ws = ws_conv_f32(&image, &bin_idx, &cb, 1);
        let pasm = pasm_conv_f32(&image, &bin_idx, &cb, 1);
        // exact sum is 98.76 (paper rounds to 98.8)
        assert!((ws.data()[0] - 98.76).abs() < 1e-4, "{}", ws.data()[0]);
        assert!((pasm.data()[0] - 98.76).abs() < 1e-4);
    }

    #[test]
    fn ws_equals_direct_on_decoded_weights() {
        let (image, bin_idx, cb) = random_case(1, 4, 6, 6, 3, 3, 3, 8);
        let weights = bin_idx.map(|b| cb[b as usize]);
        let a = ws_conv_f32(&image, &bin_idx, &cb, 1);
        let b = direct_conv_f32(&image, &weights, 1);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn pasm_close_to_ws_f32() {
        let (image, bin_idx, cb) = random_case(2, 15, 5, 5, 3, 3, 2, 16);
        let a = pasm_conv_f32(&image, &bin_idx, &cb, 1);
        let b = ws_conv_f32(&image, &bin_idx, &cb, 1);
        assert!(a.max_abs_diff(&b) < 1e-3);
    }

    #[test]
    fn pasm_bitexact_ws_fixed_point() {
        // the §5.3 exactness claim, in integer arithmetic
        for seed in 0..5u64 {
            let mut s = seed + 100;
            let image = Tensor::from_fn(&[15, 5, 5], |_| lcg(&mut s) * 8.0);
            let w = Tensor::from_fn(&[2, 15, 3, 3], |_| lcg(&mut s));
            let enc = encode_weights(&w, 16, QFormat::W16);
            let inp = FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 1);
            let a = ws_conv_fx(&inp);
            let b = pasm_conv_fx(&inp);
            assert_eq!(a.data(), b.data(), "seed {seed}");
        }
    }

    #[test]
    fn fx_matches_f32_within_quantization() {
        let (image, _, _) = random_case(3, 3, 6, 6, 3, 3, 2, 8);
        let w = Tensor::from_fn(&[2, 3, 3, 3], |i| ((i % 5) as f32 - 2.0) * 0.25);
        let enc = encode_weights(&w, 8, QFormat::W16);
        let inp = FxConvInputs::encode(&image, &enc, QFormat::IMAGE32, 1);
        let fx = ws_conv_fx(&inp);
        let scale = (1u64 << inp.out_frac()) as f32;
        let fxf = fx.map(|r| r as f32 / scale);
        // compare against f32 conv over the fx-rounded codebook
        let cb_fx: Vec<f32> = enc
            .codebook
            .raw()
            .iter()
            .map(|&r| enc.codebook.wq.decode(r) as f32)
            .collect();
        let f2 = ws_conv_f32(&image, &enc.bin_idx, &cb_fx, 1);
        // error bounded by image quantization ulp * taps * max|w|
        let tol = QFormat::IMAGE32.ulp() as f32 * 27.0 * 2.0 + 1e-3;
        assert!(fxf.max_abs_diff(&f2) < tol, "{}", fxf.max_abs_diff(&f2));
    }

    #[test]
    fn stride_2() {
        let (image, bin_idx, cb) = random_case(4, 3, 9, 9, 3, 3, 2, 4);
        let a = pasm_conv_f32(&image, &bin_idx, &cb, 2);
        let b = ws_conv_f32(&image, &bin_idx, &cb, 2);
        assert_eq!(a.dims(), &[2, 4, 4]);
        assert!(a.max_abs_diff(&b) < 1e-3);
    }

    #[test]
    fn k1_conv() {
        let (image, bin_idx, cb) = random_case(5, 8, 4, 4, 1, 1, 3, 4);
        let a = pasm_conv_f32(&image, &bin_idx, &cb, 1);
        let b = ws_conv_f32(&image, &bin_idx, &cb, 1);
        assert_eq!(a.dims(), &[3, 4, 4]);
        assert!(a.max_abs_diff(&b) < 1e-3);
    }

    #[test]
    #[should_panic]
    fn channel_mismatch_panics() {
        let image = Tensor::<f32>::zeros(&[3, 5, 5]);
        let weights = Tensor::<f32>::zeros(&[2, 4, 3, 3]);
        direct_conv_f32(&image, &weights, 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn ws_f32_out_of_range_bin_is_hard_error() {
        let image = Tensor::<f32>::zeros(&[1, 3, 3]);
        let bin_idx = Tensor::from_vec(&[1, 1, 3, 3], vec![0u16, 1, 2, 3, 9, 0, 1, 2, 3]);
        ws_conv_f32(&image, &bin_idx, &[0.5f32; 4], 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn pasm_f32_out_of_range_bin_is_hard_error() {
        let image = Tensor::<f32>::zeros(&[1, 3, 3]);
        let bin_idx = Tensor::from_vec(&[1, 1, 3, 3], vec![0u16, 1, 2, 3, 9, 0, 1, 2, 3]);
        pasm_conv_f32(&image, &bin_idx, &[0.5f32; 4], 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn ws_fx_out_of_range_bin_is_hard_error() {
        let inp = FxConvInputs {
            image_raw: Tensor::zeros(&[1, 3, 3]),
            bin_idx: Tensor::from_vec(&[1, 1, 3, 3], vec![0u16, 1, 2, 3, 9, 0, 1, 2, 3]),
            codebook_raw: vec![1i64; 4],
            iq: QFormat::IMAGE32,
            wq: QFormat::W16,
            stride: 1,
        };
        ws_conv_fx(&inp);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn pasm_fx_out_of_range_bin_is_hard_error() {
        let inp = FxConvInputs {
            image_raw: Tensor::zeros(&[1, 3, 3]),
            bin_idx: Tensor::from_vec(&[1, 1, 3, 3], vec![0u16, 1, 2, 3, 9, 0, 1, 2, 3]),
            codebook_raw: vec![1i64; 4],
            iq: QFormat::IMAGE32,
            wq: QFormat::W16,
            stride: 1,
        };
        pasm_conv_fx(&inp);
    }

    // Boundary regression: a bin index exactly *equal* to the codebook
    // length is one past the last entry and must be rejected by the same
    // strict scan as a wildly out-of-range one — in every kernel, before
    // any indexing happens (images are all-zero, so if the scan let the
    // index through, the f32 kernels would silently read garbage weights).
    fn boundary_bin_idx() -> Tensor<u16> {
        Tensor::from_vec(&[1, 1, 3, 3], vec![0u16, 1, 2, 3, 4, 0, 1, 2, 3])
    }

    fn boundary_fx_inputs() -> FxConvInputs {
        FxConvInputs {
            image_raw: Tensor::zeros(&[1, 3, 3]),
            bin_idx: boundary_bin_idx(),
            codebook_raw: vec![1i64; 4],
            iq: QFormat::IMAGE32,
            wq: QFormat::W16,
            stride: 1,
        }
    }

    #[test]
    #[should_panic(expected = "bin index 4 out of range for codebook with 4 entries")]
    fn ws_f32_bin_equal_to_codebook_len_is_hard_error() {
        ws_conv_f32(&Tensor::zeros(&[1, 3, 3]), &boundary_bin_idx(), &[0.5f32; 4], 1);
    }

    #[test]
    #[should_panic(expected = "bin index 4 out of range for codebook with 4 entries")]
    fn pasm_f32_bin_equal_to_codebook_len_is_hard_error() {
        pasm_conv_f32(&Tensor::zeros(&[1, 3, 3]), &boundary_bin_idx(), &[0.5f32; 4], 1);
    }

    #[test]
    #[should_panic(expected = "bin index 4 out of range for codebook with 4 entries")]
    fn ws_fx_bin_equal_to_codebook_len_is_hard_error() {
        ws_conv_fx(&boundary_fx_inputs());
    }

    #[test]
    #[should_panic(expected = "bin index 4 out of range for codebook with 4 entries")]
    fn pasm_fx_bin_equal_to_codebook_len_is_hard_error() {
        pasm_conv_fx(&boundary_fx_inputs());
    }

    #[test]
    fn bin_range_violation_is_strict() {
        assert_eq!(bin_range_violation(&[0, 1, 2, 3], 4), None);
        assert_eq!(bin_range_violation(&[0, 1, 4, 3], 4), Some(4));
        assert_eq!(bin_range_violation(&[], 0), Some(0));
        assert_eq!(bin_range_violation(&[0], 1), None);
    }
}
