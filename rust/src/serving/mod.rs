//! Layer-4 network serving front-end: the process boundary.
//!
//! Everything below this layer is in-process: the
//! [`crate::coordinator`] batches and executes, the
//! [`crate::model_store`] hot-swaps artifacts — but nothing could reach
//! them from outside.  This module is the host interface the paper's
//! accelerator (and any multiplier-less design like TMA) needs to be
//! deployable: a hand-rolled wire protocol and two interchangeable TCP
//! servers in front of a [`crate::coordinator::Coordinator`].
//!
//! * [`proto`] — length-prefixed canonical-JSON frames (request /
//!   response / error / metrics / model listing, plus the
//!   `hello`/`hello_ok` pipelining negotiation), reference
//!   implementation of `docs/WIRE_PROTOCOL.md`; no serde, built on
//!   [`crate::runtime::json`].
//! * [`net`] — `std::net` TCP server: one accept thread, one thread per
//!   connection (bounded), **admission control** (bounded in-flight
//!   queue depth; overload answers a typed `RESOURCE_EXHAUSTED` frame
//!   instead of stalling the socket), idle/slow-loris reaping, clean
//!   drop-to-shutdown.  Simple and debuggable; capacity is bounded by
//!   thread count.
//! * [`evented`] (unix) — C100K readiness-loop server: a fixed set of
//!   event-loop workers multiplexes tens of thousands of connections
//!   (epoll on Linux, `poll(2)` elsewhere), with per-connection
//!   byte-level backpressure and negotiated **pipelining** (many
//!   requests in flight per socket, responses matched by id).  Same
//!   protocol, same admission semantics — the e2e suite runs every
//!   scenario against both servers.
//! * [`client`] — blocking serial client plus the pipelined client used
//!   by the e2e tests, the network load generator, and
//!   `repro bench-net`.
//!
//! The full request path (socket → frame → coordinator queue → batch →
//! compiled plan → PASM kernels → response frame) is walked through in
//! `docs/ARCHITECTURE.md` for both servers.  Start one from the CLI
//! with `repro serve --listen 127.0.0.1:7878` (add `--evented` for the
//! readiness-loop front-end) and drive it with
//! `repro bench-net --addr 127.0.0.1:7878`.

pub mod client;
#[cfg(unix)]
pub mod evented;
pub mod net;
#[cfg(unix)]
pub(crate) mod poller;
pub mod proto;
pub(crate) mod shared;

pub use client::{Client, ClientError, PipelinedClient, PipelinedReply, RetryPolicy};
#[cfg(unix)]
pub use evented::{EventedConfig, EventedServer};
pub use net::{Server, ServerConfig};
pub use proto::{
    ErrorCode, ErrorFrame, Frame, InferOkFrame, MetricsFrame, NetCounters, TraceEventWire,
    TraceFrame,
};
