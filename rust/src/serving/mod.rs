//! Layer-4 network serving front-end: the process boundary.
//!
//! Everything below this layer is in-process: the
//! [`crate::coordinator`] batches and executes, the
//! [`crate::model_store`] hot-swaps artifacts — but nothing could reach
//! them from outside.  This module is the host interface the paper's
//! accelerator (and any multiplier-less design like TMA) needs to be
//! deployable: a hand-rolled wire protocol and a TCP server in front of
//! a [`crate::coordinator::Coordinator`].
//!
//! * [`proto`] — length-prefixed canonical-JSON frames (request /
//!   response / error / metrics / model listing), reference
//!   implementation of `docs/WIRE_PROTOCOL.md`; no serde, built on
//!   [`crate::runtime::json`].
//! * [`net`] — `std::net` TCP server: one accept thread, one thread per
//!   connection (bounded), **admission control** (bounded in-flight
//!   queue depth; overload answers a typed `RESOURCE_EXHAUSTED` frame
//!   instead of stalling the socket), per-connection and per-model
//!   metrics, clean drop-to-shutdown.
//! * [`client`] — blocking client used by the e2e tests, the network
//!   load generator, and `repro bench-net`.
//!
//! The full request path (socket → frame → coordinator queue → batch →
//! compiled plan → PASM kernels → response frame) is walked through in
//! `docs/ARCHITECTURE.md`.  Start a server from the CLI with
//! `repro serve --listen 127.0.0.1:7878` and drive it with
//! `repro bench-net --addr 127.0.0.1:7878`.

pub mod client;
pub mod net;
pub mod proto;

pub use client::{Client, ClientError};
pub use net::{Server, ServerConfig};
pub use proto::{ErrorCode, ErrorFrame, Frame, InferOkFrame, MetricsFrame, NetCounters};
