//! Evented TCP front-end: C100K readiness-loop server with pipelining.
//!
//! [`EventedServer`] serves the same wire protocol as the threaded
//! [`crate::serving::net::Server`], but multiplexes tens of thousands of
//! connections over a **fixed** set of worker threads instead of one
//! thread per connection.  Each worker owns a [`crate::serving::poller`]
//! readiness poller (epoll on Linux, `poll(2)` elsewhere) and a slab of
//! per-connection state machines; all socket I/O is non-blocking, so a
//! slow peer costs a few hundred bytes of state, never a parked thread.
//!
//! **Request flow.**  The accept thread hands each socket to a worker's
//! mailbox (woken through a socketpair).  The worker reads frames
//! incrementally — 4-byte length header, then payload — and submits
//! admitted `infer` frames to the coordinator with a completion
//! *callback* ([`Coordinator::submit_with`]): the shard worker that
//! finishes the batch pushes the finished reply back into the owning
//! worker's mailbox, so no thread ever blocks on a response channel.
//! Connection slots are generation-stamped; a completion for a
//! connection that died in the meantime is simply dropped.
//!
//! **Serial by default, pipelined by negotiation.**  A connection that
//! never sends `hello` gets exactly the threaded server's observable
//! behavior: one request in flight, responses in request order,
//! byte-for-byte identical frames.  A client that sends
//! `hello {pipeline:true}` and receives `hello_ok {pipeline:true}` may
//! keep up to the granted `depth` of `infer` frames in flight on one
//! socket; responses then come back **out of order**, matched by `id`.
//!
//! **Backpressure is byte-level.**  Every reply is queued in a bounded
//! per-connection write buffer and flushed as the socket drains.  When
//! the buffer crosses [`EventedConfig::max_write_buffer`], the worker
//! stops *reading* from that connection until the peer drains half of
//! it — a reader that stops draining cannot balloon server memory, and
//! its admission slots stay held (the global in-flight gauge counts
//! responses not yet flushed).  Idle peers and slow-loris senders are
//! reaped by deadline sweeps, same policy as the threaded server.
//!
//! Shutdown drains: workers stop reading, admitted requests complete and
//! their responses flush (bounded by a grace period), then sockets close.

use crate::coordinator::request::Ingress;
use crate::coordinator::server::Coordinator;
use crate::faults::FaultSite;
use crate::serving::poller::{PollEvent, Poller};
use crate::serving::proto::{self, ErrorCode, ErrorFrame, Frame, InferFrame, NetCounters};
use crate::serving::shared::{self as common, InflightSlot, NetMetrics, ReplyTrace, ValidInfer};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Wall-clock grace admitted requests and their response flushes get
/// once shutdown begins (mirrors the threaded server's grace).
const SHUTDOWN_GRACE: Duration = Duration::from_secs(2);

/// Poller token reserved for the worker's mailbox wake pipe.
const WAKE_TOKEN: u64 = u64::MAX;

/// Tunables of the evented front-end.
#[derive(Clone, Debug)]
pub struct EventedConfig {
    /// Event-loop worker threads; connections are distributed round-robin.
    pub workers: usize,
    /// Concurrent connection cap; over-cap accepts get one
    /// `RESOURCE_EXHAUSTED` error frame and are closed.
    pub max_connections: usize,
    /// Admitted-but-unflushed `infer` cap across all connections; at the
    /// cap new infer frames get `RESOURCE_EXHAUSTED`.
    pub max_inflight: usize,
    /// Per-frame payload size cap (bytes).
    pub max_frame_bytes: usize,
    /// Per-connection in-flight cap granted to clients that negotiate
    /// pipelining via `hello` (serial connections are capped at 1).
    pub max_pipeline: usize,
    /// Per-connection write-buffer high watermark (bytes): past it the
    /// worker stops reading from the connection until the peer drains
    /// the buffer below half of it.
    pub max_write_buffer: usize,
    /// Close a connection with no request in flight and no frame bytes
    /// received for this long.
    pub idle_timeout: Duration,
    /// Once the first byte of a frame arrives, the rest must follow
    /// within this budget (slow-loris reap).
    pub frame_timeout: Duration,
    /// Deadline-sweep cadence; also the poller wait timeout.
    pub sweep_interval: Duration,
    /// Kernel send-buffer size (`SO_SNDBUF`) applied to accepted sockets
    /// (Linux only; `None` keeps the kernel default).  Small values make
    /// byte-level backpressure observable in tests.
    pub sock_sndbuf: Option<usize>,
}

impl Default for EventedConfig {
    fn default() -> Self {
        EventedConfig {
            workers: 2,
            max_connections: 8192,
            max_inflight: 256,
            max_frame_bytes: proto::DEFAULT_MAX_FRAME_BYTES,
            max_pipeline: 32,
            max_write_buffer: 1 << 20,
            idle_timeout: Duration::from_secs(60),
            frame_timeout: Duration::from_secs(10),
            sweep_interval: Duration::from_millis(100),
            sock_sndbuf: None,
        }
    }
}

/// A finished request on its way back to the connection that issued it.
struct CompletionMsg {
    /// Slab index of the issuing connection on the owning worker.
    conn: usize,
    /// Generation stamp of the issuing connection; a mismatch means the
    /// connection died and was replaced — drop the message.
    gen: u64,
    /// The reply frame to enqueue.
    reply: Frame,
    /// The admission slot, released when the reply bytes are flushed.
    slot: Option<InflightSlot>,
    /// Span bookkeeping to finish once the reply is queued (infer
    /// replies that reached the coordinator only).
    trace: Option<ReplyTrace>,
}

/// Everything a worker can receive from other threads.
#[derive(Default)]
struct MailQueue {
    incoming: Vec<TcpStream>,
    completions: Vec<CompletionMsg>,
}

/// One worker's inbox plus the wake pipe that interrupts its poller.
struct Mailbox {
    queue: Mutex<MailQueue>,
    /// Write end of the worker's wake socketpair (non-blocking; a full
    /// pipe means a wake is already pending, which is all we need).
    wake: Mutex<UnixStream>,
}

impl Mailbox {
    // all mailbox locks tolerate poison (common::lock_unpoisoned): a
    // panicking completion callback must not cascade into every thread
    // that shares the mailbox — one bad request would otherwise take the
    // whole worker (and the accept loop pushing into it) down
    fn wake(&self) {
        use std::io::Write;
        let mut w = common::lock_unpoisoned(&self.wake);
        let _ = w.write(&[1]);
    }

    fn push_conn(&self, stream: TcpStream) {
        common::lock_unpoisoned(&self.queue).incoming.push(stream);
        self.wake();
    }

    fn push_completion(&self, msg: CompletionMsg) {
        common::lock_unpoisoned(&self.queue).completions.push(msg);
        self.wake();
    }
}

/// State shared between the server handle, the accept thread, every
/// worker, and in-flight completion callbacks.
struct EvShared {
    coord: Arc<Coordinator>,
    config: EventedConfig,
    shutdown: AtomicBool,
    /// Gauge: connections currently registered (or in a mailbox).
    open: AtomicUsize,
    /// Gauge: infer requests admitted and not yet flushed.
    inflight: Arc<AtomicUsize>,
    metrics: NetMetrics,
    mailboxes: Vec<Mailbox>,
}

impl EvShared {
    fn snapshot(&self) -> NetCounters {
        self.metrics
            .snapshot(self.open.load(Ordering::SeqCst), self.inflight.load(Ordering::SeqCst))
    }
}

/// Handle to a running evented serving front-end.  Dropping it shuts the
/// server down cleanly (admitted requests finish and flush first).
pub struct EventedServer {
    addr: SocketAddr,
    shared: Arc<EvShared>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl EventedServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// the accept thread plus the event-loop workers against `coord`.
    pub fn bind(
        addr: impl ToSocketAddrs,
        coord: Arc<Coordinator>,
        config: EventedConfig,
    ) -> Result<EventedServer> {
        anyhow::ensure!(config.workers >= 1, "workers must be >= 1");
        anyhow::ensure!(config.max_connections >= 1, "max_connections must be >= 1");
        anyhow::ensure!(config.max_inflight >= 1, "max_inflight must be >= 1");
        anyhow::ensure!(config.max_pipeline >= 1, "max_pipeline must be >= 1");
        anyhow::ensure!(config.max_write_buffer >= 4096, "max_write_buffer must be >= 4096");
        let listener = TcpListener::bind(addr).context("bind evented listener")?;
        let local = listener.local_addr().context("listener local addr")?;

        // wake pipes and pollers are created up front so bind fails fast
        // on fd exhaustion instead of spawning half a server
        let mut mailboxes = Vec::with_capacity(config.workers);
        let mut loops = Vec::with_capacity(config.workers);
        for _ in 0..config.workers {
            let (tx, rx) = UnixStream::pair().context("create worker wake pipe")?;
            tx.set_nonblocking(true).context("wake pipe nonblocking")?;
            rx.set_nonblocking(true).context("wake pipe nonblocking")?;
            mailboxes.push(Mailbox {
                queue: Mutex::new(MailQueue::default()),
                wake: Mutex::new(tx),
            });
            loops.push((Poller::new().context("create poller")?, rx));
        }
        let shared = Arc::new(EvShared {
            coord,
            config,
            shutdown: AtomicBool::new(false),
            open: AtomicUsize::new(0),
            inflight: Arc::new(AtomicUsize::new(0)),
            metrics: NetMetrics::default(),
            mailboxes,
        });

        let mut workers = Vec::with_capacity(loops.len());
        for (i, (poller, wake_rx)) in loops.into_iter().enumerate() {
            let shared_worker = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("pasm-evented-{i}"))
                .spawn(move || worker_loop(i, shared_worker, poller, wake_rx))
                .context("spawn evented worker")?;
            workers.push(handle);
        }
        let shared_accept = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("pasm-evented-accept".into())
            .spawn(move || accept_loop(listener, shared_accept))
            .context("spawn evented accept thread")?;
        Ok(EventedServer { addr: local, shared, accept: Some(accept), workers })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The coordinator this server fronts.
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.shared.coord
    }

    /// Snapshot of the network-layer counters.
    pub fn net_metrics(&self) -> NetCounters {
        self.shared.snapshot()
    }

    /// Stop accepting, let every admitted request finish and its response
    /// flush (bounded by a grace period), then join all threads.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // wake the blocking accept() with a throwaway connection, aimed
        // at loopback when the server bound a wildcard address
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
        for mb in &self.shared.mailboxes {
            mb.wake();
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for EventedServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<EvShared>) {
    let mut next = 0usize;
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // transient accept failure (e.g. fd pressure): back off
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if shared.open.load(Ordering::SeqCst) >= shared.config.max_connections {
            shared.metrics.connections_rejected.fetch_add(1, Ordering::SeqCst);
            let mut stream = stream;
            let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
            let frame = Frame::Error(ErrorFrame::new(
                None,
                ErrorCode::ResourceExhausted,
                format!("server at max connections ({})", shared.config.max_connections),
            ));
            let _ = proto::write_frame(&mut stream, &frame);
            continue;
        }
        shared.open.fetch_add(1, Ordering::SeqCst);
        shared.metrics.connections_opened.fetch_add(1, Ordering::SeqCst);
        shared.mailboxes[next % shared.mailboxes.len()].push_conn(stream);
        next = next.wrapping_add(1);
    }
}

/// Incremental frame-read progress of one connection.
enum ReadState {
    /// Reading the 4-byte big-endian length header.
    Header { buf: [u8; 4], filled: usize },
    /// Reading the payload announced by the header.
    Payload { buf: Vec<u8>, filled: usize },
}

/// Per-connection state machine on a worker's slab.
struct Conn {
    stream: TcpStream,
    /// Generation stamp; completions carry it so a reply can never be
    /// delivered to a reused slab slot.
    gen: u64,
    read: ReadState,
    /// Bytes queued for the peer, flushed as the socket drains.
    write_buf: VecDeque<u8>,
    /// Lifetime bytes ever queued / ever flushed; admission slots are
    /// released when `total_flushed` passes their reply's queue offset.
    total_queued: u64,
    total_flushed: u64,
    pending_slots: VecDeque<(u64, InflightSlot)>,
    /// Negotiated via `hello`: out-of-order responses allowed.
    pipeline: bool,
    /// Admitted-but-unanswered infer frames on this connection.
    admitted: usize,
    /// Serial mode: an infer is in flight, stop processing input.
    blocked: bool,
    /// Backpressure: write buffer over the high watermark, reads off.
    paused: bool,
    /// Fatal framing error: flush the goodbye error, then close (by the
    /// stored deadline at the latest).
    closing: Option<Instant>,
    /// Interest currently registered with the poller.
    reg_read: bool,
    reg_write: bool,
    /// Last read/flush progress (idle reaping).
    last_activity: Instant,
    /// Deadline for the in-progress frame (slow-loris reaping).
    frame_deadline: Option<Instant>,
    /// When the in-progress frame's header completed — the `accepted`
    /// ingress timestamp of the request it turns out to carry.
    accepted_at: Option<Instant>,
}

fn worker_loop(worker: usize, shared: Arc<EvShared>, mut poller: Poller, wake: UnixStream) {
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut gen_counter: u64 = 0;
    let mut events: Vec<PollEvent> = Vec::new();
    let mut drain_deadline: Option<Instant> = None;
    let mut last_sweep = Instant::now();
    if poller.add(wake.as_raw_fd(), WAKE_TOKEN, true, false).is_err() {
        return;
    }
    loop {
        let draining = shared.shutdown.load(Ordering::SeqCst);
        if draining && drain_deadline.is_none() {
            drain_deadline = Some(Instant::now() + SHUTDOWN_GRACE);
            // stop reading everywhere; only completions and flushes now
            for (idx, slot) in conns.iter_mut().enumerate() {
                if let Some(conn) = slot.as_mut() {
                    let _ = update_interest(&mut poller, conn, idx, true);
                }
            }
        }
        if poller.wait(&mut events, Some(shared.config.sweep_interval)).is_err() {
            return;
        }
        if events.iter().any(|e| e.token == WAKE_TOKEN) {
            drain_wake(&wake);
        }
        let (incoming, completions) = {
            let mut q = common::lock_unpoisoned(&shared.mailboxes[worker].queue);
            (std::mem::take(&mut q.incoming), std::mem::take(&mut q.completions))
        };
        for stream in incoming {
            if draining {
                shared.open.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            register_conn(&shared, &mut poller, &mut conns, &mut free, &mut gen_counter, stream);
        }
        for msg in completions {
            let idx = msg.conn;
            let alive = {
                let conn = conns.get_mut(idx).and_then(Option::as_mut);
                match conn {
                    Some(conn) if conn.gen == msg.gen => {
                        conn.admitted = conn.admitted.saturating_sub(1);
                        conn.blocked = false;
                        // the write-back stage here is "queued on the
                        // connection (plus any opportunistic flush)" —
                        // actual drain is driven by the peer and would
                        // measure the peer, not the server
                        let write_started = Instant::now();
                        let sent = enqueue_reply(&shared, conn, &msg.reply, msg.slot);
                        if let (Some(bytes), Some(t)) = (sent, &msg.trace) {
                            t.finish(&shared.coord, write_started.elapsed(), bytes);
                        }
                        sent.is_some()
                            && update_interest(&mut poller, conn, idx, draining).is_ok()
                    }
                    // the connection died first: drop the reply (and the
                    // slot riding in `msg`)
                    _ => continue,
                }
            };
            if !alive {
                close_conn(&shared, &mut poller, &mut conns, &mut free, idx);
            }
        }
        let evs = std::mem::take(&mut events);
        for ev in &evs {
            if ev.token == WAKE_TOKEN {
                continue;
            }
            let idx = ev.token as usize;
            let alive = {
                let Some(conn) = conns.get_mut(idx).and_then(Option::as_mut) else {
                    continue;
                };
                let mut alive = true;
                if ev.writable {
                    alive = try_flush(&shared, conn);
                    if alive && conn.closing.is_some() && conn.write_buf.is_empty() {
                        // the goodbye error frame is out: close for real
                        alive = false;
                    }
                }
                if alive && ev.readable {
                    alive = process_input(&shared, conn, idx, worker, draining);
                }
                alive && update_interest(&mut poller, conn, idx, draining).is_ok()
            };
            if !alive {
                close_conn(&shared, &mut poller, &mut conns, &mut free, idx);
            }
        }
        events = evs;

        let now = Instant::now();
        if now.duration_since(last_sweep) >= shared.config.sweep_interval {
            last_sweep = now;
            let doomed = sweep_deadlines(&shared, &conns, now);
            for idx in doomed {
                close_conn(&shared, &mut poller, &mut conns, &mut free, idx);
            }
        }
        if draining {
            let expired = drain_deadline.is_some_and(|d| now > d);
            let mut doomed = Vec::new();
            let mut busy = 0usize;
            for (idx, slot) in conns.iter().enumerate() {
                let Some(conn) = slot else { continue };
                if expired || (conn.admitted == 0 && conn.write_buf.is_empty()) {
                    doomed.push(idx);
                } else {
                    busy += 1;
                }
            }
            for idx in doomed {
                close_conn(&shared, &mut poller, &mut conns, &mut free, idx);
            }
            if busy == 0 {
                return;
            }
        }
    }
}

/// Empty the wake pipe so level-triggered polling quiets down.
fn drain_wake(wake: &UnixStream) {
    use std::io::Read;
    let mut r = wake;
    let mut buf = [0u8; 64];
    loop {
        match r.read(&mut buf) {
            Ok(0) => return,
            Ok(_) => {}
            Err(_) => return,
        }
    }
}

fn register_conn(
    shared: &Arc<EvShared>,
    poller: &mut Poller,
    conns: &mut Vec<Option<Conn>>,
    free: &mut Vec<usize>,
    gen_counter: &mut u64,
    stream: TcpStream,
) {
    if stream.set_nonblocking(true).is_err() {
        shared.open.fetch_sub(1, Ordering::SeqCst);
        return;
    }
    let _ = stream.set_nodelay(true);
    #[cfg(target_os = "linux")]
    if let Some(bytes) = shared.config.sock_sndbuf {
        let _ = set_send_buffer(&stream, bytes);
    }
    let idx = match free.pop() {
        Some(idx) => idx,
        None => {
            conns.push(None);
            conns.len() - 1
        }
    };
    if poller.add(stream.as_raw_fd(), idx as u64, true, false).is_err() {
        free.push(idx);
        shared.open.fetch_sub(1, Ordering::SeqCst);
        return;
    }
    *gen_counter += 1;
    conns[idx] = Some(Conn {
        stream,
        gen: *gen_counter,
        read: ReadState::Header { buf: [0; 4], filled: 0 },
        write_buf: VecDeque::new(),
        total_queued: 0,
        total_flushed: 0,
        pending_slots: VecDeque::new(),
        pipeline: false,
        admitted: 0,
        blocked: false,
        paused: false,
        closing: None,
        reg_read: true,
        reg_write: false,
        last_activity: Instant::now(),
        frame_deadline: None,
        accepted_at: None,
    });
}

fn close_conn(
    shared: &Arc<EvShared>,
    poller: &mut Poller,
    conns: &mut [Option<Conn>],
    free: &mut Vec<usize>,
    idx: usize,
) {
    if let Some(conn) = conns[idx].take() {
        // deregister while the fd is still open, then drop: the stream
        // closes and any pending admission slots release
        let _ = poller.remove(conn.stream.as_raw_fd());
        free.push(idx);
        shared.open.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Reconcile the poller's registered interest with the connection's
/// state: read while the state machine wants input, write while bytes
/// are queued.
fn update_interest(
    poller: &mut Poller,
    conn: &mut Conn,
    idx: usize,
    draining: bool,
) -> std::io::Result<()> {
    let want_read = !draining && !conn.blocked && !conn.paused && conn.closing.is_none();
    let want_write = !conn.write_buf.is_empty();
    if (want_read, want_write) != (conn.reg_read, conn.reg_write) {
        poller.modify(conn.stream.as_raw_fd(), idx as u64, want_read, want_write)?;
        conn.reg_read = want_read;
        conn.reg_write = want_write;
    }
    Ok(())
}

/// Deadline sweep: indices of connections past their idle, slow-loris,
/// or closing-flush deadlines.  Idle and slow-loris reaps increment
/// their `metrics` counters (`idle_reaped` / `loris_reaped`); a
/// closing-flush close is the tail of a framing error already counted
/// under `protocol_errors`.
fn sweep_deadlines(shared: &EvShared, conns: &[Option<Conn>], now: Instant) -> Vec<usize> {
    let mut doomed = Vec::new();
    for (idx, slot) in conns.iter().enumerate() {
        let Some(conn) = slot else { continue };
        let dead = match conn.closing {
            Some(deadline) => conn.write_buf.is_empty() || now > deadline,
            None => match conn.frame_deadline {
                Some(deadline) => {
                    let dead = now > deadline;
                    if dead {
                        shared.metrics.loris_reaped.fetch_add(1, Ordering::SeqCst);
                    }
                    dead
                }
                None => {
                    let dead = conn.admitted == 0
                        && now.duration_since(conn.last_activity) > shared.config.idle_timeout;
                    if dead {
                        shared.metrics.idle_reaped.fetch_add(1, Ordering::SeqCst);
                    }
                    dead
                }
            },
        };
        if dead {
            doomed.push(idx);
        }
    }
    doomed
}

/// Queue a reply on the connection and flush opportunistically.  `slot`
/// (for infer replies) is released when the reply bytes reach the
/// socket.  Returns the payload byte count on success, `None` when the
/// transport failed and the connection must close.
fn enqueue_reply(
    shared: &EvShared,
    conn: &mut Conn,
    frame: &Frame,
    slot: Option<InflightSlot>,
) -> Option<usize> {
    let payload = proto::encode(frame);
    let len = u32::try_from(payload.len()).ok()?;
    conn.write_buf.extend(len.to_be_bytes());
    conn.write_buf.extend(payload);
    conn.total_queued += 4 + u64::from(len);
    if let Some(slot) = slot {
        conn.pending_slots.push_back((conn.total_queued, slot));
    }
    shared.metrics.frames_sent.fetch_add(1, Ordering::SeqCst);
    conn.last_activity = Instant::now();
    if !try_flush(shared, conn) {
        return None;
    }
    if conn.write_buf.len() > shared.config.max_write_buffer {
        conn.paused = true;
    }
    Some(len as usize)
}

/// Write queued bytes until the socket would block.  Releases admission
/// slots whose replies are fully flushed and lifts backpressure at the
/// low watermark.  Returns `false` on a transport error.
fn try_flush(shared: &EvShared, conn: &mut Conn) -> bool {
    use std::io::Write;
    loop {
        if conn.write_buf.is_empty() {
            break;
        }
        let (head, _) = conn.write_buf.as_slices();
        match conn.stream.write(head) {
            Ok(0) => return false,
            Ok(n) => {
                conn.write_buf.drain(..n);
                conn.total_flushed += n as u64;
                conn.last_activity = Instant::now();
                while matches!(
                    conn.pending_slots.front(),
                    Some((off, _)) if *off <= conn.total_flushed
                ) {
                    conn.pending_slots.pop_front();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if conn.paused && conn.write_buf.len() <= shared.config.max_write_buffer / 2 {
        conn.paused = false;
    }
    true
}

/// Pump the connection's read state machine until the socket runs dry or
/// the connection stops wanting input (serial block, backpressure pause,
/// fatal framing error).  Returns `false` when the connection must close.
fn process_input(
    shared: &Arc<EvShared>,
    conn: &mut Conn,
    idx: usize,
    worker: usize,
    draining: bool,
) -> bool {
    use std::io::Read;
    loop {
        if draining || conn.blocked || conn.paused || conn.closing.is_some() {
            return true;
        }
        // a complete header opens the payload stage
        let mut header_len: Option<usize> = None;
        if let ReadState::Header { buf, filled } = &conn.read {
            if *filled == buf.len() {
                header_len = Some(u32::from_be_bytes(*buf) as usize);
            }
        }
        if let Some(len) = header_len {
            if len > shared.config.max_frame_bytes {
                // framing can no longer be trusted: answer once, flush,
                // then close
                shared.metrics.protocol_errors.fetch_add(1, Ordering::SeqCst);
                let frame = Frame::Error(ErrorFrame::new(
                    None,
                    ErrorCode::InvalidFrame,
                    format!(
                        "frame of {len} bytes exceeds the {}-byte cap",
                        shared.config.max_frame_bytes
                    ),
                ));
                let alive = enqueue_reply(shared, conn, &frame, None).is_some();
                conn.closing = Some(Instant::now() + shared.config.frame_timeout);
                return alive && !conn.write_buf.is_empty();
            }
            conn.accepted_at = Some(Instant::now());
            conn.read = ReadState::Payload { buf: vec![0u8; len], filled: 0 };
            continue;
        }
        // a complete payload is one whole frame: handle it
        let payload_done = matches!(
            &conn.read,
            ReadState::Payload { buf, filled } if *filled == buf.len()
        );
        if payload_done {
            let fresh = ReadState::Header { buf: [0; 4], filled: 0 };
            let old = std::mem::replace(&mut conn.read, fresh);
            conn.frame_deadline = None;
            let accepted = conn.accepted_at.take().unwrap_or_else(Instant::now);
            shared.metrics.frames_received.fetch_add(1, Ordering::SeqCst);
            if let ReadState::Payload { buf, .. } = old {
                if !handle_frame_bytes(shared, conn, idx, worker, &buf, accepted) {
                    return false;
                }
            }
            continue;
        }
        // otherwise pull more bytes for the current stage
        let (dst, filled): (&mut [u8], &mut usize) = match &mut conn.read {
            ReadState::Header { buf, filled } => (&mut buf[..], filled),
            ReadState::Payload { buf, filled } => (&mut buf[..], filled),
        };
        match conn.stream.read(&mut dst[*filled..]) {
            Ok(0) => return false,
            Ok(n) => {
                *filled += n;
                conn.last_activity = Instant::now();
                if conn.frame_deadline.is_none() {
                    conn.frame_deadline = Some(Instant::now() + shared.config.frame_timeout);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Decode and dispatch one framed payload.  Returns `false` when the
/// connection must close.
fn handle_frame_bytes(
    shared: &Arc<EvShared>,
    conn: &mut Conn,
    idx: usize,
    worker: usize,
    payload: &[u8],
    accepted: Instant,
) -> bool {
    let frame = match proto::decode(payload) {
        Ok(frame) => frame,
        Err(e) => {
            // well-framed but undecodable: typed error, keep serving
            shared.metrics.protocol_errors.fetch_add(1, Ordering::SeqCst);
            return enqueue_reply(shared, conn, &Frame::Error(e), None).is_some();
        }
    };
    let ingress = Ingress { accepted, decoded: Instant::now() };
    // fault injection: a chaos plan may reset the socket instead of
    // answering — completions for requests already in flight on this
    // connection are dropped by their generation stamp, and clients with
    // a retry policy reconnect and resubmit
    if let Some(plan) = shared.coord.fault_plan() {
        if plan.should(FaultSite::SocketReset) {
            return false;
        }
    }
    match frame {
        Frame::Infer(req) => handle_infer(shared, conn, idx, worker, req, ingress),
        Frame::Hello { pipeline } => {
            // this transport can interleave: grant pipelining when asked
            // for and configured
            let granted = pipeline && shared.config.max_pipeline > 1;
            conn.pipeline = granted;
            let depth = if granted { shared.config.max_pipeline as u64 } else { 1 };
            enqueue_reply(shared, conn, &Frame::HelloOk { pipeline: granted, depth }, None)
                .is_some()
        }
        Frame::ListModels => {
            enqueue_reply(shared, conn, &common::models_frame(&shared.coord), None).is_some()
        }
        Frame::GetMetrics => {
            let reply = common::metrics_frame(&shared.coord, shared.snapshot());
            enqueue_reply(shared, conn, &reply, None).is_some()
        }
        Frame::GetTrace { id, limit } => {
            let reply = common::trace_frame(&shared.coord, id, limit);
            enqueue_reply(shared, conn, &reply, None).is_some()
        }
        Frame::Ping { nonce } => {
            enqueue_reply(shared, conn, &Frame::Pong { nonce }, None).is_some()
        }
        // server-to-client frames arriving at the server
        other => {
            enqueue_reply(shared, conn, &common::wrong_direction_frame(&other), None).is_some()
        }
    }
}

/// Admit, validate, and submit one `infer` frame; the reply comes back
/// later through the worker's mailbox as a [`CompletionMsg`].
fn handle_infer(
    shared: &Arc<EvShared>,
    conn: &mut Conn,
    idx: usize,
    worker: usize,
    req: InferFrame,
    ingress: Ingress,
) -> bool {
    let req_id = req.id;
    let err = |code: ErrorCode, msg: String| Frame::Error(ErrorFrame::new(Some(req_id), code, msg));

    // per-connection fairness first: one pipelined peer cannot consume
    // the whole global in-flight budget
    let cap = if conn.pipeline { shared.config.max_pipeline } else { 1 };
    if conn.admitted >= cap {
        shared.metrics.overload_rejections.fetch_add(1, Ordering::SeqCst);
        let reply = err(
            ErrorCode::ResourceExhausted,
            format!("connection at max pipelined requests ({cap})"),
        );
        return enqueue_reply(shared, conn, &reply, None).is_some();
    }
    // then global admission control, before any validation work
    let Some(slot) = InflightSlot::acquire(&shared.inflight, shared.config.max_inflight) else {
        shared.metrics.overload_rejections.fetch_add(1, Ordering::SeqCst);
        let reply = err(
            ErrorCode::ResourceExhausted,
            format!("server at max in-flight requests ({})", shared.config.max_inflight),
        );
        return enqueue_reply(shared, conn, &reply, None).is_some();
    };
    let valid = match common::validate_infer(req, &shared.coord) {
        Ok(v) => v,
        // the validation error holds the slot through its flush, same
        // accounting as a real response
        Err(reply) => return enqueue_reply(shared, conn, &reply, Some(slot)).is_some(),
    };
    let ValidInfer { id, model, image, deadline } = valid;

    let gen = conn.gen;
    let shard = shared.coord.shard_for(model.as_deref());
    let model_cb = model.clone();
    let shared_cb = Arc::clone(shared);
    let on_done = move |coord_id: u64,
                        result: Result<crate::coordinator::request::InferenceResponse, String>| {
        let reply = match result {
            Ok(resp) => {
                shared_cb.metrics.requests_ok.fetch_add(1, Ordering::SeqCst);
                common::infer_ok_frame(id, resp)
            }
            Err(msg) => {
                shared_cb.metrics.requests_failed.fetch_add(1, Ordering::SeqCst);
                common::infer_err_frame(id, msg)
            }
        };
        let trace = ReplyTrace { shard, coord_id, model: model_cb, retry_code: None };
        let trace = trace.observe(&reply);
        let msg = CompletionMsg { conn: idx, gen, reply, slot: Some(slot), trace: Some(trace) };
        shared_cb.mailboxes[worker].push_completion(msg);
    };
    let submitted =
        shared.coord.submit_with_traced(model.as_deref(), image, deadline, Some(ingress), on_done);
    match submitted {
        Ok(_) => {
            conn.admitted += 1;
            if !conn.pipeline {
                // serial contract: stop processing input until the reply
                // is enqueued, so responses stay in request order
                conn.blocked = true;
            }
            true
        }
        Err(e) => {
            // the callback (and the slot inside it) was dropped by the
            // failed submit, so the gauge is already released
            shared.metrics.requests_failed.fetch_add(1, Ordering::SeqCst);
            let msg = e.to_string();
            let code = if msg.contains("unavailable") {
                // a dying shard is transient (the supervisor respawns it)
                ErrorCode::Unavailable
            } else {
                ErrorCode::ShuttingDown
            };
            enqueue_reply(shared, conn, &err(code, msg), None).is_some()
        }
    }
}

/// Set the kernel send-buffer size (`SO_SNDBUF`) on a socket.  Small
/// values make byte-level backpressure kick in after a few kilobytes,
/// which the e2e suite uses to observe the server pausing its reads.
#[cfg(target_os = "linux")]
pub fn set_send_buffer(sock: &impl AsRawFd, bytes: usize) -> std::io::Result<()> {
    sockopt::set(sock.as_raw_fd(), sockopt::SO_SNDBUF, bytes)
}

/// Set the kernel receive-buffer size (`SO_RCVBUF`) on a socket.  The
/// backpressure test shrinks a client's receive window with this so the
/// server's write buffer fills deterministically.
#[cfg(target_os = "linux")]
pub fn set_recv_buffer(sock: &impl AsRawFd, bytes: usize) -> std::io::Result<()> {
    sockopt::set(sock.as_raw_fd(), sockopt::SO_RCVBUF, bytes)
}

#[cfg(target_os = "linux")]
mod sockopt {
    const SOL_SOCKET: i32 = 1;
    pub(super) const SO_SNDBUF: i32 = 7;
    pub(super) const SO_RCVBUF: i32 = 8;

    extern "C" {
        fn setsockopt(fd: i32, level: i32, optname: i32, optval: *const i32, optlen: u32) -> i32;
    }

    pub(super) fn set(fd: i32, opt: i32, bytes: usize) -> std::io::Result<()> {
        let val = i32::try_from(bytes).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "buffer size exceeds i32")
        })?;
        let rc = unsafe {
            setsockopt(fd, SOL_SOCKET, opt, &val, std::mem::size_of::<i32>() as u32)
        };
        if rc < 0 {
            Err(std::io::Error::last_os_error())
        } else {
            Ok(())
        }
    }
}

/// Raise this process's soft open-file limit (`RLIMIT_NOFILE`) toward
/// `want`, capped by the hard limit, and return the resulting soft
/// limit.  Ten thousand sockets need ten thousand fds; CI runners often
/// default the soft limit to 1024, so the high-connection tests and
/// `bench-net --idle-conns` raise it themselves instead of asking every
/// harness to remember `ulimit -n`.
pub fn raise_fd_limit(want: u64) -> std::io::Result<u64> {
    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: i32 = 7;
    #[cfg(not(target_os = "linux"))]
    const RLIMIT_NOFILE: i32 = 8;

    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }

    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }

    let mut lim = RLimit { cur: 0, max: 0 };
    let rc = unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) };
    if rc < 0 {
        return Err(std::io::Error::last_os_error());
    }
    let target = want.min(lim.max);
    if target > lim.cur {
        lim.cur = target;
        let rc = unsafe { setrlimit(RLIMIT_NOFILE, &lim) };
        if rc < 0 {
            return Err(std::io::Error::last_os_error());
        }
    }
    Ok(lim.cur)
}
