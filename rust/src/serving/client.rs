//! Blocking wire-protocol clients.
//!
//! [`Client`] speaks [`crate::serving::proto`] over one TCP connection:
//! one request, one reply, in order (the server answers each
//! connection's frames serially).  It is the reference consumer of the
//! protocol — the e2e tests, the network load generator
//! ([`crate::coordinator::loadgen::run_open_loop_net`]), and
//! `repro bench-net` all drive a server through it.
//!
//! [`PipelinedClient`] negotiates pipelined mode (`hello` /
//! `hello_ok`) and keeps a window of requests in flight on one socket;
//! responses arrive **out of order** and are handed back as they come,
//! each carrying the `id` of the request it answers.  Against a server
//! that only grants serial mode it degrades to a window of one.
//!
//! Errors split into [`ClientError::Server`] (the server answered with a
//! typed `error` frame — inspect its [`proto::ErrorCode`], e.g.
//! `RESOURCE_EXHAUSTED` is retryable) and transport-level failures
//! (connection closed, malformed frame), so callers can tell overload
//! from breakage.

use crate::serving::proto::{
    self, ErrorFrame, Frame, InferFrame, InferOkFrame, MetricsFrame, ModelsFrame, ReadOutcome,
};
use crate::tensor::Tensor;
use std::fmt;
use std::net::{TcpStream, ToSocketAddrs};

/// Why a client call failed.
#[derive(Debug)]
pub enum ClientError {
    /// Transport-level I/O failure (connect, read, or write).
    Io(std::io::Error),
    /// The server answered with a typed `error` frame.
    Server(ErrorFrame),
    /// The server closed the connection before answering.
    Closed,
    /// The server sent something indecipherable or out of protocol
    /// (wrong reply type, mismatched id, undecodable payload).
    Protocol(String),
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "transport error: {e}"),
            ClientError::Server(e) => write!(f, "server error {e}"),
            ClientError::Closed => f.write_str("server closed the connection"),
            ClientError::Protocol(msg) => write!(f, "protocol violation: {msg}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl ClientError {
    /// The server's error code, when this is a typed server rejection.
    pub fn server_code(&self) -> Option<proto::ErrorCode> {
        match self {
            ClientError::Server(e) => Some(e.code),
            _ => None,
        }
    }
}

/// A blocking connection to a serving front-end.
pub struct Client {
    stream: TcpStream,
    next_id: u64,
    max_frame_bytes: usize,
}

impl Client {
    /// Connect to a running [`crate::serving::net::Server`].
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream, next_id: 1, max_frame_bytes: proto::DEFAULT_MAX_FRAME_BYTES })
    }

    /// Raise or lower the reply-size cap (must match the server's to
    /// receive large metrics/model lists; the default matches
    /// [`proto::DEFAULT_MAX_FRAME_BYTES`]).
    pub fn with_max_frame_bytes(mut self, max: usize) -> Client {
        self.max_frame_bytes = max;
        self
    }

    fn roundtrip(&mut self, frame: &Frame) -> Result<Frame, ClientError> {
        proto::write_frame(&mut self.stream, frame)?;
        match proto::read_frame(&mut self.stream, self.max_frame_bytes)? {
            ReadOutcome::Eof => Err(ClientError::Closed),
            ReadOutcome::Bad(e) => Err(ClientError::Protocol(e.to_string())),
            ReadOutcome::Frame(Frame::Error(e)) => Err(ClientError::Server(e)),
            ReadOutcome::Frame(reply) => Ok(reply),
        }
    }

    /// Run one `[C, H, W]` image through `model` (`None` = the server's
    /// default model) and block for the reply.
    pub fn infer(
        &mut self,
        model: Option<&str>,
        image: &Tensor<f32>,
    ) -> Result<InferOkFrame, ClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Infer(InferFrame {
            id,
            model: model.map(str::to_string),
            dims: image.dims().to_vec(),
            data: image.data().to_vec(),
        });
        match self.roundtrip(&frame)? {
            Frame::InferOk(ok) if ok.id == id => Ok(ok),
            Frame::InferOk(ok) => Err(ClientError::Protocol(format!(
                "reply id {} does not match request id {id}",
                ok.id
            ))),
            other => Err(ClientError::Protocol(format!(
                "expected infer_ok, got '{}'",
                other.type_str()
            ))),
        }
    }

    /// The server's registry model names and default model.
    pub fn list_models(&mut self) -> Result<ModelsFrame, ClientError> {
        match self.roundtrip(&Frame::ListModels)? {
            Frame::Models(m) => Ok(m),
            other => {
                Err(ClientError::Protocol(format!("expected models, got '{}'", other.type_str())))
            }
        }
    }

    /// A serving metrics snapshot (coordinator + network layer).
    pub fn metrics(&mut self) -> Result<MetricsFrame, ClientError> {
        match self.roundtrip(&Frame::GetMetrics)? {
            Frame::Metrics(m) => Ok(m),
            other => {
                Err(ClientError::Protocol(format!("expected metrics, got '{}'", other.type_str())))
            }
        }
    }

    /// Liveness probe: send a nonce, require the matching `pong`.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        let nonce = self.next_id;
        self.next_id += 1;
        match self.roundtrip(&Frame::Ping { nonce })? {
            Frame::Pong { nonce: got } if got == nonce => Ok(()),
            Frame::Pong { nonce: got } => {
                Err(ClientError::Protocol(format!("pong nonce {got} != ping nonce {nonce}")))
            }
            other => {
                Err(ClientError::Protocol(format!("expected pong, got '{}'", other.type_str())))
            }
        }
    }
}

/// One answered request from a pipelined window: which request it was
/// and how it went.
#[derive(Debug)]
pub struct PipelinedReply {
    /// The request id this reply answers.
    pub id: u64,
    /// The typed outcome: the response frame, or the server's error
    /// frame for that request.
    pub result: Result<InferOkFrame, ErrorFrame>,
}

/// A pipelined connection to a serving front-end.
///
/// [`PipelinedClient::connect`] performs the `hello` negotiation and
/// records the granted window depth.  [`PipelinedClient::submit`] sends
/// an `infer` without waiting; [`PipelinedClient::recv`] blocks for the
/// next reply, whichever request it answers.  The caller matches
/// replies to requests by [`PipelinedReply::id`].
pub struct PipelinedClient {
    stream: TcpStream,
    next_id: u64,
    max_frame_bytes: usize,
    depth: u64,
    in_flight: usize,
}

impl PipelinedClient {
    /// Connect and negotiate pipelining.  A server that grants only
    /// serial mode (e.g. the threaded front-end) yields a working
    /// client with a window depth of 1.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<PipelinedClient, ClientError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let mut client = PipelinedClient {
            stream,
            next_id: 1,
            max_frame_bytes: proto::DEFAULT_MAX_FRAME_BYTES,
            depth: 1,
            in_flight: 0,
        };
        proto::write_frame(&mut client.stream, &Frame::Hello { pipeline: true })?;
        match proto::read_frame(&mut client.stream, client.max_frame_bytes)? {
            ReadOutcome::Eof => return Err(ClientError::Closed),
            ReadOutcome::Bad(e) => return Err(ClientError::Protocol(e.to_string())),
            ReadOutcome::Frame(Frame::HelloOk { pipeline, depth }) => {
                client.depth = if pipeline { depth.max(1) } else { 1 };
            }
            // a pre-negotiation server rejects the hello frame as
            // unknown; fall back to a serial window of one
            ReadOutcome::Frame(Frame::Error(_)) => client.depth = 1,
            ReadOutcome::Frame(other) => {
                return Err(ClientError::Protocol(format!(
                    "expected hello_ok, got '{}'",
                    other.type_str()
                )));
            }
        }
        Ok(client)
    }

    /// The window depth the server granted (1 = serial).
    pub fn depth(&self) -> u64 {
        self.depth
    }

    /// Requests submitted and not yet answered.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Send one `[C, H, W]` infer without waiting for the reply and
    /// return its request id.  Fails with [`ClientError::Protocol`] if
    /// the granted window is already full — call
    /// [`PipelinedClient::recv`] first to free a slot.
    pub fn submit(
        &mut self,
        model: Option<&str>,
        image: &Tensor<f32>,
    ) -> Result<u64, ClientError> {
        if self.in_flight as u64 >= self.depth {
            return Err(ClientError::Protocol(format!(
                "pipeline window full ({} in flight, depth {})",
                self.in_flight, self.depth
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Infer(InferFrame {
            id,
            model: model.map(str::to_string),
            dims: image.dims().to_vec(),
            data: image.data().to_vec(),
        });
        proto::write_frame(&mut self.stream, &frame)?;
        self.in_flight += 1;
        Ok(id)
    }

    /// Block for the next reply in the window, whichever request it
    /// answers.  Per-request server errors come back inside the
    /// [`PipelinedReply`] (the window slot is freed either way);
    /// transport-level failures are the outer `Err`.
    pub fn recv(&mut self) -> Result<PipelinedReply, ClientError> {
        if self.in_flight == 0 {
            return Err(ClientError::Protocol("recv with no requests in flight".into()));
        }
        match proto::read_frame(&mut self.stream, self.max_frame_bytes)? {
            ReadOutcome::Eof => Err(ClientError::Closed),
            ReadOutcome::Bad(e) => Err(ClientError::Protocol(e.to_string())),
            ReadOutcome::Frame(Frame::InferOk(ok)) => {
                self.in_flight -= 1;
                Ok(PipelinedReply { id: ok.id, result: Ok(ok) })
            }
            ReadOutcome::Frame(Frame::Error(e)) => match e.id {
                // a typed per-request error frees that request's slot
                Some(id) => {
                    self.in_flight -= 1;
                    Ok(PipelinedReply { id, result: Err(e) })
                }
                None => Err(ClientError::Server(e)),
            },
            ReadOutcome::Frame(other) => Err(ClientError::Protocol(format!(
                "expected infer_ok or error, got '{}'",
                other.type_str()
            ))),
        }
    }
}
