//! Blocking wire-protocol clients.
//!
//! [`Client`] speaks [`crate::serving::proto`] over one TCP connection:
//! one request, one reply, in order (the server answers each
//! connection's frames serially).  It is the reference consumer of the
//! protocol — the e2e tests, the network load generator
//! ([`crate::coordinator::loadgen::run_open_loop_net`]), and
//! `repro bench-net` all drive a server through it.
//!
//! [`PipelinedClient`] negotiates pipelined mode (`hello` /
//! `hello_ok`) and keeps a window of requests in flight on one socket;
//! responses arrive **out of order** and are handed back as they come,
//! each carrying the `id` of the request it answers.  Against a server
//! that only grants serial mode it degrades to a window of one.
//!
//! Errors split into [`ClientError::Server`] (the server answered with a
//! typed `error` frame — inspect its [`proto::ErrorCode`], e.g.
//! `RESOURCE_EXHAUSTED` is retryable) and transport-level failures
//! (connection closed, malformed frame), so callers can tell overload
//! from breakage.
//!
//! **Retries.**  Both clients accept a [`RetryPolicy`]: a bounded number
//! of attempts with exponential backoff and deterministic seeded jitter.
//! Only connection loss and the protocol's retryable rejections
//! (`RESOURCE_EXHAUSTED`, `UNAVAILABLE`) are retried — an execution
//! error or deadline miss is a terminal answer, and resubmitting it
//! would double-spend compute on a request the server already judged.
//! The jitter stream is seeded, so a load run's retry schedule replays
//! exactly under a fixed seed (`tests/retry_backoff.rs` pins this).

use crate::cnn::data::Rng;
use crate::serving::proto::{
    self, ErrorCode, ErrorFrame, Frame, InferFrame, InferOkFrame, MetricsFrame, ModelsFrame,
    ReadOutcome, TraceFrame,
};
use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::fmt;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Why a client call failed.
#[derive(Debug)]
pub enum ClientError {
    /// Transport-level I/O failure (connect, read, or write).
    Io(std::io::Error),
    /// The server answered with a typed `error` frame.
    Server(ErrorFrame),
    /// The server closed the connection before answering.
    Closed,
    /// The server sent something indecipherable or out of protocol
    /// (wrong reply type, mismatched id, undecodable payload).
    Protocol(String),
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "transport error: {e}"),
            ClientError::Server(e) => write!(f, "server error {e}"),
            ClientError::Closed => f.write_str("server closed the connection"),
            ClientError::Protocol(msg) => write!(f, "protocol violation: {msg}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl ClientError {
    /// The server's error code, when this is a typed server rejection.
    pub fn server_code(&self) -> Option<proto::ErrorCode> {
        match self {
            ClientError::Server(e) => Some(e.code),
            _ => None,
        }
    }

    /// Whether a [`RetryPolicy`] may resubmit after this failure:
    /// connection loss (the socket died, not the request) and the
    /// protocol's retryable rejections.  A read *timeout* is not
    /// retryable — the request may still be in flight, and the caller
    /// (e.g. the load generator) accounts it as a deadline miss.
    pub fn retryable(&self) -> bool {
        match self {
            ClientError::Closed => true,
            ClientError::Io(e) => !matches!(
                e.kind(),
                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
            ),
            ClientError::Server(e) => e.code.retryable(),
            ClientError::Protocol(_) => false,
        }
    }
}

/// Bounded exponential backoff with deterministic seeded jitter.
///
/// Retry `n` (zero-based) sleeps `min(base * 2^n, cap)` scaled by a
/// jitter factor in `[0.5, 1.0)` drawn from a seeded
/// [`crate::cnn::data::Rng`] — decorrelated enough to avoid thundering
/// herds, deterministic enough that a fixed seed replays the exact
/// schedule.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts, including the first (1 = never retry).
    pub max_attempts: u32,
    /// Backoff before the first retry.
    pub base: Duration,
    /// Upper bound the exponential doubling saturates at.
    pub cap: Duration,
    /// Seed of the jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy::none()
    }
}

impl RetryPolicy {
    /// No retries: one attempt, failures surface immediately.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(500),
            seed: 1,
        }
    }

    /// A sane default for chaos/load runs: up to `attempts` attempts,
    /// 10 ms base doubling to a 500 ms cap, jitter seeded by `seed`.
    pub fn standard(attempts: u32, seed: u64) -> RetryPolicy {
        RetryPolicy {
            max_attempts: attempts.max(1),
            base: Duration::from_millis(10),
            cap: Duration::from_millis(500),
            seed,
        }
    }

    /// The sleep before zero-based retry `attempt`, drawing jitter from
    /// `rng` (pass a fresh `Rng::new(policy.seed)` per request stream
    /// for reproducible schedules).
    pub fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let doubled = self.base.saturating_mul(1u32 << attempt.min(16));
        doubled.min(self.cap).mul_f64(0.5 + 0.5 * f64::from(rng.uniform()))
    }
}

/// A blocking connection to a serving front-end.
pub struct Client {
    stream: TcpStream,
    addr: SocketAddr,
    next_id: u64,
    max_frame_bytes: usize,
    read_timeout: Option<Duration>,
    retry: RetryPolicy,
    rng: Rng,
    retries: u64,
}

impl Client {
    /// Connect to a running [`crate::serving::net::Server`].
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let addr = stream.peer_addr()?;
        Ok(Client {
            stream,
            addr,
            next_id: 1,
            max_frame_bytes: proto::DEFAULT_MAX_FRAME_BYTES,
            read_timeout: None,
            retry: RetryPolicy::none(),
            rng: Rng::new(1),
            retries: 0,
        })
    }

    /// Raise or lower the reply-size cap (must match the server's to
    /// receive large metrics/model lists; the default matches
    /// [`proto::DEFAULT_MAX_FRAME_BYTES`]).
    pub fn with_max_frame_bytes(mut self, max: usize) -> Client {
        self.max_frame_bytes = max;
        self
    }

    /// Retry retryable infer failures under `policy` (reconnecting on
    /// connection loss).  The jitter stream restarts at `policy.seed`.
    pub fn with_retry(mut self, policy: RetryPolicy) -> Client {
        self.retry = policy;
        self.rng = Rng::new(policy.seed);
        self
    }

    /// Bound every blocking read; an expiry surfaces as
    /// [`ClientError::Io`] with `TimedOut`/`WouldBlock`, which retries
    /// never resubmit (the request may still be in flight server-side).
    pub fn with_read_timeout(mut self, timeout: Duration) -> std::io::Result<Client> {
        self.stream.set_read_timeout(Some(timeout))?;
        self.read_timeout = Some(timeout);
        Ok(self)
    }

    /// Retries performed over this client's lifetime.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    fn reconnect(&mut self) -> std::io::Result<()> {
        let stream = TcpStream::connect(self.addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(self.read_timeout)?;
        self.stream = stream;
        Ok(())
    }

    /// Tear down and rebuild the connection.  After a read timeout the
    /// stream may hold a late reply for an abandoned request; a reset
    /// guarantees the next call cannot mis-match it.
    pub fn reset(&mut self) -> std::io::Result<()> {
        self.reconnect()
    }

    fn roundtrip(&mut self, frame: &Frame) -> Result<Frame, ClientError> {
        proto::write_frame(&mut self.stream, frame)?;
        match proto::read_frame(&mut self.stream, self.max_frame_bytes)? {
            ReadOutcome::Eof => Err(ClientError::Closed),
            ReadOutcome::Bad(e) => Err(ClientError::Protocol(e.to_string())),
            ReadOutcome::Frame(Frame::Error(e)) => Err(ClientError::Server(e)),
            ReadOutcome::Frame(reply) => Ok(reply),
        }
    }

    fn infer_once(
        &mut self,
        model: Option<&str>,
        image: &Tensor<f32>,
        deadline_ms: Option<u64>,
    ) -> Result<InferOkFrame, ClientError> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Infer(InferFrame {
            id,
            model: model.map(str::to_string),
            deadline_ms,
            dims: image.dims().to_vec(),
            data: image.data().to_vec(),
        });
        match self.roundtrip(&frame)? {
            Frame::InferOk(ok) if ok.id == id => Ok(ok),
            Frame::InferOk(ok) => Err(ClientError::Protocol(format!(
                "reply id {} does not match request id {id}",
                ok.id
            ))),
            other => Err(ClientError::Protocol(format!(
                "expected infer_ok, got '{}'",
                other.type_str()
            ))),
        }
    }

    /// Run one `[C, H, W]` image through `model` (`None` = the server's
    /// default model) and block for the reply.
    pub fn infer(
        &mut self,
        model: Option<&str>,
        image: &Tensor<f32>,
    ) -> Result<InferOkFrame, ClientError> {
        self.infer_deadline(model, image, None)
    }

    /// [`Client::infer`] with an optional relative deadline: the server
    /// answers `DEADLINE_EXCEEDED` instead of computing a reply it can
    /// no longer deliver in time.
    ///
    /// Retryable failures ([`ClientError::retryable`]) are resubmitted
    /// under the client's [`RetryPolicy`] — as a fresh request id, after
    /// a reconnect when the connection itself died.
    pub fn infer_deadline(
        &mut self,
        model: Option<&str>,
        image: &Tensor<f32>,
        deadline_ms: Option<u64>,
    ) -> Result<InferOkFrame, ClientError> {
        let mut attempt = 0u32;
        loop {
            let err = match self.infer_once(model, image, deadline_ms) {
                Ok(ok) => return Ok(ok),
                Err(e) => e,
            };
            if attempt + 1 >= self.retry.max_attempts || !err.retryable() {
                return Err(err);
            }
            self.retries += 1;
            std::thread::sleep(self.retry.backoff(attempt, &mut self.rng));
            if matches!(err, ClientError::Io(_) | ClientError::Closed) {
                // a failed reconnect leaves the dead stream in place; the
                // next attempt fails fast and consumes the next backoff
                let _ = self.reconnect();
            }
            attempt += 1;
        }
    }

    /// The server's registry model names and default model.
    pub fn list_models(&mut self) -> Result<ModelsFrame, ClientError> {
        match self.roundtrip(&Frame::ListModels)? {
            Frame::Models(m) => Ok(m),
            other => {
                Err(ClientError::Protocol(format!("expected models, got '{}'", other.type_str())))
            }
        }
    }

    /// A serving metrics snapshot (coordinator + network layer).
    pub fn metrics(&mut self) -> Result<MetricsFrame, ClientError> {
        match self.roundtrip(&Frame::GetMetrics)? {
            Frame::Metrics(m) => Ok(m),
            other => {
                Err(ClientError::Protocol(format!("expected metrics, got '{}'", other.type_str())))
            }
        }
    }

    /// A request-lifecycle trace snapshot (empty when the server runs
    /// with tracing disabled).  `id` filters to one coordinator request
    /// id; `limit` keeps only the most recent events (the server clamps
    /// it to its own cap either way).
    pub fn trace(
        &mut self,
        id: Option<u64>,
        limit: Option<u64>,
    ) -> Result<TraceFrame, ClientError> {
        match self.roundtrip(&Frame::GetTrace { id, limit })? {
            Frame::Trace(t) => Ok(t),
            other => {
                Err(ClientError::Protocol(format!("expected trace, got '{}'", other.type_str())))
            }
        }
    }

    /// Liveness probe: send a nonce, require the matching `pong`.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        let nonce = self.next_id;
        self.next_id += 1;
        match self.roundtrip(&Frame::Ping { nonce })? {
            Frame::Pong { nonce: got } if got == nonce => Ok(()),
            Frame::Pong { nonce: got } => {
                Err(ClientError::Protocol(format!("pong nonce {got} != ping nonce {nonce}")))
            }
            other => {
                Err(ClientError::Protocol(format!("expected pong, got '{}'", other.type_str())))
            }
        }
    }
}

/// One answered request from a pipelined window: which request it was
/// and how it went.
#[derive(Debug)]
pub struct PipelinedReply {
    /// The request id this reply answers.
    pub id: u64,
    /// The typed outcome: the response frame, or the server's error
    /// frame for that request.
    pub result: Result<InferOkFrame, ErrorFrame>,
}

/// A pipelined connection to a serving front-end.
///
/// [`PipelinedClient::connect`] performs the `hello` negotiation and
/// records the granted window depth.  [`PipelinedClient::submit`] sends
/// an `infer` without waiting; [`PipelinedClient::recv`] blocks for the
/// next reply, whichever request it answers.  The caller matches
/// replies to requests by [`PipelinedReply::id`].
///
/// With a [`RetryPolicy`] attached, a dropped connection is rebuilt
/// (backoff + re-negotiation) instead of surfacing as a transport
/// error; the requests that were in flight on the dead socket cannot be
/// safely resubmitted (the server may have executed them), so each is
/// handed back as a **typed terminal reply** — an `UNAVAILABLE` error
/// frame — and the caller decides whether to resubmit.
pub struct PipelinedClient {
    stream: TcpStream,
    addr: SocketAddr,
    next_id: u64,
    max_frame_bytes: usize,
    depth: u64,
    /// Ids in flight on the current connection, oldest first.
    pending: VecDeque<u64>,
    /// Ids lost to a connection drop, surfaced one per `recv` call as
    /// synthetic `UNAVAILABLE` replies.
    lost: VecDeque<u64>,
    retry: RetryPolicy,
    rng: Rng,
    retries: u64,
}

impl PipelinedClient {
    /// Connect and negotiate pipelining.  A server that grants only
    /// serial mode (e.g. the threaded front-end) yields a working
    /// client with a window depth of 1.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<PipelinedClient, ClientError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let addr = stream.peer_addr().map_err(ClientError::Io)?;
        let mut client = PipelinedClient {
            stream,
            addr,
            next_id: 1,
            max_frame_bytes: proto::DEFAULT_MAX_FRAME_BYTES,
            depth: 1,
            pending: VecDeque::new(),
            lost: VecDeque::new(),
            retry: RetryPolicy::none(),
            rng: Rng::new(1),
            retries: 0,
        };
        client.negotiate()?;
        Ok(client)
    }

    /// Rebuild dropped connections under `policy` instead of failing
    /// `recv`/`submit` with a transport error.
    pub fn with_retry(mut self, policy: RetryPolicy) -> PipelinedClient {
        self.retry = policy;
        self.rng = Rng::new(policy.seed);
        self
    }

    /// Send `hello` on the current stream and record the granted depth.
    fn negotiate(&mut self) -> Result<(), ClientError> {
        proto::write_frame(&mut self.stream, &Frame::Hello { pipeline: true })?;
        match proto::read_frame(&mut self.stream, self.max_frame_bytes)? {
            ReadOutcome::Eof => return Err(ClientError::Closed),
            ReadOutcome::Bad(e) => return Err(ClientError::Protocol(e.to_string())),
            ReadOutcome::Frame(Frame::HelloOk { pipeline, depth }) => {
                self.depth = if pipeline { depth.max(1) } else { 1 };
            }
            // a pre-negotiation server rejects the hello frame as
            // unknown; fall back to a serial window of one
            ReadOutcome::Frame(Frame::Error(_)) => self.depth = 1,
            ReadOutcome::Frame(other) => {
                return Err(ClientError::Protocol(format!(
                    "expected hello_ok, got '{}'",
                    other.type_str()
                )));
            }
        }
        Ok(())
    }

    /// Declare the current connection dead: every pending id becomes a
    /// synthetic terminal reply, then reconnect + renegotiate under the
    /// retry policy (bounded attempts, jittered backoff).
    fn reconnect(&mut self, err: ClientError) -> Result<(), ClientError> {
        self.lost.extend(self.pending.drain(..));
        let mut last = err;
        for attempt in 0..self.retry.max_attempts.saturating_sub(1) {
            if !last.retryable() {
                return Err(last);
            }
            self.retries += 1;
            std::thread::sleep(self.retry.backoff(attempt, &mut self.rng));
            match TcpStream::connect(self.addr) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    self.stream = stream;
                    match self.negotiate() {
                        Ok(()) => return Ok(()),
                        Err(e) => last = e,
                    }
                }
                Err(e) => last = ClientError::Io(e),
            }
        }
        Err(last)
    }

    /// The window depth the server granted (1 = serial).
    pub fn depth(&self) -> u64 {
        self.depth
    }

    /// Requests submitted and not yet answered (including lost ones not
    /// yet surfaced by [`PipelinedClient::recv`]).
    pub fn in_flight(&self) -> usize {
        self.pending.len() + self.lost.len()
    }

    /// Reconnections performed over this client's lifetime.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Send one `[C, H, W]` infer without waiting for the reply and
    /// return its request id.  Fails with [`ClientError::Protocol`] if
    /// the granted window is already full — call
    /// [`PipelinedClient::recv`] first to free a slot.
    pub fn submit(
        &mut self,
        model: Option<&str>,
        image: &Tensor<f32>,
    ) -> Result<u64, ClientError> {
        self.submit_deadline(model, image, None)
    }

    /// [`PipelinedClient::submit`] with an optional relative deadline
    /// (milliseconds), carried to the server as the frame's
    /// `deadline_ms` field.
    pub fn submit_deadline(
        &mut self,
        model: Option<&str>,
        image: &Tensor<f32>,
        deadline_ms: Option<u64>,
    ) -> Result<u64, ClientError> {
        if self.in_flight() as u64 >= self.depth {
            return Err(ClientError::Protocol(format!(
                "pipeline window full ({} in flight, depth {})",
                self.in_flight(),
                self.depth
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Infer(InferFrame {
            id,
            model: model.map(str::to_string),
            deadline_ms,
            dims: image.dims().to_vec(),
            data: image.data().to_vec(),
        });
        if let Err(e) = proto::write_frame(&mut self.stream, &frame) {
            // the write may have been half-sent: treat the connection as
            // dead and this id as lost, then rebuild under the policy
            self.pending.push_back(id);
            self.reconnect(ClientError::Io(e))?;
            return Ok(id);
        }
        self.pending.push_back(id);
        Ok(id)
    }

    /// Block for the next reply in the window, whichever request it
    /// answers.  Per-request server errors come back inside the
    /// [`PipelinedReply`] (the window slot is freed either way);
    /// transport-level failures are the outer `Err` — unless a
    /// [`RetryPolicy`] is attached, in which case the connection is
    /// rebuilt and the interrupted requests surface as synthetic
    /// `UNAVAILABLE` replies.
    pub fn recv(&mut self) -> Result<PipelinedReply, ClientError> {
        loop {
            if let Some(id) = self.lost.pop_front() {
                let e = ErrorFrame::new(
                    Some(id),
                    ErrorCode::Unavailable,
                    "connection lost before the reply arrived",
                );
                return Ok(PipelinedReply { id, result: Err(e) });
            }
            if self.pending.is_empty() {
                return Err(ClientError::Protocol("recv with no requests in flight".into()));
            }
            let err = match self.recv_once() {
                Ok(reply) => return Ok(reply),
                Err(e) => e,
            };
            if self.retry.max_attempts <= 1
                || !matches!(err, ClientError::Io(_) | ClientError::Closed)
                || !err.retryable()
            {
                return Err(err);
            }
            // the loop surfaces the newly lost ids on its next pass
            self.reconnect(err)?;
        }
    }

    fn recv_once(&mut self) -> Result<PipelinedReply, ClientError> {
        match proto::read_frame(&mut self.stream, self.max_frame_bytes)? {
            ReadOutcome::Eof => Err(ClientError::Closed),
            ReadOutcome::Bad(e) => Err(ClientError::Protocol(e.to_string())),
            ReadOutcome::Frame(Frame::InferOk(ok)) => {
                self.pending.retain(|&p| p != ok.id);
                Ok(PipelinedReply { id: ok.id, result: Ok(ok) })
            }
            ReadOutcome::Frame(Frame::Error(e)) => match e.id {
                // a typed per-request error frees that request's slot
                Some(id) => {
                    self.pending.retain(|&p| p != id);
                    Ok(PipelinedReply { id, result: Err(e) })
                }
                None => Err(ClientError::Server(e)),
            },
            ReadOutcome::Frame(other) => Err(ClientError::Protocol(format!(
                "expected infer_ok or error, got '{}'",
                other.type_str()
            ))),
        }
    }
}
